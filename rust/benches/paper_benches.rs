//! Paper-figure/table bench harness (criterion substitute; harness=false).
//!
//! One sub-bench per table AND figure of the xLLM paper's evaluation
//! (§5): run `cargo bench` for everything, or `cargo bench -- fig14` for
//! one.  Each bench regenerates the paper's rows/series on this testbed:
//! calibrated simulator + real CPU-PJRT microbenches.  We claim *shape*
//! fidelity (who wins, rough factors, crossovers) — see DESIGN.md §5.
//!
//! Output: human tables on stdout; EXPERIMENTS.md records paper-vs-ours.

use std::time::Instant;

use xllm::coordinator::orchestrator::{ColocationMode, ServingMode};
use xllm::coordinator::DispatchPolicy;
use xllm::engine::dpbalance;
use xllm::engine::genrec::BeamSearcher;
use xllm::engine::pipeline::{simulate_dual_stream, simulate_single_stream};
use xllm::engine::specdecode::{expected_tokens_per_round, verify_cost_multiplier, SpecConfig};
use xllm::engine::EnginePolicies;
use xllm::metrics::Slo;
use xllm::model::{ascend_910b, ascend_910c, catalog, HardwareSpec, ModelSpec};
use xllm::service::colocation::ColocationConfig;
use xllm::service::epd::EpdStrategy;
use xllm::sim::cluster::{run as sim_run, ClusterConfig};
use xllm::sim::{CostModel, EngineFeatures, GraphMode};
use xllm::util::json::Json;
use xllm::util::Rng;
use xllm::workload::scenario;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let all = args.is_empty();
    let want = |name: &str| all || args.iter().any(|a| a == name);

    println!("# xLLM paper benches ({} mode)", if all { "full" } else { "selected" });
    let t0 = Instant::now();
    if want("calibrate") {
        bench_calibrate();
    }
    if want("fig14") {
        bench_fig14();
    }
    if want("fig15") {
        bench_fig15();
    }
    if want("table3") {
        bench_table3();
    }
    if want("fig16") {
        bench_fig16();
    }
    if want("table4") {
        bench_table4();
    }
    if want("fig17") {
        bench_fig17();
    }
    if want("fig18") {
        bench_fig18();
    }
    if want("table5") {
        bench_table5();
    }
    if want("fig19") {
        bench_fig19();
    }
    if want("fig20") {
        bench_fig20();
    }
    if want("fig21") {
        bench_fig21();
    }
    if want("fig22") {
        bench_fig22();
    }
    if want("fig23") {
        bench_fig23();
    }
    if want("table6") {
        bench_table6();
    }
    if want("table7") {
        bench_table7();
    }
    if want("table8") {
        bench_table8();
    }
    if want("dpbal") {
        bench_dpbal();
    }
    if want("perf") {
        bench_perf();
    }
    if want("perfjson") {
        bench_perfjson();
        bench_indexops();
        bench_streamscale();
    }
    println!("\n# total bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
}

// ---------------------------------------------------------------------
// shared machinery
// ---------------------------------------------------------------------

fn features_for(framework: &str, tp: u32) -> EngineFeatures {
    match framework {
        "xllm" => EngineFeatures::xllm(tp),
        "mindie" => EngineFeatures::mindie(tp),
        "vllm" => EngineFeatures::vllm(tp),
        _ => unreachable!(),
    }
}

struct SloSearch {
    scenario: &'static str,
    model: ModelSpec,
    hw: HardwareSpec,
    features: EngineFeatures,
    instances: usize,
    slo: Slo,
    horizon: f64,
    attainment_target: f64,
    prefix_cache: bool,
    pd: Option<(usize, bool)>,
}

/// The paper's methodology: fixed lengths, request rate adjusted to the
/// highest value at which the SLO holds; report throughput at that rate.
///
/// The search window comes from the roofline capacity estimate (saturated
/// decode tokens/s divided by mean request tokens), so the simulator
/// never runs at pathological overload.
fn max_tput_under_slo(s: &SloSearch) -> (f64, f64, f64) {
    let eval = |rate: f64| -> (f64, f64) {
        let mut cfg =
            ClusterConfig::new(s.instances, s.hw.clone(), s.model.clone(), s.features.clone());
        cfg.slo = s.slo;
        cfg.prefix_cache = s.prefix_cache;
        if let Some((np, dynamic)) = s.pd {
            cfg.mode = ServingMode::Disaggregated { n_prefill: np, dynamic };
        }
        let mut rng = Rng::new(1234);
        let w = scenario(s.scenario).unwrap().generate(s.horizon, rate, &mut rng);
        if w.is_empty() {
            return (0.0, 1.0);
        }
        let res = sim_run(cfg, w);
        (res.report.output_throughput(), res.report.slo_attainment(&s.slo))
    };
    // capacity estimate: saturated decode throughput / mean request size
    let cost = CostModel::new(s.hw.clone(), s.model.clone(), s.features.clone());
    let mut rng = Rng::new(99);
    let (mean_in, mean_out) = scenario(s.scenario).unwrap().mean_tokens(&mut rng);
    let b = 64u64;
    let sat_tok_s = b as f64 / cost.decode_step_s(b, b * (mean_in + mean_out / 2.0) as u64);
    let prefill_tok_s = mean_in / cost.prefill_s(mean_in as u64, 0);
    // per-request service mixes decode (dominant) + prefill
    let per_req_s = mean_out / sat_tok_s + mean_in / prefill_tok_s;
    let capacity_rate = s.instances as f64 / per_req_s.max(1e-9);

    let mut lo = 0.0;
    let mut hi = (capacity_rate * 2.0).max(0.1);
    let mut best = (0.0, 0.0, 1.0);
    // 6-step bisection within the bounded window
    for _ in 0..6 {
        let mid = 0.5 * (lo + hi);
        let (tput, att) = eval(mid);
        if att >= s.attainment_target {
            best = (mid, tput, att);
            lo = mid;
        } else {
            hi = mid;
        }
    }
    best
}

fn header(title: &str) {
    println!("\n== {title} ==");
}

// ---------------------------------------------------------------------
// calibrate: real CPU-PJRT step costs for the tiny model
// ---------------------------------------------------------------------

fn bench_calibrate() {
    header("calibrate — real PJRT step costs (tiny model), online factor learning");
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    }
    let mut rt = xllm::runtime::Runtime::load(artifacts).expect("runtime");
    let dims = rt.model_dims("tiny").unwrap();

    println!("{:<16} {:>12} {:>14}", "graph", "mean ms", "tok/s equiv");
    for s in [16usize, 32, 64, 128] {
        let prompt: Vec<i32> = (0..s as i32).map(|i| (i % 250) + 1).collect();
        rt.prefill("tiny", &prompt).unwrap(); // warm compile
        let t0 = Instant::now();
        let iters = 8;
        for _ in 0..iters {
            rt.prefill("tiny", &prompt).unwrap();
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        println!("{:<16} {:>12.2} {:>14.0}", format!("prefill_s{s}"), ms, s as f64 / ms * 1e3);
    }
    for b in [1usize, 2, 4, 8] {
        let mut kv = xllm::runtime::BatchKv::zeros(dims, b);
        let tokens = vec![1i32; b];
        rt.decode("tiny", &mut kv, &tokens, &vec![4i32; b]).unwrap();
        let t0 = Instant::now();
        let iters = 16;
        for i in 0..iters {
            let pos = vec![(5 + i) as i32; b];
            rt.decode("tiny", &mut kv, &tokens, &pos).unwrap();
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        println!("{:<16} {:>12.2} {:>14.0}", format!("decode_b{b}"), ms, b as f64 / ms * 1e3);
    }
    // online factor learning demonstration on the cpu-host cost model
    let mut cm = CostModel::new(
        xllm::model::cpu_host(),
        xllm::model::tiny(),
        EngineFeatures::xllm(1),
    );
    let before = cm.decode_step_s(8, 8 * 64);
    for _ in 0..60 {
        cm.learn_decode(8, 8 * 64, before * 1.5);
    }
    println!(
        "factor learning: decode_step(8) prediction {:.3}ms -> {:.3}ms after observing 1.5x",
        before * 1e3,
        cm.decode_step_s(8, 8 * 64) * 1e3
    );
}

// ---------------------------------------------------------------------
// fig14: Qwen3-series throughput, ShareGPT, TPOT=50ms, io=2048
// ---------------------------------------------------------------------

fn bench_fig14() {
    header("fig14 — Qwen3-series max throughput @ TPOT=50ms, io=2048 (ShareGPT)");
    println!(
        "{:<12} {:>3} {:>5} | {:>10} {:>10} {:>10} | {:>10} {:>10}",
        "model", "tp", "hw", "xllm", "mindie", "vllm", "x/mindie", "x/vllm"
    );
    let models = [
        ("Qwen3-0.6B", 1u32),
        ("Qwen3-1.7B", 1),
        ("Qwen3-4B", 1),
        ("Qwen3-8B", 2),
        ("Qwen3-14B", 2),
        ("Qwen3-32B", 4),
    ];
    for hw_name in ["910B", "910C"] {
        let hw = if hw_name == "910B" { ascend_910b() } else { ascend_910c() };
        for (m, tp) in models {
            let mut tputs = Vec::new();
            for fw in ["xllm", "mindie", "vllm"] {
                let s = SloSearch {
                    scenario: "sharegpt-2048",
                    model: catalog(m).unwrap(),
                    hw: hw.clone(),
                    features: features_for(fw, tp),
                    instances: 2,
                    slo: Slo::tpot(0.050),
                    horizon: 25.0,
                    attainment_target: 0.90,
                    prefix_cache: false,
                    pd: None,
                };
                let (_, tput, _) = max_tput_under_slo(&s);
                tputs.push(tput);
            }
            println!(
                "{:<12} {:>3} {:>5} | {:>10.0} {:>10.0} {:>10.0} | {:>9.2}x {:>9.2}x",
                m,
                tp,
                hw_name,
                tputs[0],
                tputs[1],
                tputs[2],
                tputs[0] / tputs[1].max(1e-9),
                tputs[0] / tputs[2].max(1e-9)
            );
        }
    }
    println!("(paper: xLLM up to 1.7x MindIE, 1.9-2.2x vLLM-Ascend)");
}

// ---------------------------------------------------------------------
// fig15: DeepSeek-R1 throughput under TPOT + io-length variants
// ---------------------------------------------------------------------

fn bench_fig15() {
    header("fig15 — DeepSeek-R1 max throughput (MoE, EP/DP), io variants");
    println!(
        "{:<26} {:>5} | {:>10} {:>10} {:>10} | {:>9} {:>9}",
        "setting", "hw", "xllm", "mindie", "vllm", "x/mindie", "x/vllm"
    );
    for (scen, tpot, hw_name, tp) in [
        ("sharegpt-2500-1500", 0.05, "910B", 16u32),
        ("sharegpt-1500-2500", 0.05, "910B", 16),
        ("sharegpt-2048", 0.10, "910B", 16),
        ("sharegpt-2500-1500", 0.05, "910C", 8),
        ("sharegpt-1500-2500", 0.05, "910C", 8),
    ] {
        let hw = if hw_name == "910B" { ascend_910b() } else { ascend_910c() };
        let mut tputs = Vec::new();
        for fw in ["xllm", "mindie", "vllm"] {
            let mut features = features_for(fw, tp);
            features.dp_groups = 4;
            let s = SloSearch {
                scenario: scen,
                model: catalog("DeepSeek-R1").unwrap(),
                hw: hw.clone(),
                features,
                instances: 1,
                slo: Slo::tpot(tpot),
                horizon: 25.0,
                attainment_target: 0.90,
                prefix_cache: false,
                pd: None,
            };
            let (_, tput, _) = max_tput_under_slo(&s);
            tputs.push(tput);
        }
        println!(
            "{:<26} {:>5} | {:>10.0} {:>10.0} {:>10.0} | {:>8.2}x {:>8.2}x",
            format!("{scen} tpot={}ms", (tpot * 1e3) as u32),
            hw_name,
            tputs[0],
            tputs[1],
            tputs[2],
            tputs[0] / tputs[1].max(1e-9),
            tputs[0] / tputs[2].max(1e-9)
        );
    }
    println!("(paper: ~1.7x MindIE avg, ~12x vLLM-Ascend; 910C ~1.4x MindIE)");
}

// ---------------------------------------------------------------------
// table3: DS-R1 PD disaggregation, TPOT=100ms, 2048/2048
// ---------------------------------------------------------------------

fn bench_table3() {
    header("table3 — DeepSeek-R1 with PD disaggregation @ TPOT=100ms, 2048/2048");
    println!(
        "{:<8} {:>14} {:>14} {:>12}",
        "method", "tput (tok/s)", "req rate /s", "SLO att."
    );
    for fw in ["mindie", "xllm"] {
        let mut features = features_for(fw, 16);
        features.dp_groups = 4;
        let s = SloSearch {
            scenario: "sharegpt-2048",
            model: catalog("DeepSeek-R1").unwrap(),
            hw: ascend_910b(),
            features,
            instances: 3,
            slo: Slo::tpot(0.100),
            horizon: 30.0,
            attainment_target: 0.90,
            prefix_cache: false,
            pd: Some((1, fw == "xllm")),
        };
        let (rate, tput, att) = max_tput_under_slo(&s);
        println!("{:<8} {:>14.2} {:>14.2} {:>11.1}%", fw, tput, rate, att * 100.0);
    }
    println!("(paper: xLLM 11351.58 vs MindIE 8476.44 tok/s, ~1.34x)");
}

// ---------------------------------------------------------------------
// fig16 / table4: JingYan business scenario
// ---------------------------------------------------------------------

fn bench_fig16() {
    header("fig16 — JingYan scenario throughput (Qwen2/Qwen3 series)");
    println!(
        "{:<12} {:>3} | {:>10} {:>10} {:>10} | {:>9} {:>9}",
        "model", "tp", "xllm", "mindie", "vllm", "x/mindie", "x/vllm"
    );
    for (m, tp) in [("Qwen2-7B", 1u32), ("Qwen3-8B", 2), ("Qwen3-32B", 4)] {
        let mut tputs = Vec::new();
        for fw in ["xllm", "mindie", "vllm"] {
            let s = SloSearch {
                scenario: "jingyan",
                model: catalog(m).unwrap(),
                hw: ascend_910b(),
                features: features_for(fw, tp),
                instances: 2,
                slo: Slo::tpot(0.05),
                horizon: 25.0,
                attainment_target: 0.90,
                prefix_cache: fw == "xllm",
                pd: None,
            };
            let (_, tput, _) = max_tput_under_slo(&s);
            tputs.push(tput);
        }
        println!(
            "{:<12} {:>3} | {:>10.0} {:>10.0} {:>10.0} | {:>8.2}x {:>8.2}x",
            m,
            tp,
            tputs[0],
            tputs[1],
            tputs[2],
            tputs[0] / tputs[1].max(1e-9),
            tputs[0] / tputs[2].max(1e-9)
        );
    }
    println!("(paper: e.g. Qwen3-8B@4acc xLLM ~1.6x vLLM-Ascend)");
}

fn bench_table4() {
    header("table4 — DeepSeek-V3, JingYan 6800/400 @ TPOT=80ms");
    println!("{:<8} {:>14} {:>12}", "method", "tput (tok/s)", "req rate /s");
    for fw in ["vllm", "mindie", "xllm"] {
        let mut features = features_for(fw, 16);
        features.dp_groups = 4;
        let s = SloSearch {
            scenario: "jingyan-6800-400",
            model: catalog("DeepSeek-V3").unwrap(),
            hw: ascend_910b(),
            features,
            instances: 1,
            slo: Slo::tpot(0.080),
            horizon: 30.0,
            attainment_target: 0.90,
            prefix_cache: false,
            pd: None,
        };
        let (rate, tput, _) = max_tput_under_slo(&s);
        println!("{:<8} {:>14.2} {:>12.2}", fw, tput, rate);
    }
    println!("(paper: vLLM 21.17, MindIE 144.40, xLLM 196.45 tok/s)");
}

// ---------------------------------------------------------------------
// fig17: customer service, E2E=10s, scaling with accelerators
// ---------------------------------------------------------------------

fn bench_fig17() {
    header("fig17 — customer service @ E2E=10s (accelerator scaling)");
    println!(
        "{:<12} {:>4} | {:>10} {:>10} {:>10} | {:>9} {:>9}",
        "model", "tp", "xllm", "mindie", "vllm", "x/mindie", "x/vllm"
    );
    for (m, tps) in [("Qwen3-8B", vec![1u32, 2, 4]), ("Qwen3-32B", vec![4u32, 8])] {
        for tp in tps {
            let mut tputs = Vec::new();
            for fw in ["xllm", "mindie", "vllm"] {
                let s = SloSearch {
                    scenario: "customer-service",
                    model: catalog(m).unwrap(),
                    hw: ascend_910b(),
                    features: features_for(fw, tp),
                    instances: 1,
                    slo: Slo::e2e(10.0),
                    horizon: 25.0,
                    attainment_target: 0.90,
                    prefix_cache: fw == "xllm",
                    pd: None,
                };
                let (_, tput, _) = max_tput_under_slo(&s);
                tputs.push(tput);
            }
            println!(
                "{:<12} {:>4} | {:>10.0} {:>10.0} {:>10.0} | {:>8.2}x {:>8.2}x",
                m,
                tp,
                tputs[0],
                tputs[1],
                tputs[2],
                tputs[0] / tputs[1].max(1e-9),
                tputs[0] / tputs[2].max(1e-9)
            );
        }
    }
    println!("(paper: Qwen3-32B@8acc xLLM 3.1x vLLM, 1.2x MindIE; vLLM scaling flattens)");
}

// ---------------------------------------------------------------------
// fig18 / table5: merchant assistant + product understanding
// ---------------------------------------------------------------------

fn bench_fig18() {
    header("fig18 — merchant assistant tasks @ E2E=1s");
    println!(
        "{:<24} | {:>10} {:>10} {:>10} | {:>9} {:>9}",
        "task", "xllm", "mindie", "vllm", "x/mindie", "x/vllm"
    );
    for task in ["merchant-search-terms", "merchant-arrangement", "merchant-intent"] {
        let mut tputs = Vec::new();
        for fw in ["xllm", "mindie", "vllm"] {
            let s = SloSearch {
                scenario: task,
                model: catalog("Qwen2-7B").unwrap(),
                hw: ascend_910b(),
                features: features_for(fw, 2),
                instances: 2,
                slo: Slo::e2e(1.0),
                horizon: 25.0,
                attainment_target: 0.90,
                prefix_cache: fw == "xllm",
                pd: None,
            };
            let (_, tput, _) = max_tput_under_slo(&s);
            tputs.push(tput);
        }
        let ratio = |x: f64, y: f64| {
            if y < 1.0 {
                "inf".to_string()
            } else {
                format!("{:.2}x", x / y)
            }
        };
        println!(
            "{:<24} | {:>10.0} {:>10.0} {:>10.0} | {:>9} {:>9}",
            task,
            tputs[0],
            tputs[1],
            tputs[2],
            ratio(tputs[0], tputs[1]),
            ratio(tputs[0], tputs[2])
        );
    }
    println!("(paper: search-terms@4acc xLLM 1.34x MindIE, ~3.4x vLLM)");
}

fn bench_table5() {
    header("table5 — product understanding, Qwen2-7B 1200/40 (accelerator sweep)");
    println!("{:<8} {:>12} {:>12} {:>12}", "method", "#acc=1", "#acc=2", "#acc=4");
    for fw in ["vllm", "mindie", "xllm"] {
        let mut row = Vec::new();
        for tp in [1u32, 2, 4] {
            let s = SloSearch {
                scenario: "product-understanding",
                model: catalog("Qwen2-7B").unwrap(),
                hw: ascend_910b(),
                features: features_for(fw, tp),
                instances: 1,
                slo: Slo::e2e(5.0),
                horizon: 25.0,
                attainment_target: 0.90,
                prefix_cache: fw == "xllm",
                pd: None,
            };
            let (_, tput, _) = max_tput_under_slo(&s);
            row.push(tput);
        }
        println!("{:<8} {:>12.0} {:>12.0} {:>12.0}", fw, row[0], row[1], row[2]);
    }
    println!("(paper: xLLM beats MindIE by ~25% and vLLM by ~56% on average)");
}

// ---------------------------------------------------------------------
// fig19: generative recommendation E2E vs beam width & rate
// ---------------------------------------------------------------------

fn bench_fig19() {
    header("fig19 — genrec mean E2E vs beam width x request rate");
    // The host bottleneck at large beam_width x top_k (paper §4.5.1) is
    // candidate generation + partial sorting.  We measure the REAL host
    // cost both ways on this machine: naive = full sort over the vocab
    // per beam, every step, fresh allocations; xllm = heap-based partial
    // top-k + min-heap beam selection + buffer reuse, overlapped with the
    // device (§4.5 host-kernel overlap).  Device step from the roofline
    // model for Qwen3-8B.
    let cost = CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1));
    let vocab = 152_064usize; // Qwen vocab
    let steps = 64u64;
    println!(
        "{:<6} {:>6} | {:>12} {:>12} | {:>8}",
        "beam", "rate", "xllm E2E s", "naive E2E s", "saving"
    );
    let mut rng = Rng::new(3);
    let logits: Vec<f64> = (0..vocab).map(|_| rng.f64() * -20.0).collect();
    for beam in [4usize, 16, 64, 128] {
        let top_k = beam; // paper: large beam_width and top_k together
        // naive host path: full sort of the vocab per beam, no reuse
        let reps = 3.max(200 / beam);
        // naive host: per-beam partial top-k (fair baseline) but flat-sort
        // beam selection, fresh allocations, and NO host-device overlap
        let mut naive_sel = BeamSearcher::new(beam);
        let t0 = Instant::now();
        for _ in 0..reps {
            let mut expansions: Vec<Vec<(u32, f64)>> = Vec::new();
            for _ in 0..beam {
                expansions.push(xllm::engine::genrec::topk_desc_partial(&logits, top_k));
            }
            std::hint::black_box(naive_sel.step_naive(&expansions));
        }
        let naive_host_s = t0.elapsed().as_secs_f64() / reps as f64;

        // xllm host path: heap partial top-k per beam + min-heap selection
        let t1 = Instant::now();
        let mut searcher = BeamSearcher::new(beam);
        for _ in 0..reps {
            let mut expansions: Vec<Vec<(u32, f64)>> = Vec::with_capacity(beam);
            for _ in 0..beam {
                expansions.push(xllm::engine::genrec::topk_desc_partial(&logits, top_k));
            }
            std::hint::black_box(searcher.step_optimized(&expansions));
        }
        let opt_host_s = t1.elapsed().as_secs_f64() / reps as f64;

        for rate in [1.0f64, 4.0, 8.0] {
            let concurrent = (rate * 2.0).max(1.0);
            let bsz = (beam as f64 * concurrent) as u64;
            let device = cost.decode_step_s(bsz.max(1), bsz.max(1) * 256);
            // xllm overlaps the host work with the device (§4.5); naive
            // runs serially after the logits land
            let xllm_step = device.max(opt_host_s) + 0.2 * opt_host_s;
            let naive_step = device + naive_host_s;
            let xllm_e2e = xllm_step * steps as f64 * concurrent.sqrt();
            let naive_e2e = naive_step * steps as f64 * concurrent.sqrt();
            println!(
                "{:<6} {:>6.0} | {:>12.3} {:>12.3} | {:>7.1}%",
                beam,
                rate,
                xllm_e2e,
                naive_e2e,
                (1.0 - xllm_e2e / naive_e2e) * 100.0
            );
        }
    }
    println!("(paper: ~23% lower E2E at beam=128, rate=8; gap grows with beam width)");
}

// ---------------------------------------------------------------------
// fig20: MTP (speculative decoding) ablation
// ---------------------------------------------------------------------

fn bench_fig20() {
    header("fig20 — MTP impact on DeepSeek-R1 (1500 in / 2500 out)");
    let mut features = EngineFeatures::xllm(16);
    features.dp_groups = 4;
    let cost = CostModel::new(ascend_910b(), catalog("DeepSeek-R1").unwrap(), features);
    let spec = SpecConfig { m: 1, acceptance: 0.8 }; // MTP-1 (R1's MTP head)
    println!(
        "{:<12} | {:>10} {:>12} | {:>10} {:>12}",
        "concurrency", "TPOT off", "tput off", "TPOT mtp", "tput mtp"
    );
    for conc in [1u64, 4, 16, 32, 64, 128] {
        let kv = conc * 2750;
        let base_step = cost.decode_step_s(conc, kv);
        let base_tput = conc as f64 / base_step;
        let tokens = expected_tokens_per_round(spec.m, spec.acceptance);
        let mtp_step = base_step * verify_cost_multiplier(spec.m) * 1.05;
        let mtp_tpot = mtp_step / tokens;
        let mtp_tput = conc as f64 * tokens / mtp_step;
        println!(
            "{:<12} | {:>9.1}ms {:>10.0}/s | {:>9.1}ms {:>10.0}/s",
            conc,
            base_step * 1e3,
            base_tput,
            mtp_tpot * 1e3,
            mtp_tput
        );
    }
    println!("(paper: MTP lowers TPOT and raises throughput, biggest gain >32 concurrency)");
}

// ---------------------------------------------------------------------
// fig21: dynamic PD policy vs MinimalLoad vs RoundRobin
// ---------------------------------------------------------------------

fn bench_fig21() {
    header("fig21 — Dynamic PD disaggregation policy ablation");
    println!(
        "{:<12} {:<12} | {:>12} {:>12} {:>10}",
        "trace", "policy", "max rate /s", "tput tok/s", "SLO att."
    );
    for trace in ["azure-code", "azure-conv"] {
        for (name, dispatch, dynamic) in [
            ("slo-aware", DispatchPolicy::SloAware, true),
            ("min-load", DispatchPolicy::MinimalLoad, false),
            ("round-robin", DispatchPolicy::RoundRobin, false),
        ] {
            let eval = |rate: f64| -> (f64, f64) {
                let mut cfg = ClusterConfig::new(
                    4,
                    ascend_910b(),
                    catalog("Qwen3-8B").unwrap(),
                    EngineFeatures::xllm(1),
                );
                cfg.slo = Slo::interactive(2.0, 0.05);
                // all policies start from the same 1P/3D split; only the
                // SLO-aware policy may flip roles at runtime
                cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic };
                cfg.dispatch = dispatch;
                let slo = cfg.slo;
                let mut rng = Rng::new(77);
                let w = scenario(trace).unwrap().generate(40.0, rate, &mut rng);
                if w.is_empty() {
                    return (0.0, 1.0);
                }
                let res = sim_run(cfg, w);
                (res.report.output_throughput(), res.report.slo_attainment(&slo))
            };
            let mut lo = 0.1;
            let mut hi = 0.2;
            let mut best = (0.0, 0.0, 0.0);
            for _ in 0..20 {
                let (t, a) = eval(hi);
                if a >= 0.90 {
                    best = (hi, t, a);
                    lo = hi;
                    hi *= 2.0;
                } else {
                    break;
                }
            }
            for _ in 0..6 {
                let mid = 0.5 * (lo + hi);
                let (t, a) = eval(mid);
                if a >= 0.90 {
                    best = (mid, t, a);
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            println!(
                "{:<12} {:<12} | {:>12.2} {:>12.0} {:>9.1}%",
                trace,
                name,
                best.0,
                best.1,
                best.2 * 100.0
            );
        }
    }
    println!("(paper: SLO-aware 1.67x MinimalLoad on Azure Code, 1.1x on Conversation)");
}

// ---------------------------------------------------------------------
// fig22: hybrid EPD disaggregation ablation
// ---------------------------------------------------------------------

fn bench_fig22() {
    header("fig22 — hybrid EPD disaggregation ablation (TextCaps-like)");
    // Interference experiment at fixed load on a small cluster with a
    // tight TPOT SLO: fused instances expose encode time inside decode
    // iterations; the hybrid strategy isolates phases; naive batching
    // (no stage-level budgets) lets giant prefill/encode batches stall
    // decode steps.
    let slo = Slo::interactive(2.0, 0.018);
    println!("{:<28} | {:>10} {:>12} {:>10}", "config", "goodput", "mean TPOT", "SLO att.");
    for (name, strategy, stage_sched) in [
        ("xllm (hybrid EPD + stages)", Some(EpdStrategy::EpD), true),
        ("w/o hybrid EPD", None, true),
        ("w/o stage-level scheduling", None, false),
    ] {
        let mut cfg = ClusterConfig::new(
            2,
            ascend_910b(),
            catalog("Qwen2-7B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.slo = slo;
        cfg.epd = strategy.or(Some(EpdStrategy::Fused));
        cfg.n_encode = if strategy.is_some() { 1 } else { 0 };
        cfg.mode = if strategy.is_some() {
            ServingMode::Disaggregated { n_prefill: 1, dynamic: false }
        } else {
            ServingMode::Colocated
        };
        if stage_sched {
            // stage-level scheduling: profiler-style per-phase budgets
            // keep every iteration under the TPOT SLO (the fused config
            // must throttle encode hard; the disaggregated one can batch
            // encode freely on its dedicated pool)
            cfg.batch.token_budget = if strategy.is_some() { 1024 } else { 128 };
            cfg.batch.max_encode_batch = if strategy.is_some() { 8 } else { 1 };
        } else {
            cfg.batch.token_budget = 1 << 20; // unbounded prefill per iter
            cfg.batch.max_encode_batch = 64; // giant encode batches
        }
        let mut rng = Rng::new(5);
        let w = scenario("textcaps").unwrap().generate(20.0, 60.0, &mut rng);
        let res = sim_run(cfg, w);
        let report = res.report;
        println!(
            "{:<28} | {:>8.2}/s {:>10.1}ms {:>9.1}%",
            name,
            report.goodput(&slo),
            report.tpot_summary().mean() * 1e3,
            report.slo_attainment(&slo) * 100.0
        );
    }
    println!("(paper: 9.5 -> 7.2 -> 5.1 req/s goodput)");
}

// ---------------------------------------------------------------------
// fig23: online-offline co-location
// ---------------------------------------------------------------------

fn bench_fig23() {
    header("fig23 — online-offline co-location: max offline tput w/ online SLO held");
    let tpot = 0.08;
    let slo = Slo::interactive(2.0, tpot); // online SLO: TTFT 2s + TPOT 80ms
    println!("{:<16} | {:>14} {:>16}", "policy", "max offl qps", "offl tok/s @max");
    for (name, mode) in [
        ("baseline-pd", ColocationMode::BaselinePd),
        ("online-priority", ColocationMode::OnlinePriority),
        ("xllm-ooc", ColocationMode::XllmOoc),
    ] {
        let eval = |offline_rate: f64| -> (f64, f64) {
            let mut cfg = ClusterConfig::new(
                4,
                ascend_910b(),
                catalog("Qwen3-8B").unwrap(),
                EngineFeatures::xllm(1),
            );
            cfg.slo = slo;
            cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
            cfg.colocation =
                Some((mode, ColocationConfig { online_tpot_s: tpot, ..Default::default() }));
            let mut rng = Rng::new(31);
            let mut w = scenario("sharegpt").unwrap().generate(20.0, 6.0, &mut rng);
            w.extend(scenario("offline-docs").unwrap().generate(20.0, offline_rate, &mut rng));
            w.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
            let res = sim_run(cfg, w);
            let online: Vec<_> = res
                .report
                .outcomes
                .iter()
                .filter(|o| o.input_tokens < 2048 && o.output_tokens < 1024)
                .collect();
            let online_att = online.iter().filter(|o| o.meets(&slo)).count() as f64
                / online.len().max(1) as f64;
            let offline_tok: u64 = res
                .report
                .outcomes
                .iter()
                .filter(|o| o.input_tokens >= 2048 || o.output_tokens >= 1024)
                .map(|o| o.output_tokens)
                .sum();
            (online_att, offline_tok as f64 / 20.0)
        };
        let mut best = (0.0f64, 0.0f64);
        let mut rate = 0.5;
        while rate <= 32.0 {
            let (att, tok) = eval(rate);
            if att >= 0.90 {
                best = (rate, tok);
            } else {
                break;
            }
            rate *= 2.0;
        }
        println!("{:<16} | {:>14.2} {:>16.0}", name, best.0, best.1);
    }
    println!("(paper: xLLM-OOC sustains ~3x the offline throughput of both baselines)");
}

// ---------------------------------------------------------------------
// table6: async scheduling ablation
// ---------------------------------------------------------------------

fn bench_table6() {
    header("table6 — async scheduling (framework-layer pipeline) ablation, 1000/1000");
    println!(
        "{:<24} | {:>12} {:>12} {:>8}",
        "model", "sync tok/s", "async tok/s", "gain"
    );
    for m in [
        "DS-Distill-Qwen-1.5B",
        "DS-Distill-Qwen-7B",
        "DS-Distill-Qwen-14B",
        "DS-Distill-Qwen-32B",
    ] {
        let mut tputs = Vec::new();
        for async_sched in [false, true] {
            let mut features = EngineFeatures::xllm(1);
            features.async_sched = async_sched;
            let cost = CostModel::new(ascend_910b(), catalog(m).unwrap(), features);
            let b = 64u64;
            let step = cost.decode_step_s(b, b * 1500);
            tputs.push(b as f64 / step);
        }
        println!(
            "{:<24} | {:>12.0} {:>12.0} {:>7.1}%",
            m,
            tputs[0],
            tputs[1],
            (tputs[1] / tputs[0] - 1.0) * 100.0
        );
    }
    println!("(paper: +17.4% @1.5B, +0.6% @7B, +3.7% @14B, +6.6% @32B)");
}

// ---------------------------------------------------------------------
// table7: dual-stream comm/comp overlap
// ---------------------------------------------------------------------

fn bench_table7() {
    header("table7 — dual-stream micro-batch overlap, DeepSeek-R1 decoder layer");
    let layers = 61;
    let single = simulate_single_stream(layers, 13.0e-3, 9.3e-3);
    let dual = simulate_dual_stream(layers, 13.0e-3, 9.3e-3, 2, 17.0 / 13.0, 12.4 / 9.3);
    let per_layer_single = single.total_s / layers as f64;
    let per_layer_dual = dual.total_s / layers as f64;
    println!("{:<34} {:>14} {:>14}", "metric", "single-stream", "dual-stream");
    println!(
        "{:<34} {:>12.1}ms {:>12.1}ms",
        "total comm (per layer)",
        single.total_comm_s / layers as f64 * 1e3,
        dual.total_comm_s / layers as f64 * 1e3
    );
    println!(
        "{:<34} {:>13.0}% {:>13.0}%",
        "overlapped comm ratio",
        single.overlap_ratio() * 100.0,
        dual.overlap_ratio() * 100.0
    );
    println!(
        "{:<34} {:>12.1}ms {:>12.1}ms",
        "exposed comm (per layer)",
        single.exposed_comm_s / layers as f64 * 1e3,
        dual.exposed_comm_s / layers as f64 * 1e3
    );
    println!(
        "{:<34} {:>12.1}ms {:>12.1}ms",
        "total compute (per layer)",
        single.total_compute_s / layers as f64 * 1e3,
        dual.total_compute_s / layers as f64 * 1e3
    );
    println!(
        "{:<34} {:>14} {:>12.1}ms",
        "reduced time per layer",
        "-",
        (per_layer_single - per_layer_dual) * 1e3
    );
    println!(
        "{:<34} {:>14} {:>11.1}ms",
        "total reduced (61 layers)",
        "-",
        (single.total_s - dual.total_s) * 1e3
    );
    println!("(paper: 80% overlap, exposed 9.3->2.5ms, 172ms total reduction)");
}

// ---------------------------------------------------------------------
// table8: adaptive graph mode
// ---------------------------------------------------------------------

fn bench_table8() {
    header("table8 — adaptive graph mode, 2048/2048");
    println!(
        "{:<12} {:<6} | {:>12} {:>12} | {:>10} {:>10}",
        "model", "graph", "tput tok/s", "mean TPOT", "d tput", "d TPOT"
    );
    for m in ["Qwen3-1.7B", "Qwen3-4B"] {
        let mut rows = Vec::new();
        for graph in [GraphMode::Eager, GraphMode::Adaptive] {
            let mut features = EngineFeatures::xllm(1);
            features.graph_mode = graph;
            let cost = CostModel::new(ascend_910b(), catalog(m).unwrap(), features);
            let b = 48u64;
            let step = cost.decode_step_s(b, b * 3072);
            rows.push((b as f64 / step, step));
        }
        println!(
            "{:<12} {:<6} | {:>12.0} {:>10.2}ms | {:>10} {:>10}",
            m,
            "eager",
            rows[0].0,
            rows[0].1 * 1e3,
            "-",
            "-"
        );
        println!(
            "{:<12} {:<6} | {:>12.0} {:>10.2}ms | {:>+9.1}% {:>+9.1}%",
            m,
            "adapt",
            rows[1].0,
            rows[1].1 * 1e3,
            (rows[1].0 / rows[0].0 - 1.0) * 100.0,
            (rows[1].1 / rows[0].1 - 1.0) * 100.0
        );
    }
    println!("(paper: 1.7B +27.4% tput, -22.0% TPOT; 4B +8.5% tput, -8.8% TPOT)");
}

// ---------------------------------------------------------------------
// dpbal: hierarchical DP load balance (§5.2 last ablation)
// ---------------------------------------------------------------------

fn bench_dpbal() {
    header("dpbal — hierarchical DP load balance ablation");
    // layer 3: kernel-level reorder+split (paper: 32k -> ~1.3k tokens/core)
    let mut reqs = vec![32_000u64];
    reqs.extend(std::iter::repeat(200).take(23));
    let rr = dpbalance::round_robin_cores(&reqs, 24);
    let bal = dpbalance::balanced_cores(&reqs, 24, 1_500);
    println!(
        "layer3 kernel-level: max core load {} -> {} tokens ({} splits)",
        rr.makespan_tokens(),
        bal.makespan_tokens(),
        bal.splits
    );

    // layer 2: 20k-token inter-group gap
    let mut groups: Vec<dpbalance::DpGroup> = vec![
        dpbalance::DpGroup { id: 0, kv_tokens: 60_000, kv_capacity: 1 << 20, n_requests: 8 },
        dpbalance::DpGroup { id: 1, kv_tokens: 40_000, kv_capacity: 1 << 20, n_requests: 8 },
    ];
    let before = dpbalance::straggler_factor(&groups);
    let m = dpbalance::plan_migrations(&groups, 0.05, 8, 2000);
    dpbalance::apply_migrations(&mut groups, &m);
    println!(
        "layer2 inter-DP: straggler {:.3} -> {:.3} via {} migrations",
        before,
        dpbalance::straggler_factor(&groups),
        m.len()
    );

    // end-to-end: DP-balanced vs static DP on the MoE cost model
    for dp_balance in [false, true] {
        let mut features = EngineFeatures::xllm(16);
        features.dp_groups = 80;
        features.dp_balance = dp_balance;
        let cost = CostModel::new(ascend_910b(), catalog("DeepSeek-R1").unwrap(), features);
        let b = 128u64;
        let step = cost.decode_step_s(b, b * 2048);
        println!(
            "end-to-end decode tput (dp_balance={}): {:.0} tok/s",
            dp_balance,
            b as f64 / step
        );
    }
    println!("(paper: ~5% total throughput from hierarchical balancing)");
}

// ---------------------------------------------------------------------
// perf: hot-path microbenchmarks (criterion substitute)
// ---------------------------------------------------------------------

fn bench_perf() {
    header("perf — hot-path microbenchmarks");
    let mut rng = Rng::new(17);

    // event queue throughput
    {
        let mut q = xllm::sim::EventQueue::new();
        let n = 1_000_000u64;
        let t0 = Instant::now();
        for i in 0..n {
            q.schedule_at(rng.f64() * 1e6, i);
        }
        while q.next().is_some() {}
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "event queue        : {:.2}M events/s ({:.0} ns/event)",
            n as f64 / dt / 1e6,
            dt / n as f64 * 1e9
        );
    }

    // xtensor map/extend/close cycle
    {
        let mut m = xllm::engine::XTensorManager::new(4096, 16, 4096);
        let n = 200_000u64;
        let t0 = Instant::now();
        for i in 0..n {
            m.open_with_reuse(i, 64);
            m.extend(i, 64);
            m.close(i);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "xtensor open/close : {:.2}M cycles/s ({:.0} ns/cycle)",
            n as f64 / dt / 1e6,
            dt / n as f64 * 1e9
        );
    }

    // beam search step (beam 64)
    {
        let beam = 64;
        let expansions: Vec<Vec<(u32, f64)>> = (0..beam)
            .map(|_| {
                let mut v: Vec<(u32, f64)> =
                    (0..beam).map(|t| (t as u32, rng.f64() * -10.0)).collect();
                v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                v
            })
            .collect();
        let mut s = BeamSearcher::new(beam);
        let n = 2000;
        let t0 = Instant::now();
        for _ in 0..n {
            s.step_optimized(&expansions);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "beam step (w=64)   : {:.0} steps/s ({:.1} us/step)",
            n as f64 / dt,
            dt / n as f64 * 1e6
        );
    }

    // cost model decode step
    {
        let cost =
            CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1));
        let n = 2_000_000u64;
        let t0 = Instant::now();
        let mut acc = 0.0;
        for i in 0..n {
            acc += cost.decode_step_s(1 + (i % 64), 1024 * (i % 64 + 1));
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "cost model step    : {:.2}M evals/s ({:.0} ns/eval, checksum {:.1})",
            n as f64 / dt / 1e6,
            dt / n as f64 * 1e9,
            acc
        );
    }

    // cluster sim iteration rate
    {
        let cfg = ClusterConfig::new(
            4,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        let mut wrng = Rng::new(9);
        let w = scenario("sharegpt").unwrap().generate(30.0, 8.0, &mut wrng);
        let t0 = Instant::now();
        let res = sim_run(cfg, w);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "cluster sim        : {:.0} events/s wall ({} events, {} iters, {:.2}s)",
            res.events as f64 / dt,
            res.events,
            res.iterations,
            dt
        );
    }

    // real PJRT decode step (if artifacts present)
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        let mut rt = xllm::runtime::Runtime::load(artifacts).expect("runtime");
        let dims = rt.model_dims("tiny").unwrap();
        let mut kv = xllm::runtime::BatchKv::zeros(dims, 8);
        let tokens = vec![1i32; 8];
        rt.decode("tiny", &mut kv, &tokens, &vec![1i32; 8]).unwrap();
        let n = 24;
        let t0 = Instant::now();
        for i in 0..n {
            let pos = vec![(2 + i) as i32; 8];
            rt.decode("tiny", &mut kv, &tokens, &pos).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "real decode (b=8)  : {:.1} steps/s, {:.0} tok/s ({:.2} ms/step)",
            n as f64 / dt,
            8.0 * n as f64 / dt,
            dt / n as f64 * 1e3
        );
    }
}

// ---------------------------------------------------------------------
// perfjson: the BENCH_*.json perf trajectory — per-policy engine deltas
// on an MoE overload scenario, written to the repo root for CI's
// bench-smoke regression gate
// ---------------------------------------------------------------------

fn bench_perfjson() {
    header("perfjson — engine-policy deltas (writes BENCH_6.json)");
    let slo = Slo::tpot(0.08);
    let scenario_name = "sharegpt";
    let model = catalog("DeepSeek-R1").unwrap();
    let instances = 2usize;
    // heavy overload: arrivals far above capacity, so tokens/s measures
    // iteration speed (what the policies change), not the arrival rate
    let mut rng = Rng::new(0x6001);
    let workload = scenario(scenario_name).unwrap().generate(20.0, 30.0, &mut rng);

    let run_with = |label: &str| {
        let mut cfg =
            ClusterConfig::new(instances, ascend_910b(), model.clone(), EngineFeatures::xllm(16));
        cfg.slo = slo;
        cfg.policies = EnginePolicies::parse(label).unwrap();
        sim_run(cfg, workload.clone())
    };

    let off = run_with("none");
    let off_tput = off.report.output_throughput();
    let off_p99 = off.report.tpot_summary().percentile(99.0);
    println!("  {:10}: {off_tput:8.0} tok/s  p99 TPOT {:6.1} ms", "off", off_p99 * 1e3);
    let mut policies_obj = Json::obj().set(
        "off",
        Json::obj()
            .set("tokens_per_s", off_tput)
            .set("tpot_p99_s", off_p99)
            .set("delta_vs_off_pct", 0.0),
    );

    let mut all = None;
    for v in ["eplb", "dp-balance", "op-overlap", "graph", "all"] {
        let res = run_with(v);
        let tput = res.report.output_throughput();
        let p99 = res.report.tpot_summary().percentile(99.0);
        let delta = (tput / off_tput - 1.0) * 100.0;
        println!("  {v:10}: {tput:8.0} tok/s  p99 TPOT {:6.1} ms  ({delta:+.1}% vs off)", p99 * 1e3);
        policies_obj = policies_obj.set(
            v,
            Json::obj()
                .set("tokens_per_s", tput)
                .set("tpot_p99_s", p99)
                .set("delta_vs_off_pct", delta),
        );
        if v == "all" {
            all = Some(res);
        }
    }
    let all = all.unwrap();
    let report = &all.report;

    let out = Json::obj()
        .set("bench", "BENCH_6")
        .set("measured", true)
        .set("scenario", scenario_name)
        .set("model", model.name)
        .set("framework", "xllm")
        .set("instances", instances)
        .set("requests", report.n_requests())
        .set("slo_tpot_s", slo.tpot())
        .set("tokens_per_s", report.output_throughput())
        .set("ttft_p50_s", report.ttft_summary().percentile(50.0))
        .set("ttft_p99_s", report.ttft_summary().percentile(99.0))
        .set("tpot_p50_s", report.tpot_summary().percentile(50.0))
        .set("tpot_p99_s", report.tpot_summary().percentile(99.0))
        .set("goodput_req_s", report.goodput(&slo))
        .set("policies", policies_obj);
    // cargo bench runs with cwd = the package root (rust/), so the
    // default lands at the repo root next to the committed baseline
    let path =
        std::env::var("BENCH_JSON_PATH").unwrap_or_else(|_| "../BENCH_6.json".to_string());
    std::fs::write(&path, out.to_string()).expect("writing the bench JSON");
    println!("  wrote {path}");
}

// ---------------------------------------------------------------------
// indexops: radix vs linear cluster prefix index — per-heartbeat publish
// volume and best-match lookup cost at C and 10×C resident chains,
// written to BENCH_7.json.  The headline claim is *sublinear growth*:
// the legacy full-summary republish pays the whole resident set every
// heartbeat (entry volume grows 10× with 10× chains) while the delta
// publish pays only the changes since the last heartbeat (flat), and
// the radix token walk stays O(matched tokens) regardless of how many
// chains are resident.
// ---------------------------------------------------------------------

fn bench_indexops() {
    use xllm::service::{hash_chain, prefix_tokens, GlobalPrefixIndex, Tier};

    header("indexops — radix vs linear cluster index (writes BENCH_7.json)");
    let block_tokens = 64u64;
    let replicas = 4usize;
    let depth_tokens = 512u64; // queried prefix length: 8 blocks
    let chains_base = 200usize;
    let scale = 10usize;
    // steady-state heartbeat delta: a handful of residency changes per
    // replica per beat, independent of how many chains are resident
    let delta_changes = 8usize;

    // per-replica block summaries for `chains` distinct prefix groups
    let summaries = |chains: usize| -> Vec<Vec<(u64, Tier)>> {
        let mut s: Vec<Vec<(u64, Tier)>> = vec![Vec::new(); replicas];
        for c in 0..chains {
            let toks = prefix_tokens(c as u64, depth_tokens);
            for &h in &hash_chain(&toks, block_tokens as usize) {
                s[c % replicas].push((h, Tier::Dram));
            }
        }
        s
    };

    // (full_ns, delta_ns, linear_match_ns, radix_match_ns, full_entries,
    //  delta_entries) per heartbeat / per lookup at `chains` residents
    let measure = |chains: usize| -> (f64, f64, f64, f64, u64, u64) {
        let sums = summaries(chains);

        // legacy: block index fed by full-summary republish
        let mut legacy = GlobalPrefixIndex::new();
        for (r, s) in sums.iter().enumerate() {
            legacy.publish(r, s);
        }
        // token-granular: radix mirror fed by deltas
        let mut radix = GlobalPrefixIndex::new();
        radix.enable_token_granular(block_tokens);
        for (r, s) in sums.iter().enumerate() {
            let d: Vec<(u64, Option<Tier>)> = s.iter().map(|&(h, t)| (h, Some(t))).collect();
            radix.publish_delta(r, &d);
        }
        for c in 0..chains {
            radix.record_tokens(c % replicas, &prefix_tokens(c as u64, depth_tokens));
        }

        // steady state: one heartbeat republishes each replica's view
        let full_entries: u64 = sums.iter().map(|s| s.len() as u64).sum();
        let delta_entries = (delta_changes * replicas) as u64;
        let deltas: Vec<Vec<(u64, Option<Tier>)>> = sums
            .iter()
            .map(|s| s.iter().take(delta_changes).map(|&(h, t)| (h, Some(t))).collect())
            .collect();

        let publish_iters = 200usize;
        let t = Instant::now();
        for _ in 0..publish_iters {
            for (r, s) in sums.iter().enumerate() {
                legacy.publish(r, s);
            }
        }
        let full_ns = t.elapsed().as_nanos() as f64 / publish_iters as f64;

        let delta_iters = 2000usize;
        let t = Instant::now();
        for _ in 0..delta_iters {
            for (r, d) in deltas.iter().enumerate() {
                radix.publish_delta(r, d);
            }
        }
        let delta_ns = t.elapsed().as_nanos() as f64 / delta_iters as f64;

        // best-match lookups over every resident chain
        let queries: Vec<Vec<u32>> =
            (0..chains).map(|c| prefix_tokens(c as u64, depth_tokens)).collect();
        let qchains: Vec<Vec<u64>> =
            queries.iter().map(|t| hash_chain(t, block_tokens as usize)).collect();
        let match_iters = 20usize;
        let mut sink = 0usize;
        let t = Instant::now();
        for _ in 0..match_iters {
            for q in &qchains {
                sink += legacy.best_match(q).map(|(_, n, _)| n).unwrap_or(0);
            }
        }
        let linear_match_ns =
            t.elapsed().as_nanos() as f64 / (match_iters * chains) as f64;
        let t = Instant::now();
        for _ in 0..match_iters {
            for q in &queries {
                sink += radix.best_match_tokens(q).map(|(_, n, _)| n as usize).unwrap_or(0);
            }
        }
        let radix_match_ns =
            t.elapsed().as_nanos() as f64 / (match_iters * chains) as f64;
        assert!(sink > 0, "lookups must hit");

        (full_ns, delta_ns, linear_match_ns, radix_match_ns, full_entries, delta_entries)
    };

    let (f1, d1, l1, r1, fe1, de1) = measure(chains_base);
    let (f10, d10, l10, r10, fe10, de10) = measure(chains_base * scale);
    let growth = |a: f64, b: f64| if a > 0.0 { b / a } else { 0.0 };

    println!(
        "  heartbeat entries: full {fe1} -> {fe10} ({:.1}x)   delta {de1} -> {de10} ({:.1}x)",
        growth(fe1 as f64, fe10 as f64),
        growth(de1 as f64, de10 as f64)
    );
    println!(
        "  heartbeat ns:      full {f1:9.0} -> {f10:9.0} ({:.1}x)   delta {d1:7.0} -> {d10:7.0} ({:.1}x)",
        growth(f1, f10),
        growth(d1, d10)
    );
    println!(
        "  best-match ns/op:  linear {l1:7.0} -> {l10:7.0} ({:.1}x)   radix {r1:7.0} -> {r10:7.0} ({:.1}x)",
        growth(l1, l10),
        growth(r1, r10)
    );

    let out = Json::obj()
        .set("bench", "BENCH_7")
        .set("measured", true)
        .set("block_tokens", block_tokens)
        .set("replicas", replicas)
        .set("prefix_tokens", depth_tokens)
        .set("chains_base", chains_base)
        .set("chains_10x", chains_base * scale)
        .set(
            "heartbeat",
            Json::obj()
                .set("full_entries_base", fe1)
                .set("full_entries_10x", fe10)
                .set("full_entry_growth_10x", growth(fe1 as f64, fe10 as f64))
                .set("delta_entries_base", de1)
                .set("delta_entries_10x", de10)
                .set("delta_entry_growth_10x", growth(de1 as f64, de10 as f64))
                .set("full_ns_base", f1)
                .set("full_ns_10x", f10)
                .set("full_ns_growth_10x", growth(f1, f10))
                .set("delta_ns_base", d1)
                .set("delta_ns_10x", d10)
                .set("delta_ns_growth_10x", growth(d1, d10)),
        )
        .set(
            "best_match",
            Json::obj()
                .set("linear_ns_base", l1)
                .set("linear_ns_10x", l10)
                .set("linear_growth_10x", growth(l1, l10))
                .set("radix_ns_base", r1)
                .set("radix_ns_10x", r10)
                .set("radix_growth_10x", growth(r1, r10)),
        );
    let path =
        std::env::var("BENCH7_JSON_PATH").unwrap_or_else(|_| "../BENCH_7.json".to_string());
    std::fs::write(&path, out.to_string()).expect("writing the index bench JSON");
    println!("  wrote {path}");
}

// ---------------------------------------------------------------------
// streamscale: the streaming million-request workload engine —
// wall-clock requests/s and events/s must hold ~flat from 10k to 1M
// pulled arrivals (O(live) memory: bounded live-request high-water),
// and SLO-aware autoscaling must beat the backlog policy on goodput
// per replica-second on the same tide stream.  Written to BENCH_8.json.
// ---------------------------------------------------------------------

fn bench_streamscale() {
    use xllm::service::controlplane::{ScalePolicy, ScalerConfig};
    use xllm::sim::fleet::{run_fleet_stream, FleetConfig};

    header("streamscale — streaming fleet scale + SLO-goodput scaling (writes BENCH_8.json)");
    let template = || {
        let mut cfg = ClusterConfig::new(
            1,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.prefix_cache = true;
        cfg
    };
    let sc = scenario("tide").unwrap();

    // (a) streaming scale: same open-loop tide stream, 10k vs 1M pulled
    // arrivals over a fixed 4-replica fleet.  Arrivals are pulled one at
    // a time and reports run sketch-only, so the only per-request state
    // is the live window — throughput per wall second must not decay
    // with the request count.
    let rate = 8.0;
    let run_n = |n: usize| {
        let mut rng = Rng::new(0x8001);
        let cfg = FleetConfig::new(template(), 4);
        let stream = sc.stream_unbounded(rate, &mut rng).with_limit(n);
        let t0 = Instant::now();
        let res = run_fleet_stream(cfg, stream);
        let wall = t0.elapsed().as_secs_f64();
        assert!(res.all_accounted(), "streaming run lost requests at n={n}");
        assert!(!res.truncated, "streaming run truncated at n={n}");
        let events: u64 = res.per_replica.iter().map(|r| r.events).sum();
        (res, wall, events)
    };
    let (small_n, large_n) = (10_000usize, 1_000_000usize);
    let (small, wall_s, ev_s) = run_n(small_n);
    let (large, wall_l, ev_l) = run_n(large_n);
    let rps_small = small_n as f64 / wall_s.max(1e-9);
    let rps_large = large_n as f64 / wall_l.max(1e-9);
    let eps_small = ev_s as f64 / wall_s.max(1e-9);
    let eps_large = ev_l as f64 / wall_l.max(1e-9);
    println!(
        "  {:>9} requests: {:>9.0} req/s wall  {:>9.0} events/s  live high-water {:>6}  ({:.1}s)",
        small_n, rps_small, eps_small, small.live_high_water, wall_s
    );
    println!(
        "  {:>9} requests: {:>9.0} req/s wall  {:>9.0} events/s  live high-water {:>6}  ({:.1}s)",
        large_n, rps_large, eps_large, large.live_high_water, wall_l
    );
    println!(
        "  throughput ratio 1M/10k: {:.2}x (flat = streaming holds O(live) state)",
        rps_large / rps_small.max(1e-9)
    );

    // (b) SLO-goodput autoscaling: identical 20k-request tide stream,
    // one elastic fleet per policy.  The backlog rule's token target is
    // far under one typical prompt, so it over-provisions through the
    // flood; the SLO rule spends replicas only on predicted TTFT risk.
    let scaled = |policy: ScalePolicy| {
        let mut cfg = FleetConfig::new(template(), 1);
        cfg.control.scaler = Some(ScalerConfig {
            policy,
            slo_ttft_target_s: 1.0,
            capacity_target_tokens: 512,
            min_replicas: 1,
            max_replicas: 4,
            cooldown_s: 1.0,
            ..Default::default()
        });
        let mut rng = Rng::new(0x8002);
        let res = run_fleet_stream(cfg, sc.stream_unbounded(rate, &mut rng).with_limit(20_000));
        assert!(res.all_accounted(), "scaled run lost requests");
        res
    };
    let backlog = scaled(ScalePolicy::Backlog);
    let slo = scaled(ScalePolicy::Slo);
    let policy_row = |name: &str, r: &xllm::service::controlplane::FleetResult| {
        println!(
            "  {:>8}: goodput/replica-s {:.4}  replica-s {:>9.0}  ups {} downs {}  predicted violations {}",
            name,
            r.goodput_per_replica_second(),
            r.replica_seconds,
            r.counters.scale_ups,
            r.counters.scale_downs,
            r.counters.slo_violations_predicted
        );
        Json::obj()
            .set("goodput_per_replica_s", r.goodput_per_replica_second())
            .set("replica_seconds", r.replica_seconds)
            .set("scale_ups", r.counters.scale_ups)
            .set("scale_downs", r.counters.scale_downs)
            .set("slo_violations_predicted", r.counters.slo_violations_predicted)
            .set("live_high_water", r.live_high_water)
    };
    let backlog_json = policy_row("backlog", &backlog);
    let slo_json = policy_row("slo", &slo);

    let out = Json::obj()
        .set("bench", "BENCH_8")
        .set("measured", true)
        .set("scenario", "tide")
        .set("model", "Qwen3-8B")
        .set("rate_req_s", rate)
        .set(
            "streaming",
            Json::obj()
                .set("replicas", 4)
                .set("requests_small", small_n)
                .set("requests_large", large_n)
                .set("wall_s_small", wall_s)
                .set("wall_s_large", wall_l)
                .set("req_per_s_small", rps_small)
                .set("req_per_s_large", rps_large)
                .set("events_per_s_small", eps_small)
                .set("events_per_s_large", eps_large)
                .set("throughput_ratio_large_vs_small", rps_large / rps_small.max(1e-9))
                .set("live_high_water_small", small.live_high_water)
                .set("live_high_water_large", large.live_high_water),
        )
        .set(
            "goodput",
            Json::obj()
                .set("requests", 20_000u64)
                .set("backlog", backlog_json)
                .set("slo", slo_json)
                .set(
                    "slo_vs_backlog_ratio",
                    slo.goodput_per_replica_second()
                        / backlog.goodput_per_replica_second().max(1e-12),
                ),
        );
    let path =
        std::env::var("BENCH8_JSON_PATH").unwrap_or_else(|_| "../BENCH_8.json".to_string());
    std::fs::write(&path, out.to_string()).expect("writing the streaming bench JSON");
    println!("  wrote {path}");
}
