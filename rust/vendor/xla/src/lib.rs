//! API stub of the `xla` PJRT bindings (vendored, offline build).
//!
//! Host-side [`Literal`] construction/data movement is fully
//! functional; anything that would compile or execute an HLO graph
//! returns a clear error.  The simulator/coordinator/orchestrator
//! layers never reach PJRT, so the whole workspace builds and tests
//! offline; swap in the real `xla` crate (LaurentMazare/xla-rs) to run
//! the real server path.  See `rust/vendor/README.md`.

use std::fmt;

/// Stub error: rendered via `{:?}` by callers.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in the vendored xla stub; swap in the real \
         xla crate (see rust/vendor/README.md)"
    ))
}

/// Element dtypes the workspace uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side typed array (functional).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Sealed-ish helper for the element types literals carry.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn slice(data: &Data) -> Result<&[Self], Error>;
    fn slice_mut(data: &mut Data) -> Result<&mut [Self], Error>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn slice(data: &Data) -> Result<&[f32], Error> {
        match data {
            Data::F32(v) => Ok(v),
            _ => Err(Error("literal is not f32".to_string())),
        }
    }
    fn slice_mut(data: &mut Data) -> Result<&mut [f32], Error> {
        match data {
            Data::F32(v) => Ok(v),
            _ => Err(Error("literal is not f32".to_string())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn slice(data: &Data) -> Result<&[i32], Error> {
        match data {
            Data::I32(v) => Ok(v),
            _ => Err(Error("literal is not i32".to_string())),
        }
    }
    fn slice_mut(data: &mut Data) -> Result<&mut [i32], Error> {
        match data {
            Data::I32(v) => Ok(v),
            _ => Err(Error("literal is not i32".to_string())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Zero-initialized literal of the given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        let data = match ty {
            PrimitiveType::F32 => Data::F32(vec![0.0; n]),
            PrimitiveType::S32 => Data::I32(vec![0; n]),
        };
        Literal { dims: dims.iter().map(|&d| d as i64).collect(), data }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Same data, new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape to {:?} ({n} elems) from {} elems",
                dims,
                self.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Overwrite the literal's data in place (shape unchanged).
    pub fn copy_raw_from<T: NativeType>(&mut self, src: &[T]) -> Result<(), Error> {
        let dst = T::slice_mut(&mut self.data)?;
        if dst.len() != src.len() {
            return Err(Error(format!(
                "copy_raw_from: {} elems into literal of {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// Copy the literal's data out to a host slice.
    pub fn copy_raw_to<T: NativeType>(&self, dst: &mut [T]) -> Result<(), Error> {
        let src = T::slice(&self.data)?;
        if dst.len() != src.len() {
            return Err(Error(format!(
                "copy_raw_to: literal of {} elems into buffer of {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    /// The literal's data as an owned vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::slice(&self.data).map(<[T]>::to_vec)
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module (stub: carries the text, cannot lower it).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from disk (I/O is real; lowering is not).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation handle (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle (stub: never produced by a real execution).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(stub_err("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client (stub: creation succeeds, compilation errors).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(stub_err("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        let mut z = Literal::create_from_shape(PrimitiveType::F32, &[4]);
        z.copy_raw_from(&[5.0f32, 6.0, 7.0, 8.0]).unwrap();
        let mut out = [0.0f32; 4];
        z.copy_raw_to(&mut out).unwrap();
        assert_eq!(out, [5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn execution_paths_error_clearly() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { text: String::new() });
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("vendored xla stub"));
    }
}
