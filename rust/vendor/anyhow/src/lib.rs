//! Minimal `anyhow`-compatible error handling (vendored, offline build).
//!
//! Implements exactly the API surface this workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] and [`bail!`] macros, and the
//! [`Context`] extension trait on `Result` and `Option`.  Error chains
//! render outermost-first, `:`-joined, like the real crate's `{:#}`.

use std::fmt;

/// A boxed-free error: an outermost-first chain of messages.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    fn joined(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole context chain
            write!(f, "{}", self.joined())
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.joined())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension on fallible values.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_render_alternate() {
        let e: Error = io_fail().context("loading artifacts").unwrap_err();
        let full = format!("{e:#}");
        assert!(full.starts_with("loading artifacts: "), "{full}");
        let brief = format!("{e}");
        assert_eq!(brief, "loading artifacts");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        let n = 3;
        let e2 = anyhow!("bad value {n}");
        assert_eq!(format!("{e2}"), "bad value 3");
        fn bails() -> Result<()> {
            bail!("stop {}", 7)
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "stop 7");
    }
}
