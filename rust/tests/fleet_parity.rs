//! Fleet-scope golden parity: `run_fleet` must reproduce exact cluster
//! counters for fixed-seed configurations, pinning the whole control
//! plane — routing, heartbeat/lease machinery, failover re-dispatch,
//! and the elastic scaler — the way `orchestrator_parity.rs` pins the
//! single-replica lifecycle.
//!
//! The golden fixture (`tests/golden/fleet_counters.txt`) is written on
//! the first run (or when `UPDATE_GOLDEN=1`) and compared byte-exactly
//! afterwards.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use xllm::model::{ascend_910b, catalog};
use xllm::service::controlplane::{FleetResult, RoutePolicy, ScalerConfig};
use xllm::sim::cluster::ClusterConfig;
use xllm::sim::fleet::{run_fleet, FleetConfig};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

const GOLDEN_PATH: &str = "tests/golden/fleet_counters.txt";

fn template() -> ClusterConfig {
    let mut cfg = ClusterConfig::new(
        1,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.prefix_cache = true;
    cfg
}

fn counters_line(name: &str, res: &FleetResult) -> String {
    let c = &res.counters;
    let mut s = String::new();
    write!(
        s,
        "{name} submitted={} recorded={} completed={} replicas_total={} replicas_final={} \
         cache_hits={} failovers={} redispatched={} redispatched_tokens={} \
         redispatch_migrations={} offline_steered={} unroutable={} lease_expiries={} \
         scale_ups={} scale_downs={} kv_rebalances={} warm_starts={} prefix_hits={} \
         truncated={} tput_utok_s={}",
        res.submitted,
        res.report.n_requests(),
        res.report.n_completed(),
        res.per_replica.len(),
        res.n_replicas_final,
        c.routed_by_cache_hit,
        c.failovers,
        c.redispatched_requests,
        c.redispatched_tokens,
        c.redispatch_migrations,
        c.offline_steered,
        c.unroutable,
        c.lease_expiries,
        c.scale_ups,
        c.scale_downs,
        c.kv_rebalances,
        c.warm_starts,
        res.prefix_hits(),
        res.truncated,
        // micro-token/s resolution: integral, byte-stable, still
        // catches timing drift
        (res.report.output_throughput() * 1e6).round() as u64,
    )
    .unwrap();
    s
}

fn failover_case() -> String {
    let mut rng = Rng::new(0xF1EE7);
    let w = scenario("skewed-prefix").unwrap().generate(25.0, 2.5, &mut rng);
    let mut cfg = FleetConfig::new(template(), 3);
    cfg.control.routing = RoutePolicy::CacheAware;
    cfg.control.replica_faults = vec![(8.0, 1)];
    counters_line("failover", &run_fleet(cfg, w))
}

fn autoscale_case() -> String {
    let mut rng = Rng::new(0x71DA1);
    let w = scenario("tide").unwrap().generate(40.0, 5.0, &mut rng);
    let mut cfg = FleetConfig::new(template(), 1);
    cfg.control.scaler = Some(ScalerConfig {
        capacity_target_tokens: 4096,
        min_replicas: 1,
        max_replicas: 4,
        cooldown_s: 1.0,
        ..Default::default()
    });
    counters_line("autoscale-tide", &run_fleet(cfg, w))
}

/// Async-pipelined fleet: every replica keeps one look-ahead iteration
/// in flight, so the control plane interleaves concurrently pending
/// completion events — pins that the interleave stays deterministic.
fn pipelined_fleet_case() -> String {
    let mut rng = Rng::new(0x9A5F);
    let w = scenario("tide").unwrap().generate(30.0, 4.0, &mut rng);
    let mut t = template();
    t.pipeline_depth = 2;
    t.host_overhead_s = 0.002;
    counters_line("pipelined-tide-d2", &run_fleet(FleetConfig::new(t, 2), w))
}

#[test]
fn golden_fleet_counters_are_stable() {
    let got =
        format!("{}\n{}\n{}\n", failover_case(), autoscale_case(), pipelined_fleet_case());
    let path = Path::new(GOLDEN_PATH);
    let bless = std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists();
    if bless {
        // CI guard: a missing fixture must FAIL in CI instead of
        // self-blessing (GOLDEN_STRICT is set by the workflow)
        assert!(
            std::env::var("GOLDEN_STRICT").is_err() || std::env::var("UPDATE_GOLDEN").is_ok(),
            "golden fixture {GOLDEN_PATH} is not committed — run \
             UPDATE_GOLDEN=1 cargo test locally and commit the file"
        );
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, &got).unwrap();
        eprintln!("blessed golden fleet counters:\n{got}");
        return;
    }
    let want = fs::read_to_string(path).unwrap();
    assert_eq!(
        got, want,
        "fleet counters diverged from the golden fixture — the control \
         plane changed behavior.  If intentional, rerun with \
         UPDATE_GOLDEN=1 and commit the new fixture."
    );
}

#[test]
fn golden_fleet_runs_are_internally_deterministic() {
    assert_eq!(failover_case(), failover_case());
    assert_eq!(autoscale_case(), autoscale_case());
    assert_eq!(pipelined_fleet_case(), pipelined_fleet_case());
}
