//! Shard-aware replica acceptance tests (ISSUE 8).
//!
//! Three pins: (1) the unsharded configuration is *bit-identical* to an
//! explicit `tp=1,pp=1,mb=1` shard — promoting "replica = device group"
//! through the stack must not move a single float for existing runs;
//! (2) on a long-prompt workload a pipeline-parallel micro-batched
//! replica beats the same tensor width without pipelining on mean TTFT;
//! (3) the autoscaler's device accounting (`Σ tp×pp` over alive
//! replicas) never exceeds the configured budget at any scale event.

use xllm::model::{ascend_910b, catalog, ShardSpec};
use xllm::service::controlplane::{
    FleetScaler, GlobalPrefixIndex, InstanceRegistry, LoadReport, ScaleAction, ScalerConfig,
};
use xllm::sim::cluster::{ClusterConfig, ClusterSim};
use xllm::sim::fleet::{run_fleet, FleetConfig};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::{scenario, RequestSpec};

fn cfg(n: usize) -> ClusterConfig {
    let mut c = ClusterConfig::new(
        n,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    c.prefix_cache = true;
    c
}

fn workload(name: &str, horizon: f64, rate: f64, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    scenario(name).unwrap().generate(horizon, rate, &mut rng)
}

/// Everything float-valued the report derives, as raw bits.
fn report_bits(res: &xllm::sim::cluster::SimResult) -> Vec<u64> {
    let r = &res.report;
    let mut bits = vec![
        r.ttft_summary().mean().to_bits(),
        r.ttft_summary().percentile(99.0).to_bits(),
        r.tpot_summary().mean().to_bits(),
        r.e2e_summary().mean().to_bits(),
        r.output_throughput().to_bits(),
        r.total_throughput().to_bits(),
    ];
    for (_, mut s) in r.phase_summaries() {
        bits.push(s.mean().to_bits());
        bits.push(s.percentile(99.0).to_bits());
    }
    bits
}

#[test]
fn explicit_1x1x1_shard_is_bit_identical_to_the_unsharded_default() {
    let w = workload("sharegpt", 20.0, 2.0, 0x5A);
    assert!(w.len() > 20, "need a meaningful sample");

    let base = ClusterSim::new(cfg(2)).run(w.clone());
    let sharded = ClusterSim::new(cfg(2).with_shard(ShardSpec::new(1, 1, 1))).run(w);

    // every derived float, bit for bit — the shard plumbing must be
    // an exact no-op at tp=1, pp=1, mb=1
    assert_eq!(report_bits(&base), report_bits(&sharded));
    assert_eq!(base.report.n_completed(), sharded.report.n_completed());
    assert_eq!(base.iterations, sharded.iterations);
    assert_eq!(base.events, sharded.events);
    assert_eq!(base.per_instance, sharded.per_instance);
    assert_eq!(base.prefix_hits, sharded.prefix_hits);
}

#[test]
fn pp_micro_batching_cuts_mean_ttft_on_long_prompts() {
    // long prompts arriving faster than a single replica drains them:
    // prefill time dominates TTFT, which is exactly what the pipeline
    // bubble model (pp=2 halves per-stage work, mb=4 fills the
    // pipeline) is supposed to win on
    let w: Vec<RequestSpec> =
        (0..12).map(|i| RequestSpec::text(i as f64 * 0.5, 8192, 32)).collect();
    let n = w.len();

    let template = |shard: ShardSpec| cfg(1).with_shard(shard);
    let base = run_fleet(
        FleetConfig::new(template(ShardSpec::new(2, 1, 1)), 1),
        w.clone(),
    );
    let pp = run_fleet(
        FleetConfig::new(template(ShardSpec::new(2, 2, 4)), 1),
        w,
    );

    assert!(base.all_accounted());
    assert!(pp.all_accounted());
    assert_eq!(base.report.n_completed(), n);
    assert_eq!(pp.report.n_completed(), n);
    let ttft_base = base.report.ttft_summary().mean();
    let ttft_pp = pp.report.ttft_summary().mean();
    assert!(
        ttft_pp < ttft_base,
        "pp=2/mb=4 must beat pp=1 at equal tensor width on long prompts: \
         {ttft_pp} >= {ttft_base}"
    );
}

/// A heartbeat report that always reads as queue-bound overload, so the
/// scaler wants to grow on every tick it is allowed to.
fn overloaded(shard: ShardSpec) -> LoadReport {
    LoadReport {
        queued_prefill_tokens: 100_000,
        kv_capacity: 1 << 20,
        shard,
        ..Default::default()
    }
}

/// Drive scaler ticks against a registry, applying every `Up` by
/// registering the spawned replica with its chosen shard (what the
/// control plane's `scale_up` does).  Returns (replicas spawned,
/// max devices ever alive).
fn drive_scaler(budget: u64, ticks: usize) -> (usize, u64) {
    let shard0 = ShardSpec::new(2, 2, 1); // 4 devices per replica
    let mut reg = InstanceRegistry::new(1e9);
    reg.register(0, 0.0);
    reg.heartbeat(0, overloaded(shard0), 0.0);
    let mut next_id = 1usize;
    let ix = GlobalPrefixIndex::new();
    let mut s = FleetScaler::new(ScalerConfig {
        capacity_target_tokens: 64,
        cooldown_s: 0.1,
        max_replicas: 16,
        device_budget: budget,
        ..Default::default()
    });
    let mut max_devices = 0u64;
    for tick in 0..ticks {
        let now = tick as f64 * 0.5;
        for a in s.plan(now, &reg, &ix) {
            if let ScaleAction::Up { shard } = a {
                reg.register(next_id, now);
                reg.heartbeat(next_id, overloaded(shard), now);
                next_id += 1;
            }
        }
        let devices: u64 = reg
            .alive()
            .iter()
            .map(|&r| u64::from(reg.load(r).unwrap().devices()))
            .sum();
        max_devices = max_devices.max(devices);
        if budget > 0 {
            assert!(
                devices <= budget,
                "tick {tick}: {devices} devices alive exceed the budget of {budget}"
            );
        }
    }
    (next_id - 1, max_devices)
}

#[test]
fn autoscaler_never_exceeds_the_device_budget_at_any_scale_event() {
    // budget 8, 4-device replicas: exactly one scale-up fits, then the
    // scaler must hold even though every tick still reads overloaded
    let (spawned, max_devices) = drive_scaler(8, 32);
    assert_eq!(spawned, 1, "one 4-device spawn fills the 8-device budget");
    assert_eq!(max_devices, 8);
    // the budget (not the replica cap) is what binds: unlimited budget
    // grows the same overloaded fleet far past 8 devices
    let (spawned_free, max_free) = drive_scaler(0, 32);
    assert!(spawned_free > 1, "unlimited budget must keep scaling out");
    assert!(max_free > 8, "unlimited budget passes 8 devices: {max_free}");
}
