//! Cross-module policy integration: the paper's headline *directional*
//! claims, checked end-to-end on the cluster simulator.  These are the
//! coarse invariants every bench relies on — if one breaks, a figure's
//! shape is wrong.

use xllm::coordinator::orchestrator::{ColocationMode, ServingMode};
use xllm::coordinator::DispatchPolicy;
use xllm::metrics::Slo;
use xllm::model::{ascend_910b, ascend_910c, catalog};
use xllm::service::colocation::ColocationConfig;
use xllm::sim::cluster::{run, ClusterConfig};
use xllm::sim::{CostModel, EngineFeatures};
use xllm::util::Rng;
use xllm::workload::scenario;

fn workload(name: &str, rate: f64, horizon: f64, seed: u64) -> Vec<xllm::workload::RequestSpec> {
    let mut rng = Rng::new(seed);
    scenario(name).unwrap().generate(horizon, rate, &mut rng)
}

fn tput(cfg: ClusterConfig, w: Vec<xllm::workload::RequestSpec>) -> f64 {
    run(cfg, w).report.output_throughput()
}

#[test]
fn xllm_config_beats_vllm_config_under_load() {
    // fig14's core claim at one point: same cluster, same workload,
    // feature set alone separates the frameworks
    let w = workload("sharegpt-2048", 1.2, 60.0, 1);
    let mk = |f: EngineFeatures| {
        let mut cfg = ClusterConfig::new(2, ascend_910b(), catalog("Qwen3-8B").unwrap(), f);
        cfg.slo = Slo::tpot(0.05);
        cfg
    };
    let x = tput(mk(EngineFeatures::xllm(1)), w.clone());
    let v = tput(mk(EngineFeatures::vllm(1)), w.clone());
    let m = tput(mk(EngineFeatures::mindie(1)), w);
    assert!(x >= m * 0.99, "xllm {x} should be >= mindie {m}");
    assert!(x > v * 1.05, "xllm {x} should clearly beat vllm {v}");
}

#[test]
fn slo_attainment_ordering_under_pressure() {
    let w = workload("sharegpt-2048", 2.5, 60.0, 2);
    let slo = Slo::tpot(0.05);
    let att = |f: EngineFeatures| {
        let mut cfg = ClusterConfig::new(2, ascend_910b(), catalog("Qwen3-8B").unwrap(), f);
        cfg.slo = slo;
        run(cfg, w.clone()).report.slo_attainment(&slo)
    };
    let x = att(EngineFeatures::xllm(1));
    let v = att(EngineFeatures::vllm(1));
    assert!(x >= v, "xllm attainment {x} < vllm {v}");
}

#[test]
fn faster_hardware_gives_more_throughput() {
    // the fig14 910C-vs-910B claim
    let w = workload("sharegpt-2048", 4.0, 40.0, 3);
    let mk = |hw| {
        let mut cfg =
            ClusterConfig::new(2, hw, catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1));
        cfg.slo = Slo::tpot(0.05);
        cfg
    };
    let b = tput(mk(ascend_910b()), w.clone());
    let c = tput(mk(ascend_910c()), w);
    assert!(c > b * 1.2, "910C {c} should clearly exceed 910B {b}");
}

#[test]
fn dynamic_pd_beats_static_pd_on_bursty_traffic() {
    // fig21's mechanism: bursts need role flips
    let w = workload("azure-code", 5.0, 60.0, 4);
    let slo = Slo::interactive(2.0, 0.10);
    let mk = |dynamic| {
        let mut cfg = ClusterConfig::new(
            4,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.slo = slo;
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic };
        cfg
    };
    let dynamic = run(mk(true), w.clone());
    let static_ = run(mk(false), w);
    let da = dynamic.report.slo_attainment(&slo);
    let sa = static_.report.slo_attainment(&slo);
    assert!(
        da >= sa,
        "dynamic PD attainment {da} should be >= static {sa} on bursty traffic"
    );
    assert!(dynamic.role_flips > 0);
}

#[test]
fn slo_aware_dispatch_no_worse_than_round_robin() {
    let w = workload("azure-code", 4.0, 60.0, 5);
    let slo = Slo::interactive(2.0, 0.10);
    let mk = |dispatch| {
        let mut cfg = ClusterConfig::new(
            4,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.slo = slo;
        cfg.dispatch = dispatch;
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
        cfg
    };
    let sa = run(mk(DispatchPolicy::SloAware), w.clone()).report.slo_attainment(&slo);
    let rr = run(mk(DispatchPolicy::RoundRobin), w).report.slo_attainment(&slo);
    // deep-overload runs converge; require parity within noise (the
    // max-rate-under-SLO separation is measured by bench fig21)
    assert!(sa + 0.03 >= rr, "slo-aware {sa} << round-robin {rr}");
}

#[test]
fn colocation_preserves_online_slo_under_offline_load() {
    // fig23's mechanism: admission control caps offline decode impact
    let slo = Slo::tpot(0.08);
    let mut w = workload("sharegpt", 2.0, 30.0, 6);
    w.extend(workload("offline-docs", 3.0, 30.0, 7));
    w.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());

    let online_attainment = |mode: ColocationMode| {
        let mut cfg = ClusterConfig::new(
            4,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.slo = slo;
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
        cfg.colocation =
            Some((mode, ColocationConfig { online_tpot_s: 0.08, ..Default::default() }));
        let res = run(cfg, w.clone());
        let online: Vec<_> = res
            .report
            .outcomes
            .iter()
            .filter(|o| o.input_tokens < 2048 && o.output_tokens < 1024)
            .copied()
            .collect();
        online.iter().filter(|o| o.meets(&slo)).count() as f64 / online.len().max(1) as f64
    };
    let ooc = online_attainment(ColocationMode::XllmOoc);
    let base = online_attainment(ColocationMode::BaselinePd);
    assert!(
        ooc + 1e-9 >= base,
        "xllm-ooc online attainment {ooc} should be >= baseline {base}"
    );
}

#[test]
fn moe_model_benefits_from_full_feature_set() {
    // fig15's mechanism: EPLB + dual-stream + DP balance on DeepSeek-R1
    let mut fx = EngineFeatures::xllm(16);
    fx.dp_groups = 8;
    let mut fv = EngineFeatures::vllm(16);
    fv.dp_groups = 8;
    let cx = CostModel::new(ascend_910b(), catalog("DeepSeek-R1").unwrap(), fx);
    let cv = CostModel::new(ascend_910b(), catalog("DeepSeek-R1").unwrap(), fv);
    let sx = cx.decode_step_s(128, 128 * 2048);
    let sv = cv.decode_step_s(128, 128 * 2048);
    assert!(
        sv > sx * 2.0,
        "vllm-like MoE step {sv} should be >2x xllm {sx} (paper: up to 12x tput)"
    );
}

#[test]
fn fault_injection_preserves_goodput_majority() {
    let w = workload("sharegpt", 1.5, 40.0, 8);
    let n = w.len();
    let mut cfg = ClusterConfig::new(
        3,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.faults = vec![(8.0, 0), (15.0, 1)];
    let res = run(cfg, w);
    assert_eq!(res.report.n_requests(), n);
    assert!(
        res.report.n_completed() as f64 >= 0.85 * n as f64,
        "only {}/{} survived two faults",
        res.report.n_completed(),
        n
    );
    assert!(res.recoveries > 0);
}

#[test]
fn prefix_cache_improves_goodput_on_shared_prefix_workloads() {
    let w = workload("customer-service", 2.0, 50.0, 9);
    let slo = Slo::interactive(1.0, 0.20);
    let mk = |prefix_cache| {
        let mut cfg = ClusterConfig::new(
            2,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.slo = slo;
        cfg.prefix_cache = prefix_cache;
        cfg
    };
    let with = run(mk(true), w.clone());
    let without = run(mk(false), w);
    assert!(with.prefix_hits > 0);
    assert!(
        with.report.goodput(&slo) + 1e-9 >= without.report.goodput(&slo),
        "prefix cache should not hurt goodput"
    );
}
