//! Integration: the real PJRT serving engine (server.rs) end to end.

use std::path::Path;

use xllm::config::ServeConfig;
use xllm::server::{synth_prompt, GenRequest, Server};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn serves_batch_and_reports_metrics() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServeConfig { max_batch: 4, max_output_tokens: 8, ..ServeConfig::default() };
    let mut server = Server::new(dir, cfg).unwrap();
    for i in 0..6u64 {
        server.submit(GenRequest { id: i, prompt: synth_prompt(i, 12), max_new_tokens: 8 });
    }
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), 6);
    for r in &results {
        assert_eq!(r.tokens.len(), 8, "request {} wrong output length", r.id);
        assert!(r.ttft_s >= 0.0 && r.e2e_s >= r.ttft_s);
    }
    assert_eq!(server.report.n_completed(), 6);
    // prefill emits the first token of each request; decode generates 7 more
    assert!(server.stats.tokens_generated >= 42);
    // page management must have cycled
    assert!(server.page_stats().maps > 0);
}

#[test]
fn batch_size_independence() {
    // generations must not depend on batch bucket
    let Some(dir) = artifacts_dir() else { return };
    let mut outs = Vec::new();
    for batch in [1usize, 2, 4] {
        let cfg = ServeConfig { max_batch: batch, max_output_tokens: 10, ..ServeConfig::default() };
        let mut server = Server::new(dir, cfg).unwrap();
        for i in 0..3u64 {
            server.submit(GenRequest { id: i, prompt: synth_prompt(i, 20), max_new_tokens: 10 });
        }
        let mut results = server.run_to_completion().unwrap();
        results.sort_by_key(|r| r.id);
        outs.push(results.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>());
    }
    assert_eq!(outs[0], outs[1], "batch=1 vs batch=2 diverged");
    assert_eq!(outs[1], outs[2], "batch=2 vs batch=4 diverged");
}

#[test]
fn speculative_decoding_matches_plain_greedy() {
    // the §4.4.1 guarantee: spec decoding emits exactly the greedy stream
    let Some(dir) = artifacts_dir() else { return };
    let plain_cfg = ServeConfig { max_batch: 1, max_output_tokens: 12, ..ServeConfig::default() };
    let mut plain = Server::new(dir, plain_cfg).unwrap();
    let spec_cfg = ServeConfig {
        max_batch: 1,
        max_output_tokens: 12,
        speculative: true,
        ..ServeConfig::default()
    };
    let mut spec = Server::new(dir, spec_cfg).unwrap();
    for i in 0..2u64 {
        plain.submit(GenRequest { id: i, prompt: synth_prompt(i, 10), max_new_tokens: 12 });
        spec.submit(GenRequest { id: i, prompt: synth_prompt(i, 10), max_new_tokens: 12 });
    }
    let mut p = plain.run_to_completion().unwrap();
    let mut s = spec.run_to_completion().unwrap();
    p.sort_by_key(|r| r.id);
    s.sort_by_key(|r| r.id);
    for (a, b) in p.iter().zip(&s) {
        let n = a.tokens.len().min(b.tokens.len());
        assert_eq!(
            a.tokens[..n],
            b.tokens[..n],
            "speculative output diverged from greedy for request {}",
            a.id
        );
    }
    // the verify path must actually have run rounds
    assert!(spec.stats.spec.rounds > 0);
    assert!(spec.stats.spec.tokens_per_round() >= 1.0);
}

#[test]
fn long_prompts_truncate_gracefully() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServeConfig { max_batch: 1, max_output_tokens: 4, ..ServeConfig::default() };
    let mut server = Server::new(dir, cfg).unwrap();
    server.submit(GenRequest { id: 0, prompt: synth_prompt(0, 500), max_new_tokens: 4 });
    let results = server.run_to_completion().unwrap();
    assert_eq!(results.len(), 1);
    assert!(!results[0].tokens.is_empty());
}

#[test]
fn rejects_non_bucket_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServeConfig { max_batch: 3, ..ServeConfig::default() };
    assert!(Server::new(dir, cfg).is_err(), "batch=3 is not an AOT bucket");
}

#[test]
fn fleet_kv_chain_blocks_move_between_engines() {
    // ISSUE 5: real cross-replica KV movement at the executor seam —
    // a prefill with a shared prefix stashes its blocks, export ships
    // them, and a second engine core serves them back after import.
    let Some(dir) = artifacts_dir() else { return };
    use xllm::coordinator::orchestrator::{Executor, IterationWork, PrefillWork};
    use xllm::server::PjrtExecutor;
    use xllm::service::kvstore::{hash_chain, prefix_tokens};
    use xllm::workload::RequestSpec;

    let bt = 4u64; // tiny blocks so the tiny model's prompts cover them
    let cfg = ServeConfig {
        max_batch: 1,
        max_output_tokens: 2,
        prefix_block_tokens: bt,
        ..ServeConfig::default()
    };
    let mut spec = RequestSpec::text(0.0, 12, 2);
    spec.prefix_group = 5;
    spec.shared_prefix = 8; // two full blocks
    let chain = hash_chain(&prefix_tokens(spec.prefix_group, spec.shared_prefix), bt as usize);
    assert_eq!(chain.len(), 2);

    let mut src = PjrtExecutor::new(dir, &cfg).unwrap();
    assert!(src.export_chain(&chain).is_none(), "nothing stashed before any prefill");
    // fleet-style admission synthesizes the prompt; one prefill
    // iteration stashes the shared-prefix blocks
    src.admitted(0, &spec);
    let work = IterationWork {
        prefills: vec![PrefillWork { req: 0, tokens: spec.input_tokens, context_tokens: 0 }],
        ..Default::default()
    };
    let ticket = src.submit_iteration(0, 0.0, &work);
    let _ = src.poll_complete(ticket);
    let payload = src.export_chain(&chain).expect("prefilled prefix must be exportable");
    assert_eq!(payload.blocks.len(), 2, "both fully-covered blocks ship");
    assert!(payload.bytes() > 0);

    // the payload lands in a second engine core and is re-exportable
    // from there — the blocks physically moved between engines
    let mut dst = PjrtExecutor::new(dir, &cfg).unwrap();
    assert!(dst.export_chain(&chain).is_none());
    dst.import_chain(payload.clone());
    let back = dst.export_chain(&chain).expect("imported blocks must be resident");
    assert_eq!(back.blocks, payload.blocks, "blocks survive the hop bit-exactly");
}
