//! Integration: rust runtime loads + executes the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise).
//! The key correctness check mirrors python/tests/test_model.py: decode
//! continuing from a prefill must be self-consistent (same token stream as
//! a longer prefill), now across the full python-AOT -> HLO-text ->
//! PJRT-execute boundary.

use std::path::Path;

use xllm::runtime::{argmax, BatchKv, Runtime};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn load_and_model_dims() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(dir).expect("load runtime");
    let dims = rt.model_dims("tiny").unwrap();
    assert_eq!(dims.vocab, 256);
    assert_eq!(dims.n_layers, 2);
    assert_eq!(dims.max_seq, 160);
    assert!(rt.weights.param_count("tiny") > 100_000);
}

#[test]
fn prefill_then_decode_consistency() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(dir).expect("load runtime");
    let dims = rt.model_dims("tiny").unwrap();

    // Prefill a 10-token prompt, then decode 5 tokens greedily.
    let prompt: Vec<i32> = vec![5, 17, 200, 3, 90, 41, 7, 9, 12, 77];
    let p = rt.prefill("tiny", &prompt).expect("prefill");
    assert_eq!(p.last_logits.len(), dims.vocab);
    assert_eq!(p.bucket_s, 16); // smallest bucket >= 10

    let mut kv = BatchKv::zeros(dims, 1);
    kv.write_prefill(0, &p.k, &p.v, p.bucket_s, prompt.len());

    let mut history = prompt.clone();
    let mut token = argmax(&p.last_logits) as i32;
    history.push(token);
    let mut generated = vec![token];
    for step in 0..5 {
        let pos = [(prompt.len() + step) as i32];
        let out = rt.decode("tiny", &mut kv, &[token], &pos).expect("decode");
        token = argmax(&out.logits[..dims.vocab]) as i32;
        history.push(token);
        generated.push(token);
    }

    // Oracle: prefill over the extended history reproduces the last token.
    let oracle = rt.prefill("tiny", &history[..history.len() - 1]).expect("oracle prefill");
    let oracle_token = argmax(&oracle.last_logits) as i32;
    assert_eq!(
        oracle_token,
        *generated.last().unwrap(),
        "decode path diverged from prefill oracle"
    );
}

#[test]
fn batched_decode_no_crosstalk() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(dir).expect("load runtime");
    let dims = rt.model_dims("tiny").unwrap();

    let p1: Vec<i32> = vec![1, 2, 3, 4];
    let p2: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3];
    let o1 = rt.prefill("tiny", &p1).unwrap();
    let o2 = rt.prefill("tiny", &p2).unwrap();

    // batch of 2 (bucket b=2)
    let mut kv = BatchKv::zeros(dims, 2);
    kv.write_prefill(0, &o1.k, &o1.v, o1.bucket_s, p1.len());
    kv.write_prefill(1, &o2.k, &o2.v, o2.bucket_s, p2.len());
    let toks = [argmax(&o1.last_logits) as i32, argmax(&o2.last_logits) as i32];
    let pos = [p1.len() as i32, p2.len() as i32];
    let out = rt.decode("tiny", &mut kv, &toks, &pos).unwrap();
    let t1_batched = argmax(&out.logits[..dims.vocab]);
    let t2_batched = argmax(&out.logits[dims.vocab..2 * dims.vocab]);

    // singles
    let mut kv1 = BatchKv::zeros(dims, 1);
    kv1.write_prefill(0, &o1.k, &o1.v, o1.bucket_s, p1.len());
    let s1 = rt.decode("tiny", &mut kv1, &[toks[0]], &[pos[0]]).unwrap();
    let mut kv2 = BatchKv::zeros(dims, 1);
    kv2.write_prefill(0, &o2.k, &o2.v, o2.bucket_s, p2.len());
    let s2 = rt.decode("tiny", &mut kv2, &[toks[1]], &[pos[1]]).unwrap();

    assert_eq!(t1_batched, argmax(&s1.logits[..dims.vocab]));
    assert_eq!(t2_batched, argmax(&s2.logits[..dims.vocab]));
}

#[test]
fn verify_matches_sequential_decode() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(dir).expect("load runtime");
    let dims = rt.model_dims("tiny").unwrap();

    let prompt: Vec<i32> = vec![3, 1, 4, 1, 5, 9];
    let p = rt.prefill("tiny", &prompt).unwrap();
    let cand: Vec<i32> = vec![2, 6, 5, 3];

    let mut kv = BatchKv::zeros(dims, 1);
    kv.write_prefill(0, &p.k, &p.v, p.bucket_s, prompt.len());
    let vout = rt
        .verify("tiny", &mut kv, &cand, &[prompt.len() as i32])
        .expect("verify");
    assert_eq!(vout.m, 4);

    let mut kv2 = BatchKv::zeros(dims, 1);
    kv2.write_prefill(0, &p.k, &p.v, p.bucket_s, prompt.len());
    for (j, &c) in cand.iter().enumerate() {
        let d = rt
            .decode("tiny", &mut kv2, &[c], &[(prompt.len() + j) as i32])
            .unwrap();
        let vrow = &vout.logits[j * dims.vocab..(j + 1) * dims.vocab];
        let drow = &d.logits[..dims.vocab];
        let max_diff = vrow
            .iter()
            .zip(drow)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-3, "step {j}: verify vs decode logits differ by {max_diff}");
    }
}

#[test]
fn draft_model_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(dir).expect("load runtime");
    let dims = rt.model_dims("draft").unwrap();
    assert_eq!(dims.n_layers, 1);
    let prompt: Vec<i32> = vec![10, 20, 30];
    let p = rt.prefill("draft", &prompt);
    // draft has no prefill buckets in quick mode; decode from empty cache
    // is the supported path: seed by decoding the prompt token-by-token.
    drop(p);
    let mut kv = BatchKv::zeros(dims, 1);
    let mut token = prompt[0];
    for (i, &t) in prompt.iter().enumerate().skip(1) {
        let out = rt.decode("draft", &mut kv, &[token], &[(i - 1) as i32]).unwrap();
        assert_eq!(out.logits.len() % dims.vocab, 0);
        token = t;
    }
}

#[test]
fn encoder_and_moe_graphs_run() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(dir).expect("load runtime");
    let patches = vec![0.5f32; 16 * 32];
    let emb = rt.encode(&patches).expect("encode");
    assert_eq!(emb.len(), 16 * 64);
    assert!(emb.iter().all(|x| x.is_finite()));

    let x = vec![0.1f32; 32 * 64];
    let y = rt.moe(&x).expect("moe");
    assert_eq!(y.len(), 32 * 64);
    assert!(y.iter().all(|x| x.is_finite()));
}

#[test]
fn graph_cache_reuses_compiled_executables() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(dir).expect("load runtime");
    let prompt: Vec<i32> = vec![1, 2, 3];
    rt.prefill("tiny", &prompt).unwrap();
    let after_first = rt.graph_stats();
    rt.prefill("tiny", &prompt).unwrap();
    rt.prefill("tiny", &prompt).unwrap();
    let after_third = rt.graph_stats();
    assert_eq!(after_first.compiles, after_third.compiles, "bucket should compile once");
    assert_eq!(after_third.hits, after_first.hits + 2);
}
