//! Control-plane acceptance tests.
//!
//! ISSUE 2: cache-aware routing must beat round-robin on cluster
//! prefix-hit rate under skewed-prefix traffic, and a replica killed
//! mid-run must lose no requests — its in-flight work completes on the
//! survivors with every request accounted for.
//!
//! ISSUE 3 (elastic fleet): on the bursty `tide` scenario the
//! autoscaler must scale up into the flood and back down on the ebb
//! with zero lost requests during decommission drain, and beat the
//! fixed-size fleet on p99 TTFT; on `skewed-prefix`, planned KV
//! rebalancing must fire and keep cluster prefix hits at least at the
//! no-rebalance baseline.

use xllm::model::{ascend_910b, catalog};
use xllm::service::controlplane::{RoutePolicy, ScalerConfig};
use xllm::sim::cluster::ClusterConfig;
use xllm::sim::fleet::{run_fleet, FleetConfig};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

fn template() -> ClusterConfig {
    let mut cfg = ClusterConfig::new(
        1,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.prefix_cache = true;
    cfg
}

#[test]
fn cache_aware_routing_beats_round_robin_on_prefix_hits() {
    let mut rng = Rng::new(0xFEED);
    let w = scenario("skewed-prefix").unwrap().generate(40.0, 2.0, &mut rng);
    let n = w.len();
    assert!(n > 40, "need a meaningful sample, got {n}");

    let mut aware = FleetConfig::new(template(), 4);
    aware.control.routing = RoutePolicy::CacheAware;
    let mut rr = FleetConfig::new(template(), 4);
    rr.control.routing = RoutePolicy::RoundRobin;

    let res_aware = run_fleet(aware, w.clone());
    let res_rr = run_fleet(rr, w);

    assert_eq!(res_aware.report.n_completed(), n);
    assert_eq!(res_rr.report.n_completed(), n);
    assert!(
        res_aware.prefix_hits() > res_rr.prefix_hits(),
        "cache-aware routing must achieve a strictly higher cluster \
         prefix-hit rate: aware={} vs round-robin={} over {n} requests",
        res_aware.prefix_hits(),
        res_rr.prefix_hits()
    );
    assert!(
        res_aware.counters.routed_by_cache_hit > 0,
        "the router must actually observe hits in the global index"
    );
}

#[test]
fn replica_failure_mid_run_loses_no_requests() {
    let mut rng = Rng::new(0xBEEF);
    let w = scenario("skewed-prefix").unwrap().generate(30.0, 3.0, &mut rng);
    let n = w.len();

    let mut cfg = FleetConfig::new(template(), 3);
    cfg.control.replica_faults = vec![(10.0, 1)];
    let res = run_fleet(cfg, w);

    assert!(res.all_accounted(), "{} of {n} accounted", res.report.n_requests());
    assert_eq!(res.report.n_requests(), n, "every request has an outcome");
    assert_eq!(
        res.report.n_completed(),
        n,
        "in-flight requests of the dead replica must complete on survivors"
    );
    assert_eq!(res.counters.failovers, 1, "exactly one replica died");
    assert!(res.counters.lease_expiries >= 1, "death detected by lease expiry");
    assert!(
        res.counters.redispatched_requests > 0,
        "the victim had in-flight work at t=10: {:?}",
        res.counters
    );
    assert!(res.counters.unroutable == 0);
    assert!(!res.truncated);
    // per-replica reports partition the workload: the victim keeps its
    // pre-crash completions, survivors absorb the rest
    let per: usize = res.per_replica.iter().map(|r| r.report.n_requests()).sum();
    assert_eq!(per, n);
    assert!(
        res.per_replica[1].report.n_requests() < n,
        "the victim cannot have recorded everything"
    );
}

#[test]
fn tide_autoscaling_beats_the_fixed_fleet_it_started_as() {
    let mut rng = Rng::new(0x71DE);
    let w = scenario("tide").unwrap().generate(40.0, 6.0, &mut rng);
    let n = w.len();
    assert!(n > 100, "need a meaningful sample, got {n}");

    // fixed fleet: the size the autoscaled fleet starts at
    let fixed = FleetConfig::new(template(), 1);
    let mut elastic = FleetConfig::new(template(), 1);
    elastic.control.scaler = Some(ScalerConfig {
        capacity_target_tokens: 4096,
        min_replicas: 1,
        max_replicas: 6,
        cooldown_s: 1.0,
        ..Default::default()
    });

    let res_fixed = run_fleet(fixed, w.clone());
    let res_elastic = run_fleet(elastic, w);

    // zero lost requests, including across decommission drains
    assert!(res_elastic.all_accounted());
    assert_eq!(
        res_elastic.report.n_completed(),
        n,
        "decommission drain must lose nothing: {:?}",
        res_elastic.counters
    );
    assert_eq!(res_elastic.counters.unroutable, 0);
    assert_eq!(res_elastic.counters.failovers, 0, "planned shrink is not failover");

    // the flood forces scale-up, the ebb forces scale-down
    assert!(
        res_elastic.counters.scale_ups >= 1,
        "tide flood must grow the fleet: {:?}",
        res_elastic.counters
    );
    assert!(
        res_elastic.counters.scale_downs >= 1,
        "tide ebb must shrink the fleet: {:?}",
        res_elastic.counters
    );
    assert!(
        res_elastic.n_replicas_final < res_elastic.per_replica.len(),
        "fleet must end smaller than its peak ({} replicas ever, {} final)",
        res_elastic.per_replica.len(),
        res_elastic.n_replicas_final
    );

    // elasticity pays: tail TTFT beats the fixed fleet the run started as
    let p99_fixed = res_fixed.report.ttft_summary().percentile(99.0);
    let p99_elastic = res_elastic.report.ttft_summary().percentile(99.0);
    assert!(
        p99_elastic < p99_fixed,
        "autoscaled p99 TTFT {p99_elastic} must beat fixed-size {p99_fixed}"
    );
}

#[test]
fn skewed_prefix_planned_rebalance_fires_and_keeps_hits() {
    let mut rng = Rng::new(0x5EED);
    let w = scenario("skewed-prefix").unwrap().generate(30.0, 3.0, &mut rng);
    let n = w.len();

    // fixed-size fleet (min == max) isolates the rebalancing half of
    // the scaler from autoscaling
    let baseline = FleetConfig::new(template(), 3);
    let mut rebal = FleetConfig::new(template(), 3);
    rebal.control.scaler = Some(ScalerConfig {
        min_replicas: 3,
        max_replicas: 3,
        capacity_target_tokens: u64::MAX / 4,
        hot_prefix_routes: 5,
        ..Default::default()
    });

    let res_base = run_fleet(baseline, w.clone());
    let res_rebal = run_fleet(rebal, w);

    assert_eq!(res_base.report.n_completed(), n);
    assert_eq!(res_rebal.report.n_completed(), n);
    assert!(
        res_rebal.counters.kv_rebalances >= 1,
        "a hot prefix group concentrating on one replica must trigger a \
         planned migration: {:?}",
        res_rebal.counters
    );
    assert!(res_rebal.counters.rebalance_staging_s > 0.0, "staging cost is charged");
    assert!(
        res_rebal.prefix_hits() >= res_base.prefix_hits(),
        "planned migration must not cost cluster prefix hits: \
         with={} without={}",
        res_rebal.prefix_hits(),
        res_base.prefix_hits()
    );
}

/// Sorted, comparable key set of every completed request in a report
/// (arrival + shape identify a request across runs; f64 via to_bits for
/// exact equality).
fn completed_set(res: &xllm::service::controlplane::FleetResult) -> Vec<(u64, u64, u64)> {
    let mut v: Vec<(u64, u64, u64)> = res
        .report
        .outcomes
        .iter()
        .filter(|o| !o.failed)
        .map(|o| (o.arrival_s.to_bits(), o.input_tokens, o.output_tokens))
        .collect();
    v.sort_unstable();
    v
}

/// ISSUE 5: threaded stepping (each replica's queue drained on a worker
/// thread between control events) must agree with the deterministic
/// single-queue interleave on conservation counters — routed =
/// completed + lost, zero lost here — and on the completed-request set.
/// Per-event wall timing may differ; the virtual-time outcome may not.
fn assert_threaded_matches(scenario_name: &str, seed: u64, horizon: f64, rate: f64) {
    let mut rng = Rng::new(seed);
    let w = scenario(scenario_name).unwrap().generate(horizon, rate, &mut rng);
    let n = w.len();
    let single = run_fleet(FleetConfig::new(template(), 3), w.clone());
    let mut cfg = FleetConfig::new(template(), 3);
    cfg.control.threads = 2;
    let threaded = run_fleet(cfg, w);
    // conservation: everything routed is completed or lost, nothing lost
    assert!(single.all_accounted() && threaded.all_accounted());
    assert_eq!(single.report.n_completed(), n, "{scenario_name}: single lost requests");
    assert_eq!(threaded.report.n_completed(), n, "{scenario_name}: threaded lost requests");
    assert_eq!(threaded.counters.unroutable, 0);
    assert_eq!(threaded.counters.unroutable, single.counters.unroutable);
    assert_eq!(
        completed_set(&threaded),
        completed_set(&single),
        "{scenario_name}: completed-request sets diverged across stepping modes"
    );
}

#[test]
fn threaded_fleet_matches_single_threaded_on_tide() {
    assert_threaded_matches("tide", 0x7117EAD, 30.0, 4.0);
}

#[test]
fn threaded_fleet_matches_single_threaded_on_skewed_prefix() {
    assert_threaded_matches("skewed-prefix", 0x5EED2, 30.0, 2.5);
}

#[test]
fn fleet_types_are_send() {
    // compile-time pin: replicas (and the whole control plane) must be
    // movable onto stepping threads, and the registry/index handles
    // must be shareable across them
    use std::sync::{Arc, RwLock};
    use xllm::coordinator::orchestrator::Orchestrator;
    use xllm::service::controlplane::{ControlPlane, GlobalPrefixIndex, InstanceRegistry};
    use xllm::sim::executor::RooflineExecutor;
    fn assert_send<T: Send>() {}
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send::<Orchestrator<RooflineExecutor>>();
    assert_send::<ControlPlane<RooflineExecutor>>();
    assert_send_sync::<Arc<RwLock<InstanceRegistry>>>();
    assert_send_sync::<Arc<RwLock<GlobalPrefixIndex>>>();
}

#[test]
fn fleet_scales_over_one_replica_under_load() {
    // overload one replica, then give the fleet three: mean E2E must
    // drop substantially (the control plane actually spreads work)
    let mut rng = Rng::new(0xCAFE);
    let w = scenario("skewed-prefix").unwrap().generate(10.0, 12.0, &mut rng);
    let r1 = run_fleet(FleetConfig::new(template(), 1), w.clone());
    let r3 = run_fleet(FleetConfig::new(template(), 3), w);
    let e1 = r1.report.e2e_summary().mean();
    let e3 = r3.report.e2e_summary().mean();
    assert!(r1.all_accounted() && r3.all_accounted());
    assert!(e3 < e1 / 1.5, "3 replicas mean E2E {e3} !< {e1}/1.5");
}

#[test]
fn traced_failover_and_preemption_spans_stay_nested() {
    // ISSUE 7: every request's lifecycle spans must pair Begin/End with
    // at most one open at a time, across a mid-run replica crash (spans
    // closed at drain, re-opened on the survivor under a fresh id) and
    // any preemptions the re-dispatch causes.
    use xllm::obs::{check_nesting, InstantKind, TraceEventKind, TraceHandle};

    let mut rng = Rng::new(0xBEEF);
    let w = scenario("skewed-prefix").unwrap().generate(30.0, 3.0, &mut rng);
    let n = w.len();

    let trace = TraceHandle::recording();
    let mut cfg = FleetConfig::new(template(), 3);
    cfg.control.replica_faults = vec![(10.0, 1)];
    cfg.control.trace = trace.clone();
    let res = run_fleet(cfg, w);
    assert_eq!(res.report.n_completed(), n, "failover must lose nothing");
    assert_eq!(res.counters.failovers, 1);

    let events = trace.drain();
    assert!(!events.is_empty(), "a traced fleet run must record events");
    check_nesting(&events).expect("spans must stay well-nested across failover");

    // all three replica tracks show up, plus the control-plane track's
    // Failover instant
    for r in 0..3 {
        assert!(
            events.iter().any(|e| e.replica == Some(r)),
            "replica {r} must emit trace events"
        );
    }
    assert!(events.iter().any(|e| e.replica.is_none()
        && matches!(e.kind, TraceEventKind::Instant(InstantKind::Failover))));
    // arrivals and completions both present: full request lifecycles
    let arrivals = events
        .iter()
        .filter(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::Arrival)))
        .count();
    let completions = events
        .iter()
        .filter(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::Completion)))
        .count();
    assert!(arrivals >= n, "every routed request must emit an Arrival ({arrivals} < {n})");
    assert_eq!(completions, n, "every completed request must emit a Completion");
}

#[test]
fn traced_autoscale_run_emits_scale_instants() {
    use xllm::obs::{check_nesting, InstantKind, TraceEventKind, TraceHandle};
    use xllm::service::controlplane::ScalerConfig as SC;

    let mut rng = Rng::new(0x71DE);
    let w = scenario("tide").unwrap().generate(30.0, 4.0, &mut rng);

    let trace = TraceHandle::recording();
    let mut cfg = FleetConfig::new(template(), 1);
    cfg.control.scaler = Some(SC {
        capacity_target_tokens: 2048,
        min_replicas: 1,
        max_replicas: 4,
        cooldown_s: 0.5,
        ..Default::default()
    });
    cfg.control.trace = trace.clone();
    let res = run_fleet(cfg, w);
    assert!(res.counters.scale_ups >= 1, "tide must grow the fleet: {:?}", res.counters);

    let events = trace.drain();
    check_nesting(&events).expect("spans must stay well-nested across scaling");
    let ups = events
        .iter()
        .filter(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::ScaleUp)))
        .count();
    assert_eq!(ups as u64, res.counters.scale_ups, "one ScaleUp instant per scale-up");
    if res.counters.scale_downs > 0 {
        assert!(events
            .iter()
            .any(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::ScaleDown))));
    }
}
