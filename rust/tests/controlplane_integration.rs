//! Control-plane acceptance tests (ISSUE 2): cache-aware routing must
//! beat round-robin on cluster prefix-hit rate under skewed-prefix
//! traffic, and a replica killed mid-run must lose no requests — its
//! in-flight work completes on the survivors with every request
//! accounted for.

use xllm::model::{ascend_910b, catalog};
use xllm::service::controlplane::RoutePolicy;
use xllm::sim::cluster::ClusterConfig;
use xllm::sim::fleet::{run_fleet, FleetConfig};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

fn template() -> ClusterConfig {
    let mut cfg = ClusterConfig::new(
        1,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.prefix_cache = true;
    cfg
}

#[test]
fn cache_aware_routing_beats_round_robin_on_prefix_hits() {
    let mut rng = Rng::new(0xFEED);
    let w = scenario("skewed-prefix").unwrap().generate(40.0, 2.0, &mut rng);
    let n = w.len();
    assert!(n > 40, "need a meaningful sample, got {n}");

    let mut aware = FleetConfig::new(template(), 4);
    aware.routing = RoutePolicy::CacheAware;
    let mut rr = FleetConfig::new(template(), 4);
    rr.routing = RoutePolicy::RoundRobin;

    let res_aware = run_fleet(aware, w.clone());
    let res_rr = run_fleet(rr, w);

    assert_eq!(res_aware.report.n_completed(), n);
    assert_eq!(res_rr.report.n_completed(), n);
    assert!(
        res_aware.prefix_hits() > res_rr.prefix_hits(),
        "cache-aware routing must achieve a strictly higher cluster \
         prefix-hit rate: aware={} vs round-robin={} over {n} requests",
        res_aware.prefix_hits(),
        res_rr.prefix_hits()
    );
    assert!(
        res_aware.counters.routed_by_cache_hit > 0,
        "the router must actually observe hits in the global index"
    );
}

#[test]
fn replica_failure_mid_run_loses_no_requests() {
    let mut rng = Rng::new(0xBEEF);
    let w = scenario("skewed-prefix").unwrap().generate(30.0, 3.0, &mut rng);
    let n = w.len();

    let mut cfg = FleetConfig::new(template(), 3);
    cfg.replica_faults = vec![(10.0, 1)];
    let res = run_fleet(cfg, w);

    assert!(res.all_accounted(), "{} of {n} accounted", res.report.n_requests());
    assert_eq!(res.report.n_requests(), n, "every request has an outcome");
    assert_eq!(
        res.report.n_completed(),
        n,
        "in-flight requests of the dead replica must complete on survivors"
    );
    assert_eq!(res.counters.failovers, 1, "exactly one replica died");
    assert!(res.counters.lease_expiries >= 1, "death detected by lease expiry");
    assert!(
        res.counters.redispatched_requests > 0,
        "the victim had in-flight work at t=10: {:?}",
        res.counters
    );
    assert!(res.counters.unroutable == 0);
    assert!(!res.truncated);
    // per-replica reports partition the workload: the victim keeps its
    // pre-crash completions, survivors absorb the rest
    let per: usize = res.per_replica.iter().map(|r| r.report.n_requests()).sum();
    assert_eq!(per, n);
    assert!(
        res.per_replica[1].report.n_requests() < n,
        "the victim cannot have recorded everything"
    );
}

#[test]
fn fleet_scales_over_one_replica_under_load() {
    // overload one replica, then give the fleet three: mean E2E must
    // drop substantially (the control plane actually spreads work)
    let mut rng = Rng::new(0xCAFE);
    let w = scenario("skewed-prefix").unwrap().generate(10.0, 12.0, &mut rng);
    let r1 = run_fleet(FleetConfig::new(template(), 1), w.clone());
    let r3 = run_fleet(FleetConfig::new(template(), 3), w);
    let e1 = r1.report.e2e_summary().mean();
    let e3 = r3.report.e2e_summary().mean();
    assert!(r1.all_accounted() && r3.all_accounted());
    assert!(e3 < e1 / 1.5, "3 replicas mean E2E {e3} !< {e1}/1.5");
}
