//! Engine-policy integration tests (ISSUE 6): the §4 executor policies
//! must (a) change nothing at all when disabled — the seed behavior,
//! bit for bit — and (b) strictly help an MoE overload scenario when
//! enabled, with the policy counters proving each mechanism actually
//! ran.  Plus unit coverage for the dormant-module edges the policies
//! lean on: `graph::select_mode` bucket edges, `eplb::rebalance_round`
//! determinism, `opoverlap::allocate` degenerate loads.

use xllm::engine::eplb::{rebalance_round, static_table, ExpertStats};
use xllm::engine::opoverlap::{allocate, serial_makespan, OpLoad};
use xllm::engine::EnginePolicies;
use xllm::metrics::Slo;
use xllm::model::{ascend_910b, catalog};
use xllm::runtime::{select_mode, LaunchMode};
use xllm::sim::cluster::{run as sim_run, ClusterConfig, ClusterSim};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

fn moe_cfg(policies: EnginePolicies) -> ClusterConfig {
    let mut cfg = ClusterConfig::new(
        2,
        ascend_910b(),
        catalog("DeepSeek-R1").unwrap(),
        EngineFeatures::xllm(16),
    );
    cfg.slo = Slo::tpot(0.08);
    cfg.policies = policies;
    cfg
}

/// Heavy overload so tokens/s reflects iteration speed, not arrival
/// rate — at low load every variant would finish the same workload in
/// the same horizon and the policy deltas would be invisible.
fn overload_workload(seed: u64) -> Vec<xllm::workload::RequestSpec> {
    let mut rng = Rng::new(seed);
    scenario("sharegpt").unwrap().generate(20.0, 30.0, &mut rng)
}

#[test]
fn policies_off_is_bit_identical_to_seed_config() {
    assert!(!EnginePolicies::default().any(), "default must be all-off");
    let w = overload_workload(0x601D);
    let base = {
        let cfg = ClusterConfig::new(
            2,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        sim_run(cfg, w.clone())
    };
    let explicit_off = {
        let mut cfg = ClusterConfig::new(
            2,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.policies = EnginePolicies::default();
        sim_run(cfg, w)
    };
    assert_eq!(base.iterations, explicit_off.iterations);
    assert_eq!(base.report.n_completed(), explicit_off.report.n_completed());
    assert_eq!(
        base.report.output_throughput().to_bits(),
        explicit_off.report.output_throughput().to_bits(),
        "all-off must reproduce the seed executor bit for bit"
    );
}

#[test]
fn moe_policies_raise_throughput_without_hurting_p99_tpot() {
    let w = overload_workload(7702);
    let off = sim_run(moe_cfg(EnginePolicies::default()), w.clone());
    let on_policies = EnginePolicies {
        eplb: true,
        op_overlap: true,
        graph_mode: true,
        dp_balance: false,
    };
    let (on, exec) = ClusterSim::new(moe_cfg(on_policies)).run_with_executor(w);

    let tput_off = off.report.output_throughput();
    let tput_on = on.report.output_throughput();
    assert!(
        tput_on > tput_off,
        "EPLB + op-overlap + graph mode must raise MoE tokens/s: {tput_on} !> {tput_off}"
    );
    let p99_off = off.report.tpot_summary().percentile(99.0);
    let p99_on = on.report.tpot_summary().percentile(99.0);
    assert!(
        p99_on <= p99_off + 1e-9,
        "policies must not degrade p99 TPOT: {p99_on} !<= {p99_off}"
    );

    let c = exec.policy_counters().expect("policy state present when enabled");
    assert!(c.eplb_replans > 0, "monitor cadence should have re-planned EPLB: {c:?}");
    assert!(c.weight_switches > 0, "re-plans ride the staged weight swap: {c:?}");
    assert!(c.graph_compiles > 0, "first warm bucket must compile: {c:?}");
    assert!(c.graph_hits > 0, "repeated shapes must hit warm graphs: {c:?}");
}

#[test]
fn select_mode_handles_empty_and_oversized_buckets() {
    // empty bucket list: nothing pre-compiled, always eager
    assert_eq!(select_mode(4, &[]), LaunchMode::Eager);
    // request larger than every bucket: eager fallback
    assert_eq!(select_mode(512, &[16, 64, 256]), LaunchMode::Eager);
    // exact match: full graph
    assert_eq!(select_mode(64, &[16, 64, 256]), LaunchMode::FullGraph);
    // between buckets: padded into the smallest fitting one, even when
    // the list is unsorted
    assert_eq!(
        select_mode(17, &[256, 16, 64]),
        LaunchMode::PartialGraph { padded_from: 17, bucket: 64 }
    );
    // zero-sized request fits the smallest bucket (padded)
    assert_eq!(
        select_mode(0, &[16, 64]),
        LaunchMode::PartialGraph { padded_from: 0, bucket: 16 }
    );
}

#[test]
fn eplb_rebalance_round_is_deterministic_and_improves_skew() {
    for seed in [1u64, 42, 0xA57C] {
        let mut rng = Rng::new(seed);
        let n_experts = 64;
        let n_devices = 8;
        let mut stats = ExpertStats::new(n_experts);
        for _ in 0..4096 {
            let e = (rng.zipf(n_experts as u64, 1.2) - 1) as usize;
            stats.record(e, 8);
        }
        stats.roll_window();
        let table = static_table(n_experts, n_devices);
        let (b1, a1, t1) = rebalance_round(&stats, n_devices, n_devices, &table);
        let (b2, a2, t2) = rebalance_round(&stats, n_devices, n_devices, &table);
        assert_eq!(b1.to_bits(), b2.to_bits(), "seed {seed}: before must be deterministic");
        assert_eq!(a1.to_bits(), a2.to_bits(), "seed {seed}: after must be deterministic");
        assert_eq!(t1.placements, t2.placements, "seed {seed}: placements must repeat");
        assert!(
            a1 <= b1,
            "seed {seed}: rebalance must not worsen imbalance ({a1} !<= {b1})"
        );
    }
}

#[test]
fn opoverlap_allocate_degenerate_single_op_loads() {
    // one op per class: everything overlaps, makespan bounded by serial
    let cube = [OpLoad { workload: 10.0 }];
    let vector = [OpLoad { workload: 2.0 }];
    let serial = serial_makespan(&cube, &vector, 1.0, 1.0, 8, 4);
    let a = allocate(&cube, &vector, 1.0, 1.0, 8, 4);
    assert!(a.makespan > 0.0);
    assert!(a.makespan <= serial + 1e-12, "{} !<= {serial}", a.makespan);

    // a single cube op against no vector work at all
    let a = allocate(&cube, &[], 1.0, 1.0, 8, 4);
    assert!(a.makespan > 0.0);
    assert!(a.cube_units.iter().sum::<u32>() <= 8);

    // vanishingly small workloads must not divide by zero or hang
    let tiny = [OpLoad { workload: 1e-12 }];
    let a = allocate(&tiny, &tiny, 1.0, 1.0, 2, 2);
    assert!(a.makespan >= 0.0 && a.makespan.is_finite());
}
