//! Integration: the two-phase async executor pipeline (§4.2).
//!
//! Pins the ISSUE-4 acceptance claims: depth 1 is the default blocking
//! contract; at depth 2 with a nonzero modelled host overhead the sim
//! shows strictly lower mean TPOT on the `tide` scenario; and an
//! async-pipelined fleet loses no requests.

use xllm::model::{ascend_910b, catalog};
use xllm::sim::cluster::{run, ClusterConfig};
use xllm::sim::fleet::{run_fleet, FleetConfig};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

fn base_cfg(n_instances: usize) -> ClusterConfig {
    ClusterConfig::new(
        n_instances,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    )
}

fn tide(horizon: f64, rate: f64, seed: u64) -> Vec<xllm::workload::RequestSpec> {
    let mut rng = Rng::new(seed);
    scenario("tide").unwrap().generate(horizon, rate, &mut rng)
}

#[test]
fn depth1_is_the_default_contract() {
    // the config default must stay the blocking contract — the golden
    // fixtures pin its behavior, so an explicit depth-1 run must be
    // byte-identical to a default run
    let w = tide(20.0, 2.0, 11);
    let mut explicit = base_cfg(2);
    explicit.pipeline_depth = 1;
    let r_default = run(base_cfg(2), w.clone());
    let r_explicit = run(explicit, w);
    assert_eq!(base_cfg(2).pipeline_depth, 1, "depth 1 must be the default");
    assert_eq!(r_default.iterations, r_explicit.iterations);
    assert_eq!(r_default.events, r_explicit.events);
    assert_eq!(r_default.report.n_completed(), r_explicit.report.n_completed());
    assert!(
        (r_default.report.output_throughput() - r_explicit.report.output_throughput()).abs()
            < 1e-12
    );
}

#[test]
fn depth2_with_host_overhead_strictly_lowers_mean_tpot() {
    // the paper's §4.2 gain: the host-side planning cost of iteration
    // N+1 hides under iteration N's device time, so decode completions
    // tighten from (host + device) apart to device apart
    let w = tide(30.0, 2.0, 7);
    let n = w.len();
    assert!(n > 20, "need a meaningful sample, got {n}");
    let mut blocking = base_cfg(2);
    blocking.pipeline_depth = 1;
    blocking.host_overhead_s = 0.005;
    let mut pipelined = blocking.clone();
    pipelined.pipeline_depth = 2;
    let r1 = run(blocking, w.clone());
    let r2 = run(pipelined, w);
    assert_eq!(r1.report.n_completed(), n, "blocking run must drain");
    assert_eq!(r2.report.n_completed(), n, "pipelined run must drain");
    let t1 = r1.report.tpot_summary().mean();
    let t2 = r2.report.tpot_summary().mean();
    assert!(
        t2 < t1,
        "depth 2 must strictly lower mean TPOT with nonzero host overhead: {t2} !< {t1}"
    );
    // the hidden share is the whole point: the gain should be a real
    // fraction of the 5 ms overhead per iteration, not rounding noise
    assert!(t1 - t2 > 0.5e-3, "TPOT gain {} too small for a 5 ms host overhead", t1 - t2);
}

#[test]
fn depth2_without_host_overhead_still_completes_everything() {
    // zero host overhead: the pipeline changes event timing but must
    // not change what gets served
    let w = tide(20.0, 3.0, 13);
    let n = w.len();
    let mut cfg = base_cfg(2);
    cfg.pipeline_depth = 2;
    let r = run(cfg, w);
    assert_eq!(r.report.n_requests(), n);
    assert_eq!(r.report.n_completed(), n);
    assert!(!r.truncated);
}

#[test]
fn depth2_run_is_deterministic() {
    let w = tide(20.0, 3.0, 17);
    let mut cfg = base_cfg(2);
    cfg.pipeline_depth = 2;
    cfg.host_overhead_s = 0.003;
    let r1 = run(cfg.clone(), w.clone());
    let r2 = run(cfg, w);
    assert_eq!(r1.iterations, r2.iterations);
    assert_eq!(r1.events, r2.events);
    assert!((r1.report.output_throughput() - r2.report.output_throughput()).abs() < 1e-12);
}

#[test]
fn pipelined_fleet_on_tide_loses_no_requests() {
    // fleet scope: every replica keeps a look-ahead iteration in
    // flight; the control plane interleaves the concurrently pending
    // completions and still accounts for every request
    let w = tide(30.0, 4.0, 19);
    let n = w.len();
    let mut template = base_cfg(1);
    template.prefix_cache = true;
    template.pipeline_depth = 2;
    template.host_overhead_s = 0.002;
    let res = run_fleet(FleetConfig::new(template, 2), w);
    assert!(res.all_accounted(), "{} of {n} accounted", res.report.n_requests());
    assert_eq!(res.report.n_completed(), n, "zero lost requests at depth 2");
    assert_eq!(res.counters.unroutable, 0);
    assert!(!res.truncated);
}
