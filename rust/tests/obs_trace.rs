//! Observability acceptance tests (ISSUE 7).
//!
//! The hard constraint: a sink-off run is *bit-identical* to one that
//! never knew about tracing — emission is a single `Option` check and
//! the phase-start stamps are pure bookkeeping scheduling never reads.
//! On top of that: traced runs keep every request's lifecycle spans
//! well-nested across chunked prefill, faults, and recovery, and the
//! two exporters emit loadable Chrome trace JSON and well-formed
//! Prometheus text whose counters reconcile with the ServingReport.

use xllm::obs::{
    check_nesting, chrome_trace_json, prometheus_text, InstantKind, MetricsRegistry, SpanPhase,
    TraceEventKind, TraceHandle,
};
use xllm::model::{ascend_910b, catalog};
use xllm::sim::cluster::{ClusterConfig, ClusterSim};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::{scenario, RequestSpec};

fn cfg(n: usize) -> ClusterConfig {
    let mut c = ClusterConfig::new(
        n,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    c.prefix_cache = true;
    c
}

fn workload(name: &str, horizon: f64, rate: f64, seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    scenario(name).unwrap().generate(horizon, rate, &mut rng)
}

/// Everything float-valued the report derives, as raw bits.
fn report_bits(res: &xllm::sim::cluster::SimResult) -> Vec<u64> {
    let r = &res.report;
    let mut bits = vec![
        r.ttft_summary().mean().to_bits(),
        r.ttft_summary().percentile(99.0).to_bits(),
        r.tpot_summary().mean().to_bits(),
        r.e2e_summary().mean().to_bits(),
        r.output_throughput().to_bits(),
        r.total_throughput().to_bits(),
    ];
    for (_, mut s) in r.phase_summaries() {
        bits.push(s.mean().to_bits());
        bits.push(s.percentile(99.0).to_bits());
    }
    bits
}

#[test]
fn tracing_off_is_bit_identical_to_tracing_on() {
    let w = workload("sharegpt", 20.0, 2.0, 0xB17);
    assert!(w.len() > 20, "need a meaningful sample");

    let off = ClusterSim::new(cfg(2)).run(w.clone());

    let trace = TraceHandle::recording();
    let mut sim = ClusterSim::new(cfg(2));
    sim.set_trace(trace.clone());
    let on = sim.run(w);

    let events = trace.drain();
    assert!(!events.is_empty(), "the recording run must actually record");

    // every derived float, bit for bit — recording must perturb nothing
    assert_eq!(report_bits(&off), report_bits(&on));
    assert_eq!(off.report.n_completed(), on.report.n_completed());
    assert_eq!(off.iterations, on.iterations);
    assert_eq!(off.events, on.events);
    assert_eq!(off.per_instance, on.per_instance);
    assert_eq!(off.prefix_hits, on.prefix_hits);
    assert_eq!(off.preemptions, on.preemptions);
    assert_eq!(off.migrations, on.migrations);
}

#[test]
fn traced_lifecycles_nest_and_cover_every_request() {
    let w = workload("sharegpt", 20.0, 2.0, 0xB17);
    let n = w.len();
    let trace = TraceHandle::recording();
    let mut sim = ClusterSim::new(cfg(2));
    sim.set_trace(trace.clone());
    let res = sim.run(w);
    assert_eq!(res.report.n_completed(), n);

    let events = trace.drain();
    check_nesting(&events).expect("all spans must pair and nest");

    let arrivals = events
        .iter()
        .filter(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::Arrival)))
        .count();
    let completions = events
        .iter()
        .filter(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::Completion)))
        .count();
    assert_eq!(arrivals, n, "one Arrival per request");
    assert_eq!(completions, n, "one Completion per completed request");
    // every request opens a queue span and runs prefill + decode
    for phase in [SpanPhase::Queue, SpanPhase::Prefill, SpanPhase::Decode] {
        let begins = events
            .iter()
            .filter(|e| matches!(e.kind, TraceEventKind::Begin(p) if p == phase))
            .count();
        assert!(begins >= n, "{} Begin({phase:?}) < {n} requests", begins);
    }
    // iteration-utilization spans ride the instance tracks
    assert!(events
        .iter()
        .any(|e| matches!(e.kind, TraceEventKind::Complete(SpanPhase::Iteration, _))));
}

#[test]
fn traced_faults_and_recovery_keep_spans_nested() {
    // instance faults force mid-flight span closure + re-queue; the
    // async pipeline (depth 2) adds look-ahead clones on top
    let mut c = cfg(2);
    c.faults = vec![(0.5, 0), (2.0, 1)];
    c.pipeline_depth = 2;
    let w = workload("sharegpt", 15.0, 2.0, 0xFA);
    let n = w.len();

    let trace = TraceHandle::recording();
    let mut sim = ClusterSim::new(c);
    sim.set_trace(trace.clone());
    let res = sim.run(w);
    assert!(res.recoveries >= 1, "faults must actually fire");
    assert_eq!(res.report.n_completed(), n, "recovery must lose nothing");

    let events = trace.drain();
    check_nesting(&events).expect("spans must stay nested across fault + recovery");
    assert!(events
        .iter()
        .any(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::Fault))));
    assert!(events
        .iter()
        .any(|e| matches!(e.kind, TraceEventKind::Instant(InstantKind::Recovery))));
}

#[test]
fn chrome_trace_export_is_loadable_json() {
    let trace = TraceHandle::recording();
    let mut sim = ClusterSim::new(cfg(2));
    sim.set_trace(trace.clone());
    sim.run(workload("sharegpt", 10.0, 2.0, 0xC2));

    let events = trace.drain();
    let json = chrome_trace_json(&events);
    assert!(json.starts_with("{"), "object root");
    assert!(json.trim_end().ends_with("}"));
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"process_name\""), "track metadata present");
    assert!(json.contains("\"ph\":\"X\""), "complete events present");
    assert!(json.contains("\"ph\":\"i\""), "instant events present");
    // crude structural balance check (no serde in the crate set)
    let opens = json.matches('{').count();
    let closes = json.matches('}').count();
    assert_eq!(opens, closes, "braces must balance");
    assert_eq!(json.matches('[').count(), json.matches(']').count());
}

#[test]
fn prometheus_export_is_well_formed_and_reconciles() {
    let trace = TraceHandle::recording();
    let mut sim = ClusterSim::new(cfg(2));
    sim.set_trace(trace.clone());
    let (res, exec) = sim.run_with_executor(workload("sharegpt", 10.0, 2.0, 0xC2));

    let mut reg = MetricsRegistry::new();
    res.report.export_metrics(&mut reg);
    res.export_metrics(&mut reg);
    exec.policy_counters().unwrap_or_default().export_metrics(&mut reg);
    let text = prometheus_text(&reg);

    // exposition shape: every line is a comment or `name value`
    for line in text.lines() {
        assert!(
            line.starts_with('#') || line.split_whitespace().count() == 2,
            "malformed exposition line: {line:?}"
        );
    }
    assert!(text.contains("# TYPE xllm_ttft_seconds histogram"));
    assert!(text.contains("_bucket{le=\"+Inf\"}"));
    // counters reconcile with the serving report
    let n = res.report.n_requests();
    assert!(text.contains(&format!("xllm_requests_total {n}")));
    assert_eq!(reg.counter("xllm_requests_total"), n as u64);
    assert_eq!(reg.counter("xllm_iterations_total"), res.iterations);
    assert_eq!(
        reg.counter("xllm_requests_completed_total"),
        res.report.n_completed() as u64
    );
}
