//! Golden-seed parity: the orchestrator-driven simulator must reproduce
//! the exact `SimResult` counters for fixed-seed configurations, so any
//! future change to the shared lifecycle state machine that alters
//! scheduling behavior — however subtly — trips this test instead of
//! silently skewing every paper figure.
//!
//! The golden fixture (`tests/golden/parity_counters.txt`) is written on
//! the first run (or when `UPDATE_GOLDEN=1`) and compared byte-exactly
//! afterwards.  The orchestrator extraction itself was a pure code
//! motion of the pre-refactor `ClusterSim` loop — event order, RNG draw
//! order, and arithmetic were preserved — so the pinned counters carry
//! the pre-refactor behavior forward.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use xllm::coordinator::orchestrator::{ColocationMode, ServingMode};
use xllm::metrics::Slo;
use xllm::model::{ascend_910b, catalog};
use xllm::service::colocation::ColocationConfig;
use xllm::sim::cluster::{run, ClusterConfig, SimResult};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

const GOLDEN_PATH: &str = "tests/golden/parity_counters.txt";

fn counters_line(name: &str, res: &SimResult) -> String {
    let mut s = String::new();
    write!(
        s,
        "{name} requests={} completed={} iterations={} events={} role_flips={} \
         preemptions={} migrations={} recoveries={} prefix_hits={} truncated={} tput_utok_s={}",
        res.report.n_requests(),
        res.report.n_completed(),
        res.iterations,
        res.events,
        res.role_flips,
        res.preemptions,
        res.migrations,
        res.recoveries,
        res.prefix_hits,
        res.truncated,
        // throughput pinned to micro-token/s resolution: integral, so the
        // fixture is byte-stable yet still catches timing drift
        (res.report.output_throughput() * 1e6).round() as u64,
    )
    .unwrap();
    s
}

fn colocated_case() -> String {
    let mut cfg = ClusterConfig::new(
        2,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.prefix_cache = true;
    cfg.colocation = Some((
        ColocationMode::XllmOoc,
        ColocationConfig { online_tpot_s: 0.08, ..Default::default() },
    ));
    cfg.slo = Slo::tpot(0.08);
    let mut rng = Rng::new(0x601D);
    let mut w = scenario("customer-service").unwrap().generate(30.0, 1.5, &mut rng);
    w.extend(scenario("offline-docs").unwrap().generate(30.0, 1.0, &mut rng));
    w.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
    counters_line("colocated", &run(cfg, w))
}

fn disaggregated_dynamic_case() -> String {
    let mut cfg = ClusterConfig::new(
        4,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
    cfg.slo = Slo::interactive(1.0, 0.1);
    let mut rng = Rng::new(7702);
    let w = scenario("azure-code").unwrap().generate(45.0, 3.0, &mut rng);
    counters_line("disaggregated-dynamic", &run(cfg, w))
}

/// The async pipeline at depth 2 with a nonzero modelled host overhead
/// — pins the look-ahead planner and the pipelined timeline, the way
/// the first two cases pin the (depth-1 ≡ blocking) lifecycle.
fn pipelined_case() -> String {
    let mut cfg = ClusterConfig::new(
        2,
        ascend_910b(),
        catalog("Qwen3-8B").unwrap(),
        EngineFeatures::xllm(1),
    );
    cfg.prefix_cache = true;
    cfg.pipeline_depth = 2;
    cfg.host_overhead_s = 0.002;
    let mut rng = Rng::new(0xA57C);
    let w = scenario("customer-service").unwrap().generate(30.0, 1.5, &mut rng);
    counters_line("colocated-pipelined-d2", &run(cfg, w))
}

#[test]
fn golden_seed_counters_are_stable() {
    let got = format!(
        "{}\n{}\n{}\n",
        colocated_case(),
        disaggregated_dynamic_case(),
        pipelined_case()
    );
    let path = Path::new(GOLDEN_PATH);
    let bless = std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists();
    if bless {
        // CI guard: a missing fixture must FAIL in CI instead of
        // self-blessing — otherwise any behavior change silently becomes
        // the new baseline (GOLDEN_STRICT is set by the workflow).
        assert!(
            std::env::var("GOLDEN_STRICT").is_err() || std::env::var("UPDATE_GOLDEN").is_ok(),
            "golden fixture {GOLDEN_PATH} is not committed — run \
             UPDATE_GOLDEN=1 cargo test locally and commit the file"
        );
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, &got).unwrap();
        eprintln!("blessed golden counters:\n{got}");
        return;
    }
    let want = fs::read_to_string(path).unwrap();
    assert_eq!(
        got, want,
        "SimResult counters diverged from the golden fixture — the \
         orchestrator lifecycle changed behavior.  If intentional, rerun \
         with UPDATE_GOLDEN=1 and commit the new fixture."
    );
}

#[test]
fn golden_runs_are_internally_deterministic() {
    // the parity pin is only meaningful if back-to-back runs agree
    assert_eq!(colocated_case(), colocated_case());
    assert_eq!(disaggregated_dynamic_case(), disaggregated_dynamic_case());
    assert_eq!(pipelined_case(), pipelined_case());
}
