//! The real serving engine: batched greedy generation over the AOT
//! PJRT artifacts — the end-to-end composition of all three layers.
//!
//! Since the orchestrator refactor, [`Server`] is a thin façade: request
//! admission, bucketed prefill ordering, continuous batched decode, and
//! completion are all driven by the shared
//! [`coordinator::orchestrator::Orchestrator`] — the same request
//! lifecycle state machine the cluster simulator runs — while
//! [`PjrtExecutor`] implements the two-phase [`Executor`] contract over
//! the PJRT runtime (xTensor slot/page assignment, plain or speculative
//! decode).
//!
//! At pipeline depth 1 (the default) the engine state lives inline and
//! every submit completes in place, reporting measured wall time — the
//! pre-async blocking behavior, so virtual time *is* wall time.  At
//! depth ≥ 2 the engine core moves onto a dedicated worker thread:
//! `submit_iteration` hands the planned work over a channel and returns
//! immediately with a cost-model estimate, so the orchestrator's
//! host-side planning for iteration N+1 genuinely overlaps iteration
//! N's execution (§4.2); `poll_complete` joins the measured result at
//! the completion event.  Python never runs here; the artifacts were
//! lowered once by `make artifacts`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ServeConfig;
use crate::coordinator::orchestrator::{
    Executor, IterationOutcome, IterationTicket, IterationWork, KvChainPayload, Orchestrator,
    OrchestratorConfig, ServingMode,
};
use crate::coordinator::{BatchConfig, DispatchPolicy, InstanceId, RequestId};
use crate::engine::specdecode::{accept_greedy, SpecConfig, SpecStats};
use crate::engine::xtensor::{MapStats, XTensorManager};
use crate::metrics::ServingReport;
use crate::model::{cpu_host, ModelSpec};
use crate::obs::{self, InstantKind, MetricsRegistry, TraceHandle};
use crate::runtime::{
    argmax, select_mode, BatchKv, GraphStats, LaunchMode, ModelDims, PrefillOutput, Runtime,
};
use crate::service::fleet::ReplicaFactory;
use crate::service::kvstore::{hash_chain, prefix_tokens};
use crate::sim::executor::model_device_s;
use crate::sim::roofline::{CostModel, EngineFeatures};
use crate::workload::RequestSpec;

/// A generation request for the real engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_s: f64,
    pub e2e_s: f64,
}

/// Aggregate server statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub prefills: u64,
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub spec: SpecStats,
    /// Prefix-chain KV blocks stashed from local prefills (§3.4 —
    /// exportable to peer replicas in a fleet).
    pub kv_blocks_stashed: u64,
    /// KV blocks shipped to a peer replica ([`Executor::export_chain`]).
    pub kv_blocks_exported: u64,
    /// KV blocks landed from a peer replica ([`Executor::import_chain`]).
    pub kv_blocks_imported: u64,
    /// Prefill prefix regions served from migrated blocks (the imported
    /// copy overwrote the recomputed region — consistency with the
    /// fleet's staged KV).
    pub kv_block_restores: u64,
    /// Batches whose shape matched an AOT bucket exactly (§4.2
    /// graph-mode selection: one full-graph launch, no padding).
    pub graph_full_hits: u64,
    /// Batches launched through a larger bucket with padded work.
    pub graph_padded_hits: u64,
    /// Batches no bucket fits: per-op eager dispatch fallback.
    pub graph_eager_fallbacks: u64,
    /// Measured decode iterations fed back into the roofline cost
    /// model's learned factors (§3.1 online calibration).
    pub calibration_updates: u64,
}

impl ServerStats {
    /// Publish under the stable `xllm_server_*` metric names.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc("xllm_server_prefills_total", self.prefills);
        reg.inc("xllm_server_decode_steps_total", self.decode_steps);
        reg.inc("xllm_server_tokens_generated_total", self.tokens_generated);
        reg.inc("xllm_server_spec_rounds_total", self.spec.rounds);
        reg.inc("xllm_server_spec_proposed_total", self.spec.proposed);
        reg.inc("xllm_server_spec_accepted_total", self.spec.accepted);
        reg.inc("xllm_server_spec_bonus_total", self.spec.bonus);
        reg.inc("xllm_server_kv_blocks_stashed_total", self.kv_blocks_stashed);
        reg.inc("xllm_server_kv_blocks_exported_total", self.kv_blocks_exported);
        reg.inc("xllm_server_kv_blocks_imported_total", self.kv_blocks_imported);
        reg.inc("xllm_server_kv_block_restores_total", self.kv_block_restores);
        reg.inc("xllm_server_graph_full_hits_total", self.graph_full_hits);
        reg.inc("xllm_server_graph_padded_hits_total", self.graph_padded_hits);
        reg.inc("xllm_server_graph_eager_fallbacks_total", self.graph_eager_fallbacks);
        reg.inc("xllm_server_calibration_updates_total", self.calibration_updates);
    }

    /// The old struct view over the registry names (tests pin the
    /// round-trip so neither side drifts).
    pub fn from_registry(reg: &MetricsRegistry) -> ServerStats {
        ServerStats {
            prefills: reg.counter("xllm_server_prefills_total"),
            decode_steps: reg.counter("xllm_server_decode_steps_total"),
            tokens_generated: reg.counter("xllm_server_tokens_generated_total"),
            spec: SpecStats {
                rounds: reg.counter("xllm_server_spec_rounds_total"),
                proposed: reg.counter("xllm_server_spec_proposed_total"),
                accepted: reg.counter("xllm_server_spec_accepted_total"),
                bonus: reg.counter("xllm_server_spec_bonus_total"),
            },
            kv_blocks_stashed: reg.counter("xllm_server_kv_blocks_stashed_total"),
            kv_blocks_exported: reg.counter("xllm_server_kv_blocks_exported_total"),
            kv_blocks_imported: reg.counter("xllm_server_kv_blocks_imported_total"),
            kv_block_restores: reg.counter("xllm_server_kv_block_restores_total"),
            graph_full_hits: reg.counter("xllm_server_graph_full_hits_total"),
            graph_padded_hits: reg.counter("xllm_server_graph_padded_hits_total"),
            graph_eager_fallbacks: reg.counter("xllm_server_graph_eager_fallbacks_total"),
            calibration_updates: reg.counter("xllm_server_calibration_updates_total"),
        }
    }
}

/// A request admitted into a batch slot.
#[derive(Debug)]
struct SlotSeq {
    /// Caller-supplied request id (RequestId is the orchestrator's).
    orig_id: u64,
    /// Current cache position (tokens written - 1).
    pos: usize,
    generated: Vec<i32>,
    last_token: i32,
    max_new: usize,
    /// Virtual (= wall) time the first token was produced.
    first_token_s: f64,
}

/// Bound on chain-store blocks per engine core (FIFO eviction past it):
/// a long fleet run over many distinct prefixes must not grow host
/// memory without limit.
const MAX_CHAIN_BLOCKS: usize = 1024;

/// A submitted request the orchestrator has not prefilled yet.
#[derive(Debug, Clone)]
struct PendingReq {
    orig_id: u64,
    prompt: Vec<i32>,
    max_new: usize,
    /// Prefix hash chain of the prompt's shared prefix (empty when the
    /// request shares nothing).  Prefilling stashes these blocks' KV
    /// into the engine's chain store for cross-replica export.
    chain: Vec<u64>,
}

/// End-of-run snapshot handed back by the engine core (inline or over
/// the worker channel).
struct Collected {
    results: Vec<GenResult>,
    stats: ServerStats,
    page_stats: MapStats,
    graph_stats: GraphStats,
    /// First runtime error, rendered with its context chain.
    error: Option<String>,
}

/// The engine state that actually touches the PJRT runtime.  Lives
/// inline at pipeline depth 1; moves whole onto a dedicated worker
/// thread at depth ≥ 2.  (Everything here is plain host memory — the
/// vendored xla stub and the bookkeeping maps — so the core is `Send`;
/// when swapping in the real `xla-rs`, its PJRT client is owned by this
/// core alone and crosses threads exactly once, at spawn.)
struct EngineCore {
    rt: Runtime,
    dims: ModelDims,
    draft_dims: Option<ModelDims>,
    speculative: bool,
    /// Verify-bucket proposal length (speculative only).
    spec_m: usize,
    kv: BatchKv,
    draft_kv: Option<BatchKv>,
    slots: Vec<Option<SlotSeq>>,
    slot_of: HashMap<RequestId, usize>,
    pages: XTensorManager,
    pending: HashMap<RequestId, PendingReq>,
    /// Tokens emitted per decode request in the iteration just executed.
    emitted: HashMap<RequestId, u64>,
    /// Prefix-chain KV store: block hash → flat KV data (K then V, each
    /// `[L, H, block_tokens, Dh]`).  Filled by local prefills and by
    /// imports from peer replicas; the export side of real §3.4
    /// cross-replica KV movement.  Bounded by [`MAX_CHAIN_BLOCKS`] with
    /// FIFO eviction (`chain_order`), so a long run over many distinct
    /// prefixes cannot grow host memory without limit.
    chains: HashMap<u64, Vec<f32>>,
    /// Insertion order of `chains` entries (FIFO eviction queue).
    chain_order: VecDeque<u64>,
    /// Blocks that arrived via [`EngineCore::import_chain`]: only these
    /// overwrite a recomputed prefill region (a locally stashed block is
    /// bit-identical to the recomputation — copying it back would be
    /// pure overhead).
    imported: HashSet<u64>,
    /// Prefix-chain block granularity in tokens.
    block_tokens: usize,
    /// Largest prefill bucket (prompt truncation bound).
    max_prompt: usize,
    /// `cfg.policies.graph_mode`: classify every batch shape against
    /// the AOT buckets (§4.2) and count the launch modes in `stats`.
    graph_policy: bool,
    /// Sorted prefill bucket sizes (dynamic dim `s`) from the manifest.
    prefill_buckets: Vec<u64>,
    /// Sorted decode bucket sizes (dynamic dim `b`) from the manifest.
    decode_buckets: Vec<u64>,
    stats: ServerStats,
    results: Vec<GenResult>,
    /// First runtime error; surfaced by the façade after the run (the
    /// Executor trait is infallible — the lifecycle drains regardless).
    error: Option<anyhow::Error>,
}

impl EngineCore {
    fn new(artifacts: &Path, cfg: &ServeConfig) -> Result<EngineCore> {
        let rt = Runtime::load(artifacts)?;
        let dims = rt.model_dims("tiny")?;
        // batch size must match an AOT decode bucket exactly
        let bucket = rt
            .manifest
            .decode_bucket("tiny", cfg.max_batch as u64)
            .with_context(|| format!("no decode bucket fits max_batch={}", cfg.max_batch))?
            .dim("b")
            .unwrap() as usize;
        if bucket != cfg.max_batch {
            bail!(
                "max_batch={} must equal an AOT decode bucket (nearest is {bucket})",
                cfg.max_batch
            );
        }
        let (draft_dims, draft_kv, spec_m) = if cfg.speculative {
            let dd = rt.model_dims("draft")?;
            let vb = rt
                .manifest
                .verify_bucket("tiny", cfg.max_batch as u64)
                .context("speculative decoding needs a verify bucket >= max_batch")?;
            let m = vb.dim("m").context("verify bucket missing m dim")? as usize;
            (Some(dd), Some(BatchKv::zeros(dd, cfg.max_batch)), m)
        } else {
            (None, None, 0)
        };
        let kv = BatchKv::zeros(dims, cfg.max_batch);
        // xTensor pages back the batch slots: one slot = max_seq tokens
        let page_tokens = 16u64;
        let total_pages =
            (cfg.max_batch as u64 * dims.max_seq as u64).div_ceil(page_tokens) as u32;
        let max_prompt = {
            let graphs = rt.manifest.graphs_of(crate::runtime::GraphKind::Prefill, "tiny");
            graphs.iter().filter_map(|g| g.dim("s")).max().unwrap_or(0) as usize
        };
        let mut prefill_buckets: Vec<u64> = rt
            .manifest
            .graphs_of(crate::runtime::GraphKind::Prefill, "tiny")
            .iter()
            .filter_map(|g| g.dim("s"))
            .collect();
        prefill_buckets.sort_unstable();
        let mut decode_buckets: Vec<u64> = rt
            .manifest
            .graphs_of(crate::runtime::GraphKind::Decode, "tiny")
            .iter()
            .filter_map(|g| g.dim("b"))
            .collect();
        decode_buckets.sort_unstable();
        Ok(EngineCore {
            rt,
            dims,
            draft_dims,
            speculative: cfg.speculative,
            spec_m,
            kv,
            draft_kv,
            slots: (0..cfg.max_batch).map(|_| None).collect(),
            slot_of: HashMap::new(),
            pages: XTensorManager::new(total_pages, page_tokens, dims.max_seq as u64),
            pending: HashMap::new(),
            emitted: HashMap::new(),
            chains: HashMap::new(),
            chain_order: VecDeque::new(),
            imported: HashSet::new(),
            block_tokens: cfg.prefix_block_tokens.max(1) as usize,
            max_prompt,
            graph_policy: cfg.policies.graph_mode,
            prefill_buckets,
            decode_buckets,
            stats: ServerStats::default(),
            results: Vec::new(),
            error: None,
        })
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Count the §4.2 launch-mode decision for one batch shape.
    fn count_launch_mode(stats: &mut ServerStats, requested: u64, buckets: &[u64]) {
        match select_mode(requested, buckets) {
            LaunchMode::FullGraph => stats.graph_full_hits += 1,
            LaunchMode::PartialGraph { .. } => stats.graph_padded_hits += 1,
            LaunchMode::Eager => stats.graph_eager_fallbacks += 1,
        }
    }

    /// Prefill one request into a free slot (first token included).
    fn run_prefill(&mut self, req: RequestId, now_s: f64, iter_start: Instant) -> Result<()> {
        let pend = self
            .pending
            .remove(&req)
            .ok_or_else(|| anyhow!("prefill for unknown request {req}"))?;
        let slot = self.free_slot().ok_or_else(|| anyhow!("no free batch slot"))?;
        if self.graph_policy {
            Self::count_launch_mode(
                &mut self.stats,
                pend.prompt.len() as u64,
                &self.prefill_buckets,
            );
        }
        let out = self.rt.prefill("tiny", &pend.prompt)?;
        self.stats.prefills += 1;
        self.kv.write_prefill(slot, &out.k, &out.v, out.bucket_s, pend.prompt.len());
        // §3.4 real KV movement: stash the prompt's shared-prefix blocks
        // (exportable to peer replicas) and land any blocks already in
        // the chain store — e.g. imported from a peer — over the
        // recomputed region, so the slot serves the migrated copy
        if !pend.chain.is_empty() {
            self.sync_chain_blocks(&pend.chain, slot, &out, pend.prompt.len());
        }
        // xTensor session: pages for the prompt + expected output
        self.pages.open_with_reuse(req, (pend.prompt.len() + pend.max_new) as u64);
        self.pages.extend(req, pend.prompt.len() as u64);
        let first = argmax(&out.last_logits) as i32;
        // seed the draft cache with the prompt (token-by-token decode
        // through the cheap draft model) so proposals are conditioned
        // on the real context
        if let Some(dd) = self.draft_dims {
            // single-slot temp cache (b=1 bucket) so other slots'
            // draft caches are untouched, then copy into the batch
            let mut tmp = BatchKv::zeros(dd, 1);
            for (t, &tok) in pend.prompt.iter().enumerate() {
                self.rt.decode("draft", &mut tmp, &[tok], &[t as i32])?;
            }
            let dkv = self.draft_kv.as_mut().unwrap();
            dkv.clear_slot(slot);
            dkv.copy_slot_from(slot, &tmp, 0, pend.prompt.len());
        }
        self.slots[slot] = Some(SlotSeq {
            orig_id: pend.orig_id,
            pos: pend.prompt.len(),
            generated: vec![first],
            last_token: first,
            max_new: pend.max_new.max(1),
            first_token_s: now_s + iter_start.elapsed().as_secs_f64(),
        });
        self.slot_of.insert(req, slot);
        Ok(())
    }

    /// Insert one block into the chain store, FIFO-evicting past the
    /// cap (evicted imports also lose their `imported` mark).
    fn store_chain_block(&mut self, hash: u64, data: Vec<f32>) {
        if self.chains.insert(hash, data).is_none() {
            self.chain_order.push_back(hash);
        }
        while self.chains.len() > MAX_CHAIN_BLOCKS {
            let Some(old) = self.chain_order.pop_front() else { break };
            self.chains.remove(&old);
            self.imported.remove(&old);
        }
    }

    /// Per-block chain-store sync at prefill time: blocks imported from
    /// a peer overwrite the recomputed slot region (the slot serves the
    /// migrated copy); blocks not yet held are stashed from the freshly
    /// computed KV.  Locally stashed blocks are left alone — causal
    /// attention makes prefix KV deterministic in the prefix tokens, so
    /// re-copying them over an identical recomputation is pure
    /// overhead.  Only blocks fully covered by the prompt participate —
    /// a partial block has no complete KV.
    fn sync_chain_blocks(
        &mut self,
        chain: &[u64],
        slot: usize,
        out: &PrefillOutput,
        prompt_len: usize,
    ) {
        let d = self.dims;
        let bt = self.block_tokens;
        for (bi, &hash) in chain.iter().enumerate() {
            let start = bi * bt;
            let end = start + bt;
            if end > prompt_len {
                break;
            }
            if self.imported.contains(&hash) {
                let n = d.n_layers * d.n_heads * bt * d.d_head;
                if let Some(data) = self.chains.get(&hash) {
                    if data.len() >= 2 * n {
                        let (k, v) = data.split_at(n);
                        self.kv.write_range(slot, start, bt, &k[..n], &v[..n]);
                        self.stats.kv_block_restores += 1;
                    }
                }
            } else if !self.chains.contains_key(&hash) {
                let mut data = Vec::with_capacity(2 * d.n_layers * d.n_heads * bt * d.d_head);
                for kv in [&out.k, &out.v] {
                    for l in 0..d.n_layers {
                        for h in 0..d.n_heads {
                            for s in start..end {
                                let src = ((l * d.n_heads + h) * out.bucket_s + s) * d.d_head;
                                data.extend_from_slice(&kv[src..src + d.d_head]);
                            }
                        }
                    }
                }
                self.store_chain_block(hash, data);
                self.stats.kv_blocks_stashed += 1;
            }
        }
    }

    /// Export the chain-store blocks backing `chain` (longest stored
    /// prefix) for the control plane to ship to a peer replica.
    fn export_chain(&mut self, chain: &[u64]) -> Option<KvChainPayload> {
        let mut blocks = Vec::new();
        for &hash in chain {
            match self.chains.get(&hash) {
                Some(data) => blocks.push((hash, data.clone())),
                None => break, // only a contiguous stored prefix ships
            }
        }
        if blocks.is_empty() {
            return None;
        }
        self.stats.kv_blocks_exported += blocks.len() as u64;
        Some(KvChainPayload { blocks })
    }

    /// Land blocks exported by a peer replica's engine core (payload
    /// moved in — no copies beyond the original export).
    fn import_chain(&mut self, payload: KvChainPayload) {
        for (hash, data) in payload.blocks {
            if !self.chains.contains_key(&hash) {
                self.store_chain_block(hash, data);
                self.imported.insert(hash);
                self.stats.kv_blocks_imported += 1;
            }
        }
    }

    /// One plain decode iteration over the scheduled slots.
    fn run_decode(&mut self, reqs: &[RequestId]) -> Result<()> {
        let b = self.slots.len();
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        // a look-ahead plan (pipeline depth ≥ 2) may still reference a
        // request whose slot was already released — the async-scheduling
        // bubble; it simply does not join the batch
        let live: Vec<RequestId> =
            reqs.iter().copied().filter(|r| self.slot_of.contains_key(r)).collect();
        for r in &live {
            let slot = self.slot_of[r];
            let seq = self.slots[slot].as_ref().unwrap();
            tokens[slot] = seq.last_token;
            pos[slot] = seq.pos as i32;
        }
        if live.is_empty() {
            return Ok(());
        }
        if self.graph_policy {
            Self::count_launch_mode(&mut self.stats, live.len() as u64, &self.decode_buckets);
        }
        let out = self.rt.decode("tiny", &mut self.kv, &tokens, &pos)?;
        self.stats.decode_steps += 1;
        for r in &live {
            let slot = self.slot_of[r];
            let seq = self.slots[slot].as_mut().unwrap();
            // max_new is clamped at admission, but keep the cache-bound
            // guard: never write KV past max_seq
            if seq.generated.len() >= seq.max_new || seq.pos + 1 >= self.dims.max_seq {
                self.emitted.insert(*r, 0);
                continue;
            }
            let logits = &out.logits[slot * self.dims.vocab..(slot + 1) * self.dims.vocab];
            let next = argmax(logits) as i32;
            seq.pos += 1;
            self.pages.extend(*r, 1);
            self.pages.premap(*r, 1); // async pre-mapping (§4.3)
            seq.generated.push(next);
            seq.last_token = next;
            self.stats.tokens_generated += 1;
            self.emitted.insert(*r, 1);
        }
        Ok(())
    }

    /// One speculative round: draft proposes m tokens, verify scores them.
    fn run_spec(&mut self, reqs: &[RequestId]) -> Result<()> {
        let b = self.slots.len();
        let m = self.spec_m;
        let draft_dims = self.draft_dims.context("draft dims")?;
        // same bubble rule as run_decode: slot-less requests sit out
        let live: Vec<RequestId> =
            reqs.iter().copied().filter(|r| self.slot_of.contains_key(r)).collect();
        if live.is_empty() {
            return Ok(());
        }
        let active: Vec<usize> = live.iter().map(|r| self.slot_of[r]).collect();

        // 1) draft proposes m tokens autoregressively (cheap model)
        let mut proposals = vec![vec![0i32; m]; b];
        {
            let dkv = self.draft_kv.as_mut().unwrap();
            let mut cur: Vec<i32> = (0..b)
                .map(|i| self.slots[i].as_ref().map(|s| s.last_token).unwrap_or(0))
                .collect();
            let mut dpos: Vec<i32> = (0..b)
                .map(|i| self.slots[i].as_ref().map(|s| s.pos as i32).unwrap_or(0))
                .collect();
            for j in 0..m {
                let dpos_clamped: Vec<i32> = dpos
                    .iter()
                    .map(|&p| p.min(draft_dims.max_seq as i32 - 1))
                    .collect();
                let out = self.rt.decode("draft", dkv, &cur, &dpos_clamped)?;
                for &i in &active {
                    let logits =
                        &out.logits[i * draft_dims.vocab..(i + 1) * draft_dims.vocab];
                    proposals[i][j] = argmax(logits) as i32;
                    cur[i] = proposals[i][j];
                    dpos[i] += 1;
                }
            }
        }

        // 2) target verifies candidate tokens [last_token ++ proposals[..m-1]]
        //    shifted: we score the m tokens starting at each seq's pos
        let mut vtokens = vec![0i32; b * m];
        let mut vpos = vec![0i32; b];
        for &i in &active {
            let seq = self.slots[i].as_ref().unwrap();
            vtokens[i * m] = seq.last_token;
            for j in 1..m {
                vtokens[i * m + j] = proposals[i][j - 1];
            }
            vpos[i] = seq.pos as i32;
        }
        let vout = self.rt.verify("tiny", &mut self.kv, &vtokens, &vpos)?;
        self.stats.decode_steps += 1;

        // 3) greedy acceptance per sequence
        for (r, &i) in live.iter().zip(&active) {
            let seq = self.slots[i].as_mut().unwrap();
            let target_argmax: Vec<i32> = (0..m)
                .map(|j| {
                    let row = &vout.logits
                        [(i * m + j) * self.dims.vocab..(i * m + j + 1) * self.dims.vocab];
                    argmax(row) as i32
                })
                .collect();
            let draft_prefix: Vec<i32> = proposals[i][..m - 1].to_vec();
            let (n_acc, emitted) = accept_greedy(&draft_prefix, &target_argmax);
            self.stats.spec.rounds += 1;
            self.stats.spec.proposed += draft_prefix.len() as u64;
            self.stats.spec.accepted += n_acc as u64;
            self.stats.spec.bonus += 1;
            let mut n_emitted = 0u64;
            for &t in &emitted {
                if seq.generated.len() >= seq.max_new || seq.pos + 1 >= self.dims.max_seq {
                    break;
                }
                seq.pos += 1;
                self.pages.extend(*r, 1);
                seq.generated.push(t);
                seq.last_token = t;
                self.stats.tokens_generated += 1;
                n_emitted += 1;
            }
            // NOTE: the verify pass wrote KV for all m candidates; the
            // rejected suffix slots get overwritten by later positions —
            // harmless because attention masks beyond `pos`.
            self.emitted.insert(*r, n_emitted.max(1));
        }
        Ok(())
    }

    /// Execute one planned iteration; returns measured device seconds.
    fn execute(&mut self, work: &IterationWork, now_s: f64) -> f64 {
        let t0 = Instant::now();
        if self.error.is_none() {
            let mut step = || -> Result<()> {
                for p in &work.prefills {
                    self.run_prefill(p.req, now_s, t0)?;
                }
                let decode_reqs: Vec<RequestId> = work.decodes.iter().map(|d| d.req).collect();
                if !decode_reqs.is_empty() {
                    if self.speculative {
                        self.run_spec(&decode_reqs)?;
                    } else {
                        self.run_decode(&decode_reqs)?;
                    }
                }
                Ok(())
            };
            if let Err(e) = step() {
                self.error = Some(e);
            }
        }
        t0.elapsed().as_secs_f64()
    }

    /// Emission counts of the iteration just executed (drained so the
    /// next iteration starts clean).
    fn drain_emitted(&mut self) -> Vec<(RequestId, u64)> {
        self.emitted.drain().collect()
    }

    /// A request left the orchestrator: release its slot and record the
    /// generation.
    fn finish_request(&mut self, req: RequestId, now_s: f64) {
        self.pending.remove(&req);
        if let Some(slot) = self.slot_of.remove(&req) {
            if let Some(seq) = self.slots[slot].take() {
                self.results.push(GenResult {
                    id: seq.orig_id,
                    tokens: seq.generated,
                    ttft_s: seq.first_token_s,
                    e2e_s: now_s,
                });
                self.pages.close(req); // pages -> Reusable (§4.3)
                self.kv.clear_slot(slot);
            }
        }
    }

    /// End-of-run snapshot: drains results, takes the error, copies the
    /// counters.
    fn collect(&mut self) -> Collected {
        Collected {
            results: std::mem::take(&mut self.results),
            stats: self.stats,
            page_stats: self.pages.stats,
            graph_stats: self.rt.graph_stats(),
            error: self.error.take().map(|e| format!("{e:#}")),
        }
    }
}

/// Commands the façade sends to the engine worker thread (depth ≥ 2).
enum Cmd {
    /// Admit a not-yet-prefilled request into the pending set.
    Queue { req: RequestId, pend: PendingReq },
    /// Execute one planned iteration; a `Reply::Done` follows.
    Submit { seq: u64, now_s: f64, work: IterationWork },
    /// A request left the orchestrator (slot release, result record).
    Finished { req: RequestId, now_s: f64 },
    /// Export a prefix chain's KV blocks; a `Reply::Chain` follows.
    Export { chain: Vec<u64> },
    /// Land KV blocks shipped from a peer replica (fire-and-forget).
    Import(KvChainPayload),
    /// End-of-run snapshot request; a `Reply::Collect` follows.
    Collect,
}

/// Replies from the engine worker thread.
enum Reply {
    Done { seq: u64, device_s: f64, emitted: Vec<(RequestId, u64)> },
    Chain(Option<KvChainPayload>),
    Collect(Box<Collected>),
}

fn worker_loop(mut core: EngineCore, rx: mpsc::Receiver<Cmd>, tx: mpsc::Sender<Reply>) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Queue { req, pend } => {
                core.pending.insert(req, pend);
            }
            Cmd::Submit { seq, now_s, work } => {
                let device_s = core.execute(&work, now_s);
                let emitted = core.drain_emitted();
                if tx.send(Reply::Done { seq, device_s, emitted }).is_err() {
                    break; // façade hung up
                }
            }
            Cmd::Finished { req, now_s } => core.finish_request(req, now_s),
            Cmd::Export { chain } => {
                if tx.send(Reply::Chain(core.export_chain(&chain))).is_err() {
                    break;
                }
            }
            Cmd::Import(payload) => core.import_chain(payload),
            Cmd::Collect => {
                if tx.send(Reply::Collect(Box::new(core.collect()))).is_err() {
                    break;
                }
            }
        }
    }
}

/// Channel ends + join handle for the engine worker thread.
struct WorkerHandle {
    tx: Option<mpsc::Sender<Cmd>>,
    rx: mpsc::Receiver<Reply>,
    join: Option<thread::JoinHandle<()>>,
    /// `Done` replies drained while waiting for a non-`Done` reply, kept
    /// in arrival (= submission) order for the next `poll_complete`.
    done_buf: VecDeque<(u64, f64, Vec<(RequestId, u64)>)>,
}

impl WorkerHandle {
    fn send(&self, cmd: Cmd) {
        if let Some(tx) = &self.tx {
            // a send error means the worker died; the failure surfaces
            // via the disconnect on the next receive
            let _ = tx.send(cmd);
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.tx.take(); // hang up: the worker loop exits on disconnect
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Where the engine core lives.
enum Backend {
    /// Depth 1: inline, submit completes in place (blocking contract).
    Inline(Box<EngineCore>),
    /// Depth ≥ 2: on a worker thread, submissions genuinely overlap the
    /// orchestrator's host-side planning.
    Worker(WorkerHandle),
}

/// The [`Executor`] over the real PJRT runtime (see module docs).
pub struct PjrtExecutor {
    cost: CostModel,
    dims: ModelDims,
    spec_m: usize,
    /// Cost-model stand-in for the speculative multipliers when
    /// estimating submitted iterations (worker backend only).
    est_spec: Option<SpecConfig>,
    max_prompt: usize,
    /// Output-token cap for fleet-admitted requests.
    max_output: usize,
    /// Prefix-chain granularity for fleet-admitted requests.
    block_tokens: u64,
    backend: Backend,
    seq: u64,
    /// Outcome of the most recent inline submit, completed at poll.
    inline_last: Option<(u64, IterationOutcome)>,
    /// Emission counts from the most recently completed iteration.
    emitted: HashMap<RequestId, u64>,
    /// Requests with a prompt already queued (either a caller-supplied
    /// one via [`Self::queue_request`] or a fleet-synthesized one via
    /// [`Executor::admitted`]); admitted never overwrites these.
    queued: HashSet<RequestId>,
    /// Decode-only batch shapes in flight on the worker backend, keyed
    /// by submission seq: (n_seqs, kv_tokens, submit time) for §3.1
    /// calibration when the measured time joins at `poll_complete`.
    pending_shapes: HashMap<u64, (u64, u64, f64)>,
    /// Measured decode iterations fed into `CostModel::learn_decode`.
    calibration_updates: u64,
    /// Lifecycle trace emission (off by default; calibration instants).
    trace: TraceHandle,
    /// The worker channel broke (thread died); reported at collect.
    worker_lost: bool,
}

/// The shape fed to §3.1 calibration: decode-only iterations (mixed
/// iterations fold prefill time into the measurement and would skew the
/// learned decode factors).
fn decode_only_shape(work: &IterationWork) -> Option<(u64, u64)> {
    if work.decodes.is_empty() || !work.prefills.is_empty() || !work.encodes.is_empty() {
        return None;
    }
    let kv: u64 = work.decodes.iter().map(|d| d.context_tokens).sum();
    Some((work.decodes.len() as u64, kv))
}

impl PjrtExecutor {
    /// Load the AOT artifacts and build the engine (inline at pipeline
    /// depth 1; on a dedicated worker thread at depth ≥ 2).  Public so
    /// the fleet runtime can stamp real-engine replicas
    /// ([`PjrtReplicaFactory`]).
    pub fn new(artifacts: &Path, cfg: &ServeConfig) -> Result<PjrtExecutor> {
        let core = EngineCore::new(artifacts, cfg)?;
        let dims = core.dims;
        let spec_m = core.spec_m;
        let max_prompt = core.max_prompt;
        // stand-in cost model for the orchestrator's heuristics (single
        // instance: only relative magnitudes matter)
        let cost = CostModel::new(
            cpu_host(),
            tiny_model_spec(dims),
            EngineFeatures::xllm(1).with_shard(cfg.shard),
        );
        let est_spec = if cfg.speculative && spec_m > 0 {
            Some(SpecConfig { m: spec_m, acceptance: 0.75 })
        } else {
            None
        };
        let backend = if cfg.pipeline_depth >= 2 {
            let (cmd_tx, cmd_rx) = mpsc::channel();
            let (rep_tx, rep_rx) = mpsc::channel();
            let join = thread::Builder::new()
                .name("pjrt-engine".into())
                .spawn(move || worker_loop(core, cmd_rx, rep_tx))
                .context("spawning the PJRT engine worker thread")?;
            Backend::Worker(WorkerHandle {
                tx: Some(cmd_tx),
                rx: rep_rx,
                join: Some(join),
                done_buf: VecDeque::new(),
            })
        } else {
            Backend::Inline(Box::new(core))
        };
        Ok(PjrtExecutor {
            cost,
            dims,
            spec_m,
            est_spec,
            max_prompt,
            max_output: cfg.max_output_tokens,
            block_tokens: cfg.prefix_block_tokens.max(1),
            backend,
            seq: 0,
            inline_last: None,
            emitted: HashMap::new(),
            queued: HashSet::new(),
            pending_shapes: HashMap::new(),
            calibration_updates: 0,
            trace: TraceHandle::off(),
            worker_lost: false,
        })
    }

    /// Admit a not-yet-prefilled request.
    fn queue_request(&mut self, req: RequestId, pend: PendingReq) {
        self.queued.insert(req);
        match &mut self.backend {
            Backend::Inline(core) => {
                core.pending.insert(req, pend);
            }
            Backend::Worker(h) => h.send(Cmd::Queue { req, pend }),
        }
    }

    /// Block until the next `Done` reply (buffering is handled by the
    /// caller for out-of-band requests).  Returns None when the worker
    /// died.
    fn recv_done(h: &mut WorkerHandle) -> Option<(u64, f64, Vec<(RequestId, u64)>)> {
        if let Some(d) = h.done_buf.pop_front() {
            return Some(d);
        }
        loop {
            match h.rx.recv() {
                Ok(Reply::Done { seq, device_s, emitted }) => {
                    return Some((seq, device_s, emitted))
                }
                // late replies: nothing waits on them
                Ok(Reply::Collect(_)) | Ok(Reply::Chain(_)) => continue,
                Err(_) => return None,
            }
        }
    }

    /// End-of-run snapshot from whichever backend holds the core.
    fn collect(&mut self) -> Collected {
        match &mut self.backend {
            Backend::Inline(core) => core.collect(),
            Backend::Worker(h) => {
                h.send(Cmd::Collect);
                loop {
                    match h.rx.recv() {
                        Ok(Reply::Collect(c)) => return *c,
                        Ok(Reply::Chain(_)) => continue, // stale export reply
                        Ok(Reply::Done { seq, device_s, emitted }) => {
                            h.done_buf.push_back((seq, device_s, emitted));
                        }
                        Err(_) => {
                            self.worker_lost = true;
                            return Collected {
                                results: Vec::new(),
                                stats: ServerStats::default(),
                                page_stats: MapStats::default(),
                                graph_stats: GraphStats::default(),
                                error: Some("engine worker thread died".to_string()),
                            };
                        }
                    }
                }
            }
        }
    }
}

impl Executor for PjrtExecutor {
    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
    }

    fn submit_iteration(
        &mut self,
        instance: InstanceId,
        now_s: f64,
        work: &IterationWork,
    ) -> IterationTicket {
        self.seq += 1;
        let seq = self.seq;
        match &mut self.backend {
            Backend::Inline(core) => {
                // blocking contract: execute in place, measured wall time
                let device_s = core.execute(work, now_s);
                for (r, n) in core.drain_emitted() {
                    self.emitted.insert(r, n);
                }
                // §3.1: the measurement is already in hand — calibrate
                // the roofline's learned factors on the spot
                if let Some((n, kv)) = decode_only_shape(work) {
                    self.cost.learn_decode(n, kv, device_s);
                    self.calibration_updates += 1;
                    self.trace.instant(now_s, Some(instance), None, InstantKind::Calibration);
                }
                let out = IterationOutcome { host_s: 0.0, device_s, ramp_s: 0.0 };
                self.inline_last = Some((seq, out));
                IterationTicket { instance, seq, est: out }
            }
            Backend::Worker(h) => {
                h.send(Cmd::Submit { seq, now_s, work: work.clone() });
                if let Some((n, kv)) = decode_only_shape(work) {
                    self.pending_shapes.insert(seq, (n, kv, now_s));
                }
                // the estimate orders the completion event in virtual
                // time; the measured span arrives at poll_complete
                let device_s = model_device_s(&self.cost, self.est_spec, work);
                IterationTicket {
                    instance,
                    seq,
                    est: IterationOutcome { host_s: 0.0, device_s, ramp_s: 0.0 },
                }
            }
        }
    }

    fn poll_complete(&mut self, ticket: IterationTicket) -> IterationOutcome {
        match &mut self.backend {
            Backend::Inline(_) => {
                let (seq, out) = self.inline_last.take().unwrap_or((ticket.seq, ticket.est));
                debug_assert_eq!(seq, ticket.seq, "inline completion out of order");
                out
            }
            Backend::Worker(h) => match Self::recv_done(h) {
                Some((seq, device_s, emitted)) => {
                    debug_assert_eq!(seq, ticket.seq, "worker completion out of order");
                    for (r, n) in emitted {
                        self.emitted.insert(r, n);
                    }
                    // §3.1: the measured span just joined — feed it back
                    // so later submit estimates track the real engine
                    if let Some((n, kv, t)) = self.pending_shapes.remove(&seq) {
                        self.cost.learn_decode(n, kv, device_s);
                        self.calibration_updates += 1;
                        self.trace.instant(t, Some(ticket.instance), None, InstantKind::Calibration);
                    }
                    IterationOutcome { host_s: 0.0, device_s, ramp_s: 0.0 }
                }
                None => {
                    // worker died: fall back to the estimate so the
                    // lifecycle drains; the loss surfaces at collect
                    self.worker_lost = true;
                    ticket.est
                }
            },
        }
    }

    fn decode_emission(&mut self, _instance: InstanceId, req: RequestId) -> u64 {
        // after a runtime error the default of 1 token/iteration lets the
        // lifecycle drain so the error can surface
        self.emitted.remove(&req).unwrap_or(1).max(1)
    }

    fn admitted(&mut self, req: RequestId, spec: &RequestSpec) {
        // the serving façade queues real prompts before the orchestrator
        // starts — never clobber those
        if !self.queued.insert(req) {
            return;
        }
        // fleet path: synthesize a deterministic prompt for the routed
        // spec.  The shared prefix is group-deterministic, so requests
        // of one prefix group genuinely share prompt tokens — and
        // therefore KV blocks — across replicas.
        let len = (spec.input_tokens as usize).clamp(1, self.max_prompt.max(1));
        let shared = (spec.shared_prefix.min(spec.input_tokens) as usize).min(len);
        let mut prompt = synth_prompt(0x9E3779B9u64 ^ spec.prefix_group, shared);
        let tail_seed = req.wrapping_mul(0x9E3779B97F4A7C15) ^ spec.input_tokens;
        prompt.extend(synth_prompt(tail_seed, len - shared));
        let headroom = 1 + self.spec_m;
        let max_new = (spec.output_tokens as usize)
            .min(self.dims.max_seq.saturating_sub(len + headroom))
            .min(self.max_output)
            .max(1);
        let chain = if spec.shared_prefix > 0 {
            hash_chain(
                &prefix_tokens(spec.prefix_group, spec.shared_prefix),
                self.block_tokens as usize,
            )
        } else {
            Vec::new()
        };
        let pend = PendingReq { orig_id: req, prompt, max_new, chain };
        match &mut self.backend {
            Backend::Inline(core) => {
                core.pending.insert(req, pend);
            }
            Backend::Worker(h) => h.send(Cmd::Queue { req, pend }),
        }
    }

    fn export_chain(&mut self, chain: &[u64]) -> Option<KvChainPayload> {
        match &mut self.backend {
            Backend::Inline(core) => core.export_chain(chain),
            Backend::Worker(h) => {
                h.send(Cmd::Export { chain: chain.to_vec() });
                loop {
                    match h.rx.recv() {
                        Ok(Reply::Chain(p)) => return p,
                        Ok(Reply::Done { seq, device_s, emitted }) => {
                            h.done_buf.push_back((seq, device_s, emitted));
                        }
                        Ok(Reply::Collect(_)) => continue, // stale: nothing waits on it
                        Err(_) => {
                            self.worker_lost = true;
                            return None;
                        }
                    }
                }
            }
        }
    }

    fn import_chain(&mut self, payload: KvChainPayload) {
        match &mut self.backend {
            Backend::Inline(core) => core.import_chain(payload),
            Backend::Worker(h) => h.send(Cmd::Import(payload)),
        }
    }

    fn kv_transfer_s(&self, _tokens: u64) -> f64 {
        0.0 // single instance: no PD handoff on this backend (yet)
    }

    fn finished(&mut self, req: RequestId, now_s: f64) {
        match &mut self.backend {
            Backend::Inline(core) => core.finish_request(req, now_s),
            Backend::Worker(h) => h.send(Cmd::Finished { req, now_s }),
        }
    }

    fn debug_check(&self) -> Result<(), String> {
        // xTensor page-table consistency, swept by the orchestrator's
        // debug assertions at every iteration boundary.  KNOWN GAP: the
        // worker backend skips the per-iteration sweep — a synchronous
        // round-trip here would serialize the very overlap the worker
        // exists for — so page-table corruption at depth ≥ 2 only
        // surfaces through execution errors; depth-1 runs and the test
        // suite keep the full sweep.
        match &self.backend {
            Backend::Inline(core) => core.pages.check_invariants(),
            Backend::Worker(_) => Ok(()),
        }
    }
}

/// Orchestrator policy for one PJRT engine replica: single instance,
/// colocated, whole-prompt prefill (the AOT graphs cannot resume a
/// partial chunk), physical batch slots capped at the decode bucket.
/// Shared by the serving façade ([`Server`]) and the fleet factory
/// ([`PjrtReplicaFactory`]) so both paths run the identical lifecycle
/// policy.
fn engine_orchestrator_config(
    cfg: &ServeConfig,
    dims: ModelDims,
    prefix_cache: bool,
) -> OrchestratorConfig {
    OrchestratorConfig {
        n_instances: 1,
        mode: ServingMode::Colocated,
        dispatch: DispatchPolicy::SloAware,
        slo: cfg.slo,
        batch: BatchConfig {
            max_decode_seqs: cfg.max_batch,
            // whole-prompt prefill: the AOT graphs cannot resume a
            // partial chunk, so never split a prompt across iterations
            token_budget: u64::MAX,
            kv_capacity_tokens: (cfg.max_batch * dims.max_seq) as u64,
            // a prefilled request occupies a physical batch slot
            max_seqs: cfg.max_batch,
            ..BatchConfig::default()
        },
        monitor_interval_s: 1.0,
        pipeline_depth: cfg.pipeline_depth.max(1),
        prefix_cache,
        prefix_block_tokens: cfg.prefix_block_tokens.max(1),
        ..OrchestratorConfig::default()
    }
}

/// [`ReplicaFactory`] stamping N real PJRT engine replicas for the
/// shared fleet runtime (`xllm fleet --backend pjrt`): each replica is
/// a full [`Orchestrator`] over its own [`PjrtExecutor`] — its own
/// runtime, KV batch, xTensor pages, and (at pipeline depth ≥ 2) its
/// own engine worker thread.  Construction preflights the artifacts
/// once, so later builds (including mid-run scale-up spawns) can only
/// fail on environmental loss of the artifact directory.
pub struct PjrtReplicaFactory {
    artifacts: PathBuf,
    cfg: ServeConfig,
    /// Engine limits from the preflight probe (largest prefill bucket,
    /// cache length, verify headroom).
    max_prompt: usize,
    max_seq: usize,
    spec_m: usize,
    /// The preflight engine, handed out as the first replica so the
    /// probe's artifact load (and, at depth ≥ 2, its worker thread) is
    /// not wasted.
    probe: Option<PjrtExecutor>,
}

impl PjrtReplicaFactory {
    /// Validate the artifacts load and return the factory.
    pub fn new(artifacts: &Path, cfg: ServeConfig) -> Result<PjrtReplicaFactory> {
        let probe = PjrtExecutor::new(artifacts, &cfg)
            .with_context(|| format!("loading PJRT artifacts from {}", artifacts.display()))?;
        Ok(PjrtReplicaFactory {
            artifacts: artifacts.to_path_buf(),
            max_prompt: probe.max_prompt,
            max_seq: probe.dims.max_seq,
            spec_m: probe.spec_m,
            probe: Some(probe),
            cfg,
        })
    }

    /// Clamp scenario specs to the engine's AOT limits — prompts to the
    /// largest prefill bucket, outputs to the cache headroom and the
    /// configured cap — so the orchestrator's planner (KV accounting,
    /// chunk sizes) sees the same request shape the engine actually
    /// runs.  Mirrors the clamping [`Executor::admitted`] applies to
    /// the synthesized prompt.
    pub fn clamp_workload(&self, specs: Vec<RequestSpec>) -> Vec<RequestSpec> {
        let headroom = 1 + self.spec_m;
        specs
            .into_iter()
            .map(|mut s| {
                s.input_tokens = s.input_tokens.clamp(1, (self.max_prompt as u64).max(1));
                s.shared_prefix = s.shared_prefix.min(s.input_tokens);
                let cap = self.max_seq.saturating_sub(s.input_tokens as usize + headroom);
                s.output_tokens = s
                    .output_tokens
                    .min(cap as u64)
                    .min(self.cfg.max_output_tokens as u64)
                    .max(1);
                s
            })
            .collect()
    }
}

impl ReplicaFactory for PjrtReplicaFactory {
    type Exec = PjrtExecutor;

    fn build(&mut self, id: usize) -> Orchestrator<PjrtExecutor> {
        // startup builds fail fast: the preflight already proved the
        // artifacts load, so a failure here is immediate and fatal
        self.try_build(id).expect("preflighted PJRT artifacts must load")
    }

    fn try_build(&mut self, _id: usize) -> Option<Orchestrator<PjrtExecutor>> {
        let exec = match self.probe.take() {
            Some(probe) => probe, // first build reuses the preflight engine
            None => match PjrtExecutor::new(&self.artifacts, &self.cfg) {
                Ok(exec) => exec,
                Err(e) => {
                    // mid-run spawn declined (e.g. the artifacts dir went
                    // away): the fleet keeps serving at its current size
                    obs::log::info(format!("# pjrt replica spawn declined: {e:#}"));
                    return None;
                }
            },
        };
        let ocfg = engine_orchestrator_config(&self.cfg, exec.dims, true);
        Some(Orchestrator::new(ocfg, exec))
    }
}

/// Rough dense-transformer spec matching the AOT tiny model, for the
/// orchestrator's scheduling heuristics.
fn tiny_model_spec(dims: ModelDims) -> ModelSpec {
    let d = dims.d_model as f64;
    let params = 12.0 * dims.n_layers as f64 * d * d + dims.vocab as f64 * d;
    ModelSpec {
        name: "tiny-aot",
        params,
        active_params: params,
        n_layers: dims.n_layers as u32,
        d_model: dims.d_model as u32,
        n_heads: dims.n_heads as u32,
        n_kv_heads: dims.n_heads as u32,
        head_dim: dims.d_head as u32,
        is_moe: false,
        n_experts: 0,
        experts_per_tok: 0,
    }
}

/// The batched PJRT serving engine: a façade over the shared orchestrator.
pub struct Server {
    exec: Option<PjrtExecutor>,
    dims: ModelDims,
    cfg: ServeConfig,
    queue: Vec<GenRequest>,
    pub stats: ServerStats,
    pub report: ServingReport,
    page_stats: MapStats,
    graph_stats: GraphStats,
    trace: TraceHandle,
}

impl Server {
    /// Load artifacts and prepare a decode batch of `cfg.max_batch` slots.
    pub fn new(artifacts: &Path, cfg: ServeConfig) -> Result<Server> {
        let exec = PjrtExecutor::new(artifacts, &cfg)?;
        let dims = exec.dims;
        Ok(Server {
            exec: Some(exec),
            dims,
            cfg,
            queue: Vec::new(),
            stats: ServerStats::default(),
            report: ServingReport::new(),
            page_stats: MapStats::default(),
            graph_stats: GraphStats::default(),
            trace: TraceHandle::off(),
        })
    }

    pub fn model_dims(&self) -> ModelDims {
        self.dims
    }

    /// Install a lifecycle trace sink; the next [`Self::run_to_completion`]
    /// emits request spans and engine instants into it.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push(req);
    }

    /// Run until the queue and all slots drain; returns the generations.
    ///
    /// All queued requests enter the orchestrator at virtual time 0 (so
    /// TTFT includes time spent queued behind a full batch), are
    /// prefilled FCFS as slots free up, and decode continuously.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let mut exec = self.exec.take().expect("executor present");
        let max_prompt = exec.max_prompt;
        // reserve headroom for the speculative verify window
        let seq_headroom = 1 + exec.spec_m;

        // validate before draining so a bad request rejects the batch
        // without losing its neighbours
        if let Some(bad) = self.queue.iter().find(|r| r.prompt.is_empty()) {
            let id = bad.id;
            self.exec = Some(exec);
            bail!("empty prompt for request {id}");
        }

        let mut specs: Vec<RequestSpec> = Vec::new();
        for (idx, req) in self.queue.drain(..).enumerate() {
            // chunk-free fallback: truncate to the largest bucket
            // (chunked prefill over multiple buckets is exercised in
            // the simulator; the real tiny model caps prompts)
            let prompt = if req.prompt.len() > max_prompt {
                req.prompt[req.prompt.len() - max_prompt..].to_vec()
            } else {
                req.prompt.clone()
            };
            let max_new = req
                .max_new_tokens
                .min(self.dims.max_seq.saturating_sub(prompt.len() + seq_headroom))
                .min(self.cfg.max_output_tokens)
                .max(1);
            let rid = idx as RequestId;
            specs.push(RequestSpec::text(0.0, prompt.len() as u64, max_new as u64));
            exec.queue_request(
                rid,
                PendingReq { orig_id: req.id, prompt, max_new, chain: Vec::new() },
            );
        }

        let ocfg = engine_orchestrator_config(&self.cfg, self.dims, false);
        let mut orch = Orchestrator::new(ocfg, exec);
        orch.set_trace(self.trace.clone());
        let (res, mut exec) = orch.run(specs);
        let collected = exec.collect();
        let worker_lost = exec.worker_lost;
        self.report = res.report;
        self.stats = collected.stats;
        // calibration lives façade-side (the cost model never crosses
        // the worker channel) — stitch it into the snapshot
        self.stats.calibration_updates = exec.calibration_updates;
        self.page_stats = collected.page_stats;
        self.graph_stats = collected.graph_stats;
        let results = collected.results;
        self.exec = Some(exec);
        if let Some(e) = collected.error {
            return Err(anyhow!("{e}"));
        }
        if worker_lost {
            bail!("engine worker thread died mid-run");
        }
        Ok(results)
    }

    /// Page-manager statistics (map/unmap/reuse counters), as of the
    /// last completed run.
    pub fn page_stats(&self) -> crate::engine::xtensor::MapStats {
        self.page_stats
    }

    /// Graph-cache statistics, as of the last completed run.
    pub fn graph_stats(&self) -> crate::runtime::GraphStats {
        self.graph_stats
    }
}

/// Deterministic synthetic prompt (byte-level "tokens").
pub fn synth_prompt(seed: u64, len: usize) -> Vec<i32> {
    let mut rng = crate::util::Rng::new(seed.wrapping_add(1));
    (0..len).map(|_| (rng.range(1, 255)) as i32).collect()
}
