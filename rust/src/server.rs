//! The real serving engine: batched greedy generation over the AOT
//! PJRT artifacts — the end-to-end composition of all three layers.
//!
//! This is the path the `quickstart` example and the `serve` CLI run:
//! request admission → bucketed prefill → xTensor slot/page assignment →
//! continuous batched decode (optionally speculative via the draft model)
//! → completion, with TTFT/TPOT metrics recorded exactly as the paper
//! reports them.  Python never runs here; the artifacts were lowered once
//! by `make artifacts`.

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ServeConfig;
use crate::engine::specdecode::{accept_greedy, SpecStats};
use crate::engine::xtensor::XTensorManager;
use crate::metrics::{RequestOutcome, ServingReport};
use crate::runtime::{argmax, BatchKv, ModelDims, Runtime};

/// A generation request for the real engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub ttft_s: f64,
    pub e2e_s: f64,
}

#[derive(Debug)]
struct ActiveSeq {
    id: u64,
    /// Current cache position (tokens written - 1).
    pos: usize,
    prompt_len: usize,
    generated: Vec<i32>,
    last_token: i32,
    max_new: usize,
    admitted_at: Instant,
    first_token_at: Option<Instant>,
}

/// Aggregate server statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerStats {
    pub prefills: u64,
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub spec: SpecStats,
}

/// The batched PJRT serving engine.
pub struct Server {
    rt: Runtime,
    dims: ModelDims,
    draft_dims: Option<ModelDims>,
    cfg: ServeConfig,
    kv: BatchKv,
    draft_kv: Option<BatchKv>,
    slots: Vec<Option<ActiveSeq>>,
    pages: XTensorManager,
    queue: VecDeque<GenRequest>,
    pub stats: ServerStats,
    started: Instant,
    pub report: ServingReport,
    results: Vec<GenResult>,
}

impl Server {
    /// Load artifacts and prepare a decode batch of `cfg.max_batch` slots.
    pub fn new(artifacts: &Path, cfg: ServeConfig) -> Result<Server> {
        let mut rt = Runtime::load(artifacts)?;
        let dims = rt.model_dims("tiny")?;
        // batch size must match an AOT decode bucket exactly
        let bucket = rt
            .manifest
            .decode_bucket("tiny", cfg.max_batch as u64)
            .with_context(|| format!("no decode bucket fits max_batch={}", cfg.max_batch))?
            .dim("b")
            .unwrap() as usize;
        if bucket != cfg.max_batch {
            bail!(
                "max_batch={} must equal an AOT decode bucket (nearest is {bucket})",
                cfg.max_batch
            );
        }
        let (draft_dims, draft_kv) = if cfg.speculative {
            let dd = rt.model_dims("draft")?;
            if rt.manifest.verify_bucket("tiny", cfg.max_batch as u64).is_none() {
                bail!("speculative decoding needs a verify bucket >= max_batch");
            }
            (Some(dd), Some(BatchKv::zeros(dd, cfg.max_batch)))
        } else {
            (None, None)
        };
        let kv = BatchKv::zeros(dims, cfg.max_batch);
        // xTensor pages back the batch slots: one slot = max_seq tokens
        let page_tokens = 16u64;
        let total_pages = (cfg.max_batch as u64 * dims.max_seq as u64).div_ceil(page_tokens) as u32;
        Ok(Server {
            rt,
            dims,
            draft_dims,
            kv,
            draft_kv,
            slots: (0..cfg.max_batch).map(|_| None).collect(),
            pages: XTensorManager::new(total_pages, page_tokens, dims.max_seq as u64),
            queue: VecDeque::new(),
            stats: ServerStats::default(),
            started: Instant::now(),
            report: ServingReport::new(),
            results: Vec::new(),
            cfg,
        })
    }

    pub fn model_dims(&self) -> ModelDims {
        self.dims
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Admit queued requests into free slots (prefill them).
    fn admit(&mut self) -> Result<()> {
        while let Some(slot) = self.free_slot() {
            let Some(req) = self.queue.pop_front() else { break };
            let t0 = Instant::now();
            let max_prompt = self
                .rt
                .manifest
                .graphs_of(crate::runtime::GraphKind::Prefill, "tiny")
                .iter()
                .filter_map(|g| g.dim("s"))
                .max()
                .unwrap_or(0) as usize;
            let prompt = if req.prompt.len() > max_prompt {
                // chunk-free fallback: truncate to the largest bucket
                // (chunked prefill over multiple buckets is exercised in
                // the simulator; the real tiny model caps prompts)
                req.prompt[req.prompt.len() - max_prompt..].to_vec()
            } else {
                req.prompt.clone()
            };
            let out = self.rt.prefill("tiny", &prompt)?;
            self.stats.prefills += 1;
            self.kv.write_prefill(slot, &out.k, &out.v, out.bucket_s, prompt.len());
            // xTensor session: pages for the prompt + expected output
            let sid = req.id;
            self.pages.open_with_reuse(sid, (prompt.len() + req.max_new_tokens) as u64);
            self.pages.extend(sid, prompt.len() as u64);
            let first = argmax(&out.last_logits) as i32;
            // seed the draft cache with the prompt (token-by-token decode
            // through the cheap draft model) so proposals are conditioned
            // on the real context
            if let Some(dd) = self.draft_dims {
                // single-slot temp cache (b=1 bucket) so other slots'
                // draft caches are untouched, then copy into the batch
                let mut tmp = BatchKv::zeros(dd, 1);
                for (t, &tok) in prompt.iter().enumerate() {
                    self.rt.decode("draft", &mut tmp, &[tok], &[t as i32])?;
                }
                let dkv = self.draft_kv.as_mut().unwrap();
                dkv.clear_slot(slot);
                dkv.copy_slot_from(slot, &tmp, 0, prompt.len());
            }
            let max_new = req
                .max_new_tokens
                .min(self.dims.max_seq - prompt.len() - 1)
                .min(self.cfg.max_output_tokens);
            let now = Instant::now();
            self.slots[slot] = Some(ActiveSeq {
                id: req.id,
                pos: prompt.len(),
                prompt_len: prompt.len(),
                generated: vec![first],
                last_token: first,
                max_new: max_new.max(1),
                admitted_at: t0,
                first_token_at: Some(now),
            });
        }
        Ok(())
    }

    fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// One plain decode iteration over all active slots.
    fn decode_step(&mut self) -> Result<()> {
        let b = self.cfg.max_batch;
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(s) = s {
                tokens[i] = s.last_token;
                pos[i] = s.pos as i32;
            }
        }
        let out = self.rt.decode("tiny", &mut self.kv, &tokens, &pos)?;
        self.stats.decode_steps += 1;
        for i in 0..b {
            let Some(seq) = self.slots[i].as_mut() else { continue };
            let logits = &out.logits[i * self.dims.vocab..(i + 1) * self.dims.vocab];
            let next = argmax(logits) as i32;
            seq.pos += 1;
            self.pages.extend(seq.id, 1);
            self.pages.premap(seq.id, 1); // async pre-mapping (§4.3)
            seq.generated.push(next);
            seq.last_token = next;
            self.stats.tokens_generated += 1;
            if seq.generated.len() >= seq.max_new || seq.pos + 1 >= self.dims.max_seq {
                self.retire(i);
            }
        }
        Ok(())
    }

    /// One speculative round: draft proposes m tokens, verify scores them.
    fn spec_step(&mut self) -> Result<()> {
        let b = self.cfg.max_batch;
        let m = self
            .rt
            .manifest
            .verify_bucket("tiny", b as u64)
            .context("verify bucket")?
            .dim("m")
            .unwrap() as usize;
        let draft_dims = self.draft_dims.context("draft dims")?;

        // 1) draft proposes m tokens autoregressively (cheap model)
        let mut proposals = vec![vec![0i32; m]; b];
        {
            let dkv = self.draft_kv.as_mut().unwrap();
            let mut cur: Vec<i32> = (0..b)
                .map(|i| self.slots[i].as_ref().map(|s| s.last_token).unwrap_or(0))
                .collect();
            let mut dpos: Vec<i32> = (0..b)
                .map(|i| self.slots[i].as_ref().map(|s| s.pos as i32).unwrap_or(0))
                .collect();
            for j in 0..m {
                let dpos_clamped: Vec<i32> = dpos
                    .iter()
                    .map(|&p| p.min(draft_dims.max_seq as i32 - 1))
                    .collect();
                let out = self.rt.decode("draft", dkv, &cur, &dpos_clamped)?;
                for i in 0..b {
                    if self.slots[i].is_none() {
                        continue;
                    }
                    let logits =
                        &out.logits[i * draft_dims.vocab..(i + 1) * draft_dims.vocab];
                    proposals[i][j] = argmax(logits) as i32;
                    cur[i] = proposals[i][j];
                    dpos[i] += 1;
                }
            }
        }

        // 2) target verifies candidate tokens [last_token ++ proposals[..m-1]]
        //    shifted: we score the m tokens starting at each seq's pos
        let mut vtokens = vec![0i32; b * m];
        let mut vpos = vec![0i32; b];
        for i in 0..b {
            let Some(seq) = self.slots[i].as_ref() else { continue };
            vtokens[i * m] = seq.last_token;
            for j in 1..m {
                vtokens[i * m + j] = proposals[i][j - 1];
            }
            vpos[i] = seq.pos as i32;
        }
        let vout = self.rt.verify("tiny", &mut self.kv, &vtokens, &vpos)?;
        self.stats.decode_steps += 1;

        // 3) greedy acceptance per sequence
        let mut retire: Vec<usize> = Vec::new();
        for i in 0..b {
            let Some(seq) = self.slots[i].as_mut() else { continue };
            let target_argmax: Vec<i32> = (0..m)
                .map(|j| {
                    let row =
                        &vout.logits[(i * m + j) * self.dims.vocab..(i * m + j + 1) * self.dims.vocab];
                    argmax(row) as i32
                })
                .collect();
            let draft_prefix: Vec<i32> = proposals[i][..m - 1].to_vec();
            let (n_acc, emitted) = accept_greedy(&draft_prefix, &target_argmax);
            self.stats.spec.rounds += 1;
            self.stats.spec.proposed += draft_prefix.len() as u64;
            self.stats.spec.accepted += n_acc as u64;
            self.stats.spec.bonus += 1;
            for &t in &emitted {
                seq.pos += 1;
                self.pages.extend(seq.id, 1);
                seq.generated.push(t);
                seq.last_token = t;
                self.stats.tokens_generated += 1;
                if seq.generated.len() >= seq.max_new || seq.pos + m + 1 >= self.dims.max_seq {
                    retire.push(i);
                    break;
                }
            }
            // NOTE: the verify pass wrote KV for all m candidates; the
            // rejected suffix slots get overwritten by later positions —
            // harmless because attention masks beyond `pos`.
        }
        for i in retire {
            self.retire(i);
        }
        Ok(())
    }

    fn retire(&mut self, slot: usize) {
        if let Some(seq) = self.slots[slot].take() {
            let now = Instant::now();
            let arrival = seq.admitted_at.duration_since(self.started).as_secs_f64();
            let first = seq
                .first_token_at
                .unwrap_or(now)
                .duration_since(self.started)
                .as_secs_f64();
            let finish = now.duration_since(self.started).as_secs_f64();
            self.report.record(RequestOutcome {
                arrival_s: arrival,
                first_token_s: first,
                finish_s: finish,
                input_tokens: seq.prompt_len as u64,
                output_tokens: seq.generated.len() as u64,
                failed: false,
            });
            self.results.push(GenResult {
                id: seq.id,
                tokens: seq.generated,
                ttft_s: first - arrival,
                e2e_s: finish - arrival,
            });
            self.pages.close(seq.id); // pages -> Reusable (§4.3)
            self.kv.clear_slot(slot);
        }
    }

    /// Run until the queue and all slots drain; returns the generations.
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        loop {
            self.admit()?;
            if self.active_count() == 0 {
                if self.queue.is_empty() {
                    break;
                }
                continue;
            }
            if self.cfg.speculative {
                self.spec_step()?;
            } else {
                self.decode_step()?;
            }
        }
        Ok(std::mem::take(&mut self.results))
    }

    /// Page-manager statistics (map/unmap/reuse counters).
    pub fn page_stats(&self) -> crate::engine::xtensor::MapStats {
        self.pages.stats
    }

    pub fn graph_stats(&self) -> crate::runtime::GraphStats {
        self.rt.graph_stats()
    }
}

/// Deterministic synthetic prompt (byte-level "tokens").
pub fn synth_prompt(seed: u64, len: usize) -> Vec<i32> {
    let mut rng = crate::util::Rng::new(seed.wrapping_add(1));
    (0..len).map(|_| (rng.range(1, 255)) as i32).collect()
}
