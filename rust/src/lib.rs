//! # xLLM — decoupled service-engine LLM inference framework
//!
//! A from-scratch reproduction of the *xLLM Technical Report* (JD.com,
//! cs.DC 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the request path. `service` implements
//!   xLLM-Service (online/offline co-location, dynamic PD disaggregation,
//!   hybrid EPD disaggregation, global KV cache management, fault
//!   recovery); `engine` implements xLLM-Engine (multi-layer pipeline,
//!   adaptive graph mode, xTensor memory, speculative decoding, EPLB,
//!   hierarchical DP balance, generative recommendation); `coordinator`
//!   holds the shared request/batch/instance machinery **and the serving
//!   orchestrator** — one request-lifecycle state machine
//!   ([`coordinator::orchestrator::Orchestrator`]) driven through the
//!   pluggable [`coordinator::orchestrator::Executor`] trait.
//! * **L2 (python/compile/model.py)** — the JAX transformer, AOT-lowered
//!   once to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas attention/MoE kernels
//!   (interpret mode), verified against pure-jnp oracles.
//!
//! Module map (see DESIGN.md for the full architecture):
//!
//! * [`coordinator`] — request lifecycle, batcher, pools, scheduler,
//!   predictor, and the shared serving **orchestrator** + `Executor`.
//! * [`service`] — xLLM-Service policies (colocation, EPD, fault, KV
//!   store) and the distributed **control plane**
//!   ([`service::controlplane`]): instance registry with heartbeat
//!   leases, global prefix-cache index, cache-aware routing, failover
//!   across N orchestrator replicas, and the elastic **fleet scaler**
//!   (replica autoscaling + planned cross-replica KV rebalancing; see
//!   DESIGN.md §Control-Plane).  [`service::fleet`] is the
//!   executor-agnostic **fleet runtime**: a `ReplicaFactory` seam
//!   builds N replicas (roofline sim or real PJRT engines) behind one
//!   lock-protected, optionally multi-threaded control plane (see
//!   DESIGN.md §Fleet-Runtime).
//! * [`engine`] — xLLM-Engine optimizations (xtensor, specdecode, EPLB,
//!   DP balance, pipeline, genrec).
//! * [`sim`] — event clock, roofline cost model, the roofline `Executor`,
//!   `ClusterConfig` (the Ascend-cluster substitute; see DESIGN.md
//!   §Hardware-Adaptation), and `sim::fleet` (N replica clusters under
//!   one control plane).
//! * [`server`] — the PJRT `Executor` + serving façade over the
//!   orchestrator; [`runtime`] loads the AOT artifacts via the PJRT C API
//!   (`xla` crate) — Python never runs at serve time.
//! * [`workload`] — synthetic scenario generators (DESIGN.md
//!   §Substitutions); [`metrics`], [`model`], [`config`], [`util`],
//!   [`testutil`] support the rest.

pub mod config;
pub mod coordinator;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod service;
pub mod sim;
pub mod testutil;
pub mod util;
pub mod workload;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
