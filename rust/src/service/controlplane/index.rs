//! Global prefix-cache index (paper §3.4).
//!
//! Aggregates the per-replica [`TieredCache`] chain summaries that
//! replicas publish with their heartbeats, so the router sees
//! cluster-wide KV reuse without a synchronous query per request.  The
//! index is *eventually consistent*: a heartbeat publish replaces a
//! replica's whole block map (version bump), and the router records an
//! optimistic entry at dispatch time so back-to-back requests sharing a
//! prefix co-locate even within one heartbeat interval.  Staleness is
//! harmless — a phantom hit only costs the routed replica a prefill it
//! would have done anyway.
//!
//! [`TieredCache`]: crate::service::kvstore::TieredCache

use std::collections::HashMap;

use crate::service::kvstore::{hash_chain, Tier};
use crate::service::radix::ClusterRadix;

/// Cluster-wide view of which replica caches which prefix blocks.
///
/// Token-granular mode (`enable_token_granular`) mirrors every update
/// into a [`ClusterRadix`] — one tree for the whole fleet with
/// per-replica tier bitsets — so `match_prefix_tokens` /
/// `best_match_tokens` answer at arbitrary token split points in
/// O(matched tokens), while the flat per-replica maps keep serving the
/// block-level contracts unchanged.
#[derive(Debug, Default)]
pub struct GlobalPrefixIndex {
    per_replica: HashMap<usize, HashMap<u64, Tier>>,
    versions: HashMap<usize, u64>,
    radix: Option<ClusterRadix>,
    published_entries: u64,
}

impl GlobalPrefixIndex {
    pub fn new() -> GlobalPrefixIndex {
        GlobalPrefixIndex::default()
    }

    /// Switch on the token-granular radix mirror.  Must be called before
    /// any entries exist; from then on publishes should flow through
    /// `publish_delta` / `record_tokens` so both views stay in sync.
    pub fn enable_token_granular(&mut self, block_tokens: u64) {
        if self.radix.is_none() {
            self.radix = Some(ClusterRadix::new(block_tokens));
        }
    }

    pub fn token_granular(&self) -> bool {
        self.radix.is_some()
    }

    /// Entries pushed through `publish`/`publish_delta` since start —
    /// the observable cost of index republishing (a full `summary()`
    /// publish pays its whole resident set; a delta pays only the
    /// changes since the last heartbeat).
    pub fn published_entries(&self) -> u64 {
        self.published_entries
    }

    /// Replace `replica`'s published block map (heartbeat publish);
    /// returns the new monotonic version.
    pub fn publish(&mut self, replica: usize, summary: &[(u64, Tier)]) -> u64 {
        self.published_entries += summary.len() as u64;
        self.per_replica.insert(replica, summary.iter().copied().collect());
        let v = self.versions.entry(replica).or_insert(0);
        *v += 1;
        *v
    }

    /// Incremental publish: apply residency changes in event order
    /// (`Some(tier)` upsert, `None` eviction) instead of replacing the
    /// whole map.  Mirrors each change into the radix (block-span bit
    /// set/clear keyed by the boundary prefix hash).  Returns the new
    /// version; an empty delta still bumps it (the heartbeat observed a
    /// consistent, unchanged view).
    pub fn publish_delta(&mut self, replica: usize, delta: &[(u64, Option<Tier>)]) -> u64 {
        self.published_entries += delta.len() as u64;
        let map = self.per_replica.entry(replica).or_default();
        for &(h, tier) in delta {
            match tier {
                Some(t) => {
                    map.insert(h, t);
                }
                None => {
                    map.remove(&h);
                }
            }
        }
        if let Some(radix) = &mut self.radix {
            for &(h, tier) in delta {
                radix.apply_block(replica, h, tier);
            }
        }
        let v = self.versions.entry(replica).or_insert(0);
        *v += 1;
        *v
    }

    /// Optimistically record a routed chain: the target replica will
    /// hold these blocks (in DRAM per the consistency rule) once it
    /// admits the request.
    pub fn record(&mut self, replica: usize, chain: &[u64]) {
        let map = self.per_replica.entry(replica).or_default();
        for &h in chain {
            map.entry(h).or_insert(Tier::Dram);
        }
    }

    /// Token-granular optimistic record: the routed token path lands in
    /// the radix (structure + replica bits at any split point) *and* in
    /// the flat map (its block chain), so block-level consumers — the
    /// scaler's rebalance planner, failover `best_match` — see the same
    /// dispatch the token-granular router saw.
    pub fn record_tokens(&mut self, replica: usize, tokens: &[u32]) {
        let Some(radix) = &mut self.radix else {
            return;
        };
        radix.record_tokens(replica, tokens, Tier::Dram);
        let bt = radix.block_tokens() as usize;
        let chain = hash_chain(tokens, bt);
        self.record(replica, &chain);
    }

    /// Longest token prefix `replica` holds per the radix, worst tier
    /// along the path.  Falls back to the block-derived answer when
    /// token granularity is off.
    pub fn match_prefix_tokens(&self, replica: usize, tokens: &[u32]) -> (u64, Option<Tier>) {
        match &self.radix {
            Some(radix) => radix.match_prefix_tokens(replica, tokens),
            None => (0, None),
        }
    }

    /// Best replica for a token path: one radix walk over all replicas —
    /// O(matched tokens), not O(replicas × chain length).  Same contract
    /// as `best_match`: longest match, lowest id on ties.
    pub fn best_match_tokens(&self, tokens: &[u32]) -> Option<(usize, u64, Tier)> {
        self.radix.as_ref()?.best_match_tokens(tokens)
    }

    /// Longest prefix of `chain` the replica holds, and the slowest tier
    /// that must be read to serve it (mirrors `TieredCache::match_prefix`
    /// without touching LRU state — the index is a remote view).
    pub fn match_prefix(&self, replica: usize, chain: &[u64]) -> (usize, Option<Tier>) {
        let Some(map) = self.per_replica.get(&replica) else {
            return (0, None);
        };
        let mut worst: Option<Tier> = None;
        let mut n = 0;
        for h in chain {
            match map.get(h) {
                Some(&tier) => {
                    worst = Some(match worst {
                        Some(w) if w >= tier => w,
                        _ => tier,
                    });
                    n += 1;
                }
                None => break,
            }
        }
        (n, worst)
    }

    /// Best surviving replica for a chain: `(replica, matched_blocks,
    /// worst_tier)` with the longest match (lowest replica id on ties).
    /// Drives the §3.5 recompute-vs-migrate failover decision.
    pub fn best_match(&self, chain: &[u64]) -> Option<(usize, usize, Tier)> {
        let mut ids: Vec<usize> = self.per_replica.keys().copied().collect();
        ids.sort_unstable();
        ids.iter()
            .filter_map(|&r| match self.match_prefix(r, chain) {
                (n, Some(t)) if n > 0 => Some((r, n, t)),
                _ => None,
            })
            .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0)))
    }

    /// Forget a dead replica's blocks (its HBM/DRAM copies died with it).
    pub fn remove(&mut self, replica: usize) {
        self.per_replica.remove(&replica);
        self.versions.remove(&replica);
        if let Some(radix) = &mut self.radix {
            radix.remove(replica);
        }
    }

    pub fn version(&self, replica: usize) -> u64 {
        self.versions.get(&replica).copied().unwrap_or(0)
    }

    pub fn blocks(&self, replica: usize) -> usize {
        self.per_replica.get(&replica).map(|m| m.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::kvstore::{hash_chain, prefix_tokens};

    fn chain(group: u64, blocks: u64) -> Vec<u64> {
        hash_chain(&prefix_tokens(group, blocks * 16), 16)
    }

    #[test]
    fn publish_then_match() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 4);
        let summary: Vec<(u64, Tier)> = c.iter().map(|&h| (h, Tier::Dram)).collect();
        assert_eq!(ix.publish(3, &summary), 1);
        assert_eq!(ix.match_prefix(3, &c), (4, Some(Tier::Dram)));
        assert_eq!(ix.match_prefix(0, &c), (0, None), "unknown replica has nothing");
        // partial overlap: only the shared prefix matches
        let other = chain(2, 4);
        assert_eq!(ix.match_prefix(3, &other), (0, None));
    }

    #[test]
    fn publish_replaces_and_bumps_version() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 4);
        let full: Vec<(u64, Tier)> = c.iter().map(|&h| (h, Tier::Dram)).collect();
        ix.publish(0, &full);
        // the replica evicted the tail: a fresh publish must shrink the view
        assert_eq!(ix.publish(0, &full[..2]), 2);
        assert_eq!(ix.match_prefix(0, &c), (2, Some(Tier::Dram)));
        assert_eq!(ix.blocks(0), 2);
    }

    #[test]
    fn worst_tier_governs_the_match() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 3);
        ix.publish(0, &[(c[0], Tier::Hbm), (c[1], Tier::Ssd), (c[2], Tier::Dram)]);
        assert_eq!(ix.match_prefix(0, &c), (3, Some(Tier::Ssd)));
    }

    #[test]
    fn optimistic_record_fills_the_gap() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(5, 3);
        ix.record(2, &c);
        assert_eq!(ix.match_prefix(2, &c), (3, Some(Tier::Dram)));
        // an authoritative publish overrides the optimism
        ix.publish(2, &[(c[0], Tier::Hbm)]);
        assert_eq!(ix.match_prefix(2, &c), (1, Some(Tier::Hbm)));
    }

    #[test]
    fn best_match_prefers_longest_then_lowest_id() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 4);
        ix.record(4, &c[..2]);
        ix.record(1, &c);
        ix.record(7, &c);
        assert_eq!(ix.best_match(&c), Some((1, 4, Tier::Dram)), "longest match, lowest id");
        ix.remove(1);
        assert_eq!(ix.best_match(&c), Some((7, 4, Tier::Dram)));
        ix.remove(7);
        assert_eq!(ix.best_match(&c), Some((4, 2, Tier::Dram)));
        ix.remove(4);
        assert_eq!(ix.best_match(&c), None);
    }

    #[test]
    fn remove_clears_blocks_and_version() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 2);
        ix.record(0, &c);
        ix.publish(0, &[(c[0], Tier::Dram)]);
        assert_eq!(ix.version(0), 1);
        ix.remove(0);
        assert_eq!(ix.version(0), 0);
        assert_eq!(ix.blocks(0), 0);
    }

    #[test]
    fn delta_publish_applies_in_event_order() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 3);
        assert_eq!(ix.publish_delta(0, &[(c[0], Some(Tier::Dram)), (c[1], Some(Tier::Dram))]), 1);
        assert_eq!(ix.match_prefix(0, &c), (2, Some(Tier::Dram)));
        // eviction then re-insert of the same block within one delta:
        // last event wins
        let v = ix.publish_delta(
            0,
            &[(c[1], None), (c[2], Some(Tier::Ssd)), (c[1], Some(Tier::Hbm))],
        );
        assert_eq!(v, 2);
        assert_eq!(ix.match_prefix(0, &c), (3, Some(Tier::Ssd)));
        assert_eq!(ix.publish_delta(0, &[]), 3, "empty delta still bumps the version");
        assert_eq!(ix.published_entries(), 5, "two + three entries, empty delta free");
    }

    #[test]
    fn token_granular_record_feeds_both_views() {
        let mut ix = GlobalPrefixIndex::new();
        ix.enable_token_granular(16);
        let toks = prefix_tokens(1, 40); // 2 blocks + 8-token tail
        ix.record_tokens(2, &toks);
        assert_eq!(ix.match_prefix_tokens(2, &toks), (40, Some(Tier::Dram)));
        assert_eq!(ix.match_prefix_tokens(2, &toks[..19]).0, 19);
        // flat view sees the block chain of the same dispatch
        assert_eq!(ix.match_prefix(2, &hash_chain(&toks, 16)), (2, Some(Tier::Dram)));
        assert_eq!(ix.best_match_tokens(&toks), Some((2, 40, Tier::Dram)));
    }

    #[test]
    fn token_granular_dedups_shared_prefixes_at_any_split() {
        let mut ix = GlobalPrefixIndex::new();
        ix.enable_token_granular(16);
        let toks = prefix_tokens(3, 48);
        ix.record_tokens(0, &toks[..24]); // 1.5 blocks
        ix.record_tokens(5, &toks);
        // replica 0's credit extends past its block boundary to token 24
        assert_eq!(ix.match_prefix_tokens(0, &toks).0, 24);
        let (r, n, _) = ix.best_match_tokens(&toks).unwrap();
        assert_eq!((r, n), (5, 48), "longest wins");
        let (r, n, _) = ix.best_match_tokens(&toks[..20]).unwrap();
        assert_eq!((r, n), (0, 20), "tie at 20 tokens breaks to the lowest id");
    }

    #[test]
    fn property_radix_matches_linear_scan_at_block_splits() {
        // differential oracle (satellite of ISSUE 9): drive randomized
        // chain churn — optimistic records, authoritative residency
        // deltas from real TieredCaches, replica removal — through a
        // token-granular index, and after every op compare the radix
        // answers against the old linear-scan flat maps at block-aligned
        // splits: identical matched lengths, tiers, and best-match
        // tie-breaks.
        use crate::service::kvstore::TieredCache;
        crate::testutil::check("index-radix-vs-linear", 96, |rng| {
            let block = 8u64;
            let n_replicas = 4usize;
            let mut ix = GlobalPrefixIndex::new();
            ix.enable_token_granular(block);
            let mut caches: Vec<TieredCache> = (0..n_replicas)
                .map(|_| {
                    let mut c = TieredCache::new(
                        block,
                        block * rng.range(1, 4),
                        block * rng.range(2, 8),
                        block * rng.range(2, 8),
                    );
                    c.enable_delta_tracking();
                    c
                })
                .collect();
            for _ in 0..120 {
                let r = rng.index(n_replicas);
                let group = rng.range(0, 4);
                let blocks = rng.range(1, 8);
                let tokens = prefix_tokens(group, blocks * block);
                match rng.range(0, 3) {
                    0 => ix.record_tokens(r, &tokens),
                    1 => {
                        // the replica admits and caches the routed path,
                        // then heartbeats a residency delta
                        ix.record_tokens(r, &tokens);
                        caches[r].insert_tokens(&tokens, Tier::Dram);
                        let delta = caches[r].take_summary_delta();
                        ix.publish_delta(r, &delta);
                    }
                    2 => {
                        let (n, tier) = ix.match_prefix(r, &hash_chain(&tokens, block as usize));
                        let (tok, ttier) = ix.match_prefix_tokens(r, &tokens);
                        crate::prop_assert!(
                            tok == n as u64 * block,
                            "replica {r}: radix {tok} != linear {n} x {block}"
                        );
                        crate::prop_assert!(ttier == tier, "tier {ttier:?} != {tier:?}");
                    }
                    _ => {
                        ix.remove(r);
                        let mut c = TieredCache::new(block, block * 2, block * 4, block * 4);
                        c.enable_delta_tracking();
                        caches[r] = c;
                    }
                }
                // cross-replica: best_match must agree with the radix walk
                let probe = prefix_tokens(rng.range(0, 4), rng.range(1, 8) * block);
                let linear = ix.best_match(&hash_chain(&probe, block as usize));
                let radix = ix.best_match_tokens(&probe);
                match (linear, radix) {
                    (None, None) => {}
                    (Some((lr, ln, lt)), Some((rr, rn, rt))) => {
                        crate::prop_assert!(
                            lr == rr && ln as u64 * block == rn && lt == rt,
                            "best_match diverged: linear {:?} radix {:?}",
                            (lr, ln, lt),
                            (rr, rn, rt)
                        );
                    }
                    (l, x) => {
                        crate::prop_assert!(false, "presence diverged: {l:?} vs {x:?}");
                    }
                }
            }
            Ok(())
        });
    }
}
