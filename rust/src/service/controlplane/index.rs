//! Global prefix-cache index (paper §3.4).
//!
//! Aggregates the per-replica [`TieredCache`] chain summaries that
//! replicas publish with their heartbeats, so the router sees
//! cluster-wide KV reuse without a synchronous query per request.  The
//! index is *eventually consistent*: a heartbeat publish replaces a
//! replica's whole block map (version bump), and the router records an
//! optimistic entry at dispatch time so back-to-back requests sharing a
//! prefix co-locate even within one heartbeat interval.  Staleness is
//! harmless — a phantom hit only costs the routed replica a prefill it
//! would have done anyway.
//!
//! [`TieredCache`]: crate::service::kvstore::TieredCache

use std::collections::HashMap;

use crate::service::kvstore::Tier;

/// Cluster-wide view of which replica caches which prefix blocks.
#[derive(Debug, Default)]
pub struct GlobalPrefixIndex {
    per_replica: HashMap<usize, HashMap<u64, Tier>>,
    versions: HashMap<usize, u64>,
}

impl GlobalPrefixIndex {
    pub fn new() -> GlobalPrefixIndex {
        GlobalPrefixIndex::default()
    }

    /// Replace `replica`'s published block map (heartbeat publish);
    /// returns the new monotonic version.
    pub fn publish(&mut self, replica: usize, summary: &[(u64, Tier)]) -> u64 {
        self.per_replica.insert(replica, summary.iter().copied().collect());
        let v = self.versions.entry(replica).or_insert(0);
        *v += 1;
        *v
    }

    /// Optimistically record a routed chain: the target replica will
    /// hold these blocks (in DRAM per the consistency rule) once it
    /// admits the request.
    pub fn record(&mut self, replica: usize, chain: &[u64]) {
        let map = self.per_replica.entry(replica).or_default();
        for &h in chain {
            map.entry(h).or_insert(Tier::Dram);
        }
    }

    /// Longest prefix of `chain` the replica holds, and the slowest tier
    /// that must be read to serve it (mirrors `TieredCache::match_prefix`
    /// without touching LRU state — the index is a remote view).
    pub fn match_prefix(&self, replica: usize, chain: &[u64]) -> (usize, Option<Tier>) {
        let Some(map) = self.per_replica.get(&replica) else {
            return (0, None);
        };
        let mut worst: Option<Tier> = None;
        let mut n = 0;
        for h in chain {
            match map.get(h) {
                Some(&tier) => {
                    worst = Some(match worst {
                        Some(w) if w >= tier => w,
                        _ => tier,
                    });
                    n += 1;
                }
                None => break,
            }
        }
        (n, worst)
    }

    /// Best surviving replica for a chain: `(replica, matched_blocks,
    /// worst_tier)` with the longest match (lowest replica id on ties).
    /// Drives the §3.5 recompute-vs-migrate failover decision.
    pub fn best_match(&self, chain: &[u64]) -> Option<(usize, usize, Tier)> {
        let mut ids: Vec<usize> = self.per_replica.keys().copied().collect();
        ids.sort_unstable();
        ids.iter()
            .filter_map(|&r| match self.match_prefix(r, chain) {
                (n, Some(t)) if n > 0 => Some((r, n, t)),
                _ => None,
            })
            .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0)))
    }

    /// Forget a dead replica's blocks (its HBM/DRAM copies died with it).
    pub fn remove(&mut self, replica: usize) {
        self.per_replica.remove(&replica);
        self.versions.remove(&replica);
    }

    pub fn version(&self, replica: usize) -> u64 {
        self.versions.get(&replica).copied().unwrap_or(0)
    }

    pub fn blocks(&self, replica: usize) -> usize {
        self.per_replica.get(&replica).map(|m| m.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::kvstore::{hash_chain, prefix_tokens};

    fn chain(group: u64, blocks: u64) -> Vec<u64> {
        hash_chain(&prefix_tokens(group, blocks * 16), 16)
    }

    #[test]
    fn publish_then_match() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 4);
        let summary: Vec<(u64, Tier)> = c.iter().map(|&h| (h, Tier::Dram)).collect();
        assert_eq!(ix.publish(3, &summary), 1);
        assert_eq!(ix.match_prefix(3, &c), (4, Some(Tier::Dram)));
        assert_eq!(ix.match_prefix(0, &c), (0, None), "unknown replica has nothing");
        // partial overlap: only the shared prefix matches
        let other = chain(2, 4);
        assert_eq!(ix.match_prefix(3, &other), (0, None));
    }

    #[test]
    fn publish_replaces_and_bumps_version() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 4);
        let full: Vec<(u64, Tier)> = c.iter().map(|&h| (h, Tier::Dram)).collect();
        ix.publish(0, &full);
        // the replica evicted the tail: a fresh publish must shrink the view
        assert_eq!(ix.publish(0, &full[..2]), 2);
        assert_eq!(ix.match_prefix(0, &c), (2, Some(Tier::Dram)));
        assert_eq!(ix.blocks(0), 2);
    }

    #[test]
    fn worst_tier_governs_the_match() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 3);
        ix.publish(0, &[(c[0], Tier::Hbm), (c[1], Tier::Ssd), (c[2], Tier::Dram)]);
        assert_eq!(ix.match_prefix(0, &c), (3, Some(Tier::Ssd)));
    }

    #[test]
    fn optimistic_record_fills_the_gap() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(5, 3);
        ix.record(2, &c);
        assert_eq!(ix.match_prefix(2, &c), (3, Some(Tier::Dram)));
        // an authoritative publish overrides the optimism
        ix.publish(2, &[(c[0], Tier::Hbm)]);
        assert_eq!(ix.match_prefix(2, &c), (1, Some(Tier::Hbm)));
    }

    #[test]
    fn best_match_prefers_longest_then_lowest_id() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 4);
        ix.record(4, &c[..2]);
        ix.record(1, &c);
        ix.record(7, &c);
        assert_eq!(ix.best_match(&c), Some((1, 4, Tier::Dram)), "longest match, lowest id");
        ix.remove(1);
        assert_eq!(ix.best_match(&c), Some((7, 4, Tier::Dram)));
        ix.remove(7);
        assert_eq!(ix.best_match(&c), Some((4, 2, Tier::Dram)));
        ix.remove(4);
        assert_eq!(ix.best_match(&c), None);
    }

    #[test]
    fn remove_clears_blocks_and_version() {
        let mut ix = GlobalPrefixIndex::new();
        let c = chain(1, 2);
        ix.record(0, &c);
        ix.publish(0, &[(c[0], Tier::Dram)]);
        assert_eq!(ix.version(0), 1);
        ix.remove(0);
        assert_eq!(ix.version(0), 0);
        assert_eq!(ix.blocks(0), 0);
    }
}
