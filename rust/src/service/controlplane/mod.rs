//! The distributed control plane (paper §3.4–§3.5): one `ControlPlane`
//! over N orchestrator replicas.
//!
//! This is the layer that turns the service-policy modules into one
//! system serving traffic across more than one engine:
//!
//! * [`registry`] — instance registry with heartbeat TTL leases and
//!   per-replica load reports (composes [`crate::service::meta`], the
//!   ETCD substitute).
//! * [`index`] — global prefix-cache index aggregating per-replica
//!   `TieredCache` chain summaries, refreshed on every heartbeat.
//! * [`router`] — cache-aware routing running the paper's three-step
//!   selection over the live registry + index (generalizes
//!   [`crate::service::kvstore::route`]), with the §3.1 offline tide
//!   rule applied across replicas via
//!   [`crate::service::colocation::ColocationConfig`].
//! * failover — an expired lease marks a replica dead; its in-flight
//!   requests re-queue onto survivors, with the recompute-vs-migrate
//!   decision delegated to [`crate::service::fault::plan_recovery`]
//!   against what the global index still holds (§3.5).
//!
//! Mechanically, the control plane is a discrete-event driver of
//! drivers: each replica is a steppable [`Orchestrator`] with its own
//! event queue, and the control plane interleaves them with its own
//! queue (arrivals, heartbeats, fault injections) by always advancing
//! whichever head event is earliest.  Determinism is preserved — ties
//! break control-plane-first, then by replica id.  With async-pipelined
//! replicas (`pipeline_depth ≥ 2`) several replicas hold in-flight
//! iterations *concurrently* — their pending `IterDone` events overlap
//! in fleet time — and the same `next_event_time` interleave drives
//! them without any special casing.
//!
//! The control plane is also *thread-capable*: the registry and global
//! index live behind `Arc<RwLock<…>>`, executors are `Send`, and
//! [`ControlPlaneConfig::threads`] ≥ 2 steps each replica on its own
//! worker thread between control events — the same `next_event_time`
//! ordering contract (every replica event strictly before the next
//! control event runs before it fires), with real parallelism across
//! replica backends.  Threaded and single-threaded runs agree on
//! conservation (routed = completed + lost) and on which requests
//! complete; the single-threaded interleave remains the deterministic
//! default.

pub mod index;
pub mod registry;
pub mod router;
pub mod scaler;

pub use index::GlobalPrefixIndex;
pub use registry::{InstanceRegistry, LoadReport};
pub use router::{FleetRouter, RouteDecision, RoutePolicy, RouterCtx};
pub use scaler::{FleetScaler, ScaleAction, ScalePolicy, ScalerConfig};

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};
use std::sync::{Arc, RwLock};

use crate::coordinator::orchestrator::{
    Executor, InFlightSnapshot, KvChainPayload, Orchestrator, RunResult, DEFAULT_MAX_EVENTS,
    DEFAULT_PREFIX_BLOCK_TOKENS,
};
use crate::coordinator::predictor::TtftPredictor;
use crate::metrics::{PhaseBreakdown, RequestOutcome, ServingReport};
use crate::model::ShardSpec;
use crate::obs::{InstantKind, MetricsRegistry, TraceHandle};
use crate::service::colocation::ColocationConfig;
use crate::service::fault::{plan_recovery, InterruptedRequest, RecoveryAction};
use crate::service::kvstore::{Tier, TransferEngine};
use crate::sim::clock::EventQueue;
use crate::sim::CostModel;
use crate::workload::RequestSpec;

/// Control-plane events (the cluster-scope queue; replicas keep their
/// own per-replica queues).
#[derive(Debug, Clone)]
enum CtlEv {
    /// Global request `workload[i]` arrives and must be routed.
    Arrive(usize),
    /// A pulled arrival from the streaming source (`run_stream`): the
    /// spec rides the event itself, and routing it pulls + schedules the
    /// next one (one-ahead), so arrival state stays O(1) in workload
    /// length.
    ArriveSpec(RequestSpec),
    /// Periodic heartbeat: replicas publish load + cache summaries,
    /// lapsed leases are swept, and the elastic scaler takes its tick.
    Heartbeat,
    /// Whole-replica crash injection: the replica stops executing and
    /// stops heartbeating; detection happens via lease expiry.
    Fault(usize),
    /// A planned KV rebalance finished staging: the chain lands on the
    /// target replica (global index + local cache adoption).  `payload`
    /// carries the source executor's exported KV when the backend ships
    /// real blocks ([`Executor::export_chain`]); `None` keeps the
    /// movement cost-only (model-priced executors).
    RebalanceDone { to: usize, chain: Vec<u64>, payload: Option<KvChainPayload> },
}

/// Control-plane configuration.
#[derive(Debug, Clone)]
pub struct ControlPlaneConfig {
    pub routing: RoutePolicy,
    /// Heartbeat / lease-renewal interval.
    pub heartbeat_s: f64,
    /// Lease TTL: a replica silent longer than this is declared dead at
    /// the next sweep (detection bound = ttl + heartbeat interval).
    pub lease_ttl_s: f64,
    /// Whole-replica crash injections: (time, replica).
    pub replica_faults: Vec<(f64, usize)>,
    /// Prefix-chain granularity — must match the replicas'
    /// `OrchestratorConfig::prefix_block_tokens`.
    pub block_tokens: u64,
    /// Token-granular cluster index: the global prefix index keeps a
    /// radix tree over token ids with per-replica residency bitsets,
    /// heartbeats publish incremental residency deltas instead of full
    /// summary snapshots, routing and dispatch charging use exact
    /// matched-token counts, and the scaler ships sub-chain token
    /// ranges.  Off (the default) preserves the block-aligned chain
    /// behavior bit-identically.
    pub token_granular: bool,
    /// Cross-replica online/offline steering thresholds (§3.1).
    pub colocation: ColocationConfig,
    /// Transfer-cost model for routing and failover decisions.
    pub xfer: TransferEngine,
    /// Elastic fleet scaling + planned KV rebalancing (None = fixed
    /// fleet, the pre-scaler behavior).
    pub scaler: Option<ScalerConfig>,
    /// Replica stepping threads.  1 (the default) is the deterministic
    /// single-queue interleave; N ≥ 2 steps the replicas on worker
    /// threads between control events (see [`ControlPlane::run`]) —
    /// same `next_event_time` ordering contract, real parallelism
    /// across replica backends.
    pub threads: usize,
    /// Cap on control-plane scheduling turns (safety net).
    pub max_events: u64,
    /// Lifecycle trace sink.  Off by default (zero overhead); when set,
    /// every replica orchestrator gets a [`TraceHandle::for_replica`]
    /// clone and the control plane emits its own cluster-scope instants
    /// (scale, failover, rebalance) on the shared sink.
    pub trace: TraceHandle,
}

impl Default for ControlPlaneConfig {
    fn default() -> Self {
        ControlPlaneConfig {
            routing: RoutePolicy::CacheAware,
            heartbeat_s: 0.25,
            lease_ttl_s: 0.65,
            replica_faults: Vec::new(),
            block_tokens: DEFAULT_PREFIX_BLOCK_TOKENS,
            token_granular: false,
            colocation: ColocationConfig::default(),
            xfer: TransferEngine::default(),
            scaler: None,
            threads: 1,
            max_events: DEFAULT_MAX_EVENTS,
            trace: TraceHandle::off(),
        }
    }
}

/// Cluster-level counters the control plane maintains on top of the
/// per-replica [`RunResult`]s.
#[derive(Debug, Clone, Copy, Default)]
pub struct ControlCounters {
    /// Requests routed to a replica already caching part of their prefix.
    pub routed_by_cache_hit: u64,
    /// Replica deaths handled (lease expiry or wedged event loop).
    pub failovers: u64,
    /// Requests re-queued onto survivors after a replica death.
    pub redispatched_requests: u64,
    /// Context tokens those requests had accumulated on the dead
    /// replica (the KV that must be recomputed or re-staged).
    pub redispatched_tokens: u64,
    /// Re-dispatches where §3.5 recovery chose migration over recompute
    /// (a surviving replica still held the prefix).
    pub redispatch_migrations: u64,
    /// Offline requests narrowed to latency-relaxed replicas (§3.1).
    pub offline_steered: u64,
    /// Requests failed because no replica held a lease.
    pub unroutable: u64,
    pub heartbeats: u64,
    pub lease_expiries: u64,
    /// Replicas spawned by the elastic scaler.
    pub scale_ups: u64,
    /// Replicas gracefully decommissioned by the elastic scaler
    /// (drained + re-dispatched; distinct from `failovers`).
    pub scale_downs: u64,
    /// Planned cross-replica KV migrations of hot prefix chains (§3.4
    /// proactive movement; distinct from failover `redispatch_migrations`).
    pub kv_rebalances: u64,
    /// Hot chains pre-staged onto freshly spawned replicas (scale-up
    /// warm start; distinct from `kv_rebalances`).
    pub warm_starts: u64,
    /// KV blocks physically shipped between replica executors (payloads
    /// from [`Executor::export_chain`] landed via `import_chain`).
    /// Stays 0 for cost-only backends like the roofline executor.
    pub kv_blocks_shipped: u64,
    /// Total staging + transfer time charged for planned rebalances and
    /// warm starts.
    pub rebalance_staging_s: f64,
    /// Index entries shipped by heartbeat publishes over the run (full
    /// snapshots count every entry, delta publishes count only the
    /// residency mutations since the previous heartbeat) — the
    /// republish-volume measure the incremental publish satellite pins.
    pub index_published_entries: u64,
    /// Replica-heartbeats where the SLO scaling policy predicted a TTFT
    /// target violation from the published queue depth (scale-up signal;
    /// stays 0 under the backlog policy).
    pub slo_violations_predicted: u64,
}

impl ControlCounters {
    /// Publish under the stable `xllm_ctl_*` metric names.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc("xllm_ctl_routed_cache_hits_total", self.routed_by_cache_hit);
        reg.inc("xllm_ctl_failovers_total", self.failovers);
        reg.inc("xllm_ctl_redispatched_requests_total", self.redispatched_requests);
        reg.inc("xllm_ctl_redispatched_tokens_total", self.redispatched_tokens);
        reg.inc("xllm_ctl_redispatch_migrations_total", self.redispatch_migrations);
        reg.inc("xllm_ctl_offline_steered_total", self.offline_steered);
        reg.inc("xllm_ctl_unroutable_total", self.unroutable);
        reg.inc("xllm_ctl_heartbeats_total", self.heartbeats);
        reg.inc("xllm_ctl_lease_expiries_total", self.lease_expiries);
        reg.inc("xllm_ctl_scale_ups_total", self.scale_ups);
        reg.inc("xllm_ctl_scale_downs_total", self.scale_downs);
        reg.inc("xllm_ctl_kv_rebalances_total", self.kv_rebalances);
        reg.inc("xllm_ctl_warm_starts_total", self.warm_starts);
        reg.inc("xllm_ctl_kv_blocks_shipped_total", self.kv_blocks_shipped);
        reg.set_gauge("xllm_ctl_rebalance_staging_seconds", self.rebalance_staging_s);
        reg.inc("xllm_index_published_entries_total", self.index_published_entries);
        reg.inc("xllm_slo_violations_predicted_total", self.slo_violations_predicted);
    }

    /// The old struct view over the registry names (tests pin the
    /// round-trip so neither side drifts).
    pub fn from_registry(reg: &MetricsRegistry) -> ControlCounters {
        ControlCounters {
            routed_by_cache_hit: reg.counter("xllm_ctl_routed_cache_hits_total"),
            failovers: reg.counter("xllm_ctl_failovers_total"),
            redispatched_requests: reg.counter("xllm_ctl_redispatched_requests_total"),
            redispatched_tokens: reg.counter("xllm_ctl_redispatched_tokens_total"),
            redispatch_migrations: reg.counter("xllm_ctl_redispatch_migrations_total"),
            offline_steered: reg.counter("xllm_ctl_offline_steered_total"),
            unroutable: reg.counter("xllm_ctl_unroutable_total"),
            heartbeats: reg.counter("xllm_ctl_heartbeats_total"),
            lease_expiries: reg.counter("xllm_ctl_lease_expiries_total"),
            scale_ups: reg.counter("xllm_ctl_scale_ups_total"),
            scale_downs: reg.counter("xllm_ctl_scale_downs_total"),
            kv_rebalances: reg.counter("xllm_ctl_kv_rebalances_total"),
            warm_starts: reg.counter("xllm_ctl_warm_starts_total"),
            kv_blocks_shipped: reg.counter("xllm_ctl_kv_blocks_shipped_total"),
            rebalance_staging_s: reg.gauge("xllm_ctl_rebalance_staging_seconds"),
            index_published_entries: reg.counter("xllm_index_published_entries_total"),
            slo_violations_predicted: reg.counter("xllm_slo_violations_predicted_total"),
        }
    }
}

/// Aggregated fleet run output.
#[derive(Debug)]
pub struct FleetResult {
    /// Merged serving report across every replica (plus unroutable
    /// requests recorded as failed).
    pub report: ServingReport,
    /// Per-replica results, indexed by replica id.
    pub per_replica: Vec<RunResult>,
    pub counters: ControlCounters,
    /// Requests submitted to the control plane (re-dispatches are not
    /// double-counted).
    pub submitted: usize,
    /// Replicas still live when the run finished (after autoscaling;
    /// `per_replica.len()` is every replica that ever existed).
    pub n_replicas_final: usize,
    /// Peak concurrently-live (routed but not yet recorded) requests,
    /// sampled at heartbeats — the bounded-live-state measure for
    /// streaming runs (stays far below `submitted` on a drained fleet).
    pub live_high_water: usize,
    /// Integral of the alive-replica count over fleet time: the
    /// denominator for goodput-per-replica-second comparisons across
    /// scaling policies.
    pub replica_seconds: f64,
    /// The control plane or any replica hit its event cap.
    pub truncated: bool,
}

impl FleetResult {
    /// Cluster-wide prefix-cache hits (sum over replicas).
    pub fn prefix_hits(&self) -> u64 {
        self.per_replica.iter().map(|r| r.prefix_hits).sum()
    }

    /// Cluster-wide prompt tokens served from prefix caches (token-exact
    /// under `token_granular`, block-rounded otherwise).
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.per_replica.iter().map(|r| r.prefix_hit_tokens).sum()
    }

    /// Cluster-wide prefill tokens admitted beyond free KV after the
    /// decode-growth reserve (zero by construction under token-exact
    /// admission).
    pub fn admission_overcommit_tokens(&self) -> u64 {
        self.per_replica.iter().map(|r| r.admission_overcommit_tokens).sum()
    }

    /// Every submitted request has a recorded outcome somewhere.
    pub fn all_accounted(&self) -> bool {
        self.report.n_requests() == self.submitted
    }

    /// SLO-attaining completions per replica-second of fleet capacity —
    /// the efficiency measure the scaling policies compete on (serving
    /// the same goodput with fewer replica-seconds scores higher).
    pub fn goodput_per_replica_second(&self) -> f64 {
        if self.replica_seconds <= 0.0 {
            return 0.0;
        }
        let good: u64 = self.report.tier_goodput().iter().map(|t| t.good).sum();
        good as f64 / self.replica_seconds
    }
}

struct Replica<X: Executor> {
    /// Taken (and finalized into `result`) when the replica dies.
    orch: Option<Orchestrator<X>>,
    alive: bool,
    result: Option<RunResult>,
}

/// The control plane: owns N orchestrator replicas and drives the full
/// paper loop — registry leases, global prefix index, cache-aware
/// routing, failure detection + re-dispatch, cross-replica co-location.
pub struct ControlPlane<X: Executor> {
    cfg: ControlPlaneConfig,
    replicas: Vec<Replica<X>>,
    /// Registry and index are the shared control-plane state proper —
    /// lock-protected so heartbeat publishes, routing decisions, and
    /// scaler reads stay consistent while replica stepping runs on
    /// worker threads (`cfg.threads ≥ 2`).  The single-threaded
    /// interleave takes the same locks, uncontended.
    registry: Arc<RwLock<InstanceRegistry>>,
    index: Arc<RwLock<GlobalPrefixIndex>>,
    router: FleetRouter,
    clock: EventQueue<CtlEv>,
    workload: Vec<RequestSpec>,
    /// Pull-based arrival source (`run_stream`): at most one pending
    /// `ArriveSpec` at a time, pulled one-ahead as arrivals route.
    stream: Option<Box<dyn Iterator<Item = RequestSpec> + Send>>,
    /// Requests handed to the fleet so far (workload length for `run`,
    /// running count of pulled arrivals for `run_stream`).
    submitted: usize,
    /// Streaming mode: replica + lost reports keep sketches only.
    streaming: bool,
    /// Routing/failover cost model (cloned from the replicas' executor).
    cost: CostModel,
    counters: ControlCounters,
    /// Queue-depth TTFT predictor driving the SLO scaling policy.
    predictor: TtftPredictor,
    /// Min-heap over `(head_event_time.to_bits(), replica)` for the
    /// single-threaded interleave: picking the next replica to step is
    /// O(log n) instead of an O(n) scan per event.  Entries are lazily
    /// invalidated — every mutation that can move a replica's head event
    /// pushes a fresh entry, and stale ones are popped on surfacing.
    replica_heap: BinaryHeap<Reverse<(u64, usize)>>,
    /// Heap maintenance is only paid inside `run_interleaved`.
    use_heap: bool,
    /// Peak live (routed, unrecorded) requests, sampled at heartbeats.
    live_high_water: usize,
    /// Integral of alive-replica count over fleet time.
    replica_seconds: f64,
    last_sample_s: f64,
    /// Failed outcomes for requests no replica could take.
    lost: ServingReport,
    /// Elastic-scaling policy (built from `cfg.scaler`).
    scaler: Option<FleetScaler>,
    /// Factory for scale-up replicas (`(id, shard) -> fresh
    /// orchestrator`, the shard chosen by the scaler's device-budget
    /// policy); without one the scaler can still decommission but never
    /// spawn.  Returning `None` declines the spawn (e.g. the backend's
    /// artifacts became unavailable mid-run) — the fleet keeps serving
    /// at its current size instead of crashing.  `Send` so the whole
    /// control plane stays movable across threads.
    spawner: Option<Box<dyn FnMut(usize, ShardSpec) -> Option<Orchestrator<X>> + Send>>,
}

impl<X: Executor> ControlPlane<X> {
    pub fn new(cfg: ControlPlaneConfig, replicas: Vec<Orchestrator<X>>) -> ControlPlane<X> {
        assert!(!replicas.is_empty(), "control plane needs at least one replica");
        let cost = replicas[0].executor().cost().clone();
        let router = FleetRouter::new(cfg.routing);
        let registry = InstanceRegistry::new(cfg.lease_ttl_s);
        // the scaler always plans against the control plane's chain
        // granularity; token-granular fleets additionally ship sub-chain
        // token ranges instead of whole chains
        let scaler = cfg.scaler.map(|mut sc| {
            sc.block_tokens = cfg.block_tokens;
            sc.token_ranges = sc.token_ranges || cfg.token_granular;
            FleetScaler::new(sc)
        });
        let token_granular = cfg.token_granular;
        let replicas: Vec<Replica<X>> = replicas
            .into_iter()
            .enumerate()
            .map(|(id, mut orch)| {
                orch.set_trace(cfg.trace.for_replica(id));
                if token_granular {
                    orch.enable_cache_delta_tracking();
                }
                orch.start(Vec::new()); // empty workload: arrivals come via submit
                Replica { orch: Some(orch), alive: true, result: None }
            })
            .collect();
        let mut index = GlobalPrefixIndex::new();
        if token_granular {
            index.enable_token_granular(cfg.block_tokens);
        }
        ControlPlane {
            cfg,
            replicas,
            registry: Arc::new(RwLock::new(registry)),
            index: Arc::new(RwLock::new(index)),
            router,
            clock: EventQueue::new(),
            workload: Vec::new(),
            stream: None,
            submitted: 0,
            streaming: false,
            cost,
            counters: ControlCounters::default(),
            predictor: TtftPredictor::new(),
            replica_heap: BinaryHeap::new(),
            use_heap: false,
            live_high_water: 0,
            replica_seconds: 0.0,
            last_sample_s: 0.0,
            lost: ServingReport::new(),
            scaler,
            spawner: None,
        }
    }

    /// Install the replica factory the scaler uses for scale-up.  The
    /// factory gets the new replica's id plus the device-group shape the
    /// scaler picked, and returns an orchestrator that has NOT been
    /// started (the control plane aligns its clock with fleet time and
    /// registers it; it becomes routable after its first heartbeat), or
    /// `None` to decline the spawn — the scale-up is skipped and the
    /// fleet keeps serving at its current size.
    pub fn with_spawner(
        mut self,
        f: impl FnMut(usize, ShardSpec) -> Option<Orchestrator<X>> + Send + 'static,
    ) -> ControlPlane<X> {
        self.spawner = Some(Box::new(f));
        self
    }

    /// Shared handle to the lock-protected instance registry.
    pub fn shared_registry(&self) -> Arc<RwLock<InstanceRegistry>> {
        Arc::clone(&self.registry)
    }

    /// Shared handle to the lock-protected global prefix index.
    pub fn shared_index(&self) -> Arc<RwLock<GlobalPrefixIndex>> {
        Arc::clone(&self.index)
    }

    /// Serve the workload across the fleet to completion.
    ///
    /// With `cfg.threads == 1` (the default) this is the deterministic
    /// single-queue interleave: always advance whichever head event —
    /// control queue or a live replica's queue — is earliest.  With
    /// `cfg.threads ≥ 2` replicas step on worker threads between
    /// control events under the same ordering contract: every replica
    /// event strictly before the next control event runs (in parallel,
    /// replicas are mutually independent between control events), then
    /// the control event fires against the settled fleet state.  Ties
    /// break control-first in both modes, so the two agree on
    /// conservation (routed = completed + lost) and on which requests
    /// complete; only wall-clock concurrency differs.
    pub fn run(mut self, workload: Vec<RequestSpec>) -> FleetResult {
        for (g, spec) in workload.iter().enumerate() {
            self.clock.schedule_at(spec.arrival_s, CtlEv::Arrive(g));
        }
        self.submitted = workload.len();
        self.workload = workload;
        self.start_fleet();
        let truncated = if self.cfg.threads >= 2 {
            self.run_threaded()
        } else {
            self.run_interleaved()
        };
        self.finish(truncated)
    }

    /// Serve a pull-based arrival stream to completion.  Arrivals are
    /// pulled one-ahead — exactly one pending `ArriveSpec` event exists
    /// at any time — and every report sink runs in streaming (sketch-
    /// only) mode, so control-plane memory stays O(live requests) no
    /// matter how many requests the stream yields.  For any finite
    /// stream this completes the same requests `run(stream.collect())`
    /// would; it just never materializes the workload.
    pub fn run_stream(
        mut self,
        stream: impl Iterator<Item = RequestSpec> + Send + 'static,
    ) -> FleetResult {
        self.streaming = true;
        self.lost.set_streaming();
        for rep in &mut self.replicas {
            if let Some(orch) = rep.orch.as_mut() {
                orch.enable_streaming_report();
            }
        }
        let mut stream: Box<dyn Iterator<Item = RequestSpec> + Send> = Box::new(stream);
        if let Some(spec) = stream.next() {
            self.clock.schedule_at(spec.arrival_s.max(0.0), CtlEv::ArriveSpec(spec));
        }
        self.stream = Some(stream);
        self.start_fleet();
        let truncated = if self.cfg.threads >= 2 {
            self.run_threaded()
        } else {
            self.run_interleaved()
        };
        self.finish(truncated)
    }

    /// Shared startup: fault injections, registration, the t=0 report
    /// publish, and the first heartbeat tick.
    fn start_fleet(&mut self) {
        for (t, r) in self.cfg.replica_faults.clone() {
            self.clock.schedule_at(t, CtlEv::Fault(r));
        }
        {
            let mut reg = self.registry.write().expect("registry lock");
            for r in 0..self.replicas.len() {
                reg.register(r, 0.0);
            }
        }
        // initial report sync: registration alone does not grant
        // liveness (a never-heartbeated replica must not be routable),
        // so the starting fleet publishes its first reports at t=0
        // before any arrival can be routed
        self.publish_reports(0.0);
        self.clock.schedule_at(self.cfg.heartbeat_s, CtlEv::Heartbeat);
    }

    /// The deterministic default: one global event order across the
    /// control queue and every replica queue.  Returns `true` when the
    /// turn cap was hit.
    ///
    /// Picking the next replica is a heap pop, not an O(n_replicas)
    /// scan per event — at fleet scale the scan dominated the whole
    /// interleave (every replica event paid for inspecting every other
    /// replica).  Heap entries carry `(time.to_bits(), id)`; `to_bits`
    /// is order-preserving for the non-negative times the clock emits,
    /// and the tuple order reproduces the scan's tie-break exactly
    /// (earliest time, then lowest replica id, control queue winning
    /// ties against replicas).  Entries are lazily invalidated: every
    /// mutation that can move a replica's head event pushes a fresh
    /// entry ([`Self::push_replica_event`]), and an entry that no longer
    /// matches its replica's actual head time is discarded when it
    /// surfaces.
    fn run_interleaved(&mut self) -> bool {
        self.use_heap = true;
        self.replica_heap.clear();
        for i in 0..self.replicas.len() {
            self.push_replica_event(i);
        }
        let mut turns = 0u64;
        loop {
            turns += 1;
            if turns > self.cfg.max_events {
                self.use_heap = false;
                return true;
            }
            let tc = self.clock.peek_time();
            let tr = loop {
                let Some(&Reverse((bits, i))) = self.replica_heap.peek() else {
                    break None;
                };
                let cur = self.replicas.get(i).and_then(|rep| {
                    if rep.alive {
                        rep.orch.as_ref().and_then(|o| o.next_event_time())
                    } else {
                        None
                    }
                });
                match cur {
                    Some(t) if t.to_bits() == bits => break Some((t, i)),
                    // stale (the event was consumed, moved, or the
                    // replica died) — the current head, if any, was
                    // pushed at the mutation that moved it
                    _ => {
                        self.replica_heap.pop();
                    }
                }
            };
            match (tc, tr) {
                (None, None) => {
                    self.use_heap = false;
                    return false;
                }
                (Some(_), None) => self.control_event(),
                (None, Some((_, i))) => self.step_replica(i),
                (Some(c), Some((t, i))) => {
                    if c <= t {
                        self.control_event();
                    } else {
                        self.step_replica(i);
                    }
                }
            }
        }
    }

    /// Record replica `i`'s current head event in the interleave heap
    /// (no-op outside `run_interleaved` and for dead/idle replicas).
    /// Called wherever a replica's head event can move: after stepping
    /// it, after `submit_at` lands a request on it, after a staged
    /// chain adoption, and at spawn.
    fn push_replica_event(&mut self, i: usize) {
        if !self.use_heap {
            return;
        }
        if let Some(rep) = self.replicas.get(i) {
            if rep.alive {
                if let Some(t) = rep.orch.as_ref().and_then(|o| o.next_event_time()) {
                    self.replica_heap.push(Reverse((t.to_bits(), i)));
                }
            }
        }
    }

    /// Threaded stepping: between control events, every live replica
    /// drains its own queue strictly below the next control-event time
    /// on a worker thread (replicas only touch replica-local state, so
    /// the window is race-free by construction; the lock-protected
    /// registry/index are only written by the control thread).  Returns
    /// `true` when the turn cap was hit.
    ///
    /// Threads are scoped per window rather than pooled: a window's
    /// workers borrow `&mut` into `self.replicas` directly, which a
    /// persistent pool cannot do safely.  The spawn/join cost per
    /// window only matters when replica steps are far cheaper than
    /// thread creation (tiny sim steps); real engine iterations dwarf
    /// it, and the deterministic `threads == 1` interleave remains the
    /// right mode for cheap-step simulation.
    fn run_threaded(&mut self) -> bool {
        let threads = self.cfg.threads.max(1);
        let mut turns = 0u64;
        loop {
            // the cap counts processed events like the interleave does:
            // one per control event plus one per replica event stepped
            // in the windows (checked per window, not per event)
            turns += 1;
            if turns > self.cfg.max_events {
                return true;
            }
            // horizon: replica events at exactly the control time wait
            // (ties break control-first, same as the interleave)
            let horizon = self.clock.peek_time();
            let mut stepped_events = 0u64;
            {
                let mut live: Vec<&mut Replica<X>> = self
                    .replicas
                    .iter_mut()
                    .filter(|rep| rep.alive && rep.orch.is_some())
                    .collect();
                let chunk = live.len().div_ceil(threads).max(1);
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for group in live.chunks_mut(chunk) {
                        handles.push(s.spawn(move || {
                            let mut stepped = 0u64;
                            for rep in group.iter_mut() {
                                let orch =
                                    rep.orch.as_mut().expect("live replica has an orchestrator");
                                while orch
                                    .next_event_time()
                                    .is_some_and(|t| horizon.is_none_or(|h| t < h))
                                {
                                    stepped += 1;
                                    if !orch.step() && orch.truncated() {
                                        break;
                                    }
                                }
                            }
                            stepped
                        }));
                    }
                    for h in handles {
                        stepped_events += h.join().expect("replica stepping thread panicked");
                    }
                });
            }
            turns = turns.saturating_add(stepped_events);
            // event-cap wedges fail over on the control thread, exactly
            // as the interleave does right after the wedging step
            let wedged: Vec<usize> = self
                .replicas
                .iter()
                .enumerate()
                .filter(|(_, rep)| rep.alive && rep.orch.as_ref().is_some_and(|o| o.truncated()))
                .map(|(i, _)| i)
                .collect();
            for i in wedged {
                let now = self.clock.now();
                self.fail_replica(i, now);
            }
            match horizon {
                Some(_) => self.control_event(),
                None if stepped_events == 0 => return false,
                None => {}
            }
        }
    }

    fn control_event(&mut self) {
        let Some((t, ev)) = self.clock.next() else {
            return;
        };
        match ev {
            CtlEv::Arrive(g) => {
                let spec = self.workload[g];
                self.route_spec(spec, t, t);
            }
            CtlEv::ArriveSpec(spec) => {
                self.submitted += 1;
                self.route_spec(spec, t, t);
                // one-ahead: pull the next arrival only now, so the
                // stream is never materialized (clamped to fleet time —
                // the generators emit nondecreasing arrivals, but a
                // hostile stream must not rewind the clock)
                if let Some(next) = self.stream.as_mut().and_then(|s| s.next()) {
                    self.clock.schedule_at(next.arrival_s.max(t), CtlEv::ArriveSpec(next));
                }
            }
            CtlEv::Fault(r) => {
                // silent crash: the replica stops executing and stops
                // heartbeating; the lease sweep detects it (§3.5).
                // Out-of-range ids (bad --fail-replica) are ignored.
                if let Some(rep) = self.replicas.get_mut(r) {
                    rep.alive = false;
                }
            }
            CtlEv::Heartbeat => self.on_heartbeat(t),
            CtlEv::RebalanceDone { to, chain, payload } => {
                // staging finished: the chain is now resident on the
                // target (skip if it died while the transfer ran)
                if self.replicas.get(to).map(|r| r.orch.is_some()).unwrap_or(false) {
                    self.index.write().expect("index lock").record(to, &chain);
                    if let Some(orch) = self.replicas[to].orch.as_mut() {
                        orch.adopt_chain(&chain);
                        // real backends land the shipped blocks in the
                        // target engine core; cost-only backends had no
                        // payload to ship
                        if let Some(p) = payload {
                            self.counters.kv_blocks_shipped += p.blocks.len() as u64;
                            orch.executor_mut().import_chain(p);
                        }
                    }
                    self.push_replica_event(to);
                }
            }
        }
    }

    fn step_replica(&mut self, i: usize) {
        let wedged = {
            let orch = self.replicas[i].orch.as_mut().expect("live replica has an orchestrator");
            !orch.step() && orch.truncated()
        };
        if wedged {
            // event-cap wedge: treat as a failure so its work re-queues
            let now = self.clock.now();
            self.fail_replica(i, now);
        }
        self.push_replica_event(i);
    }

    /// Route one request (fresh arrival or failover re-dispatch).
    /// `now` is fleet time of the decision; the target replica admits
    /// the request no earlier than `earliest_s` (≥ now when a staging
    /// delay is charged).
    fn route_spec(&mut self, spec: RequestSpec, now: f64, earliest_s: f64) {
        match self.decide(&spec) {
            None => self.mark_lost(spec, now),
            Some(d) => self.admit(spec, d, earliest_s),
        }
    }

    /// Run the routing policy over the current registry + index state.
    fn decide(&mut self, spec: &RequestSpec) -> Option<RouteDecision> {
        let registry = self.registry.read().expect("registry lock");
        let index = self.index.read().expect("index lock");
        let ctx = RouterCtx {
            registry: &registry,
            index: &index,
            cost: &self.cost,
            xfer: &self.cfg.xfer,
            coloc: &self.cfg.colocation,
            block_tokens: self.cfg.block_tokens,
        };
        self.router.route(spec, &ctx)
    }

    /// Every lease gone: the request has nowhere to run.
    fn mark_lost(&mut self, spec: RequestSpec, now: f64) {
        self.counters.unroutable += 1;
        self.cfg.trace.instant(now, None, None, InstantKind::Failure);
        self.lost.record(RequestOutcome {
            arrival_s: spec.arrival_s,
            first_token_s: now,
            finish_s: now,
            input_tokens: spec.input_tokens,
            output_tokens: 0,
            failed: true,
            prefix_hit_tokens: 0,
            phases: PhaseBreakdown::default(),
            tier: spec.tier,
        });
    }

    /// Hand a routed request to its replica (counters, optimistic index
    /// and load bookkeeping, admission no earlier than `earliest_s`).
    fn admit(&mut self, spec: RequestSpec, d: RouteDecision, earliest_s: f64) {
        if d.matched_blocks > 0 || d.matched_tokens > 0 {
            self.counters.routed_by_cache_hit += 1;
        }
        if d.offline_steered {
            self.counters.offline_steered += 1;
        }
        let chain = FleetRouter::chain_for(&spec, self.cfg.block_tokens);
        if self.cfg.token_granular {
            // optimistic: the target caches this token path on admit
            // (feeds both the cluster radix and the flat chain view,
            // including a sub-block prefix too short for any chain)
            let toks = FleetRouter::tokens_for(&spec);
            if !toks.is_empty() {
                self.index.write().expect("index lock").record_tokens(d.replica, &toks);
            }
        } else if !chain.is_empty() {
            // optimistic: the target caches this chain on admit
            self.index.write().expect("index lock").record(d.replica, &chain);
        }
        if !chain.is_empty() {
            if let Some(s) = self.scaler.as_mut() {
                s.note_route(&chain, d.replica);
            }
        }
        // token-exact admission math: the target only computes the
        // unmatched prompt suffix, so only that share loads its queue
        let charge = if self.cfg.token_granular {
            spec.input_tokens.saturating_sub(d.matched_tokens)
        } else {
            spec.input_tokens
        };
        self.registry.write().expect("registry lock").note_dispatch(d.replica, charge);
        self.replicas[d.replica]
            .orch
            .as_mut()
            .expect("routed replica is alive")
            .submit_at(spec, earliest_s);
        self.push_replica_event(d.replica);
    }

    /// Collect load reports + cache summaries from live replicas (the
    /// heartbeat publish; also run once at t=0 so the starting fleet is
    /// routable before its first tick).
    fn publish_reports(&mut self, now: f64) {
        let token_granular = self.cfg.token_granular;
        let mut registry = self.registry.write().expect("registry lock");
        let mut index = self.index.write().expect("index lock");
        for r in 0..self.replicas.len() {
            if !self.replicas[r].alive {
                continue; // crashed or wedged: no lease renewal
            }
            let Some(orch) = self.replicas[r].orch.as_mut() else {
                continue;
            };
            let report = orch.load_report();
            registry.heartbeat(r, report, now);
            if token_granular {
                // incremental publish: only the residency mutations since
                // the previous heartbeat, replayed in event order (the
                // satellite fix for the full-summary republish)
                let delta = orch.cache_summary_delta();
                index.publish_delta(r, &delta);
            } else {
                index.publish(r, &orch.cache_summary());
            }
        }
    }

    fn on_heartbeat(&mut self, now: f64) {
        self.counters.heartbeats += 1;
        // capacity + live-state sampling: replica-seconds integrate the
        // alive count between ticks (the goodput-per-replica-second
        // denominator), and the live high-water mark is the streaming
        // bounded-memory witness
        let n_alive = self.replicas.iter().filter(|r| r.alive && r.orch.is_some()).count();
        self.replica_seconds += n_alive as f64 * (now - self.last_sample_s).max(0.0);
        self.last_sample_s = now;
        let live = self.submitted.saturating_sub(self.recorded());
        self.live_high_water = self.live_high_water.max(live);
        self.publish_reports(now);
        let dead = self.registry.write().expect("registry lock").sweep(now);
        for r in dead {
            if self.replicas[r].orch.is_some() {
                self.counters.lease_expiries += 1;
                self.fail_replica(r, now);
            }
        }
        // elastic-scaling tick (§3.1): plan against the state just
        // published, then apply (spawn / decommission / rebalance)
        let policy = self.cfg.scaler.map(|s| s.policy).unwrap_or_default();
        let mut actions = Vec::new();
        if let Some(s) = self.scaler.as_mut() {
            let registry = self.registry.read().expect("registry lock");
            let index = self.index.read().expect("index lock");
            actions = match policy {
                ScalePolicy::Backlog => s.plan(now, &registry, &index),
                ScalePolicy::Slo => {
                    let (acts, violations) =
                        s.plan_slo(now, &registry, &index, &self.cost, &self.predictor);
                    self.counters.slo_violations_predicted += violations;
                    acts
                }
            };
        }
        for a in actions {
            self.apply_scale_action(a, now);
        }
        // keep ticking while ANY control or replica event is pending —
        // not merely while submitted requests are unaccounted.  Gating
        // on `accounted_all` alone stopped heartbeats forever the moment
        // all currently-submitted requests were momentarily accounted;
        // any later submission (exactly what autoscaled/decommission
        // re-dispatch creates) then ran against a registry whose leases
        // had silently gone stale and expired en masse on revival.
        if self.work_pending() {
            self.clock.schedule_in(self.cfg.heartbeat_s, CtlEv::Heartbeat);
        }
    }

    /// Anything left for the fleet to do: unaccounted requests, queued
    /// control events (arrivals, faults, staging completions), or
    /// pending events on any live replica.
    fn work_pending(&self) -> bool {
        !self.accounted_all()
            || !self.clock.is_empty()
            || self.replicas.iter().any(|rep| {
                rep.alive && rep.orch.as_ref().and_then(|o| o.next_event_time()).is_some()
            })
    }

    fn apply_scale_action(&mut self, action: ScaleAction, now: f64) {
        match action {
            ScaleAction::Up { shard } => self.scale_up(now, shard),
            ScaleAction::Down(r) => self.decommission_replica(r, now),
            ScaleAction::Rebalance { chain, from, to, token_lo, token_hi } => {
                self.start_rebalance(chain, from, to, token_lo, token_hi)
            }
        }
    }

    /// Spawn a fresh replica with the scaler-chosen device-group shape:
    /// clock aligned to fleet time, registered now, routable after its
    /// first heartbeat publishes a load report.
    fn scale_up(&mut self, now: f64, shard: ShardSpec) {
        // clamp against every live replica, including ones still pending
        // their first heartbeat (the registry cannot see those yet)
        let live = self.replicas.iter().filter(|r| r.orch.is_some()).count();
        let max = self.cfg.scaler.map(|s| s.max_replicas).unwrap_or(usize::MAX);
        if live >= max {
            return;
        }
        let Some(spawn) = self.spawner.as_mut() else {
            return; // no factory: the scaler can only shrink this fleet
        };
        let id = self.replicas.len();
        let Some(mut orch) = spawn(id, shard) else {
            return; // factory declined (e.g. backend lost its artifacts)
        };
        orch.set_trace(self.cfg.trace.for_replica(id));
        if self.cfg.token_granular {
            orch.enable_cache_delta_tracking();
        }
        if self.streaming {
            orch.enable_streaming_report();
        }
        orch.start_at(Vec::new(), now);
        self.replicas.push(Replica { orch: Some(orch), alive: true, result: None });
        self.push_replica_event(id);
        self.registry.write().expect("registry lock").register(id, now);
        self.counters.scale_ups += 1;
        self.cfg.trace.instant(now, Some(id), None, InstantKind::ScaleUp);
        // warm start (§3.4 proactive movement): pre-stage the hottest
        // prefix chains onto the spawned replica while it waits for its
        // first heartbeat — the staging delay runs concurrently with the
        // registration window, so by the time the registry makes it
        // routable the top shared prefixes hit its local cache instead
        // of costing a from-scratch prefill each.
        let k = self.cfg.scaler.map(|s| s.warm_start_chains).unwrap_or(0);
        if k > 0 {
            let chains = self.scaler.as_ref().map(|s| s.hottest_chains(k)).unwrap_or_default();
            for chain in chains {
                // only chains some live replica still holds can ship KV
                let best = self.index.read().expect("index lock").best_match(&chain);
                let Some((src, _, _)) = best else { continue };
                self.counters.warm_starts += 1;
                self.cfg.trace.instant(now, Some(id), None, InstantKind::WarmStart);
                self.stage_chain(chain, src, id, 0);
            }
        }
    }

    /// Gracefully decommission a replica: stop routing to it, drain its
    /// in-flight work, and re-dispatch onto the survivors.  Distinct
    /// from crash failover — no lease expiry, and the source KV is still
    /// live for staging, so nothing is lost and migration is judged
    /// against a real surviving copy.
    fn decommission_replica(&mut self, r: usize, now: f64) {
        let Some(mut orch) = self.replicas[r].orch.take() else {
            return; // already gone
        };
        self.replicas[r].alive = false;
        self.registry.write().expect("registry lock").deregister(r);
        self.router.forget(r);
        if let Some(s) = self.scaler.as_mut() {
            s.forget_replica(r);
        }
        self.counters.scale_downs += 1;
        self.cfg.trace.instant(now, Some(r), None, InstantKind::ScaleDown);
        let drained = orch.drain_in_flight();
        let (result, mut executor) = orch.finish();
        self.replicas[r].result = Some(result);
        // the victim's index entries stay visible during re-dispatch so
        // the recompute-vs-migrate decision can see the staging tier of
        // the still-live source copies — and the drained executor is
        // kept alive as the KV export source for migrating targets
        self.redispatch_drained(r, drained, now, Some(&mut executor));
        self.index.write().expect("index lock").remove(r);
    }

    /// Begin a planned hot-prefix migration: charge the staging +
    /// transfer cost now, land the chain on the target when it elapses.
    /// `[token_lo, token_hi)` is the sub-chain range the scaler planned
    /// to ship — in token-range mode the target already holds the chain
    /// below `token_lo`, so only the missing suffix is billed; legacy
    /// plans always cover the whole chain (`lo = 0`).
    fn start_rebalance(
        &mut self,
        mut chain: Vec<u64>,
        from: usize,
        to: usize,
        token_lo: u64,
        token_hi: u64,
    ) {
        self.counters.kv_rebalances += 1;
        self.cfg.trace.instant(self.clock.now(), Some(to), None, InstantKind::Rebalance);
        let bt = self.cfg.block_tokens.max(1);
        chain.truncate((token_hi / bt) as usize);
        self.stage_chain(chain, from, to, token_lo);
    }

    /// Shared staging mechanics for planned rebalancing and scale-up
    /// warm start: charge the `TransferEngine` cost for shipping the
    /// chain's KV off `from`'s slowest holding tier, then land it on
    /// `to` (global index + local `adopt_chain`) when the delay elapses.
    /// The chain is truncated to the prefix `from` actually holds —
    /// staging the unmatched tail would land (and bill for) KV that
    /// exists nowhere in the fleet, crediting the target with phantom
    /// prefix hits.  When the source backend can ship real blocks
    /// ([`Executor::export_chain`]), the payload rides the staging event
    /// and lands in the target's engine core at adoption.
    ///
    /// `skip_tokens` is the prefix the target already holds (token-range
    /// rebalancing): those blocks still land logically via `adopt_chain`
    /// but are not billed for transfer — only the missing suffix moves.
    /// Legacy callers pass 0 and bill the whole staged chain.
    fn stage_chain(&mut self, mut chain: Vec<u64>, from: usize, to: usize, skip_tokens: u64) {
        let (matched, tier) = self.index.read().expect("index lock").match_prefix(from, &chain);
        chain.truncate(matched);
        if chain.is_empty() {
            return; // the source no longer holds any of it
        }
        let skip_blocks = (skip_tokens / self.cfg.block_tokens.max(1)).min(chain.len() as u64);
        let ship_blocks = chain.len() as u64 - skip_blocks;
        if ship_blocks == 0 {
            return; // the target already holds everything the plan covers
        }
        let payload = self
            .replicas
            .get_mut(from)
            .and_then(|r| r.orch.as_mut())
            .and_then(|o| o.executor_mut().export_chain(&chain));
        let tier = tier.unwrap_or(Tier::Dram);
        let bytes =
            ship_blocks as f64 * self.cfg.block_tokens as f64 * self.cost.model.kv_bytes_per_token();
        let delay = self.cfg.xfer.load_to_hbm_s(tier, bytes) + self.cfg.xfer.migrate_s(bytes);
        self.counters.rebalance_staging_s += delay;
        self.clock.schedule_in(delay, CtlEv::RebalanceDone { to, chain, payload });
    }

    /// A replica is dead: finalize it, then re-dispatch everything it
    /// had in flight onto the survivors (§3.5), deciding
    /// recompute-vs-migrate per request against the surviving global
    /// cache.
    fn fail_replica(&mut self, r: usize, now: f64) {
        let Some(mut orch) = self.replicas[r].orch.take() else {
            return; // already failed over
        };
        self.replicas[r].alive = false;
        self.registry.write().expect("registry lock").deregister(r);
        // HBM/DRAM copies died with the replica
        self.index.write().expect("index lock").remove(r);
        self.router.forget(r);
        if let Some(s) = self.scaler.as_mut() {
            s.forget_replica(r);
        }
        self.counters.failovers += 1;
        self.cfg.trace.instant(now, Some(r), None, InstantKind::Failover);
        let drained = orch.drain_in_flight();
        let (result, _executor) = orch.finish();
        self.replicas[r].result = Some(result);
        // crash: no export source — the KV is gone, survivors recompute
        self.redispatch_drained(r, drained, now, None);
    }

    /// Re-dispatch a drained replica's in-flight work onto the
    /// survivors (§3.5), deciding recompute-vs-migrate per request.
    ///
    /// The decision is judged against the replica the router actually
    /// chose: if THAT replica still holds (part of) the request's
    /// prefix, migration charges the staging + transfer delay up front
    /// and the survivor then serves the prefix from its own cache.  On
    /// crash failover (`source = None`) a cache-cold target simply
    /// recomputes (re-runs prefill on admit) with no phantom delay — so
    /// round-robin failover is never billed for KV it cannot reuse.  On
    /// a planned drain (`source = Some`) the source is still alive, so
    /// a cold target can additionally weigh staging the KV from the
    /// source's surviving copy against recomputing — and when the
    /// backend ships real blocks, they are exported from the drained
    /// source executor before it is dropped.
    fn redispatch_drained(
        &mut self,
        victim: usize,
        drained: Vec<InFlightSnapshot>,
        now: f64,
        mut source: Option<&mut X>,
    ) {
        let planned = source.is_some();
        // one physical export per (chain, target): drained requests
        // sharing a hot prefix would otherwise queue N identical block
        // copies; later events still adopt the chain logically
        let mut shipped: HashSet<(u64, usize)> = HashSet::new();
        for snap in drained {
            self.counters.redispatched_requests += 1;
            self.counters.redispatched_tokens += snap.context_tokens;
            let Some(d) = self.decide(&snap.spec) else {
                self.mark_lost(snap.spec, now);
                continue;
            };
            let mut earliest = now;
            if snap.context_tokens > 0 {
                let chain = FleetRouter::chain_for(&snap.spec, self.cfg.block_tokens);
                let index = self.index.read().expect("index lock");
                let (matched, tier) = index.match_prefix(d.replica, &chain);
                let replica_tier = if matched > 0 {
                    tier
                } else if planned {
                    // graceful drain: the source still holds the KV
                    // (worst case a DRAM copy) and can ship it — on a
                    // crash the victim's index entries are already gone,
                    // so this lookup only runs on the planned path
                    index.match_prefix(victim, &chain).1.or(Some(Tier::Dram))
                } else {
                    None
                };
                drop(index);
                let interrupted = InterruptedRequest {
                    request: 0, // fleet-level: per-request ids stay replica-local
                    context_tokens: snap.context_tokens,
                    replica_tier,
                };
                let (action, delay) = plan_recovery(&interrupted, &self.cost, &self.cfg.xfer);
                if action == RecoveryAction::Migrate {
                    self.counters.redispatch_migrations += 1;
                    earliest = now + delay;
                    if planned && matched == 0 && !chain.is_empty() {
                        // the staged KV shipped from the source includes
                        // the prefix chain — it lands on the cold target
                        // when the transfer completes (same mechanism as
                        // planned rebalancing), so the request does not
                        // pay the transfer delay AND a from-scratch
                        // prefill of the shared prefix
                        let payload = chain
                            .last()
                            .map(|&h| (h, d.replica))
                            .filter(|&key| shipped.insert(key))
                            .and_then(|_| source.as_mut().and_then(|x| x.export_chain(&chain)));
                        self.clock.schedule_in(
                            delay,
                            CtlEv::RebalanceDone { to: d.replica, chain, payload },
                        );
                    }
                }
            }
            // original arrival preserved but admission bounded below by
            // fleet time: drain/failover delay lands in the request's E2E
            self.admit(snap.spec, d, earliest);
        }
    }

    /// Outcomes recorded anywhere in the fleet: completed/failed on a
    /// live replica, finalized in a dead replica's result, or lost as
    /// unroutable.
    fn recorded(&self) -> usize {
        let mut recorded = self.lost.n_requests();
        for rep in &self.replicas {
            recorded += match (&rep.result, &rep.orch) {
                (Some(res), _) => res.report.n_requests(),
                (None, Some(orch)) => orch.n_recorded(),
                (None, None) => 0,
            };
        }
        recorded
    }

    /// Every submitted request has an outcome recorded somewhere.
    fn accounted_all(&self) -> bool {
        self.recorded() >= self.submitted
    }

    fn finish(mut self, truncated: bool) -> FleetResult {
        self.counters.index_published_entries =
            self.index.read().expect("index lock").published_entries();
        // close the replica-second integral at the last event time, so
        // runs shorter than one heartbeat still report capacity
        let end = self.clock.now();
        let n_alive = self.replicas.iter().filter(|r| r.alive && r.orch.is_some()).count();
        self.replica_seconds += n_alive as f64 * (end - self.last_sample_s).max(0.0);
        let mut report =
            if self.streaming { ServingReport::streaming() } else { ServingReport::new() };
        report.merge(&self.lost);
        let n_replicas_final = self.replicas.iter().filter(|r| r.orch.is_some()).count();
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        for rep in std::mem::take(&mut self.replicas) {
            let result = match (rep.result, rep.orch) {
                (Some(res), _) => res,
                (None, Some(orch)) => orch.finish().0,
                (None, None) => unreachable!("replica lost both orchestrator and result"),
            };
            report.merge(&result.report);
            per_replica.push(result);
        }
        let truncated = truncated || per_replica.iter().any(|r| r.truncated);
        FleetResult {
            report,
            per_replica,
            counters: self.counters,
            submitted: self.submitted,
            n_replicas_final,
            live_high_water: self.live_high_water,
            replica_seconds: self.replica_seconds,
            truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orchestrator::OrchestratorConfig;
    use crate::testutil::FixedCostExecutor as FixedCost;

    fn fleet(n: usize) -> Vec<Orchestrator<FixedCost>> {
        (0..n)
            .map(|_| {
                let cfg = OrchestratorConfig {
                    n_instances: 1,
                    prefix_cache: true,
                    ..Default::default()
                };
                Orchestrator::new(cfg, FixedCost::new(0.01))
            })
            .collect()
    }

    #[test]
    fn fleet_completes_and_accounts_everything() {
        let workload: Vec<RequestSpec> =
            (0..12).map(|i| RequestSpec::text(i as f64 * 0.05, 256, 16)).collect();
        let n = workload.len();
        let cp = ControlPlane::new(ControlPlaneConfig::default(), fleet(3));
        let res = cp.run(workload);
        assert_eq!(res.submitted, n);
        assert!(res.all_accounted(), "{} recorded != {n}", res.report.n_requests());
        assert_eq!(res.report.n_completed(), n);
        assert!(!res.truncated);
        assert!(res.counters.heartbeats > 0);
        assert_eq!(res.counters.failovers, 0);
        // work spread beyond a single replica
        let with_work = res.per_replica.iter().filter(|r| r.iterations > 0).count();
        assert!(with_work >= 2, "load must spread: {with_work} replicas worked");
    }

    #[test]
    fn replica_crash_fails_over_without_losing_requests() {
        let workload: Vec<RequestSpec> =
            (0..10).map(|i| RequestSpec::text(i as f64 * 0.05, 256, 400)).collect();
        let n = workload.len();
        let cfg = ControlPlaneConfig {
            replica_faults: vec![(1.0, 0)],
            ..Default::default()
        };
        let res = ControlPlane::new(cfg, fleet(2)).run(workload);
        assert!(res.all_accounted(), "{} recorded != {n}", res.report.n_requests());
        assert_eq!(res.report.n_completed(), n, "survivors must finish everything");
        assert_eq!(res.counters.failovers, 1);
        assert_eq!(res.counters.lease_expiries, 1, "death detected via lease expiry");
        assert!(res.counters.redispatched_requests > 0, "victim had work in flight");
        assert!(res.counters.redispatched_tokens > 0);
        // the dead replica's pre-crash completions (if any) plus the
        // survivor's recordings cover the workload exactly once
        let per: usize = res.per_replica.iter().map(|r| r.report.n_requests()).sum();
        assert_eq!(per, n);
    }

    #[test]
    fn all_replicas_dead_marks_requests_unroutable() {
        let mut workload = vec![RequestSpec::text(0.0, 128, 200)];
        workload.extend((0..4).map(|i| RequestSpec::text(3.0 + i as f64 * 0.1, 128, 8)));
        let n = workload.len();
        let cfg = ControlPlaneConfig {
            replica_faults: vec![(0.5, 0), (0.5, 1)],
            ..Default::default()
        };
        let res = ControlPlane::new(cfg, fleet(2)).run(workload);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_requests(), n);
        assert_eq!(res.report.n_completed(), 0, "nothing can run without replicas");
        assert_eq!(res.counters.failovers, 2);
        assert_eq!(res.counters.unroutable as usize, n);
    }

    #[test]
    fn heartbeats_continue_while_replica_events_pend() {
        // regression: heartbeats were rescheduled only while some
        // submitted request was unaccounted.  Here the single request
        // completes within ~0.1s but the replica still owes itself a
        // Recover event ~1.5s out (instance fault + RecoveryModel);
        // heartbeats must keep ticking until the fleet is actually
        // quiescent, or every lease goes silently stale and expires en
        // masse the moment later work (autoscale/decommission
        // re-dispatch) revives the fleet.
        let cfg = OrchestratorConfig {
            n_instances: 2,
            faults: vec![(0.05, 0)],
            ..Default::default()
        };
        let orchs = vec![Orchestrator::new(cfg, FixedCost::new(0.01))];
        let res = ControlPlane::new(ControlPlaneConfig::default(), orchs)
            .run(vec![RequestSpec::text(0.0, 64, 4)]);
        assert_eq!(res.report.n_completed(), 1);
        assert_eq!(res.counters.lease_expiries, 0, "healthy replica must never be swept");
        // Recover fires no earlier than RecoveryModel::restart_s (1.0s)
        // after the fault, so at least ticks 0.25..1.0 must fire; the
        // pre-fix behavior stopped after the single 0.25 tick.
        assert!(
            res.counters.heartbeats >= 4,
            "heartbeats stopped while the replica's Recover event was pending: \
             only {} ticks",
            res.counters.heartbeats
        );
    }

    #[test]
    fn autoscaler_spawns_and_decommissions_without_losing_requests() {
        let mk = || {
            let cfg = OrchestratorConfig {
                n_instances: 1,
                prefix_cache: true,
                ..Default::default()
            };
            Orchestrator::new(cfg, FixedCost::new(0.05))
        };
        let cfg = ControlPlaneConfig {
            scaler: Some(ScalerConfig {
                capacity_target_tokens: 512,
                min_replicas: 1,
                max_replicas: 3,
                cooldown_s: 0.3,
                ..Default::default()
            }),
            ..Default::default()
        };
        // sustained burst (arrivals keep coming while spawned replicas
        // become routable), then a long quiet gap, then one straggler
        let mut w: Vec<RequestSpec> =
            (0..16).map(|i| RequestSpec::text(i as f64 * 0.2, 2048, 32)).collect();
        w.push(RequestSpec::text(14.0, 64, 4));
        let n = w.len();
        let res = ControlPlane::new(cfg, vec![mk()]).with_spawner(move |_, _| Some(mk())).run(w);
        assert!(res.all_accounted());
        assert_eq!(
            res.report.n_completed(),
            n,
            "zero lost requests across scale-up and decommission drain: {:?}",
            res.counters
        );
        assert_eq!(res.counters.unroutable, 0);
        assert_eq!(res.counters.failovers, 0, "planned shrink is not a failover");
        assert_eq!(res.counters.lease_expiries, 0);
        assert!(res.counters.scale_ups >= 1, "burst must grow the fleet: {:?}", res.counters);
        assert!(
            res.counters.scale_downs >= 1,
            "quiet gap must shrink the fleet: {:?}",
            res.counters
        );
        assert!(res.per_replica.len() > 1, "spawned replicas report results");
        assert!(
            res.per_replica[1..].iter().any(|r| r.iterations > 0),
            "a spawned replica must actually serve traffic: {:?}",
            res.per_replica.iter().map(|r| r.iterations).collect::<Vec<_>>()
        );
        assert!(
            res.n_replicas_final < res.per_replica.len(),
            "decommissioned replicas must not survive to the end"
        );
    }

    #[test]
    fn scale_up_warm_starts_the_spawned_replica() {
        let mk = || {
            let cfg = OrchestratorConfig {
                n_instances: 1,
                prefix_cache: true,
                ..Default::default()
            };
            Orchestrator::new(cfg, FixedCost::new(0.05))
        };
        let cfg = ControlPlaneConfig {
            scaler: Some(ScalerConfig {
                capacity_target_tokens: 512,
                min_replicas: 1,
                max_replicas: 3,
                cooldown_s: 0.3,
                warm_start_chains: 2,
                ..Default::default()
            }),
            ..Default::default()
        };
        // a hot shared prefix dominates the burst, so the route tracker
        // has chains to pre-stage when the scaler grows the fleet
        let w: Vec<RequestSpec> = (0..16)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.2, 2048, 32);
                s.prefix_group = 7;
                s.shared_prefix = 512;
                s
            })
            .collect();
        let n = w.len();
        let res = ControlPlane::new(cfg, vec![mk()]).with_spawner(move |_, _| Some(mk())).run(w);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n, "warm start must lose nothing: {:?}", res.counters);
        assert!(res.counters.scale_ups >= 1, "burst must grow the fleet: {:?}", res.counters);
        assert!(
            res.counters.warm_starts >= 1,
            "spawn under a hot prefix must pre-stage it: {:?}",
            res.counters
        );
        assert!(res.counters.rebalance_staging_s > 0.0, "staging cost must be charged");
        assert!(res.per_replica.len() > 1, "a replica was actually spawned");
    }

    #[test]
    fn hot_prefix_concentration_triggers_planned_rebalance() {
        let cfg = ControlPlaneConfig {
            scaler: Some(ScalerConfig {
                // fixed-size fleet: isolate the rebalancing half
                min_replicas: 2,
                max_replicas: 2,
                capacity_target_tokens: u64::MAX / 4,
                hot_prefix_routes: 3,
                ..Default::default()
            }),
            ..Default::default()
        };
        let w: Vec<RequestSpec> = (0..10)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.3, 1024, 64);
                s.prefix_group = 1;
                s.shared_prefix = 512;
                s
            })
            .collect();
        let n = w.len();
        let res = ControlPlane::new(cfg, fleet(2)).run(w);
        assert_eq!(res.report.n_completed(), n);
        assert!(
            res.counters.kv_rebalances >= 1,
            "one group dogpiling one replica must trigger a planned migration: {:?}",
            res.counters
        );
        assert!(res.counters.rebalance_staging_s > 0.0, "staging cost must be charged");
        assert!(res.prefix_hits() > 0);
        assert_eq!(res.counters.failovers, 0);
    }

    #[test]
    fn token_granular_fleet_beats_block_rounding_with_zero_overcommit() {
        use crate::coordinator::BatchConfig;
        // 300-token shared prefix (NOT a multiple of the 64-token
        // block): block-granular credit rounds down to 256 per hit,
        // token-granular credits all 300 — pinned at pipeline depth 2
        // together with zero admission overcommit and the smaller
        // incremental republish volume
        let mk_fleet = |token: bool| -> Vec<Orchestrator<FixedCost>> {
            (0..2)
                .map(|_| {
                    let cfg = OrchestratorConfig {
                        n_instances: 1,
                        prefix_cache: true,
                        prefix_token_granular: token,
                        pipeline_depth: 2,
                        batch: BatchConfig { token_admission: token, ..BatchConfig::default() },
                        ..Default::default()
                    };
                    Orchestrator::new(cfg, FixedCost::new(0.01))
                })
                .collect()
        };
        let w: Vec<RequestSpec> = (0..12)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.3, 512, 4);
                s.prefix_group = 2;
                s.shared_prefix = 300;
                s
            })
            .collect();
        let n = w.len();
        let legacy =
            ControlPlane::new(ControlPlaneConfig::default(), mk_fleet(false)).run(w.clone());
        let cfg = ControlPlaneConfig { token_granular: true, ..Default::default() };
        let token = ControlPlane::new(cfg, mk_fleet(true)).run(w);
        assert!(legacy.all_accounted() && token.all_accounted());
        assert_eq!(legacy.report.n_completed(), n);
        assert_eq!(token.report.n_completed(), n);
        assert!(
            token.prefix_hit_tokens() > legacy.prefix_hit_tokens(),
            "token-exact credit must beat block rounding on an unaligned prefix: \
             token {} vs block {}",
            token.prefix_hit_tokens(),
            legacy.prefix_hit_tokens()
        );
        assert_eq!(
            token.admission_overcommit_tokens(),
            0,
            "token-exact admission never overcommits"
        );
        assert!(
            token.counters.index_published_entries < legacy.counters.index_published_entries,
            "incremental publish must ship fewer entries than full republish: \
             delta {} vs full {}",
            token.counters.index_published_entries,
            legacy.counters.index_published_entries
        );
        assert!(token.counters.index_published_entries > 0, "deltas must actually publish");
        assert!(token.counters.routed_by_cache_hit > 0);
    }

    #[test]
    fn fleet_run_is_deterministic() {
        let workload: Vec<RequestSpec> = (0..8)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.1, 512, 32);
                s.prefix_group = 1 + (i % 2);
                s.shared_prefix = 256;
                s
            })
            .collect();
        let cfg = ControlPlaneConfig { replica_faults: vec![(0.8, 1)], ..Default::default() };
        let r1 = ControlPlane::new(cfg.clone(), fleet(3)).run(workload.clone());
        let r2 = ControlPlane::new(cfg, fleet(3)).run(workload);
        assert_eq!(r1.report.n_completed(), r2.report.n_completed());
        assert_eq!(r1.counters.routed_by_cache_hit, r2.counters.routed_by_cache_hit);
        assert_eq!(r1.counters.redispatched_tokens, r2.counters.redispatched_tokens);
        assert_eq!(r1.prefix_hits(), r2.prefix_hits());
        let i1: Vec<u64> = r1.per_replica.iter().map(|r| r.iterations).collect();
        let i2: Vec<u64> = r2.per_replica.iter().map(|r| r.iterations).collect();
        assert_eq!(i1, i2);
    }

    #[test]
    fn threaded_stepping_matches_the_interleave() {
        // replicas are mutually independent between control events, so
        // the threaded window (all replica events strictly before the
        // next control event, control-first on ties) must agree with
        // the single-queue interleave on conservation and completions
        let workload: Vec<RequestSpec> = (0..14)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.07, 512, 24);
                s.prefix_group = 1 + (i % 3);
                s.shared_prefix = 256;
                s
            })
            .collect();
        let single = ControlPlane::new(ControlPlaneConfig::default(), fleet(3))
            .run(workload.clone());
        let cfg = ControlPlaneConfig { threads: 2, ..Default::default() };
        let threaded = ControlPlane::new(cfg, fleet(3)).run(workload);
        assert_eq!(threaded.submitted, single.submitted);
        assert!(single.all_accounted() && threaded.all_accounted());
        assert_eq!(threaded.report.n_completed(), single.report.n_completed());
        assert_eq!(threaded.counters.unroutable, single.counters.unroutable);
        assert_eq!(threaded.counters.routed_by_cache_hit, single.counters.routed_by_cache_hit);
        assert_eq!(threaded.prefix_hits(), single.prefix_hits());
        let i1: Vec<u64> = single.per_replica.iter().map(|r| r.iterations).collect();
        let i2: Vec<u64> = threaded.per_replica.iter().map(|r| r.iterations).collect();
        assert_eq!(i1, i2, "per-replica work must be identical across modes");
    }

    #[test]
    fn threaded_stepping_survives_a_replica_crash() {
        let workload: Vec<RequestSpec> =
            (0..10).map(|i| RequestSpec::text(i as f64 * 0.05, 256, 400)).collect();
        let n = workload.len();
        let cfg = ControlPlaneConfig {
            replica_faults: vec![(1.0, 0)],
            threads: 3,
            ..Default::default()
        };
        let res = ControlPlane::new(cfg, fleet(2)).run(workload);
        assert!(res.all_accounted(), "{} recorded != {n}", res.report.n_requests());
        assert_eq!(res.report.n_completed(), n, "survivors must finish everything");
        assert_eq!(res.counters.failovers, 1);
    }

    #[test]
    fn control_counters_round_trip_the_registry() {
        let c = ControlCounters {
            routed_by_cache_hit: 1,
            failovers: 2,
            redispatched_requests: 3,
            redispatched_tokens: 4,
            redispatch_migrations: 5,
            offline_steered: 6,
            unroutable: 7,
            heartbeats: 8,
            lease_expiries: 9,
            scale_ups: 10,
            scale_downs: 11,
            kv_rebalances: 12,
            warm_starts: 13,
            kv_blocks_shipped: 14,
            rebalance_staging_s: 1.5,
            index_published_entries: 16,
            slo_violations_predicted: 17,
        };
        let mut reg = MetricsRegistry::new();
        c.export_metrics(&mut reg);
        let back = ControlCounters::from_registry(&reg);
        assert_eq!(format!("{c:?}"), format!("{back:?}"));
    }

    #[test]
    fn traced_fleet_failover_keeps_spans_nested() {
        use crate::obs::{check_nesting, TraceEventKind};
        let workload: Vec<RequestSpec> =
            (0..10).map(|i| RequestSpec::text(i as f64 * 0.05, 256, 400)).collect();
        let trace = TraceHandle::recording();
        let cfg = ControlPlaneConfig {
            replica_faults: vec![(1.0, 0)],
            trace: trace.clone(),
            ..Default::default()
        };
        let res = ControlPlane::new(cfg, fleet(2)).run(workload);
        assert_eq!(res.counters.failovers, 1);
        let events = trace.drain();
        assert!(!events.is_empty(), "traced run must record events");
        // both replica tracks present, and the cluster-scope Failover
        // instant rides the control-plane track (replica = None)
        assert!(events.iter().any(|e| e.replica == Some(0)));
        assert!(events.iter().any(|e| e.replica == Some(1)));
        assert!(events
            .iter()
            .any(|e| e.replica.is_none()
                && matches!(e.kind, TraceEventKind::Instant(InstantKind::Failover))));
        // span discipline holds across the crash + re-dispatch
        check_nesting(&events).expect("failover trace must stay well-nested");
    }

    #[test]
    fn streaming_run_matches_the_collected_run() {
        // run_stream over an iterator must complete exactly the same
        // requests as run() over the collected Vec — the streaming mode
        // only changes what is *retained*, not what is *served*
        // 0.07 spacing keeps arrivals off the 0.25 heartbeat grid — a
        // coinciding arrival+heartbeat would order differently across
        // the two modes (run() enqueues all arrivals up front)
        let workload: Vec<RequestSpec> = (0..12)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.07, 256, 16);
                s.prefix_group = 1 + (i % 2);
                s.shared_prefix = 128;
                s
            })
            .collect();
        let n = workload.len();
        let collected =
            ControlPlane::new(ControlPlaneConfig::default(), fleet(3)).run(workload.clone());
        let streamed = ControlPlane::new(ControlPlaneConfig::default(), fleet(3))
            .run_stream(workload.into_iter());
        assert_eq!(streamed.submitted, n);
        assert!(streamed.all_accounted());
        assert_eq!(streamed.report.n_completed(), collected.report.n_completed());
        assert_eq!(streamed.report.n_requests(), collected.report.n_requests());
        assert!(
            (streamed.report.horizon() - collected.report.horizon()).abs() < 1e-9,
            "streamed horizon {} vs collected {}",
            streamed.report.horizon(),
            collected.report.horizon()
        );
        assert_eq!(
            streamed.counters.routed_by_cache_hit,
            collected.counters.routed_by_cache_hit
        );
        let i1: Vec<u64> = collected.per_replica.iter().map(|r| r.iterations).collect();
        let i2: Vec<u64> = streamed.per_replica.iter().map(|r| r.iterations).collect();
        assert_eq!(i1, i2, "per-replica work must be identical across modes");
        // the streaming sinks kept no per-request state…
        assert!(!streamed.report.retains_outcomes());
        assert!(streamed.report.outcomes.is_empty());
        // …but the sketch aggregates still agree with the retained run
        assert!(
            (streamed.report.sketch.ttft_mean() - collected.report.sketch.ttft_mean()).abs()
                < 1e-12
        );
        // live state was bounded and capacity was metered
        assert!(streamed.live_high_water <= n);
        assert!(streamed.replica_seconds > 0.0);
    }

    #[test]
    fn slo_policy_scales_up_and_counts_predicted_violations() {
        let mk = || {
            let cfg = OrchestratorConfig {
                n_instances: 1,
                prefix_cache: true,
                ..Default::default()
            };
            Orchestrator::new(cfg, FixedCost::new(0.05))
        };
        let cfg = ControlPlaneConfig {
            scaler: Some(ScalerConfig {
                policy: ScalePolicy::Slo,
                slo_ttft_target_s: 0.2,
                min_replicas: 1,
                max_replicas: 3,
                cooldown_s: 0.3,
                ..Default::default()
            }),
            ..Default::default()
        };
        // sustained burst: queued prefill backlog pushes predicted TTFT
        // past the 0.2s target, so the SLO policy must grow the fleet
        let w: Vec<RequestSpec> =
            (0..16).map(|i| RequestSpec::text(i as f64 * 0.2, 2048, 32)).collect();
        let n = w.len();
        let res = ControlPlane::new(cfg, vec![mk()]).with_spawner(move |_, _| Some(mk())).run(w);
        assert!(res.all_accounted());
        assert_eq!(
            res.report.n_completed(),
            n,
            "SLO scaling must lose nothing: {:?}",
            res.counters
        );
        assert!(
            res.counters.scale_ups >= 1,
            "predicted violations must grow the fleet: {:?}",
            res.counters
        );
        assert!(
            res.counters.slo_violations_predicted >= 1,
            "the violation counter must see the burst: {:?}",
            res.counters
        );
        assert!(res.goodput_per_replica_second() > 0.0);
    }

    #[test]
    fn control_plane_state_is_thread_capable() {
        // compile-time capability pins: executors (and therefore
        // orchestrators and the whole control plane) cross threads, and
        // the shared registry/index handles are lock-protected
        fn assert_send<T: Send>() {}
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send::<Orchestrator<FixedCost>>();
        assert_send::<ControlPlane<FixedCost>>();
        assert_send_sync::<Arc<RwLock<InstanceRegistry>>>();
        assert_send_sync::<Arc<RwLock<GlobalPrefixIndex>>>();
        let cp = ControlPlane::new(ControlPlaneConfig::default(), fleet(1));
        let reg = cp.shared_registry();
        let ix = cp.shared_index();
        assert_eq!(reg.read().expect("registry lock").alive(), Vec::<usize>::new());
        assert_eq!(ix.read().expect("index lock").blocks(0), 0);
    }
}
