//! Instance registry: heartbeat leases + load reports (paper §3.4).
//!
//! Each orchestrator replica renews a TTL lease on every heartbeat and
//! publishes an aggregate [`LoadReport`] alongside it — the "load-info
//! synchronization at regular intervals via ETCD heartbeat mechanisms"
//! of the paper.  Lease bookkeeping and the ordered event log are
//! delegated to the [`MetaStore`] (the ETCD substitute), so watchers see
//! the same `Registered`/`Updated`/`Expired` stream a real deployment
//! would.  Between heartbeats the router charges optimistic dispatch
//! load ([`InstanceRegistry::note_dispatch`]) so a burst arriving inside
//! one heartbeat interval does not pile onto a single replica.

use std::collections::HashMap;

use crate::service::meta::{InstanceRecord, MetaStore};

pub use crate::coordinator::orchestrator::LoadReport;

/// Lease-based replica registry over the [`MetaStore`].
#[derive(Debug)]
pub struct InstanceRegistry {
    meta: MetaStore,
    loads: HashMap<usize, LoadReport>,
    /// Sorted cache of the alive set (lease held AND first heartbeat
    /// seen).  Membership only changes in `heartbeat`/`sweep`/
    /// `deregister`, so those maintain it incrementally and `alive()`
    /// is a clone instead of a rebuild-and-sort over the meta map —
    /// the routing hot path calls it per request.
    alive_cache: Vec<usize>,
}

impl InstanceRegistry {
    /// `ttl_s`: a replica silent for longer than this is declared dead
    /// at the next sweep.
    pub fn new(ttl_s: f64) -> InstanceRegistry {
        InstanceRegistry {
            meta: MetaStore::new(ttl_s),
            loads: HashMap::new(),
            alive_cache: Vec::new(),
        }
    }

    /// Register a replica (lease starts at `now_s`).
    ///
    /// Registration alone does NOT make the replica routable: until its
    /// first heartbeat publishes a real [`LoadReport`], the replica is
    /// absent from [`Self::alive`].  (A registered-but-silent replica
    /// used to surface with `LoadReport::default()` — zero load, zero
    /// capacity — and the router would dogpile it; mid-run scale-up made
    /// that a real path, not a startup curiosity.)  The lease still
    /// starts now, so a replica that never reports is swept like any
    /// other silent one.
    pub fn register(&mut self, replica: usize, now_s: f64) {
        self.meta.register(InstanceRecord {
            instance: replica,
            role: "replica".to_string(),
            kv_used: 0,
            kv_capacity: 0,
            last_heartbeat_s: now_s,
        });
        // no loads entry yet: the first heartbeat inserts it
    }

    /// Heartbeat: renew the lease and replace the published load report.
    /// Returns false for an unknown (or already-expired) replica.
    pub fn heartbeat(&mut self, replica: usize, report: LoadReport, now_s: f64) -> bool {
        if !self.meta.heartbeat(replica, report.kv_used, now_s) {
            return false;
        }
        if self.loads.insert(replica, report).is_none() {
            // first heartbeat: the replica just became routable
            if let Err(pos) = self.alive_cache.binary_search(&replica) {
                self.alive_cache.insert(pos, replica);
            }
        }
        true
    }

    /// Charge optimistic load for a request just routed to `replica`
    /// (overwritten by the authoritative report at the next heartbeat).
    pub fn note_dispatch(&mut self, replica: usize, input_tokens: u64) {
        if let Some(l) = self.loads.get_mut(&replica) {
            l.queued_prefill_tokens += input_tokens;
            l.n_queued += 1;
        }
    }

    /// Expire lapsed leases; returns the newly-dead replica ids,
    /// ascending (the MetaStore sweeps a hash map, so ordering must be
    /// imposed here to keep failover deterministic).
    pub fn sweep(&mut self, now_s: f64) -> Vec<usize> {
        let mut dead = self.meta.sweep(now_s);
        dead.sort_unstable();
        for d in &dead {
            self.loads.remove(d);
            if let Ok(pos) = self.alive_cache.binary_search(d) {
                self.alive_cache.remove(pos);
            }
        }
        dead
    }

    /// Drop a replica without waiting for its lease to lapse (used when
    /// the control plane already knows it is gone, e.g. a wedged event
    /// loop).  Removes both the load view and the meta record, so
    /// watchers never see a phantom `Expired` for it later.
    pub fn deregister(&mut self, replica: usize) {
        self.loads.remove(&replica);
        self.meta.deregister(replica);
        if let Ok(pos) = self.alive_cache.binary_search(&replica) {
            self.alive_cache.remove(pos);
        }
    }

    /// Replica ids holding a live lease, ascending (deterministic
    /// routing order).  O(n) clone of the maintained cache — no
    /// rebuild/sort per call.
    pub fn alive(&self) -> Vec<usize> {
        self.alive_cache.clone()
    }

    /// Number of routable replicas without materializing the id list.
    pub fn n_alive(&self) -> usize {
        self.alive_cache.len()
    }

    /// Copy the alive ids (ascending) into `out` without allocating —
    /// the router's per-request path reuses one scratch buffer.
    pub fn alive_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend_from_slice(&self.alive_cache);
    }

    pub fn is_alive(&self, replica: usize) -> bool {
        self.loads.contains_key(&replica) && self.meta.get(replica).is_some()
    }

    pub fn load(&self, replica: usize) -> Option<&LoadReport> {
        self.loads.get(&replica)
    }

    /// The underlying metadata store (event log for watchers/tests).
    pub fn meta(&self) -> &MetaStore {
        &self.meta
    }

    /// Publish the live-fleet view as `xllm_registry_*` gauges: the
    /// live-replica count plus each live replica's last published load
    /// (labels in replica-id order, so the exposition is deterministic).
    pub fn export_metrics(&self, reg: &mut crate::obs::MetricsRegistry) {
        let alive = self.alive();
        reg.set_gauge("xllm_registry_replicas_live", alive.len() as f64);
        for r in alive {
            let Some(l) = self.loads.get(&r) else { continue };
            reg.set_gauge(
                &format!("xllm_registry_queued_prefill_tokens{{replica=\"{r}\"}}"),
                l.queued_prefill_tokens as f64,
            );
            reg.set_gauge(
                &format!("xllm_registry_queued_requests{{replica=\"{r}\"}}"),
                l.n_queued as f64,
            );
            reg.set_gauge(&format!("xllm_registry_kv_used{{replica=\"{r}\"}}"), l.kv_used as f64);
            reg.set_gauge(
                &format!("xllm_shard_devices{{replica=\"{r}\"}}"),
                f64::from(l.devices()),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::meta::MetaEvent;

    fn report(queued: u64) -> LoadReport {
        LoadReport { queued_prefill_tokens: queued, kv_capacity: 1000, ..Default::default() }
    }

    #[test]
    fn lease_expiry_marks_dead() {
        let mut r = InstanceRegistry::new(0.6);
        r.register(0, 0.0);
        r.register(1, 0.0);
        r.heartbeat(0, report(10), 0.25);
        r.heartbeat(1, report(20), 0.25);
        assert_eq!(r.alive(), vec![0, 1]);
        // replica 1 goes silent
        r.heartbeat(0, report(10), 0.5);
        r.heartbeat(0, report(10), 0.75);
        assert!(r.sweep(0.75).is_empty(), "0.5s silence < 0.6s TTL");
        r.heartbeat(0, report(10), 1.0);
        assert_eq!(r.sweep(1.0), vec![1], "0.75s silence > TTL");
        assert_eq!(r.alive(), vec![0]);
        assert!(!r.is_alive(1));
        assert!(!r.heartbeat(1, report(0), 1.1), "expired lease cannot renew");
    }

    #[test]
    fn heartbeat_replaces_optimistic_dispatch_load() {
        let mut r = InstanceRegistry::new(5.0);
        r.register(0, 0.0);
        r.heartbeat(0, report(100), 0.1);
        r.note_dispatch(0, 512);
        r.note_dispatch(0, 256);
        assert_eq!(r.load(0).unwrap().queued_prefill_tokens, 100 + 512 + 256);
        assert_eq!(r.load(0).unwrap().n_queued, 2);
        // authoritative report overwrites the optimistic charges
        r.heartbeat(0, report(300), 0.2);
        assert_eq!(r.load(0).unwrap().queued_prefill_tokens, 300);
    }

    #[test]
    fn meta_event_log_sees_lifecycle() {
        let mut r = InstanceRegistry::new(0.5);
        r.register(2, 0.0);
        r.heartbeat(2, report(0), 0.1);
        r.sweep(5.0);
        let (_, events) = r.meta().watch(0);
        assert_eq!(
            events,
            &[MetaEvent::Registered(2), MetaEvent::Updated(2), MetaEvent::Expired(2)]
        );
    }

    #[test]
    fn registered_but_never_heartbeated_is_not_alive() {
        // regression: a registered-but-silent replica used to surface in
        // alive() with LoadReport::default() (zero load, zero capacity),
        // so the router would dogpile the replica that had not even
        // booted.  Liveness must wait for the first heartbeat.
        let mut r = InstanceRegistry::new(10.0);
        r.register(0, 0.0);
        r.register(1, 0.0);
        r.heartbeat(0, report(10), 0.1);
        assert_eq!(r.alive(), vec![0], "silent replica 1 must not be routable");
        assert!(!r.is_alive(1));
        assert!(r.load(1).is_none(), "no phantom default load report");
        // dispatch charges against a silent replica are dropped, not
        // booked against a phantom report
        r.note_dispatch(1, 512);
        assert!(r.load(1).is_none());
        // the first heartbeat brings it up
        r.heartbeat(1, report(20), 0.2);
        assert_eq!(r.alive(), vec![0, 1]);
        assert_eq!(r.load(1).unwrap().queued_prefill_tokens, 20);
    }

    #[test]
    fn never_heartbeated_replica_is_swept_like_any_silent_one() {
        let mut r = InstanceRegistry::new(0.5);
        r.register(0, 0.0);
        r.register(1, 0.0);
        r.heartbeat(0, report(0), 0.4);
        // replica 1 never booted: its lease (started at registration)
        // lapses on schedule
        assert_eq!(r.sweep(0.6), vec![1]);
        assert_eq!(r.alive(), vec![0]);
        assert!(!r.heartbeat(1, report(0), 0.7), "expired lease cannot renew");
    }

    #[test]
    fn alive_cache_tracks_every_membership_transition() {
        // the cached list must agree with a from-scratch rebuild after
        // any interleaving of heartbeat / sweep / deregister
        let mut r = InstanceRegistry::new(0.6);
        let rebuild = |r: &InstanceRegistry| -> Vec<usize> {
            let mut ids: Vec<usize> =
                r.meta().alive().into_iter().filter(|i| r.load(*i).is_some()).collect();
            ids.sort_unstable();
            ids
        };
        for i in 0..5 {
            r.register(i, 0.0);
        }
        assert_eq!(r.alive(), rebuild(&r));
        for i in [3, 0, 4] {
            r.heartbeat(i, report(i as u64), 0.1);
        }
        assert_eq!(r.alive(), vec![0, 3, 4]);
        assert_eq!(r.alive(), rebuild(&r));
        assert_eq!(r.n_alive(), 3);
        // re-heartbeat must not duplicate
        r.heartbeat(3, report(9), 0.2);
        assert_eq!(r.alive(), vec![0, 3, 4]);
        r.deregister(3);
        assert_eq!(r.alive(), rebuild(&r));
        // replicas 1/2 never heartbeated and 4 goes silent: one sweep
        r.heartbeat(0, report(0), 1.0);
        r.sweep(1.0);
        assert_eq!(r.alive(), vec![0]);
        assert_eq!(r.alive(), rebuild(&r));
    }

    #[test]
    fn deregister_is_immediate_and_consistent() {
        let mut r = InstanceRegistry::new(10.0);
        r.register(0, 0.0);
        r.register(1, 0.0);
        r.heartbeat(0, report(0), 0.0);
        r.heartbeat(1, report(0), 0.0);
        r.deregister(0);
        assert_eq!(r.alive(), vec![1]);
        assert!(r.load(0).is_none());
        assert!(!r.is_alive(0), "load and meta views must agree");
        assert!(r.meta().get(0).is_none());
        // a much-later sweep never emits a phantom expiry for 0
        r.heartbeat(1, report(0), 1.0);
        assert!(r.sweep(2.0).is_empty());
        let (_, ev) = r.meta().watch(0);
        assert!(!ev.contains(&MetaEvent::Expired(0)));
        assert!(ev.contains(&MetaEvent::Deregistered(0)));
    }
}
