//! Elastic fleet scaling (paper §3.1 unified elastic scheduling, §3.4
//! proactive KV movement).
//!
//! The paper's xLLM-Service treats elasticity as a first-class scheduler
//! concern: capacity follows the tidal load curve instead of being
//! provisioned for the peak, and the global KV cache supports *planned*
//! cross-replica migration — not just the reactive failover path.  The
//! [`FleetScaler`] is the policy half of both:
//!
//! * **Autoscaling** — each heartbeat tick it compares the fleet's
//!   aggregate backlog (queued prefill + resident decode tokens, from the
//!   registry's load reports) against a per-replica capacity target and
//!   emits [`ScaleAction::Up`] (spawn a replica; routable only after its
//!   first heartbeat per the registry's liveness rule) or
//!   [`ScaleAction::Down`] (gracefully decommission the least-loaded
//!   replica: stop routing, drain, re-dispatch — no lease expiry, no
//!   lost work).  A cooldown prevents flapping on a single burst.
//! * **Planned KV rebalancing** — the scaler tracks which replica each
//!   hot prefix chain's requests were routed to; when one chain
//!   concentrates enough routes on a single above-mean-load replica, it
//!   plans a [`ScaleAction::Rebalance`]: the control plane charges the
//!   `TransferEngine` staging cost, records the chain on the target in
//!   the [`GlobalPrefixIndex`], and the target orchestrator adopts the
//!   chain into its local cache — so subsequent cache-aware routing
//!   spreads the hot group instead of dogpiling its original home.
//!
//! The scaler is pure policy over registry/index snapshots; the
//! mechanics (spawning orchestrators, draining, staging delays) live in
//! [`crate::service::controlplane::ControlPlane`].

use std::cmp::Reverse;
use std::collections::HashMap;

use crate::coordinator::predictor::TtftPredictor;
use crate::model::ShardSpec;
use crate::service::controlplane::index::GlobalPrefixIndex;
use crate::service::controlplane::registry::InstanceRegistry;
use crate::sim::roofline::CostModel;

/// Which signal drives elastic capacity decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScalePolicy {
    /// Token-backlog thresholds against `capacity_target_tokens` (the
    /// original policy and the default: simple, oscillation-free, no
    /// model of latency).
    #[default]
    Backlog,
    /// Scale on *predicted* SLO violation: the control plane's
    /// [`TtftPredictor`] estimates each replica's next-request TTFT
    /// from its queued prefill backlog; capacity grows when the worst
    /// replica is predicted past `slo_ttft_target_s` and shrinks only
    /// when the evicted backlog provably stays under it.  Spends
    /// replicas exactly where the SLO is at risk instead of tracking a
    /// token count that may or may not correlate with latency.
    Slo,
}

/// Elastic-scaling policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct ScalerConfig {
    /// Capacity signal: backlog thresholds (default) or predicted-TTFT
    /// SLO violation (see [`ScalePolicy`]).
    pub policy: ScalePolicy,
    /// TTFT the SLO policy defends (seconds).  Only read under
    /// `ScalePolicy::Slo`; the default matches the premium interactive
    /// tier (`tier_slo(0)`).
    pub slo_ttft_target_s: f64,
    /// Representative prompt length used when predicting the TTFT a
    /// *new* arrival would see on a replica (the predictor needs an
    /// input size; the scaler has no concrete request in hand).
    pub typical_input_tokens: u64,
    /// Per-replica backlog target in tokens (queued prefill + resident
    /// decode context).  Scale up when the fleet backlog exceeds
    /// `target × n_alive`; scale down when it would comfortably fit in
    /// one replica fewer (under half of `target × (n_alive - 1)`).
    pub capacity_target_tokens: u64,
    /// Clamped to ≥ 1: an empty fleet can never scale back up (there is
    /// no heartbeat left to carry the decision), so the last replica is
    /// never decommissioned.
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Minimum time between scale actions (anti-flapping).
    pub cooldown_s: f64,
    /// Routes of one prefix chain onto one replica before a planned
    /// rebalance is considered.
    pub hot_prefix_routes: u64,
    /// Scale-up warm start: pre-stage this many of the hottest tracked
    /// prefix chains onto a freshly spawned replica (via the same
    /// staging path as planned rebalancing) while it waits for its
    /// first heartbeat, so the top shared prefixes already hit its
    /// local cache by the time it becomes routable.  0 disables.
    pub warm_start_chains: usize,
    /// Total device budget across the fleet (`Σ tp×pp` over alive
    /// replicas plus any spawn in flight must stay ≤ this).  Replicas
    /// are priced in devices, not heads: a tp=4,pp=2 replica costs 8.
    /// 0 = unlimited (replica count is still capped by `max_replicas`).
    pub device_budget: u64,
    /// Plan rebalances as sub-chain token ranges: the target adopts only
    /// the suffix it is missing — `(chain, token_lo, token_hi)` — so a
    /// partially-warm replica is a valid target and the transfer ships
    /// fewer bytes.  Off = whole chains to fully-cold targets only (the
    /// legacy behavior).
    pub token_ranges: bool,
    /// Chain granularity in tokens, for expressing block matches as
    /// token ranges; must equal the fleet's prefix block size.
    pub block_tokens: u64,
}

impl Default for ScalerConfig {
    fn default() -> Self {
        ScalerConfig {
            policy: ScalePolicy::Backlog,
            slo_ttft_target_s: 1.0,
            typical_input_tokens: 512,
            capacity_target_tokens: 4096,
            min_replicas: 1,
            max_replicas: 8,
            cooldown_s: 1.0,
            hot_prefix_routes: 8,
            warm_start_chains: 2,
            device_budget: 0,
            token_ranges: false,
            block_tokens: 64,
        }
    }
}

/// One control action planned by the scaler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleAction {
    /// Spawn a fresh replica with this device-group shape (routable
    /// after its first heartbeat).
    Up { shard: ShardSpec },
    /// Gracefully decommission this replica (drain + re-dispatch).
    Down(usize),
    /// Proactively migrate a hot prefix chain from `from` to `to` —
    /// the token range `[token_lo, token_hi)` of it.  Whole-chain plans
    /// use `token_lo == 0`; under `ScalerConfig::token_ranges` the range
    /// starts at the target's existing coverage.
    Rebalance { chain: Vec<u64>, from: usize, to: usize, token_lo: u64, token_hi: u64 },
}

/// Route concentration stats for one prefix chain.
#[derive(Debug)]
struct HotChain {
    chain: Vec<u64>,
    /// Replica → routes of this chain since the stats were last reset.
    per_replica: HashMap<usize, u64>,
}

/// The elastic fleet manager (policy only — see module docs).
#[derive(Debug)]
pub struct FleetScaler {
    pub cfg: ScalerConfig,
    last_scale_s: f64,
    /// Chain-tail hash → concentration stats.
    hot: HashMap<u64, HotChain>,
}

/// Bound on tracked chains: when exceeded, the coldest entry is evicted
/// so a long run over many distinct prefixes cannot grow the tracker
/// (or the per-tick scan) without limit.
const MAX_TRACKED_CHAINS: usize = 256;

/// Fleet-wide KV utilization above which a scale-up prefers a
/// tensor-wider replica (more HBM per replica) over another replica at
/// the current width: the fleet is memory-bound, not queue-bound.
const KV_PRESSURE_WIDEN: f64 = 0.85;

/// Headroom factor for SLO-policy scale-down: the survivors' predicted
/// TTFT (with the victim's redistributed backlog charged) must stay
/// under `target / SLO_DOWN_MARGIN`, not merely under the target —
/// shrinking onto the violation boundary would flap straight back up.
const SLO_DOWN_MARGIN: f64 = 1.5;

fn backlog(registry: &InstanceRegistry, replica: usize) -> u64 {
    registry
        .load(replica)
        .map(|l| l.queued_prefill_tokens + l.running_tokens)
        .unwrap_or(0)
}

impl FleetScaler {
    pub fn new(cfg: ScalerConfig) -> FleetScaler {
        FleetScaler { cfg, last_scale_s: f64::NEG_INFINITY, hot: HashMap::new() }
    }

    /// Record that a request carrying `chain` was routed to `replica`
    /// (called by the control plane on every admit).
    pub fn note_route(&mut self, chain: &[u64], replica: usize) {
        let Some(&key) = chain.last() else {
            return;
        };
        let e = self
            .hot
            .entry(key)
            .or_insert_with(|| HotChain { chain: chain.to_vec(), per_replica: HashMap::new() });
        *e.per_replica.entry(replica).or_insert(0) += 1;
        if self.hot.len() > MAX_TRACKED_CHAINS {
            // evict the coldest chain (fewest total routes, ties to the
            // smallest key — deterministic); a genuinely hot chain is
            // never the victim
            let coldest = self
                .hot
                .iter()
                .map(|(&k, s)| (s.per_replica.values().sum::<u64>(), k))
                .min()
                .map(|(_, k)| k);
            if let Some(k) = coldest {
                self.hot.remove(&k);
            }
        }
    }

    /// Top-`k` tracked chains by total route count, hottest first (ties
    /// to the smallest chain key — deterministic).  Drives the scale-up
    /// warm start: these are the prefixes a fresh replica will most
    /// likely be asked to serve.
    pub fn hottest_chains(&self, k: usize) -> Vec<Vec<u64>> {
        let mut ranked: Vec<(u64, u64)> = self
            .hot
            .iter()
            .map(|(&key, s)| (s.per_replica.values().sum::<u64>(), key))
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
        ranked.into_iter().take(k).map(|(_, key)| self.hot[&key].chain.clone()).collect()
    }

    /// Drop a dead/decommissioned replica from the concentration stats.
    pub fn forget_replica(&mut self, replica: usize) {
        for e in self.hot.values_mut() {
            e.per_replica.remove(&replica);
        }
    }

    /// Publish the tracker state as `xllm_scaler_*`/`xllm_shard_*` gauges.
    pub fn export_metrics(&self, reg: &mut crate::obs::MetricsRegistry) {
        reg.set_gauge("xllm_scaler_tracked_chains", self.hot.len() as f64);
        let routes: u64 = self.hot.values().map(|s| s.per_replica.values().sum::<u64>()).sum();
        reg.set_gauge("xllm_scaler_tracked_routes", routes as f64);
        reg.set_gauge("xllm_shard_device_budget", self.cfg.device_budget as f64);
    }

    /// Plan this tick's actions against the live registry/index state.
    /// At most one scale action and one rebalance per tick.
    pub fn plan(
        &mut self,
        now_s: f64,
        registry: &InstanceRegistry,
        index: &GlobalPrefixIndex,
    ) -> Vec<ScaleAction> {
        let mut actions = Vec::new();
        let alive = registry.alive();
        if alive.is_empty() {
            return actions;
        }
        let n = alive.len();
        let total: u64 = alive.iter().map(|&r| backlog(registry, r)).sum();
        if now_s - self.last_scale_s >= self.cfg.cooldown_s {
            let target = self.cfg.capacity_target_tokens;
            // never shrink to zero: an empty fleet cannot scale back up
            let min = self.cfg.min_replicas.max(1);
            if n < self.cfg.max_replicas && total > target.saturating_mul(n as u64) {
                if let Some(shard) = self.plan_up_shard(&alive, registry) {
                    self.last_scale_s = now_s;
                    actions.push(ScaleAction::Up { shard });
                }
            } else if n > min && total <= target.saturating_mul((n - 1) as u64) / 2 {
                // retire the least-loaded replica; ties break to the
                // newest id (oldest replicas are the stable core)
                let victim = alive
                    .iter()
                    .copied()
                    .min_by_key(|&r| (backlog(registry, r), Reverse(r)))
                    .expect("alive is non-empty");
                self.last_scale_s = now_s;
                actions.push(ScaleAction::Down(victim));
            }
        }
        // no rebalance on a tick that already scaled: the fleet is about
        // to change shape (and the migration target could otherwise be
        // the very replica being decommissioned)
        if actions.is_empty() {
            if let Some(rb) = self.plan_rebalance(&alive, total, registry, index) {
                actions.push(rb);
            }
        }
        actions
    }

    /// SLO-policy tick ([`ScalePolicy::Slo`]): capacity follows
    /// *predicted* TTFT, not token backlog.  Scale up when any alive
    /// replica's predicted next-arrival TTFT exceeds the target; scale
    /// down only when the fleet is violation-free AND redistributing
    /// the cheapest victim's backlog provably keeps every survivor
    /// under `target / SLO_DOWN_MARGIN`.  Returns the planned actions
    /// plus the number of replicas predicted in violation (feeds
    /// `xllm_slo_violations_predicted_total`).  Cooldown, shard
    /// selection, and hot-chain rebalancing are shared with the
    /// backlog policy.
    pub fn plan_slo(
        &mut self,
        now_s: f64,
        registry: &InstanceRegistry,
        index: &GlobalPrefixIndex,
        cost: &CostModel,
        predictor: &TtftPredictor,
    ) -> (Vec<ScaleAction>, u64) {
        let mut actions = Vec::new();
        let alive = registry.alive();
        if alive.is_empty() {
            return (actions, 0);
        }
        let n = alive.len();
        let typical = self.cfg.typical_input_tokens;
        let predicted = |r: usize, extra_queued: u64| -> f64 {
            let queued = registry.load(r).map(|l| l.queued_prefill_tokens).unwrap_or(0);
            predictor.predict(cost, queued + extra_queued, typical)
        };
        let target = self.cfg.slo_ttft_target_s.max(1e-9);
        let violations = alive.iter().filter(|&&r| predicted(r, 0) > target).count() as u64;
        if now_s - self.last_scale_s >= self.cfg.cooldown_s {
            let min = self.cfg.min_replicas.max(1);
            if violations > 0 && n < self.cfg.max_replicas {
                if let Some(shard) = self.plan_up_shard(&alive, registry) {
                    self.last_scale_s = now_s;
                    actions.push(ScaleAction::Up { shard });
                }
            } else if violations == 0 && n > min {
                // candidate victim: least backlog, ties to the newest id
                // (same ordering as the backlog policy)
                let victim = alive
                    .iter()
                    .copied()
                    .min_by_key(|&r| (backlog(registry, r), Reverse(r)))
                    .expect("alive is non-empty");
                // its queued work lands on the survivors; charge each
                // one an even share (ceil) and demand predicted TTFT
                // headroom, not just non-violation — shrinking on a
                // knife's edge would flap right back up
                let moved = backlog(registry, victim);
                let share = moved.div_ceil((n - 1) as u64);
                let safe = alive
                    .iter()
                    .copied()
                    .filter(|&r| r != victim)
                    .all(|r| predicted(r, share) <= target / SLO_DOWN_MARGIN);
                if safe {
                    self.last_scale_s = now_s;
                    actions.push(ScaleAction::Down(victim));
                }
            }
        }
        if actions.is_empty() {
            let total: u64 = alive.iter().map(|&r| backlog(registry, r)).sum();
            if let Some(rb) = self.plan_rebalance(&alive, total, registry, index) {
                actions.push(rb);
            }
        }
        (actions, violations)
    }

    /// Choose the device-group shape for a scale-up, or `None` when the
    /// device budget has no room for another replica.
    ///
    /// The base shape copies the first alive replica's reported shard
    /// (the fleet is homogeneous today).  A *memory*-bound fleet — KV
    /// pools past [`KV_PRESSURE_WIDEN`] utilization in aggregate — gets
    /// a tensor-wider group (tp×2: more HBM behind each replica); a
    /// queue-bound fleet scales out at the current width.  Either pick
    /// must fit the remaining `device_budget`: a widened group that
    /// does not fit falls back to the base width, and when even the
    /// base exceeds the budget the scale-up is suppressed.
    fn plan_up_shard(
        &self,
        alive: &[usize],
        registry: &InstanceRegistry,
    ) -> Option<ShardSpec> {
        let base = alive
            .first()
            .and_then(|&r| registry.load(r))
            .map(|l| l.shard)
            .unwrap_or_default();
        let (mut kv_used, mut kv_cap, mut used_devices) = (0u64, 0u64, 0u64);
        for &r in alive {
            let Some(l) = registry.load(r) else { continue };
            kv_used += l.kv_used;
            kv_cap += l.kv_capacity;
            used_devices += u64::from(l.devices());
        }
        let budget = self.cfg.device_budget;
        let fits = |shard: ShardSpec| -> Option<ShardSpec> {
            (budget == 0 || used_devices + u64::from(shard.devices()) <= budget)
                .then_some(shard)
        };
        let memory_bound = kv_cap > 0 && kv_used as f64 > KV_PRESSURE_WIDEN * kv_cap as f64;
        if memory_bound {
            let wide = ShardSpec::new(base.tp.saturating_mul(2), base.pp, base.micro_batches);
            fits(wide).or_else(|| fits(base))
        } else {
            fits(base)
        }
    }

    /// A hot chain is worth moving when one replica absorbed at least
    /// `hot_prefix_routes` of its routes AND that replica's backlog sits
    /// above the fleet mean (the chain is *concentrating* load, not just
    /// popular on an idle node).  Target: the least-loaded replica not
    /// already holding any of the chain.
    fn plan_rebalance(
        &mut self,
        alive: &[usize],
        total: u64,
        registry: &InstanceRegistry,
        index: &GlobalPrefixIndex,
    ) -> Option<ScaleAction> {
        if alive.len() < 2 {
            return None;
        }
        let mean = total as f64 / alive.len() as f64;
        let mut keys: Vec<u64> = self.hot.keys().copied().collect();
        keys.sort_unstable();
        let mut best: Option<(u64, u64, usize)> = None; // (routes, key, from)
        for key in keys {
            let stat = &self.hot[&key];
            let Some((&from, &routes)) =
                stat.per_replica.iter().max_by_key(|&(&r, &c)| (c, Reverse(r)))
            else {
                continue;
            };
            if routes < self.cfg.hot_prefix_routes || !alive.contains(&from) {
                continue;
            }
            if (backlog(registry, from) as f64) <= mean {
                continue;
            }
            if index.match_prefix(from, &stat.chain).0 == 0 {
                // route stats outlive cache eviction: if the source no
                // longer holds any of the chain there is nothing to
                // migrate — don't materialize KV from a dead copy
                continue;
            }
            if best.map(|(c, k, _)| (routes, Reverse(key)) > (c, Reverse(k))).unwrap_or(true) {
                best = Some((routes, key, from));
            }
        }
        let (_, key, from) = best?;
        let chain = self.hot[&key].chain.clone();
        let bt = self.cfg.block_tokens.max(1);
        let (to, token_lo, token_hi) = if self.cfg.token_ranges {
            // sub-chain shipping: any replica missing part of the
            // source's resident prefix is a target; plan exactly the
            // missing token range
            let hi = index.match_prefix(from, &chain).0 as u64 * bt;
            let to = alive
                .iter()
                .copied()
                .filter(|&r| r != from && (index.match_prefix(r, &chain).0 as u64) * bt < hi)
                .min_by_key(|&r| (backlog(registry, r), r))?;
            let lo = index.match_prefix(to, &chain).0 as u64 * bt;
            (to, lo, hi)
        } else {
            let to = alive
                .iter()
                .copied()
                .filter(|&r| r != from && index.match_prefix(r, &chain).0 == 0)
                .min_by_key(|&r| (backlog(registry, r), r))?;
            (to, 0, chain.len() as u64 * bt)
        };
        // reset this chain's stats so the migration gets a window to
        // take effect before it can re-trigger
        self.hot.remove(&key);
        Some(ScaleAction::Rebalance { chain, from, to, token_lo, token_hi })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::controlplane::registry::LoadReport;

    fn registry(loads: &[(usize, u64)]) -> InstanceRegistry {
        let mut reg = InstanceRegistry::new(100.0);
        for &(r, backlog) in loads {
            reg.register(r, 0.0);
            reg.heartbeat(
                r,
                LoadReport {
                    queued_prefill_tokens: backlog,
                    kv_capacity: 1 << 20,
                    ..Default::default()
                },
                0.0,
            );
        }
        reg
    }

    fn cfg() -> ScalerConfig {
        ScalerConfig { capacity_target_tokens: 1000, cooldown_s: 1.0, ..Default::default() }
    }

    #[test]
    fn scales_up_when_backlog_exceeds_capacity() {
        let reg = registry(&[(0, 1500), (1, 900)]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(cfg());
        // 2400 total > 1000 * 2 replicas; unsharded fleet spawns at width 1
        assert_eq!(s.plan(0.0, &reg, &ix), vec![ScaleAction::Up { shard: ShardSpec::default() }]);
        // cooldown: no immediate second action
        assert!(s.plan(0.5, &reg, &ix).is_empty());
        // after the cooldown it may act again
        assert_eq!(s.plan(1.5, &reg, &ix), vec![ScaleAction::Up { shard: ShardSpec::default() }]);
    }

    #[test]
    fn max_replicas_caps_scale_up() {
        let reg = registry(&[(0, 5000), (1, 5000)]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(ScalerConfig { max_replicas: 2, ..cfg() });
        assert!(s.plan(0.0, &reg, &ix).is_empty());
    }

    #[test]
    fn scales_down_the_least_loaded_replica_when_idle() {
        // 300 total fits easily in 2 replicas (<= 1000 * 2 / 2)
        let reg = registry(&[(0, 200), (1, 90), (2, 10)]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(cfg());
        assert_eq!(s.plan(0.0, &reg, &ix), vec![ScaleAction::Down(2)]);
    }

    #[test]
    fn min_replicas_blocks_scale_down() {
        let reg = registry(&[(0, 0), (1, 0)]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(ScalerConfig { min_replicas: 2, ..cfg() });
        assert!(s.plan(0.0, &reg, &ix).is_empty());
        // in the steady band (neither over target nor near-empty) the
        // scaler holds even when shrinking is allowed
        let reg = registry(&[(0, 800), (1, 700)]);
        let mut s = FleetScaler::new(cfg());
        assert!(s.plan(0.0, &reg, &ix).is_empty());
    }

    #[test]
    fn min_replicas_zero_never_empties_the_fleet() {
        // an empty fleet has no heartbeat left to carry a scale-up
        // decision, so min_replicas is clamped to 1
        let reg = registry(&[(0, 0)]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(ScalerConfig { min_replicas: 0, ..cfg() });
        assert!(
            s.plan(0.0, &reg, &ix).is_empty(),
            "the last replica must never be decommissioned"
        );
    }

    #[test]
    fn no_rebalance_on_a_tick_that_scales() {
        // replica 2 is both the scale-down victim (least-loaded) and
        // the natural rebalance target; emitting both in one tick would
        // migrate the chain onto the replica being decommissioned
        let mut reg = registry(&[(0, 700), (1, 250), (2, 10)]);
        let mut ix = GlobalPrefixIndex::new();
        let chain = vec![1u64, 2];
        ix.record(0, &chain);
        let mut s = FleetScaler::new(ScalerConfig { hot_prefix_routes: 1, ..cfg() });
        s.note_route(&chain, 0);
        let actions = s.plan(0.0, &reg, &ix);
        assert_eq!(actions, vec![ScaleAction::Down(2)], "scale action only: {actions:?}");
        // the control plane applies the decommission; on the next quiet
        // tick the surviving hot stats fire the deferred rebalance
        reg.deregister(2);
        let actions = s.plan(5.0, &reg, &ix);
        assert_eq!(
            actions,
            vec![ScaleAction::Rebalance { chain, from: 0, to: 1, token_lo: 0, token_hi: 128 }]
        );
    }

    fn cost() -> CostModel {
        use crate::model::{ascend_910b, catalog};
        use crate::sim::EngineFeatures;
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1))
    }

    fn slo_cfg(target_s: f64) -> ScalerConfig {
        ScalerConfig { policy: ScalePolicy::Slo, slo_ttft_target_s: target_s, ..cfg() }
    }

    #[test]
    fn slo_policy_scales_up_on_predicted_violation() {
        let reg = registry(&[(0, 50_000), (1, 1_000)]);
        let ix = GlobalPrefixIndex::new();
        let cost = cost();
        let p = TtftPredictor::new();
        // target below replica 0's predicted TTFT → predicted violation
        let worst = p.predict(&cost, 50_000, 512);
        let mut s = FleetScaler::new(slo_cfg(worst * 0.5));
        let (actions, violations) = s.plan_slo(0.0, &reg, &ix, &cost, &p);
        assert_eq!(actions, vec![ScaleAction::Up { shard: ShardSpec::default() }]);
        assert!(violations >= 1, "the loaded replica must count as a predicted violation");
        // cooldown holds exactly like the backlog policy
        let (actions, _) = s.plan_slo(0.5, &reg, &ix, &cost, &p);
        assert!(actions.is_empty());
    }

    #[test]
    fn slo_policy_shrinks_only_with_predicted_headroom() {
        let ix = GlobalPrefixIndex::new();
        let cost = cost();
        let p = TtftPredictor::new();
        // ample headroom: nearly idle fleet far under a loose target
        let reg = registry(&[(0, 2000), (1, 100), (2, 10)]);
        let loose = p.predict(&cost, 4000, 512) * 10.0;
        let mut s = FleetScaler::new(slo_cfg(loose));
        let (actions, violations) = s.plan_slo(0.0, &reg, &ix, &cost, &p);
        assert_eq!(actions, vec![ScaleAction::Down(2)], "least-loaded replica drains");
        assert_eq!(violations, 0);
        // no violation, but redistributing the victim's backlog would
        // eat the SLO_DOWN_MARGIN headroom → hold steady
        let reg = registry(&[(0, 10_000), (1, 10_000)]);
        let tight = p.predict(&cost, 10_000, 512) * 1.05;
        let mut s = FleetScaler::new(slo_cfg(tight));
        let (actions, violations) = s.plan_slo(0.0, &reg, &ix, &cost, &p);
        assert!(actions.is_empty(), "knife-edge shrink must be refused: {actions:?}");
        assert_eq!(violations, 0);
    }

    fn sharded_registry(loads: &[(usize, u64, u64, u64, ShardSpec)]) -> InstanceRegistry {
        let mut reg = InstanceRegistry::new(100.0);
        for &(r, backlog, kv_used, kv_capacity, shard) in loads {
            reg.register(r, 0.0);
            reg.heartbeat(
                r,
                LoadReport {
                    queued_prefill_tokens: backlog,
                    kv_used,
                    kv_capacity,
                    shard,
                    ..Default::default()
                },
                0.0,
            );
        }
        reg
    }

    #[test]
    fn device_budget_suppresses_scale_up_when_exhausted() {
        // two tp=2,pp=2 replicas already occupy all 8 budgeted devices
        let reg = sharded_registry(&[
            (0, 5000, 0, 1 << 20, ShardSpec::new(2, 2, 1)),
            (1, 5000, 0, 1 << 20, ShardSpec::new(2, 2, 1)),
        ]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(ScalerConfig { device_budget: 8, ..cfg() });
        assert!(s.plan(0.0, &reg, &ix).is_empty(), "8 + 4 devices would exceed the budget");
        // a wider budget admits the same-shape scale-out
        let mut s = FleetScaler::new(ScalerConfig { device_budget: 12, ..cfg() });
        assert_eq!(
            s.plan(0.0, &reg, &ix),
            vec![ScaleAction::Up { shard: ShardSpec::new(2, 2, 1) }]
        );
    }

    #[test]
    fn memory_bound_fleet_widens_tp_within_budget() {
        // KV ~94% full: the fleet is memory-bound, so the scale-up
        // prefers a tensor-wider replica (more HBM per replica)
        let loads = [(0, 5000, 15_000, 16_000, ShardSpec::new(2, 1, 1))];
        let reg = sharded_registry(&loads);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(ScalerConfig { device_budget: 8, ..cfg() });
        assert_eq!(
            s.plan(0.0, &reg, &ix),
            vec![ScaleAction::Up { shard: ShardSpec::new(4, 1, 1) }]
        );
        // 2 devices of headroom cannot take the widened (4-device)
        // pick — fall back to the current width
        let mut s = FleetScaler::new(ScalerConfig { device_budget: 4, ..cfg() });
        assert_eq!(
            s.plan(0.0, &reg, &ix),
            vec![ScaleAction::Up { shard: ShardSpec::new(2, 1, 1) }]
        );
    }

    #[test]
    fn hottest_chains_rank_by_routes_then_key() {
        let mut s = FleetScaler::new(cfg());
        for _ in 0..3 {
            s.note_route(&[10, 11], 0);
        }
        s.note_route(&[20, 21], 1);
        for _ in 0..3 {
            s.note_route(&[5, 6], 2);
        }
        let top = s.hottest_chains(2);
        // three routes each for [10,11] (key 11) and [5,6] (key 6):
        // the tie breaks to the smaller key, the 1-route chain is cut
        assert_eq!(top, vec![vec![5, 6], vec![10, 11]]);
        assert!(s.hottest_chains(0).is_empty());
    }

    #[test]
    fn tracker_is_bounded() {
        let mut s = FleetScaler::new(cfg());
        for i in 0..10_000u64 {
            s.note_route(&[i], 0);
        }
        assert!(s.hot.len() <= MAX_TRACKED_CHAINS + 1, "tracker grew to {}", s.hot.len());
    }

    #[test]
    fn hot_concentrated_chain_plans_a_rebalance() {
        // replica 0 is above the mean backlog and absorbed every route
        // of the hot chain; replica 2 is the least-loaded cold target
        let reg = registry(&[(0, 1200), (1, 500), (2, 100)]);
        let mut ix = GlobalPrefixIndex::new();
        let chain = vec![11u64, 22, 33];
        ix.record(0, &chain);
        let mut s = FleetScaler::new(ScalerConfig { hot_prefix_routes: 4, ..cfg() });
        for _ in 0..4 {
            s.note_route(&chain, 0);
        }
        let actions = s.plan(0.0, &reg, &ix);
        assert_eq!(
            actions,
            vec![ScaleAction::Rebalance {
                chain: chain.clone(),
                from: 0,
                to: 2,
                token_lo: 0,
                token_hi: 192,
            }]
        );
        // stats were reset: the same tick's decision does not repeat
        assert!(s.plan(0.0, &reg, &ix).is_empty());
    }

    #[test]
    fn popular_chain_on_an_idle_replica_does_not_rebalance() {
        // replica 1 holds the hot chain but is BELOW the mean backlog:
        // the chain is popular, not concentrating load
        let reg = registry(&[(0, 2000), (1, 100)]);
        let ix = GlobalPrefixIndex::new();
        let mut s = FleetScaler::new(ScalerConfig { hot_prefix_routes: 2, ..cfg() });
        s.note_route(&[7, 8], 1);
        s.note_route(&[7, 8], 1);
        let actions = s.plan(5.0, &reg, &ix);
        assert!(
            !actions.iter().any(|a| matches!(a, ScaleAction::Rebalance { .. })),
            "idle holder must not trigger migration: {actions:?}"
        );
    }

    #[test]
    fn rebalance_skips_replicas_already_holding_the_chain() {
        let reg = registry(&[(0, 1500), (1, 10), (2, 20)]);
        let mut ix = GlobalPrefixIndex::new();
        let chain = vec![5u64, 6];
        ix.record(0, &chain);
        ix.record(1, &chain); // least-loaded replica already holds it
        let mut s = FleetScaler::new(ScalerConfig { hot_prefix_routes: 1, ..cfg() });
        s.note_route(&chain, 0);
        let actions = s.plan(5.0, &reg, &ix);
        assert_eq!(
            actions,
            vec![ScaleAction::Rebalance { chain, from: 0, to: 2, token_lo: 0, token_hi: 128 }]
        );
    }

    #[test]
    fn token_ranges_ship_only_the_missing_suffix() {
        let reg = registry(&[(0, 1500), (1, 10)]);
        let mut ix = GlobalPrefixIndex::new();
        let chain = vec![5u64, 6, 7, 8];
        ix.record(0, &chain);
        ix.record(1, &chain[..1]); // the target already holds block 1
        // legacy planning needs a fully-cold target: with only a
        // partially-warm one available, nothing moves
        let mut s = FleetScaler::new(ScalerConfig { hot_prefix_routes: 1, ..cfg() });
        s.note_route(&chain, 0);
        assert!(s.plan(5.0, &reg, &ix).is_empty());
        // token-range planning ships exactly the missing [64, 256)
        let mut s = FleetScaler::new(ScalerConfig {
            hot_prefix_routes: 1,
            token_ranges: true,
            ..cfg()
        });
        s.note_route(&chain, 0);
        let actions = s.plan(5.0, &reg, &ix);
        assert_eq!(
            actions,
            vec![ScaleAction::Rebalance { chain, from: 0, to: 1, token_lo: 64, token_hi: 256 }]
        );
    }
}
