//! Cluster-level cache-aware routing (paper §3.4 steps 1–3).
//!
//! Generalizes [`kvstore::route`] from a pure function over candidate
//! structs to the live control plane: candidates are the replicas with a
//! valid lease in the [`InstanceRegistry`], prefix matching runs against
//! the [`GlobalPrefixIndex`], and load comes from the heartbeat reports
//! (plus optimistic dispatch charges).  A `RoundRobin` policy is kept as
//! the ablation baseline (the Fig 21-style comparison at fleet scope).
//!
//! Offline requests get the cross-replica form of the §3.1 elastic
//! admission: they are steered to replicas whose in-flight work is
//! mostly offline already (`online_fraction` below the co-location
//! config's relaxed-pool threshold), keeping latency-strict replicas
//! clear — the fleet-scope analogue of `colocation::assign_pool`'s
//! tide rule.

use std::collections::HashMap;

use crate::service::colocation::ColocationConfig;
use crate::service::controlplane::index::GlobalPrefixIndex;
use crate::service::controlplane::registry::InstanceRegistry;
use crate::service::kvstore::{self, hash_chain, prefix_tokens, RouteCandidate, TransferEngine};
use crate::sim::CostModel;
use crate::workload::{RequestClass, RequestSpec};

/// Fleet routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Static spray (baseline).
    RoundRobin,
    /// The paper's three-step selection: prefix match rate → latency
    /// estimate (load + hit tier + transfer cost) → optimal node.
    CacheAware,
}

/// Read-only context a routing decision consults.
pub struct RouterCtx<'a> {
    pub registry: &'a InstanceRegistry,
    pub index: &'a GlobalPrefixIndex,
    pub cost: &'a CostModel,
    pub xfer: &'a TransferEngine,
    pub coloc: &'a ColocationConfig,
    /// Chain granularity — must match the replicas' prefix caches.
    pub block_tokens: u64,
}

/// Outcome of routing one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub replica: usize,
    /// Prefix blocks the chosen replica already caches (per the index).
    pub matched_blocks: usize,
    /// Exact matched tokens when the index runs token-granular (0 under
    /// the legacy block-only index).
    pub matched_tokens: u64,
    /// The offline tide rule narrowed the candidate set.
    pub offline_steered: bool,
}

/// The fleet router (owns only the round-robin fairness state).
#[derive(Debug)]
pub struct FleetRouter {
    pub policy: RoutePolicy,
    /// Monotonic pick counter for the round-robin policy.
    rr_clock: u64,
    /// Replica id → tick of its last round-robin pick (0 = never).
    /// Keyed by *id*, not candidate-list position: a positional cursor
    /// (`cands[rr % cands.len()]`) skews the spray whenever offline
    /// steering or failover narrows the candidate list, because the
    /// modulus changes under the cursor (e.g. with an odd-phase cursor a
    /// 2-candidate narrowing picks index 1 every single time).
    rr_last: HashMap<usize, u64>,
    /// Per-call scratch buffers, reused across requests: `route()` is
    /// the control plane's per-request hot path, and rebuilding these
    /// three Vecs allocated O(n_replicas) fresh on every single route.
    scratch_alive: Vec<usize>,
    scratch_cands: Vec<usize>,
    scratch_rcs: Vec<RouteCandidate>,
}

impl FleetRouter {
    pub fn new(policy: RoutePolicy) -> FleetRouter {
        FleetRouter {
            policy,
            rr_clock: 0,
            rr_last: HashMap::new(),
            scratch_alive: Vec::new(),
            scratch_cands: Vec::new(),
            scratch_rcs: Vec::new(),
        }
    }

    /// Round-robin pick: the least-recently-routed candidate (ties break
    /// to the lowest id).  Id-stable under any narrowing of the
    /// candidate set, and plain rotation when the set is stable.
    fn rr_pick(&mut self, cands: &[usize]) -> usize {
        let pick = cands
            .iter()
            .copied()
            .min_by_key(|&i| (self.rr_last.get(&i).copied().unwrap_or(0), i))
            .expect("rr_pick needs a non-empty candidate set");
        self.rr_clock += 1;
        self.rr_last.insert(pick, self.rr_clock);
        pick
    }

    /// Drop a dead replica's round-robin state so its id can be reused
    /// cleanly if the scaler ever re-registers it.
    pub fn forget(&mut self, replica: usize) {
        self.rr_last.remove(&replica);
    }

    /// The request's prefix hash chain at the fleet granularity (empty
    /// for requests with no shared prefix).
    pub fn chain_for(spec: &RequestSpec, block_tokens: u64) -> Vec<u64> {
        if spec.shared_prefix == 0 {
            return Vec::new();
        }
        hash_chain(
            &prefix_tokens(spec.prefix_group, spec.shared_prefix),
            block_tokens as usize,
        )
    }

    /// The request's raw prefix token stream (empty when it shares no
    /// prefix) — what the token-granular index matches against.
    pub fn tokens_for(spec: &RequestSpec) -> Vec<u32> {
        if spec.shared_prefix == 0 {
            return Vec::new();
        }
        prefix_tokens(spec.prefix_group, spec.shared_prefix)
    }

    /// Route one request; `None` only when no replica holds a lease.
    ///
    /// When the index runs token-granular, candidates additionally carry
    /// their exact radix-matched token count (prompt_tokens −
    /// matched_tokens is what the pick will really prefill), so the
    /// latency estimate stops rounding down to block boundaries.
    pub fn route(&mut self, spec: &RequestSpec, ctx: &RouterCtx) -> Option<RouteDecision> {
        // scratch buffers are taken out of self for the duration of the
        // call (borrow-splitting) and restored before every return
        let mut alive = std::mem::take(&mut self.scratch_alive);
        ctx.registry.alive_into(&mut alive);
        let mut cands = std::mem::take(&mut self.scratch_cands);
        let offline_steered = offline_candidates(spec, &alive, ctx, &mut cands);
        let decision = if cands.is_empty() {
            None
        } else {
            let chain = Self::chain_for(spec, ctx.block_tokens);
            let token_granular = ctx.index.token_granular();
            let toks = if token_granular { Self::tokens_for(spec) } else { Vec::new() };
            // matched_blocks reports the picked replica's index match
            // under BOTH policies, so cache-hit accounting is comparable
            // across the cache-aware/round-robin ablation
            match self.policy {
                RoutePolicy::RoundRobin => {
                    let pick = self.rr_pick(&cands);
                    let tok = if token_granular {
                        ctx.index.match_prefix_tokens(pick, &toks).0
                    } else {
                        0
                    };
                    Some(RouteDecision {
                        replica: pick,
                        matched_blocks: ctx.index.match_prefix(pick, &chain).0,
                        matched_tokens: tok,
                        offline_steered,
                    })
                }
                RoutePolicy::CacheAware => {
                    let mut rcs = std::mem::take(&mut self.scratch_rcs);
                    rcs.clear();
                    rcs.extend(cands.iter().map(|&i| {
                        let (matched_blocks, mut hit_tier) = ctx.index.match_prefix(i, &chain);
                        let mut matched_tokens = 0;
                        if token_granular {
                            let (mt, tt) = ctx.index.match_prefix_tokens(i, &toks);
                            if mt > 0 {
                                matched_tokens = mt;
                                hit_tier = tt;
                            }
                        }
                        let queued_prefill_tokens = ctx
                            .registry
                            .load(i)
                            .map(|l| l.queued_prefill_tokens)
                            .unwrap_or(0);
                        RouteCandidate {
                            instance: i,
                            matched_blocks,
                            matched_tokens,
                            hit_tier,
                            queued_prefill_tokens,
                        }
                    }));
                    let picked = kvstore::route(
                        &rcs,
                        chain.len(),
                        spec.input_tokens,
                        ctx.block_tokens,
                        ctx.cost,
                        ctx.xfer,
                    )
                    .map(|(pick, _)| {
                        let c = rcs.iter().find(|c| c.instance == pick);
                        RouteDecision {
                            replica: pick,
                            matched_blocks: c.map(|c| c.matched_blocks).unwrap_or(0),
                            matched_tokens: c.map(|c| c.matched_tokens).unwrap_or(0),
                            offline_steered,
                        }
                    });
                    self.scratch_rcs = rcs;
                    picked
                }
            }
        };
        self.scratch_alive = alive;
        self.scratch_cands = cands;
        decision
    }
}

/// The §3.1 tide rule at fleet scope: offline requests prefer replicas
/// whose in-flight mix is already mostly offline, unless every replica
/// is latency-busy (then the full set stays eligible).  Writes the
/// candidate set into `out` (scratch, cleared here); returns whether
/// the offline narrowing applied.
fn offline_candidates(
    spec: &RequestSpec,
    alive: &[usize],
    ctx: &RouterCtx,
    out: &mut Vec<usize>,
) -> bool {
    out.clear();
    if spec.class == RequestClass::Offline {
        out.extend(alive.iter().copied().filter(|&i| {
            ctx.registry
                .load(i)
                .map(|l| l.online_fraction < ctx.coloc.relaxed_idle_threshold)
                .unwrap_or(false)
        }));
        if !out.is_empty() && out.len() < alive.len() {
            return true;
        }
        out.clear();
    }
    out.extend_from_slice(alive);
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::service::controlplane::registry::LoadReport;
    use crate::service::kvstore::Tier;
    use crate::sim::EngineFeatures;

    fn cost() -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1))
    }

    fn setup(n: usize) -> (InstanceRegistry, GlobalPrefixIndex) {
        let mut reg = InstanceRegistry::new(10.0);
        for i in 0..n {
            reg.register(i, 0.0);
            reg.heartbeat(i, LoadReport { kv_capacity: 1 << 20, ..Default::default() }, 0.0);
        }
        (reg, GlobalPrefixIndex::new())
    }

    fn spec_with_prefix(group: u64) -> RequestSpec {
        let mut s = RequestSpec::text(0.0, 1024, 16);
        s.prefix_group = group;
        s.shared_prefix = 512;
        s
    }

    #[test]
    fn cache_aware_follows_the_prefix() {
        let (reg, mut ix) = setup(3);
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let spec = spec_with_prefix(7);
        let chain = FleetRouter::chain_for(&spec, 64);
        assert!(!chain.is_empty());
        ix.record(2, &chain);
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let mut router = FleetRouter::new(RoutePolicy::CacheAware);
        let d = router.route(&spec, &ctx).unwrap();
        assert_eq!(d.replica, 2, "the replica caching the prefix must win");
        assert_eq!(d.matched_blocks, chain.len());
    }

    #[test]
    fn cache_aware_abandons_an_overloaded_hit() {
        let (mut reg, mut ix) = setup(2);
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let spec = spec_with_prefix(3);
        let chain = FleetRouter::chain_for(&spec, 64);
        ix.record(1, &chain);
        // replica 1 holds the prefix but is buried in queued prefill
        reg.heartbeat(
            1,
            LoadReport { queued_prefill_tokens: 5_000_000, ..Default::default() },
            0.1,
        );
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let d = FleetRouter::new(RoutePolicy::CacheAware).route(&spec, &ctx).unwrap();
        assert_eq!(d.replica, 0, "a huge queue outweighs the prefix hit");
        assert_eq!(d.matched_blocks, 0);
    }

    #[test]
    fn round_robin_sprays_in_order() {
        let (reg, ix) = setup(3);
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let mut router = FleetRouter::new(RoutePolicy::RoundRobin);
        let spec = RequestSpec::text(0.0, 256, 8);
        let picks: Vec<usize> =
            (0..6).map(|_| router.route(&spec, &ctx).unwrap().replica).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_narrowed_candidates_do_not_dogpile() {
        // regression: the positional cursor (`cands[rr % cands.len()]`)
        // sprayed every offline request onto the SAME replica when
        // offline steering narrowed the set to two candidates — the
        // cursor advanced by one per online pick, so the narrowed
        // modulus always landed on index 1.  The id-stable cursor must
        // spread the narrowed picks across both relaxed replicas.
        let (mut reg, ix) = setup(3);
        // replica 0 online-busy; replicas 1 and 2 latency-relaxed
        for (i, frac) in [(0usize, 0.9), (1, 0.1), (2, 0.1)] {
            reg.heartbeat(
                i,
                LoadReport { online_fraction: frac, ..Default::default() },
                0.1,
            );
        }
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let mut router = FleetRouter::new(RoutePolicy::RoundRobin);
        let online = RequestSpec::text(0.0, 256, 8);
        let offline = RequestSpec::text(0.0, 256, 8).offline();
        let mut offline_picks = Vec::new();
        for _ in 0..4 {
            router.route(&online, &ctx).unwrap();
            let d = router.route(&offline, &ctx).unwrap();
            assert!(d.offline_steered, "setup must narrow offline to replicas 1/2");
            offline_picks.push(d.replica);
        }
        assert!(
            offline_picks.contains(&1) && offline_picks.contains(&2),
            "narrowed round-robin must use both relaxed replicas, got {offline_picks:?}"
        );
    }

    #[test]
    fn round_robin_spray_stays_even_across_a_replica_kill() {
        let (mut reg, ix) = setup(3);
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let mut router = FleetRouter::new(RoutePolicy::RoundRobin);
        let spec = RequestSpec::text(0.0, 256, 8);
        let mut counts = [0usize; 3];
        {
            let ctx = RouterCtx {
                registry: &reg,
                index: &ix,
                cost: &c,
                xfer: &xfer,
                coloc: &coloc,
                block_tokens: 64,
            };
            // 7 picks over 3 replicas: kill happens mid-rotation so a
            // positional cursor would be mid-phase
            for _ in 0..7 {
                counts[router.route(&spec, &ctx).unwrap().replica] += 1;
            }
        }
        reg.deregister(1);
        router.forget(1);
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let mut after = [0usize; 3];
        for _ in 0..8 {
            after[router.route(&spec, &ctx).unwrap().replica] += 1;
        }
        assert_eq!(after[1], 0, "dead replica must get nothing");
        assert_eq!(after[0], 4, "survivors split the spray evenly: {after:?}");
        assert_eq!(after[2], 4, "survivors split the spray evenly: {after:?}");
    }

    #[test]
    fn offline_steers_to_relaxed_replicas() {
        let (mut reg, ix) = setup(3);
        // replica 0/1 busy with online work, replica 2 mostly offline
        for (i, frac) in [(0usize, 0.9), (1, 0.8), (2, 0.1)] {
            reg.heartbeat(
                i,
                LoadReport { online_fraction: frac, ..Default::default() },
                0.1,
            );
        }
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default(); // relaxed_idle_threshold 0.5
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let offline = RequestSpec::text(0.0, 512, 32).offline();
        let d = FleetRouter::new(RoutePolicy::CacheAware).route(&offline, &ctx).unwrap();
        assert_eq!(d.replica, 2);
        assert!(d.offline_steered);
        // an online request is NOT narrowed
        let online = RequestSpec::text(0.0, 512, 32);
        let d = FleetRouter::new(RoutePolicy::CacheAware).route(&online, &ctx).unwrap();
        assert!(!d.offline_steered);
    }

    #[test]
    fn no_leases_means_no_route() {
        let reg = InstanceRegistry::new(1.0);
        let ix = GlobalPrefixIndex::new();
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let spec = RequestSpec::text(0.0, 64, 4);
        assert_eq!(FleetRouter::new(RoutePolicy::CacheAware).route(&spec, &ctx), None);
    }

    #[test]
    fn token_granular_routing_sees_sub_block_hits() {
        let (reg, mut ix) = setup(2);
        ix.enable_token_granular(64);
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let mut spec = RequestSpec::text(0.0, 1024, 16);
        spec.prefix_group = 4;
        spec.shared_prefix = 300; // 4 blocks + a 44-token tail
        let toks = FleetRouter::tokens_for(&spec);
        ix.record_tokens(1, &toks);
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let d = FleetRouter::new(RoutePolicy::CacheAware).route(&spec, &ctx).unwrap();
        assert_eq!(d.replica, 1, "the replica holding the prefix must win");
        assert_eq!(d.matched_tokens, 300, "token-exact, past the 256-token block floor");
        assert_eq!(d.matched_blocks, 4);
    }

    #[test]
    fn hit_tier_breaks_otherwise_equal_candidates() {
        let (reg, mut ix) = setup(2);
        let c = cost();
        let xfer = TransferEngine::default();
        let coloc = ColocationConfig::default();
        let spec = spec_with_prefix(9);
        let chain = FleetRouter::chain_for(&spec, 64);
        // both replicas hold the full chain, but replica 1 holds it hot
        let cold: Vec<(u64, Tier)> = chain.iter().map(|&h| (h, Tier::Ssd)).collect();
        let hot: Vec<(u64, Tier)> = chain.iter().map(|&h| (h, Tier::Hbm)).collect();
        ix.publish(0, &cold);
        ix.publish(1, &hot);
        let ctx = RouterCtx {
            registry: &reg,
            index: &ix,
            cost: &c,
            xfer: &xfer,
            coloc: &coloc,
            block_tokens: 64,
        };
        let d = FleetRouter::new(RoutePolicy::CacheAware).route(&spec, &ctx).unwrap();
        assert_eq!(d.replica, 1, "HBM-resident prefix beats SSD staging");
    }
}
