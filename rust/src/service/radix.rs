//! Radix (compressed-trie) indexes over token ids (paper §3.4,
//! LightLLM's TokenAttention).
//!
//! Two structures share the same edge-compressed arena layout:
//!
//! * [`TokenRadix`] — the *local* structural index inside a
//!   [`TieredCache`]: which token paths have ever been inserted.  It
//!   carries no residency state of its own; `TieredCache` validates a
//!   structural match lazily against its live block table by
//!   recomputing the rolling block hashes along the walk, so eviction
//!   needs no radix bookkeeping at all.
//!
//! * [`ClusterRadix`] — the *global* index: one tree for the whole
//!   fleet, with three per-node replica bitsets (one per storage tier).
//!   A replica matches a prefix to depth `d` iff its bit is set on
//!   every node along the path (path contiguity), so clearing one
//!   block's bits truncates every deeper match without touching
//!   descendants, and `best_match` is one walk that intersects
//!   survivor sets — O(matched tokens), not O(replicas × chain length).
//!
//! Edges never cross block boundaries (insertion segments paths at
//! every `block_tokens` multiple), so each full block ends at a node
//! and the node records the rolling hash of the whole prefix up to
//! that boundary (`end_hash`).  The `boundary` map from hash to node
//! is what lets hash-keyed delta publishes (block added / evicted /
//! tier moved) land on the tree without re-walking token streams.
//!
//! Residency is tracked at block granularity: evicting a block clears
//! the replica's bits on every node inside that block's token span.
//! Paths that diverge *mid-block* share interior nodes, so such an
//! eviction can also truncate a sibling's match — under-crediting,
//! never over-crediting (conservative for admission).  Token streams
//! derived from [`prefix_tokens`] diverge only at position 0, so the
//! case never arises in practice here.
//!
//! [`TieredCache`]: crate::service::kvstore::TieredCache
//! [`prefix_tokens`]: crate::service::kvstore::prefix_tokens

use std::collections::HashMap;

use crate::service::kvstore::Tier;

/// Seed of the rolling FNV-1a prefix hash (must match
/// [`crate::service::kvstore::hash_chain`] exactly — the radix
/// recomputes the same chain hashes along its walks).
pub const HASH_SEED: u64 = 0xcbf29ce484222325;

/// One rolling-hash step (one token).
#[inline]
pub fn hash_step(h: u64, t: u32) -> u64 {
    (h ^ (t as u64 + 1)).wrapping_mul(0x100000001b3)
}

fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

// ---------------------------------------------------------------------
// TokenRadix: local structural index
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct TokenNode {
    edge: Vec<u32>,
    /// (first token of the child's edge, child id), sorted by token so
    /// walks are deterministic.
    children: Vec<(u32, usize)>,
}

/// Compressed trie over token ids: pure structure, no residency.
#[derive(Debug, Clone)]
pub struct TokenRadix {
    nodes: Vec<TokenNode>,
}

impl Default for TokenRadix {
    fn default() -> Self {
        TokenRadix::new()
    }
}

impl TokenRadix {
    pub fn new() -> TokenRadix {
        TokenRadix { nodes: vec![TokenNode { edge: Vec::new(), children: Vec::new() }] }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    fn child(&self, node: usize, t: u32) -> Option<usize> {
        self.nodes[node].children.iter().find(|&&(f, _)| f == t).map(|&(_, c)| c)
    }

    fn attach(&mut self, parent: usize, child: usize) {
        let f = self.nodes[child].edge[0];
        self.nodes[parent].children.push((f, child));
        self.nodes[parent].children.sort_unstable_by_key(|&(t, _)| t);
    }

    /// Split `node`'s edge at `at` (0 < at < edge len): `node` keeps the
    /// head, a new child takes the tail and the old children.
    fn split(&mut self, node: usize, at: usize) {
        let tail = self.nodes[node].edge.split_off(at);
        let moved = std::mem::take(&mut self.nodes[node].children);
        let id = self.nodes.len();
        self.nodes.push(TokenNode { edge: tail, children: moved });
        self.attach(node, id);
    }

    /// Insert a token path (idempotent; shared prefixes dedup).
    pub fn insert(&mut self, tokens: &[u32]) {
        let mut node = 0usize;
        let mut i = 0usize;
        while i < tokens.len() {
            match self.child(node, tokens[i]) {
                None => {
                    let id = self.nodes.len();
                    self.nodes
                        .push(TokenNode { edge: tokens[i..].to_vec(), children: Vec::new() });
                    self.attach(node, id);
                    return;
                }
                Some(c) => {
                    let n = lcp(&self.nodes[c].edge, &tokens[i..]);
                    if n < self.nodes[c].edge.len() {
                        self.split(c, n);
                    }
                    node = c;
                    i += n;
                }
            }
        }
    }

    /// Longest prefix of `tokens` structurally present (may end
    /// mid-edge — token-granular, not block-granular).
    pub fn matched_tokens(&self, tokens: &[u32]) -> usize {
        let mut node = 0usize;
        let mut i = 0usize;
        while i < tokens.len() {
            let Some(c) = self.child(node, tokens[i]) else { break };
            let n = lcp(&self.nodes[c].edge, &tokens[i..]);
            i += n;
            if n < self.nodes[c].edge.len() {
                break;
            }
            node = c;
        }
        i
    }
}

// ---------------------------------------------------------------------
// ClusterRadix: global index with per-replica tier bitsets
// ---------------------------------------------------------------------

/// Growable replica bitset (replica ids are dense, but long elastic
/// runs can mint ids past 64 — the word vector grows on demand).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplicaSet {
    words: Vec<u64>,
}

impl ReplicaSet {
    pub fn set(&mut self, r: usize) {
        let w = r / 64;
        if self.words.len() <= w {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1 << (r % 64);
    }

    pub fn clear(&mut self, r: usize) {
        if let Some(x) = self.words.get_mut(r / 64) {
            *x &= !(1 << (r % 64));
        }
    }

    pub fn contains(&self, r: usize) -> bool {
        self.words.get(r / 64).is_some_and(|w| w & (1 << (r % 64)) != 0)
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|w| *w == 0)
    }

    pub fn union_with(&mut self, o: &ReplicaSet) {
        if self.words.len() < o.words.len() {
            self.words.resize(o.words.len(), 0);
        }
        for (a, b) in self.words.iter_mut().zip(&o.words) {
            *a |= b;
        }
    }

    pub fn intersect_with(&mut self, o: &ReplicaSet) {
        for (i, a) in self.words.iter_mut().enumerate() {
            *a &= o.words.get(i).copied().unwrap_or(0);
        }
    }

    /// Lowest set replica id (the deterministic tie-break).
    pub fn lowest(&self) -> Option<usize> {
        for (i, w) in self.words.iter().enumerate() {
            if *w != 0 {
                return Some(i * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }
}

#[derive(Debug, Clone)]
struct ClusterNode {
    edge: Vec<u32>,
    children: Vec<(u32, usize)>,
    parent: usize,
    /// Token depth of the start of this node's edge.
    start: usize,
    /// Rolling prefix hash at this node's end, iff the end is exactly a
    /// block boundary (then `boundary[hash] == this node`).
    end_hash: Option<u64>,
    /// Per-tier replica residency (a replica's bit lives in at most one
    /// tier set per node).
    bits: [ReplicaSet; 3],
}

/// Cluster-wide radix tree: which replica holds which token prefix, at
/// which tier.  Mirrors the flat per-replica hash maps of
/// `GlobalPrefixIndex` but supports token-granular matching and
/// single-walk `best_match`.
#[derive(Debug, Clone)]
pub struct ClusterRadix {
    nodes: Vec<ClusterNode>,
    boundary: HashMap<u64, usize>,
    block_tokens: usize,
}

impl ClusterRadix {
    pub fn new(block_tokens: u64) -> ClusterRadix {
        ClusterRadix {
            nodes: vec![ClusterNode {
                edge: Vec::new(),
                children: Vec::new(),
                parent: 0,
                start: 0,
                end_hash: None,
                bits: Default::default(),
            }],
            boundary: HashMap::new(),
            block_tokens: block_tokens.max(1) as usize,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn block_tokens(&self) -> u64 {
        self.block_tokens as u64
    }

    fn child(&self, node: usize, t: u32) -> Option<usize> {
        self.nodes[node].children.iter().find(|&&(f, _)| f == t).map(|&(_, c)| c)
    }

    fn attach(&mut self, parent: usize, child: usize) {
        let f = self.nodes[child].edge[0];
        self.nodes[parent].children.push((f, child));
        self.nodes[parent].children.sort_unstable_by_key(|&(t, _)| t);
    }

    /// Split `node` at `at`: the new tail child inherits the bits (a
    /// resident whole edge implies both halves are resident), the old
    /// children, and the end-of-edge hash registration.
    fn split(&mut self, node: usize, at: usize) {
        let tail = self.nodes[node].edge.split_off(at);
        let moved = std::mem::take(&mut self.nodes[node].children);
        let end_hash = self.nodes[node].end_hash.take();
        let bits = self.nodes[node].bits.clone();
        let start = self.nodes[node].start + at;
        let id = self.nodes.len();
        self.nodes.push(ClusterNode {
            edge: tail,
            children: moved,
            parent: node,
            start,
            end_hash,
            bits,
        });
        let moved_ids: Vec<usize> = self.nodes[id].children.iter().map(|&(_, c)| c).collect();
        for c in moved_ids {
            self.nodes[c].parent = id;
        }
        if let Some(h) = end_hash {
            self.boundary.insert(h, id);
        }
        self.attach(node, id);
    }

    /// The replica's tier at `node`, if resident there.
    fn tier_at(&self, node: usize, replica: usize) -> Option<Tier> {
        for (i, s) in self.nodes[node].bits.iter().enumerate() {
            if s.contains(replica) {
                return Some(match i {
                    0 => Tier::Hbm,
                    1 => Tier::Dram,
                    _ => Tier::Ssd,
                });
            }
        }
        None
    }

    /// Optimistic mark: set the replica at `tier` unless it already
    /// holds this node at some tier (mirrors the flat map's
    /// `entry().or_insert()` — optimism never downgrades).
    fn mark(&mut self, node: usize, replica: usize, tier: Tier) {
        if self.tier_at(node, replica).is_some() {
            return;
        }
        self.nodes[node].bits[tier as usize].set(replica);
    }

    /// Authoritative mark: move the replica to exactly `tier`.
    fn mark_move(&mut self, node: usize, replica: usize, tier: Tier) {
        for s in &mut self.nodes[node].bits {
            s.clear(replica);
        }
        self.nodes[node].bits[tier as usize].set(replica);
    }

    fn clear_at(&mut self, node: usize, replica: usize) {
        for s in &mut self.nodes[node].bits {
            s.clear(replica);
        }
    }

    /// Record that `replica` holds the whole token path (optimistically
    /// in `tier` where it holds nothing yet).  Creates structure as
    /// needed, segmenting fresh edges at block boundaries and
    /// registering boundary hashes.
    pub fn record_tokens(&mut self, replica: usize, tokens: &[u32], tier: Tier) {
        let bt = self.block_tokens;
        let mut node = 0usize;
        let mut i = 0usize;
        let mut h = HASH_SEED;
        while i < tokens.len() {
            match self.child(node, tokens[i]) {
                None => {
                    // create the remaining path, one block segment at a time
                    let mut parent = node;
                    let mut j = i;
                    while j < tokens.len() {
                        let e = ((j / bt + 1) * bt).min(tokens.len());
                        let id = self.nodes.len();
                        self.nodes.push(ClusterNode {
                            edge: tokens[j..e].to_vec(),
                            children: Vec::new(),
                            parent,
                            start: j,
                            end_hash: None,
                            bits: Default::default(),
                        });
                        self.attach(parent, id);
                        for &t in &tokens[j..e] {
                            h = hash_step(h, t);
                        }
                        if e % bt == 0 {
                            self.nodes[id].end_hash = Some(h);
                            self.boundary.insert(h, id);
                        }
                        self.mark(id, replica, tier);
                        parent = id;
                        j = e;
                    }
                    return;
                }
                Some(c) => {
                    let n = lcp(&self.nodes[c].edge, &tokens[i..]);
                    if n < self.nodes[c].edge.len() {
                        self.split(c, n);
                    }
                    for &t in &tokens[i..i + n] {
                        h = hash_step(h, t);
                    }
                    self.mark(c, replica, tier);
                    node = c;
                    i += n;
                }
            }
        }
    }

    /// Apply one block-level delta for `replica`: `Some(tier)` = the
    /// block (identified by its boundary prefix hash) is now resident
    /// at `tier`; `None` = evicted.  Bits are updated on every node
    /// inside the block's token span; unknown hashes (structure never
    /// routed through this index) are skipped — conservative.
    pub fn apply_block(&mut self, replica: usize, hash: u64, tier: Option<Tier>) {
        let Some(&node) = self.boundary.get(&hash) else { return };
        let end = self.nodes[node].start + self.nodes[node].edge.len();
        let block_start = end.saturating_sub(self.block_tokens);
        let mut n = node;
        while n != 0 && self.nodes[n].start >= block_start {
            match tier {
                Some(t) => self.mark_move(n, replica, t),
                None => self.clear_at(n, replica),
            }
            n = self.nodes[n].parent;
        }
    }

    /// Forget a replica entirely (failover / decommission).
    pub fn remove(&mut self, replica: usize) {
        for node in &mut self.nodes {
            for s in &mut node.bits {
                s.clear(replica);
            }
        }
    }

    /// Longest token prefix `replica` holds (path-contiguous), plus the
    /// slowest tier along the matched path.
    pub fn match_prefix_tokens(&self, replica: usize, tokens: &[u32]) -> (u64, Option<Tier>) {
        let mut node = 0usize;
        let mut i = 0usize;
        let mut worst: Option<Tier> = None;
        while i < tokens.len() {
            let Some(c) = self.child(node, tokens[i]) else { break };
            let Some(t) = self.tier_at(c, replica) else { break };
            let n = lcp(&self.nodes[c].edge, &tokens[i..]);
            if n == 0 {
                break;
            }
            worst = Some(match worst {
                Some(w) if w >= t => w,
                _ => t,
            });
            i += n;
            if n < self.nodes[c].edge.len() {
                break;
            }
            node = c;
        }
        (i as u64, if i > 0 { worst } else { None })
    }

    /// Best replica for the token path: one walk intersecting the
    /// survivor sets node by node.  Returns `(replica, matched_tokens,
    /// worst_tier)` — longest match, lowest replica id on ties (the
    /// same contract as the linear-scan `best_match`).
    pub fn best_match_tokens(&self, tokens: &[u32]) -> Option<(usize, u64, Tier)> {
        let mut node = 0usize;
        let mut i = 0usize;
        let mut survivors: Option<ReplicaSet> = None;
        let mut best: Option<(ReplicaSet, usize)> = None;
        while i < tokens.len() {
            let Some(c) = self.child(node, tokens[i]) else { break };
            let n = lcp(&self.nodes[c].edge, &tokens[i..]);
            if n == 0 {
                break;
            }
            let mut present = self.nodes[c].bits[0].clone();
            present.union_with(&self.nodes[c].bits[1]);
            present.union_with(&self.nodes[c].bits[2]);
            let s = match survivors {
                None => present,
                Some(mut s) => {
                    s.intersect_with(&present);
                    s
                }
            };
            if s.is_empty() {
                break;
            }
            i += n;
            best = Some((s.clone(), i));
            survivors = Some(s);
            if n < self.nodes[c].edge.len() {
                break;
            }
            node = c;
        }
        let (s, matched) = best?;
        let replica = s.lowest()?;
        let (got, tier) = self.match_prefix_tokens(replica, &tokens[..matched]);
        debug_assert_eq!(got as usize, matched, "survivor walk disagrees with its witness");
        tier.map(|t| (replica, matched as u64, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::kvstore::{hash_chain, prefix_tokens};

    #[test]
    fn token_radix_matches_at_any_split_point() {
        let mut r = TokenRadix::new();
        let a = prefix_tokens(1, 100);
        r.insert(&a);
        assert_eq!(r.matched_tokens(&a), 100);
        assert_eq!(r.matched_tokens(&a[..37]), 37, "split points are token-granular");
        let longer = prefix_tokens(1, 140);
        assert_eq!(r.matched_tokens(&longer), 100, "match stops at the stored frontier");
        assert_eq!(r.matched_tokens(&prefix_tokens(2, 64)), 0, "groups diverge at 0");
    }

    #[test]
    fn token_radix_dedups_shared_prefixes() {
        let mut r = TokenRadix::new();
        r.insert(&prefix_tokens(1, 96));
        let before = r.n_nodes();
        r.insert(&prefix_tokens(1, 96));
        assert_eq!(r.n_nodes(), before, "idempotent insert");
        r.insert(&prefix_tokens(1, 160));
        assert_eq!(r.matched_tokens(&prefix_tokens(1, 160)), 160);
        // extending an existing path adds at most a handful of nodes
        assert!(r.n_nodes() <= before + 2, "extension must reuse the shared prefix");
    }

    #[test]
    fn token_radix_splits_mid_edge() {
        let mut r = TokenRadix::new();
        r.insert(&[1, 2, 3, 4, 5]);
        r.insert(&[1, 2, 9, 9]);
        assert_eq!(r.matched_tokens(&[1, 2, 3, 4, 5]), 5);
        assert_eq!(r.matched_tokens(&[1, 2, 9, 9]), 4);
        assert_eq!(r.matched_tokens(&[1, 2, 7]), 2);
    }

    #[test]
    fn replica_set_grows_and_tiebreaks() {
        let mut s = ReplicaSet::default();
        assert!(s.is_empty());
        s.set(70);
        s.set(3);
        assert!(s.contains(70) && s.contains(3) && !s.contains(4));
        assert_eq!(s.lowest(), Some(3), "lowest id wins ties");
        s.clear(3);
        assert_eq!(s.lowest(), Some(70));
        let mut o = ReplicaSet::default();
        o.set(70);
        o.set(2);
        s.union_with(&o);
        assert_eq!(s.lowest(), Some(2));
        let mut t = ReplicaSet::default();
        t.set(70);
        s.intersect_with(&t);
        assert_eq!(s.lowest(), Some(70));
    }

    #[test]
    fn cluster_radix_boundary_hashes_match_hash_chain() {
        let mut r = ClusterRadix::new(16);
        let toks = prefix_tokens(3, 64);
        r.record_tokens(0, &toks, Tier::Dram);
        let chain = hash_chain(&toks, 16);
        for h in chain {
            assert!(r.boundary.contains_key(&h), "every block boundary is registered");
        }
    }

    #[test]
    fn cluster_match_is_token_granular_and_worst_tier() {
        let mut r = ClusterRadix::new(16);
        let toks = prefix_tokens(1, 40); // 2 blocks + 8-token tail
        r.record_tokens(0, &toks, Tier::Dram);
        assert_eq!(r.match_prefix_tokens(0, &toks), (40, Some(Tier::Dram)));
        assert_eq!(r.match_prefix_tokens(0, &toks[..23]).0, 23);
        assert_eq!(r.match_prefix_tokens(1, &toks), (0, None));
        // authoritative tier move of block 2 governs the worst tier
        let chain = hash_chain(&toks, 16);
        r.apply_block(0, chain[1], Some(Tier::Ssd));
        assert_eq!(r.match_prefix_tokens(0, &toks), (40, Some(Tier::Ssd)));
    }

    #[test]
    fn cluster_eviction_truncates_path_contiguously() {
        let mut r = ClusterRadix::new(16);
        let toks = prefix_tokens(1, 48);
        r.record_tokens(0, &toks, Tier::Dram);
        let chain = hash_chain(&toks, 16);
        r.apply_block(0, chain[1], None); // evict the middle block
        assert_eq!(r.match_prefix_tokens(0, &toks).0, 16, "match stops at the hole");
        // re-adding restores the deeper blocks (their bits survived)
        r.apply_block(0, chain[1], Some(Tier::Dram));
        assert_eq!(r.match_prefix_tokens(0, &toks).0, 48);
    }

    #[test]
    fn best_match_prefers_longest_then_lowest_id() {
        let mut r = ClusterRadix::new(16);
        let toks = prefix_tokens(1, 64);
        r.record_tokens(4, &toks[..32], Tier::Dram);
        r.record_tokens(1, &toks, Tier::Dram);
        r.record_tokens(7, &toks, Tier::Dram);
        assert_eq!(r.best_match_tokens(&toks), Some((1, 64, Tier::Dram)));
        r.remove(1);
        assert_eq!(r.best_match_tokens(&toks), Some((7, 64, Tier::Dram)));
        r.remove(7);
        assert_eq!(r.best_match_tokens(&toks), Some((4, 32, Tier::Dram)));
        r.remove(4);
        assert_eq!(r.best_match_tokens(&toks), None);
    }

    #[test]
    fn best_match_walk_drops_replicas_at_their_own_frontier() {
        let mut r = ClusterRadix::new(16);
        let toks = prefix_tokens(2, 80);
        r.record_tokens(0, &toks[..16], Tier::Dram);
        r.record_tokens(3, &toks[..48], Tier::Hbm);
        let (rep, n, tier) = r.best_match_tokens(&toks).unwrap();
        assert_eq!((rep, n, tier), (3, 48, Tier::Hbm));
        // replica 0 wins only when the query stays inside its coverage
        let (rep, n, _) = r.best_match_tokens(&toks[..16]).unwrap();
        assert_eq!((rep, n), (0, 16), "tie at 16 tokens breaks to the lowest id");
    }
}
