//! Executor-agnostic fleet runtime: the factory seam between the
//! control plane and *how replicas are built*.
//!
//! The [`ControlPlane`] is generic over the executor but still needs a
//! way to stamp replicas — both the initial fleet and the scale-up
//! spawns.  [`ReplicaFactory`] is that seam: a factory builds one
//! not-yet-started [`Orchestrator`] per replica id, and
//! [`run_fleet_with`] wires N of them (plus the factory itself, as the
//! scaler's spawner) into a control plane and serves the workload.
//!
//! Instantiations:
//!
//! * `sim::fleet::run_fleet` — roofline replicas stamped from a
//!   `ClusterConfig` template (the discrete-event fleet simulation).
//! * `server::PjrtReplicaFactory` — N real `PjrtExecutor` replicas over
//!   the AOT PJRT artifacts (`xllm fleet --backend pjrt`): the same
//!   registry/index/router/scaler drive real engines, and with
//!   [`ControlPlaneConfig::threads`] ≥ 2 each replica's engine steps on
//!   its own worker thread.
//!
//! Factories are `Send + 'static` because the control plane keeps the
//! factory as its scale-up spawner and the whole control plane must
//! stay movable across threads.

use crate::coordinator::orchestrator::{Executor, Orchestrator};
use crate::service::controlplane::{ControlPlane, ControlPlaneConfig, FleetResult};
use crate::workload::RequestSpec;

/// Builds fleet replicas: one orchestrator (over a fresh executor) per
/// replica id.  The returned orchestrator must NOT be started — the
/// control plane aligns its clock with fleet time and registers it.
pub trait ReplicaFactory: Send {
    type Exec: Executor;

    /// Build replica `id`.  Ids are assigned densely by the control
    /// plane: `0..n_replicas` at startup, then one per scale-up.
    fn build(&mut self, id: usize) -> Orchestrator<Self::Exec>;

    /// Fallible build for mid-run scale-up spawns: `None` declines the
    /// spawn and the fleet keeps serving at its current size (a startup
    /// build may fail fast; a mid-run crash would lose every in-flight
    /// request on the healthy replicas).  Default: infallible
    /// [`Self::build`].
    fn try_build(&mut self, id: usize) -> Option<Orchestrator<Self::Exec>> {
        Some(self.build(id))
    }

    /// Like [`Self::try_build`], but for a scaler-chosen device-group
    /// shape (`devices = tp × pp`): the scaler may widen a scale-up
    /// replica when the fleet is memory-bound.  Backends that cannot
    /// reshape (e.g. the real engine over fixed AOT artifacts) keep the
    /// default, which ignores the shard and builds at the factory's
    /// native shape.
    fn try_build_sharded(
        &mut self,
        id: usize,
        _shard: crate::model::ShardSpec,
    ) -> Option<Orchestrator<Self::Exec>> {
        self.try_build(id)
    }
}

/// Build `n_replicas` replicas with `factory`, install the factory as
/// the scale-up spawner, and serve `workload` across the fleet.  This
/// is the one fleet entry point every backend shares; policy (routing,
/// leases, scaler, threads) comes in through `cfg`.
pub fn run_fleet_with<F>(
    cfg: ControlPlaneConfig,
    n_replicas: usize,
    mut factory: F,
    workload: Vec<RequestSpec>,
) -> FleetResult
where
    F: ReplicaFactory + 'static,
{
    let replicas: Vec<Orchestrator<F::Exec>> =
        (0..n_replicas).map(|i| factory.build(i)).collect();
    ControlPlane::new(cfg, replicas)
        .with_spawner(move |i, shard| factory.try_build_sharded(i, shard))
        .run(workload)
}

/// [`run_fleet_with`] over a pull-based arrival stream: arrivals are
/// pulled one at a time (never materialized into a `Vec`) and every
/// report sink runs in streaming (sketch-only) mode, so fleet memory is
/// O(live requests) — the million-request entry point.
pub fn run_fleet_stream_with<F>(
    cfg: ControlPlaneConfig,
    n_replicas: usize,
    mut factory: F,
    stream: impl Iterator<Item = RequestSpec> + Send + 'static,
) -> FleetResult
where
    F: ReplicaFactory + 'static,
{
    let replicas: Vec<Orchestrator<F::Exec>> =
        (0..n_replicas).map(|i| factory.build(i)).collect();
    ControlPlane::new(cfg, replicas)
        .with_spawner(move |i, shard| factory.try_build_sharded(i, shard))
        .run_stream(stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orchestrator::OrchestratorConfig;
    use crate::service::controlplane::ScalerConfig;
    use crate::testutil::FixedCostExecutor as FixedCost;

    struct FixedFactory {
        step_s: f64,
    }

    impl ReplicaFactory for FixedFactory {
        type Exec = FixedCost;

        fn build(&mut self, _id: usize) -> Orchestrator<FixedCost> {
            let cfg = OrchestratorConfig {
                n_instances: 1,
                prefix_cache: true,
                ..Default::default()
            };
            Orchestrator::new(cfg, FixedCost::new(self.step_s))
        }
    }

    #[test]
    fn factory_builds_the_initial_fleet_and_serves() {
        let workload: Vec<RequestSpec> =
            (0..12).map(|i| RequestSpec::text(i as f64 * 0.05, 256, 16)).collect();
        let n = workload.len();
        let res = run_fleet_with(
            ControlPlaneConfig::default(),
            3,
            FixedFactory { step_s: 0.01 },
            workload,
        );
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n);
        assert_eq!(res.per_replica.len(), 3, "factory stamped the initial fleet");
    }

    #[test]
    fn factory_doubles_as_the_scale_up_spawner() {
        let cfg = ControlPlaneConfig {
            scaler: Some(ScalerConfig {
                capacity_target_tokens: 512,
                min_replicas: 1,
                max_replicas: 3,
                cooldown_s: 0.3,
                ..Default::default()
            }),
            ..Default::default()
        };
        let w: Vec<RequestSpec> =
            (0..16).map(|i| RequestSpec::text(i as f64 * 0.2, 2048, 32)).collect();
        let n = w.len();
        let res = run_fleet_with(cfg, 1, FixedFactory { step_s: 0.05 }, w);
        assert_eq!(res.report.n_completed(), n);
        assert!(res.counters.scale_ups >= 1, "burst must grow the fleet: {:?}", res.counters);
        assert!(res.per_replica.len() > 1, "the factory spawned mid-run replicas");
    }

    #[test]
    fn threaded_runtime_serves_through_the_same_factory() {
        let workload: Vec<RequestSpec> =
            (0..12).map(|i| RequestSpec::text(i as f64 * 0.05, 256, 16)).collect();
        let n = workload.len();
        let cfg = ControlPlaneConfig { threads: 2, ..Default::default() };
        let res = run_fleet_with(cfg, 3, FixedFactory { step_s: 0.01 }, workload);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n, "zero lost requests in threaded mode");
        assert_eq!(res.counters.unroutable, 0);
    }
}
