//! Global multi-level KV cache management (paper §3.4).
//!
//! Per instance: a tiered HBM → DRAM → SSD cache of KV *blocks* (fixed
//! token granularity) under the paper's strict consistency rule — "if data
//! resides in HBM, it must also be present in DRAM".  Blocks are identified
//! by a rolling prefix hash chain, so shared prompt prefixes dedupe across
//! requests (prefix cache).
//!
//! Globally: a cache-aware router implementing the paper's three steps:
//! (1) prefix matching detection — per-candidate KV reuse rate;
//! (2) performance estimation — expected latency from load state, hit
//!     tier, and recompute cost;
//! (3) optimal node selection.
//!
//! The transfer engine (Mooncake substitute) prices tier loads and
//! instance-to-instance migrations from bandwidth parameters.

use std::collections::HashMap;

use crate::service::radix::{hash_step, TokenRadix, HASH_SEED};
use crate::sim::CostModel;

/// Storage tier, fastest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Hbm = 0,
    Dram = 1,
    Ssd = 2,
}

/// Synthetic token stream for a shared prompt prefix: group `g`, token
/// position `t` maps to `(g << 16) | t`.  Both the orchestrator's local
/// prefix cache and the control plane's global index derive chains from
/// this, so a request hashes identically wherever it is routed.
pub fn prefix_tokens(group: u64, len: u64) -> Vec<u32> {
    (0..len as u32).map(|t| ((group as u32) << 16) | t).collect()
}

/// Rolling hash chain over token blocks: hash[i] covers tokens
/// [0, (i+1)*block) — a prefix identity, so equal chains = equal prefixes.
pub fn hash_chain(tokens: &[u32], block_tokens: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / block_tokens);
    let mut h: u64 = 0xcbf29ce484222325;
    for (i, &t) in tokens.iter().enumerate() {
        h ^= t as u64 + 1;
        h = h.wrapping_mul(0x100000001b3);
        if (i + 1) % block_tokens == 0 {
            out.push(h);
        }
    }
    out
}

#[derive(Debug, Clone, Copy)]
struct BlockMeta {
    tier: Tier,
    last_access: u64,
}

/// Per-instance tiered cache (token capacities per tier).
#[derive(Debug)]
pub struct TieredCache {
    pub block_tokens: u64,
    cap_blocks: [u64; 3],
    used_blocks: [u64; 3],
    blocks: HashMap<u64, BlockMeta>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
    /// Token-granular structural index over every inserted token path
    /// (populated only via `insert_tokens`; block-hash inserts leave it
    /// empty, so the default chain paths never pay for it).
    radix: TokenRadix,
    /// When set, every residency change is appended to `delta` so the
    /// control plane can publish increments instead of full summaries.
    track_deltas: bool,
    delta: Vec<(u64, Option<Tier>)>,
}

impl TieredCache {
    pub fn new(block_tokens: u64, hbm_tokens: u64, dram_tokens: u64, ssd_tokens: u64) -> Self {
        TieredCache {
            block_tokens,
            cap_blocks: [
                hbm_tokens / block_tokens,
                dram_tokens / block_tokens,
                ssd_tokens / block_tokens,
            ],
            used_blocks: [0; 3],
            blocks: HashMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            radix: TokenRadix::new(),
            track_deltas: false,
            delta: Vec::new(),
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Start recording residency deltas for incremental publish.  Off by
    /// default so callers that never drain `take_summary_delta` don't
    /// accumulate an unbounded log.
    pub fn enable_delta_tracking(&mut self) {
        self.track_deltas = true;
    }

    fn note(&mut self, h: u64, tier: Option<Tier>) {
        if self.track_deltas {
            self.delta.push((h, tier));
        }
    }

    /// Residency changes since the last call, in event order (an upsert
    /// is `Some(tier)`, an eviction `None`).  Feed to
    /// `GlobalPrefixIndex::publish_delta`.
    pub fn take_summary_delta(&mut self) -> Vec<(u64, Option<Tier>)> {
        std::mem::take(&mut self.delta)
    }

    /// Nodes in the token-granular structural index (bench/metrics).
    pub fn radix_nodes(&self) -> usize {
        self.radix.n_nodes()
    }

    /// Longest cached prefix (in blocks) of the hash chain, and the
    /// slowest tier that must be read to serve it.
    pub fn match_prefix(&mut self, chain: &[u64]) -> (usize, Option<Tier>) {
        let mut worst: Option<Tier> = None;
        let mut n = 0;
        let now = self.tick();
        for h in chain {
            match self.blocks.get_mut(h) {
                Some(meta) => {
                    meta.last_access = now;
                    worst = Some(match worst {
                        Some(w) if w >= meta.tier => w,
                        _ => meta.tier,
                    });
                    n += 1;
                }
                None => break,
            }
        }
        if n > 0 {
            self.hits += 1;
        } else if !chain.is_empty() {
            self.misses += 1;
        }
        (n, worst)
    }

    /// Token-granular prefix match: the longest matched *token* count at
    /// any split point, not just block boundaries, with tier = worst
    /// tier along the matched path.  The radix gives structural
    /// coverage; residency is validated lazily against the live block
    /// table by recomputing the rolling block hashes along the walk
    /// (bumping LRU like `match_prefix`).  Tail tokens past the last
    /// full block count only when every preceding block is resident —
    /// their KV rides in DRAM, so a block-less match reports `Dram`.
    /// On a block-aligned path this returns exactly
    /// `match_prefix(chain).0 * block_tokens` with the same tier.
    pub fn match_prefix_tokens(&mut self, tokens: &[u32]) -> (u64, Option<Tier>) {
        let covered = self.radix.matched_tokens(tokens);
        let now = self.tick();
        let bt = self.block_tokens as usize;
        let mut worst: Option<Tier> = None;
        let mut matched = 0usize;
        let mut broken = false;
        let mut h: u64 = HASH_SEED;
        for (i, &t) in tokens[..covered].iter().enumerate() {
            h = hash_step(h, t);
            if (i + 1) % bt == 0 {
                match self.blocks.get_mut(&h) {
                    Some(meta) => {
                        meta.last_access = now;
                        worst = Some(match worst {
                            Some(w) if w >= meta.tier => w,
                            _ => meta.tier,
                        });
                        matched = i + 1;
                    }
                    None => {
                        broken = true;
                        break;
                    }
                }
            }
        }
        if !broken {
            matched = covered;
            if matched > 0 && worst.is_none() {
                worst = Some(Tier::Dram);
            }
        }
        if matched > 0 {
            self.hits += 1;
        } else if !tokens.is_empty() {
            self.misses += 1;
        }
        (matched as u64, if matched > 0 { worst } else { None })
    }

    /// Insert a token path: blocks land in the tiered block table (same
    /// residency/eviction as `insert_chain`), the full path — including
    /// the sub-block tail — lands in the structural radix.
    pub fn insert_tokens(&mut self, tokens: &[u32], tier: Tier) {
        if tokens.is_empty() {
            return;
        }
        self.radix.insert(tokens);
        let chain = hash_chain(tokens, self.block_tokens as usize);
        self.insert_chain(&chain, tier);
    }

    fn evict_lru_from(&mut self, tier: Tier) -> Option<u64> {
        let victim = self
            .blocks
            .iter()
            .filter(|(_, m)| m.tier == tier)
            .min_by_key(|(_, m)| m.last_access)
            .map(|(h, _)| *h)?;
        self.demote(victim);
        Some(victim)
    }

    /// Demote a block one tier down (HBM→DRAM is a pure drop of the HBM
    /// copy under the consistency rule; DRAM→SSD and SSD→out move it).
    fn demote(&mut self, h: u64) {
        let meta = match self.blocks.get(&h) {
            Some(m) => *m,
            None => return,
        };
        match meta.tier {
            Tier::Hbm => {
                // HBM copy implies a DRAM copy exists: drop the HBM copy
                self.used_blocks[0] -= 1;
                self.blocks.get_mut(&h).unwrap().tier = Tier::Dram;
                self.note(h, Some(Tier::Dram));
                // note: DRAM occupancy already counted when inserted
            }
            Tier::Dram => {
                self.used_blocks[1] -= 1;
                if self.used_blocks[2] < self.cap_blocks[2] {
                    self.used_blocks[2] += 1;
                    self.blocks.get_mut(&h).unwrap().tier = Tier::Ssd;
                    self.note(h, Some(Tier::Ssd));
                } else {
                    self.blocks.remove(&h);
                    self.note(h, None);
                }
            }
            Tier::Ssd => {
                self.used_blocks[2] -= 1;
                self.blocks.remove(&h);
                self.note(h, None);
            }
        }
    }

    /// Insert a block at a tier, evicting LRU as needed.  Maintains the
    /// HBM⊆DRAM rule: inserting to HBM counts occupancy in both HBM and
    /// DRAM.
    pub fn insert(&mut self, h: u64, tier: Tier) {
        let now = self.tick();
        if let Some(meta) = self.blocks.get(&h).copied() {
            if meta.tier <= tier {
                self.blocks.get_mut(&h).unwrap().last_access = now;
                return; // already at this tier or faster
            }
            // promote: charge the faster tiers
            if tier == Tier::Hbm && meta.tier >= Tier::Dram {
                if meta.tier == Tier::Ssd {
                    // must enter DRAM first (consistency rule)
                    while self.used_blocks[1] >= self.cap_blocks[1] {
                        if self.evict_lru_from(Tier::Dram).is_none() {
                            return;
                        }
                    }
                    self.used_blocks[1] += 1;
                    self.used_blocks[2] -= 1;
                }
                while self.used_blocks[0] >= self.cap_blocks[0] {
                    if self.evict_lru_from(Tier::Hbm).is_none() {
                        return;
                    }
                }
                self.used_blocks[0] += 1;
                let m = self.blocks.get_mut(&h).unwrap();
                m.tier = Tier::Hbm;
                m.last_access = now;
                self.note(h, Some(Tier::Hbm));
            } else if tier == Tier::Dram && meta.tier == Tier::Ssd {
                while self.used_blocks[1] >= self.cap_blocks[1] {
                    if self.evict_lru_from(Tier::Dram).is_none() {
                        return;
                    }
                }
                self.used_blocks[1] += 1;
                self.used_blocks[2] -= 1;
                let m = self.blocks.get_mut(&h).unwrap();
                m.tier = Tier::Dram;
                m.last_access = now;
                self.note(h, Some(Tier::Dram));
            }
            return;
        }
        // fresh insert: DRAM first (consistency), then optional HBM charge
        while self.used_blocks[1] >= self.cap_blocks[1] {
            if self.evict_lru_from(Tier::Dram).is_none() {
                return;
            }
        }
        self.used_blocks[1] += 1;
        let mut t = Tier::Dram;
        if tier == Tier::Hbm {
            while self.used_blocks[0] >= self.cap_blocks[0] {
                if self.evict_lru_from(Tier::Hbm).is_none() {
                    break;
                }
            }
            if self.used_blocks[0] < self.cap_blocks[0] {
                self.used_blocks[0] += 1;
                t = Tier::Hbm;
            }
        } else if tier == Tier::Ssd {
            // explicit SSD insert (offload path)
            self.used_blocks[1] -= 1;
            while self.used_blocks[2] >= self.cap_blocks[2] {
                if self.evict_lru_from(Tier::Ssd).is_none() {
                    return;
                }
            }
            self.used_blocks[2] += 1;
            t = Tier::Ssd;
        }
        self.blocks.insert(h, BlockMeta { tier: t, last_access: now });
        self.note(h, Some(t));
    }

    /// Insert a whole chain (prefix store after a prefill).
    pub fn insert_chain(&mut self, chain: &[u64], tier: Tier) {
        for &h in chain {
            self.insert(h, tier);
        }
    }

    pub fn contains(&self, h: u64) -> Option<Tier> {
        self.blocks.get(&h).map(|m| m.tier)
    }

    pub fn used_tokens(&self, tier: Tier) -> u64 {
        self.used_blocks[tier as usize] * self.block_tokens
    }

    /// Chain summary for the control plane's global prefix index: every
    /// resident block hash with its tier, sorted by hash for a
    /// deterministic publish order.
    pub fn summary(&self) -> Vec<(u64, Tier)> {
        let mut out: Vec<(u64, Tier)> = self.blocks.iter().map(|(h, m)| (*h, m.tier)).collect();
        out.sort_unstable();
        out
    }

    /// Invariant check: occupancy counters match block table; HBM⊆DRAM is
    /// modelled by HBM blocks counting toward DRAM occupancy.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counts = [0u64; 3];
        for m in self.blocks.values() {
            counts[m.tier as usize] += 1;
        }
        // HBM blocks also hold a DRAM copy
        let dram_total = counts[1] + counts[0];
        if counts[0] != self.used_blocks[0] {
            return Err(format!("hbm count {} != {}", counts[0], self.used_blocks[0]));
        }
        if dram_total != self.used_blocks[1] {
            return Err(format!("dram count {dram_total} != {}", self.used_blocks[1]));
        }
        if counts[2] != self.used_blocks[2] {
            return Err(format!("ssd count {} != {}", counts[2], self.used_blocks[2]));
        }
        for (t, (&u, &c)) in self.used_blocks.iter().zip(&self.cap_blocks).enumerate() {
            if u > c {
                return Err(format!("tier {t} over capacity: {u} > {c}"));
            }
        }
        Ok(())
    }
}

/// Bandwidth parameters of the transfer engine (Mooncake substitute).
#[derive(Debug, Clone, Copy)]
pub struct TransferEngine {
    pub dram_bw: f64,
    pub ssd_bw: f64,
    pub net_bw: f64,
    /// Per-operation latency floor.
    pub op_latency_s: f64,
}

impl Default for TransferEngine {
    fn default() -> Self {
        TransferEngine { dram_bw: 50e9, ssd_bw: 5e9, net_bw: 25e9, op_latency_s: 200e-6 }
    }
}

impl TransferEngine {
    /// Time to stage `bytes` from `tier` into HBM.
    pub fn load_to_hbm_s(&self, tier: Tier, bytes: f64) -> f64 {
        match tier {
            Tier::Hbm => 0.0,
            Tier::Dram => self.op_latency_s + bytes / self.dram_bw,
            Tier::Ssd => self.op_latency_s + bytes / self.ssd_bw,
        }
    }

    /// Time to migrate `bytes` between instances.
    pub fn migrate_s(&self, bytes: f64) -> f64 {
        self.op_latency_s + bytes / self.net_bw
    }
}

/// One candidate instance's state for routing.
#[derive(Debug, Clone, Copy)]
pub struct RouteCandidate {
    pub instance: usize,
    /// Blocks of the request's chain cached here.
    pub matched_blocks: usize,
    /// Exact matched tokens from a token-granular index; 0 means
    /// "unknown — derive from `matched_blocks`" (the legacy path).
    pub matched_tokens: u64,
    /// Slowest tier holding the matched prefix.
    pub hit_tier: Option<Tier>,
    /// Prompt tokens queued ahead on this instance.
    pub queued_prefill_tokens: u64,
}

/// Cache-aware routing decision (paper §3.4, steps 1–3).
///
/// Estimated latency = queueing + prefill of the *missing* suffix +
/// staging the matched prefix from its tier.  Equal-score candidates
/// resolve to the lowest instance id, so routing is reproducible
/// regardless of candidate ordering (the control plane's golden-seed
/// runs depend on this).
pub fn route(
    candidates: &[RouteCandidate],
    chain_len: usize,
    input_tokens: u64,
    block_tokens: u64,
    cost: &CostModel,
    xfer: &TransferEngine,
) -> Option<(usize, f64)> {
    candidates
        .iter()
        .map(|c| {
            let matched_tokens = if c.matched_tokens > 0 {
                c.matched_tokens.min(input_tokens)
            } else {
                (c.matched_blocks as u64 * block_tokens).min(input_tokens)
            };
            let missing = input_tokens - matched_tokens;
            let queue_s = cost.prefill_s(c.queued_prefill_tokens, 0);
            let prefill = if missing > 0 { cost.prefill_s(missing, matched_tokens) } else { 0.0 };
            let stage = match c.hit_tier {
                Some(t) => xfer
                    .load_to_hbm_s(t, matched_tokens as f64 * cost.model.kv_bytes_per_token()),
                None => 0.0,
            };
            let _ = chain_len;
            (c.instance, queue_s + prefill + stage)
        })
        .min_by(|a, b| {
            a.1.partial_cmp(&b.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.0.cmp(&b.0))
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn cache() -> TieredCache {
        TieredCache::new(16, 16 * 4, 16 * 8, 16 * 16) // 4/8/16 blocks
    }

    #[test]
    fn hash_chain_prefix_property() {
        let a: Vec<u32> = (0..64).collect();
        let mut b = a.clone();
        b[48] = 999; // differs in the last block only
        let ca = hash_chain(&a, 16);
        let cb = hash_chain(&b, 16);
        assert_eq!(ca.len(), 4);
        assert_eq!(ca[..3], cb[..3]);
        assert_ne!(ca[3], cb[3]);
    }

    #[test]
    fn match_prefix_counts_blocks() {
        let mut c = cache();
        let tokens: Vec<u32> = (0..64).collect();
        let chain = hash_chain(&tokens, 16);
        c.insert_chain(&chain[..3], Tier::Dram);
        let (n, tier) = c.match_prefix(&chain);
        assert_eq!(n, 3);
        assert_eq!(tier, Some(Tier::Dram));
        c.check_invariants().unwrap();
    }

    #[test]
    fn hbm_implies_dram_occupancy() {
        let mut c = cache();
        c.insert(42, Tier::Hbm);
        assert_eq!(c.contains(42), Some(Tier::Hbm));
        assert_eq!(c.used_tokens(Tier::Hbm), 16);
        assert_eq!(c.used_tokens(Tier::Dram), 16, "HBM copy counts in DRAM");
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_demotes_through_tiers() {
        let mut c = TieredCache::new(16, 16, 16 * 2, 16 * 2); // 1/2/2 blocks
        c.insert(1, Tier::Hbm);
        c.insert(2, Tier::Hbm); // evicts 1's HBM copy -> stays in DRAM
        assert_eq!(c.contains(1), Some(Tier::Dram));
        assert_eq!(c.contains(2), Some(Tier::Hbm));
        c.check_invariants().unwrap();
        c.insert(3, Tier::Hbm); // DRAM full: 1 demotes to SSD
        c.check_invariants().unwrap();
        assert_eq!(c.contains(1), Some(Tier::Ssd));
    }

    #[test]
    fn routing_prefers_cache_hit() {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        let xfer = TransferEngine::default();
        let cands = [
            RouteCandidate {
                instance: 0,
                matched_blocks: 0,
                matched_tokens: 0,
                hit_tier: None,
                queued_prefill_tokens: 0,
            },
            RouteCandidate {
                instance: 1,
                matched_blocks: 60,
                matched_tokens: 0,
                hit_tier: Some(Tier::Dram),
                queued_prefill_tokens: 0,
            },
        ];
        let (pick, _) = route(&cands, 64, 1024, 16, &cost, &xfer).unwrap();
        assert_eq!(pick, 1, "instance with 960/1024 tokens cached must win");
    }

    #[test]
    fn routing_balances_hit_against_queue() {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        let xfer = TransferEngine::default();
        let cands = [
            RouteCandidate {
                instance: 0,
                matched_blocks: 0,
                matched_tokens: 0,
                hit_tier: None,
                queued_prefill_tokens: 0,
            },
            RouteCandidate {
                instance: 1,
                matched_blocks: 64,
                matched_tokens: 0,
                hit_tier: Some(Tier::Ssd),
                queued_prefill_tokens: 2_000_000, // massive queue
            },
        ];
        let (pick, _) = route(&cands, 64, 1024, 16, &cost, &xfer).unwrap();
        assert_eq!(pick, 0, "hit is not worth a huge queue");
    }

    #[test]
    fn prefix_tokens_are_group_disjoint() {
        let a = prefix_tokens(1, 64);
        let b = prefix_tokens(2, 64);
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|t| !b.contains(t)), "groups must not collide");
        assert_ne!(hash_chain(&a, 16), hash_chain(&b, 16));
        assert_eq!(hash_chain(&a, 16), hash_chain(&prefix_tokens(1, 64), 16));
    }

    #[test]
    fn summary_reports_resident_blocks_sorted() {
        let mut c = cache();
        c.insert(9, Tier::Dram);
        c.insert(3, Tier::Hbm);
        c.insert(7, Tier::Ssd);
        let s = c.summary();
        assert_eq!(s, vec![(3, Tier::Hbm), (7, Tier::Ssd), (9, Tier::Dram)]);
    }

    #[test]
    fn routing_ties_resolve_to_lowest_instance_id() {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        let xfer = TransferEngine::default();
        let cand = |i| RouteCandidate {
            instance: i,
            matched_blocks: 8,
            matched_tokens: 0,
            hit_tier: Some(Tier::Dram),
            queued_prefill_tokens: 512,
        };
        // identical state in every order: the pick must always be the
        // lowest instance id
        let orders: [[usize; 3]; 3] = [[5, 2, 9], [9, 5, 2], [2, 9, 5]];
        for order in orders {
            let cands: Vec<RouteCandidate> = order.iter().map(|&i| cand(i)).collect();
            let (pick, _) = route(&cands, 8, 1024, 16, &cost, &xfer).unwrap();
            assert_eq!(pick, 2, "tie must break to lowest id, got {pick} for {order:?}");
        }
    }

    #[test]
    fn transfer_engine_ordering() {
        let x = TransferEngine::default();
        let b = 1e9;
        assert!(x.load_to_hbm_s(Tier::Hbm, b) == 0.0);
        assert!(x.load_to_hbm_s(Tier::Dram, b) < x.load_to_hbm_s(Tier::Ssd, b));
        assert!(x.migrate_s(b) > 0.0);
    }

    #[test]
    fn property_chain_churn_keeps_invariants() {
        // hammer insert_chain / match_prefix / eviction on undersized
        // caches: the occupancy invariants must hold after every op, and
        // a matched prefix must never exceed what was inserted
        crate::testutil::check("kv-chain-churn", 96, |rng| {
            let block = 8u64;
            let mut c = TieredCache::new(
                block,
                block * rng.range(1, 6),
                block * rng.range(2, 10),
                block * rng.range(2, 10),
            );
            for _ in 0..200 {
                let group = rng.range(0, 5);
                let blocks = rng.range(1, 12);
                let tokens = prefix_tokens(group, blocks * block);
                let chain = hash_chain(&tokens, block as usize);
                match rng.range(0, 2) {
                    0 => {
                        let tier = match rng.range(0, 2) {
                            0 => Tier::Hbm,
                            1 => Tier::Dram,
                            _ => Tier::Ssd,
                        };
                        c.insert_chain(&chain, tier);
                    }
                    1 => {
                        let (n, tier) = c.match_prefix(&chain);
                        crate::prop_assert!(n <= chain.len(), "matched past the chain");
                        crate::prop_assert!(
                            n == 0 || tier.is_some(),
                            "match without a tier"
                        );
                    }
                    _ => {
                        // re-insert a sub-chain at SSD (offload path)
                        let cut = rng.index(chain.len()) + 1;
                        c.insert_chain(&chain[..cut], Tier::Ssd);
                    }
                }
                c.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn token_match_credits_sub_block_tail() {
        let mut c = cache(); // block 16
        let toks = prefix_tokens(1, 40); // 2 blocks + 8-token tail
        c.insert_tokens(&toks, Tier::Dram);
        assert_eq!(c.match_prefix_tokens(&toks), (40, Some(Tier::Dram)));
        assert_eq!(c.match_prefix_tokens(&toks[..23]).0, 23, "any split point");
        // a sub-block path with no resident block still matches, served
        // from DRAM
        let short = prefix_tokens(2, 10);
        c.insert_tokens(&short, Tier::Dram);
        assert_eq!(c.match_prefix_tokens(&short), (10, Some(Tier::Dram)));
        c.check_invariants().unwrap();
    }

    #[test]
    fn token_match_requires_resident_blocks() {
        // DRAM holds 2 blocks, no SSD spill: inserting 3 blocks evicts
        // the first, and the token match must not credit past the hole —
        // not even the structural tail.
        let mut c = TieredCache::new(16, 0, 16 * 2, 0);
        let toks = prefix_tokens(1, 56); // 3 blocks + 8 tail
        c.insert_tokens(&toks, Tier::Dram);
        assert_eq!(c.contains(hash_chain(&toks, 16)[0]), None, "first block evicted");
        assert_eq!(c.match_prefix_tokens(&toks), (0, None));
        c.check_invariants().unwrap();
    }

    #[test]
    fn property_token_match_agrees_with_block_match_when_aligned() {
        // differential oracle: a token-granular cache driven with
        // block-aligned paths must be indistinguishable from the block
        // cache — matched tokens, tier, hit/miss counters, residency
        crate::testutil::check("kv-token-vs-block", 96, |rng| {
            let block = 8u64;
            let (hbm, dram, ssd) =
                (block * rng.range(1, 6), block * rng.range(2, 10), block * rng.range(2, 10));
            let mut by_block = TieredCache::new(block, hbm, dram, ssd);
            let mut by_token = TieredCache::new(block, hbm, dram, ssd);
            for _ in 0..150 {
                let group = rng.range(0, 5);
                let blocks = rng.range(1, 10);
                let tokens = prefix_tokens(group, blocks * block);
                let chain = hash_chain(&tokens, block as usize);
                match rng.range(0, 1) {
                    0 => {
                        let tier = if rng.range(0, 1) == 0 { Tier::Hbm } else { Tier::Dram };
                        by_block.insert_chain(&chain, tier);
                        by_token.insert_tokens(&tokens, tier);
                    }
                    _ => {
                        let (n, tier) = by_block.match_prefix(&chain);
                        let (tok, ttier) = by_token.match_prefix_tokens(&tokens);
                        crate::prop_assert!(
                            tok == n as u64 * block,
                            "token match {tok} != block match {n} x {block}"
                        );
                        crate::prop_assert!(ttier == tier, "tier {ttier:?} != {tier:?}");
                    }
                }
                crate::prop_assert!(
                    (by_block.hits, by_block.misses) == (by_token.hits, by_token.misses),
                    "hit/miss counters diverged"
                );
                crate::prop_assert!(
                    by_block.summary() == by_token.summary(),
                    "residency diverged"
                );
                by_block.check_invariants()?;
                by_token.check_invariants()?;
            }
            Ok(())
        });
    }

    #[test]
    fn delta_log_replays_to_the_full_summary() {
        let mut tracked = TieredCache::new(16, 16, 16 * 2, 16 * 2);
        tracked.enable_delta_tracking();
        let mut replayed: std::collections::HashMap<u64, Tier> = Default::default();
        let mut apply = |replayed: &mut std::collections::HashMap<u64, Tier>,
                         delta: Vec<(u64, Option<Tier>)>| {
            for (h, t) in delta {
                match t {
                    Some(t) => {
                        replayed.insert(h, t);
                    }
                    None => {
                        replayed.remove(&h);
                    }
                }
            }
        };
        tracked.insert(1, Tier::Hbm);
        tracked.insert(2, Tier::Hbm); // demotes 1's HBM copy
        apply(&mut replayed, tracked.take_summary_delta());
        let want: Vec<(u64, Tier)> = tracked.summary();
        let mut got: Vec<(u64, Tier)> = replayed.iter().map(|(h, t)| (*h, *t)).collect();
        got.sort_unstable();
        assert_eq!(got, want, "replaying the delta reproduces the summary");
        assert!(tracked.take_summary_delta().is_empty(), "drained");
        tracked.insert(3, Tier::Hbm); // DRAM full: 1 demotes to SSD
        tracked.insert(4, Tier::Hbm);
        apply(&mut replayed, tracked.take_summary_delta());
        let want: Vec<(u64, Tier)> = tracked.summary();
        let mut got: Vec<(u64, Tier)> = replayed.iter().map(|(h, t)| (*h, *t)).collect();
        got.sort_unstable();
        assert_eq!(got, want, "evictions and tier moves replay too");
        tracked.check_invariants().unwrap();
    }

    #[test]
    fn route_uses_exact_matched_tokens_when_present() {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        let xfer = TransferEngine::default();
        // same block count, but instance 1's token-granular match covers
        // 1020 of 1024 tokens vs instance 0's block-rounded 960
        let cands = [
            RouteCandidate {
                instance: 0,
                matched_blocks: 60,
                matched_tokens: 0,
                hit_tier: Some(Tier::Dram),
                queued_prefill_tokens: 0,
            },
            RouteCandidate {
                instance: 1,
                matched_blocks: 60,
                matched_tokens: 1020,
                hit_tier: Some(Tier::Dram),
                queued_prefill_tokens: 0,
            },
        ];
        let (pick, _) = route(&cands, 64, 1024, 16, &cost, &xfer).unwrap();
        assert_eq!(pick, 1, "exact token match must beat the block-rounded estimate");
    }

    #[test]
    fn property_tier_invariants_under_churn() {
        crate::testutil::check("kv-tier-invariants", 96, |rng| {
            let mut c = TieredCache::new(
                8,
                8 * rng.range(1, 8),
                8 * rng.range(2, 16),
                8 * rng.range(2, 16),
            );
            for _ in 0..300 {
                let h = rng.range(0, 40);
                match rng.range(0, 2) {
                    0 => {
                        let tier = match rng.range(0, 2) {
                            0 => Tier::Hbm,
                            1 => Tier::Dram,
                            _ => Tier::Ssd,
                        };
                        c.insert(h, tier);
                    }
                    _ => {
                        let chain: Vec<u64> = (0..rng.range(1, 5)).map(|i| h + i).collect();
                        c.match_prefix(&chain);
                    }
                }
                c.check_invariants()?;
            }
            Ok(())
        });
    }
}
