//! Online-offline co-location scheduler policy (paper §3.1).
//!
//! The latency-constrained decoupled architecture: the cluster is viewed
//! as a *latency-relaxed* pool (the old Prefill instances) and a
//! *latency-strict* pool (the old Decode instances).  Work items are
//! assigned by their latency class, not their phase:
//!
//! * online prefill  -> latency-relaxed (with preemption rights)
//! * online decode   -> latency-strict
//! * offline prefill -> latency-relaxed, best-effort
//! * offline decode  -> EITHER pool — the degree of freedom this policy
//!   exploits to keep both pools busy (offline decodes migrate to the
//!   relaxed pool when online prefill load drops).
//!
//! Two safety mechanisms from the paper:
//! * **Performance-bottleneck analysis** — the roofline model classifies a
//!   candidate decode batch as compute- or memory-bound; offline requests
//!   are merged only while the predicted step latency stays within the
//!   TPOT SLO ("dynamically select requests for decoding batching").
//! * **Efficient preemption** — online prefill arrivals interrupt offline
//!   prefill execution at chunk granularity (the "model execution
//!   interruption" technique: chunked prefill bounds the preemption
//!   latency to one chunk).

use crate::sim::{Bound, CostModel};
use crate::workload::RequestClass;

/// Which pool a work item should run in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolChoice {
    LatencyRelaxed,
    LatencyStrict,
}

/// Co-location policy configuration.
#[derive(Debug, Clone, Copy)]
pub struct ColocationConfig {
    /// TPOT SLO of online requests (s) — the hard constraint.
    pub online_tpot_s: f64,
    /// Fraction of the TPOT budget a decode step may use after admitting
    /// offline work (headroom guard).
    pub tpot_headroom: f64,
    /// Relaxed-pool online-prefill utilization below which offline decode
    /// migrates INTO the relaxed pool.
    pub relaxed_idle_threshold: f64,
}

impl Default for ColocationConfig {
    fn default() -> Self {
        ColocationConfig { online_tpot_s: 0.1, tpot_headroom: 0.9, relaxed_idle_threshold: 0.5 }
    }
}

/// Decide the pool for a work item (the latency-constrained reassignment).
pub fn assign_pool(
    class: RequestClass,
    is_decode: bool,
    relaxed_online_util: f64,
    cfg: &ColocationConfig,
) -> PoolChoice {
    match (class, is_decode) {
        (RequestClass::Online, false) => PoolChoice::LatencyRelaxed,
        (RequestClass::Online, true) => PoolChoice::LatencyStrict,
        (RequestClass::Offline, false) => PoolChoice::LatencyRelaxed,
        (RequestClass::Offline, true) => {
            // offline decode is the flexible load: fill the relaxed pool
            // when online prefill traffic is low, otherwise ride along on
            // strict instances (subject to the admission check below)
            if relaxed_online_util < cfg.relaxed_idle_threshold {
                PoolChoice::LatencyRelaxed
            } else {
                PoolChoice::LatencyStrict
            }
        }
    }
}

/// Admission decision for merging offline decodes into a strict-pool
/// decode batch: model the step with and without the extra sequences and
/// admit only if the TPOT budget holds (§3.1 Solution 1).
///
/// Returns how many of `offline_candidates` sequences (each with the given
/// mean context) can be admitted.
pub fn admit_offline_decodes(
    cost: &CostModel,
    online_seqs: u64,
    online_kv_tokens: u64,
    offline_candidates: u64,
    offline_ctx_tokens: u64,
    cfg: &ColocationConfig,
) -> u64 {
    let budget = cfg.online_tpot_s * cfg.tpot_headroom;
    // base step must already fit, else admit nothing
    if cost.decode_step_s(online_seqs.max(1), online_kv_tokens) > budget {
        return 0;
    }
    let mut lo = 0u64;
    let mut hi = offline_candidates;
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let t = cost.decode_step_s(
            online_seqs + mid,
            online_kv_tokens + mid * offline_ctx_tokens,
        );
        if t <= budget {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Bottleneck-aware candidate ordering (§3.1 Solution 1): when the online
/// batch is memory-bound, prefer *short-context* offline requests (they
/// add compute but little memory traffic); when compute-bound, prefer
/// long-context ones (memory-heavy, compute-light).  Returns indices of
/// `offline_ctxs` in admission order.
pub fn order_offline_candidates(
    cost: &CostModel,
    online_seqs: u64,
    online_kv_tokens: u64,
    offline_ctxs: &[u64],
) -> Vec<usize> {
    let bound = cost.decode_bound(online_seqs.max(1), online_kv_tokens);
    let mut idx: Vec<usize> = (0..offline_ctxs.len()).collect();
    match bound {
        Bound::Memory => idx.sort_by_key(|&i| offline_ctxs[i]),
        Bound::Compute => idx.sort_by_key(|&i| std::cmp::Reverse(offline_ctxs[i])),
    }
    idx
}

/// Preemption decision at chunk granularity (§3.1 Solution 2): an online
/// prefill arrival preempts offline prefill work; the latency cost is at
/// most one chunk's execution, which is bounded by `chunk_tokens`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptAction {
    /// Let the current offline chunk finish (bounded delay), then switch.
    FinishChunkThenSwitch,
    /// Nothing to preempt.
    None,
}

pub fn preempt_for_online_prefill(offline_running: bool) -> PreemptAction {
    if offline_running {
        PreemptAction::FinishChunkThenSwitch
    } else {
        PreemptAction::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn cost() -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1))
    }

    #[test]
    fn pool_assignment_matrix() {
        let cfg = ColocationConfig::default();
        use PoolChoice::*;
        use RequestClass::*;
        assert_eq!(assign_pool(Online, false, 0.9, &cfg), LatencyRelaxed);
        assert_eq!(assign_pool(Online, true, 0.9, &cfg), LatencyStrict);
        assert_eq!(assign_pool(Offline, false, 0.9, &cfg), LatencyRelaxed);
        // offline decode follows the tide:
        assert_eq!(assign_pool(Offline, true, 0.9, &cfg), LatencyStrict);
        assert_eq!(assign_pool(Offline, true, 0.1, &cfg), LatencyRelaxed);
    }

    #[test]
    fn admission_monotone_and_bounded() {
        let c = cost();
        let cfg = ColocationConfig { online_tpot_s: 0.05, ..Default::default() };
        let n = admit_offline_decodes(&c, 8, 8 * 2048, 64, 2048, &cfg);
        assert!(n <= 64);
        // admitted batch must still meet the budget
        let t = c.decode_step_s(8 + n, 8 * 2048 + n * 2048);
        assert!(t <= cfg.online_tpot_s * cfg.tpot_headroom + 1e-9);
        // one more must violate (or all were admitted)
        if n < 64 {
            let t1 = c.decode_step_s(8 + n + 1, 8 * 2048 + (n + 1) * 2048);
            assert!(t1 > cfg.online_tpot_s * cfg.tpot_headroom);
        }
    }

    #[test]
    fn admission_zero_when_budget_blown() {
        let c = cost();
        let cfg = ColocationConfig { online_tpot_s: 1e-6, ..Default::default() };
        assert_eq!(admit_offline_decodes(&c, 32, 32 * 4096, 10, 2048, &cfg), 0);
    }

    #[test]
    fn ordering_depends_on_bottleneck() {
        let c = cost();
        let ctxs = vec![8000u64, 100, 3000];
        // decode at small batch is memory bound -> short ctx first
        let order = order_offline_candidates(&c, 4, 4 * 2048, &ctxs);
        assert_eq!(order[0], 1);
        assert_eq!(order[2], 0);
    }

    #[test]
    fn preemption_is_chunk_bounded() {
        assert_eq!(preempt_for_online_prefill(true), PreemptAction::FinishChunkThenSwitch);
        assert_eq!(preempt_for_online_prefill(false), PreemptAction::None);
    }

    #[test]
    fn property_admission_never_violates_budget() {
        crate::testutil::check("coloc-admission", 64, |rng| {
            let c = cost();
            let cfg = ColocationConfig {
                online_tpot_s: 0.02 + rng.f64() * 0.2,
                ..Default::default()
            };
            let online = rng.range(1, 32);
            let kv = online * rng.range(256, 4096);
            let cand = rng.range(0, 64);
            let ctx = rng.range(128, 4096);
            let n = admit_offline_decodes(&c, online, kv, cand, ctx, &cfg);
            if n > 0 {
                let t = c.decode_step_s(online + n, kv + n * ctx);
                crate::prop_assert!(
                    t <= cfg.online_tpot_s * cfg.tpot_headroom + 1e-9,
                    "admitted batch violates TPOT: {t}"
                );
            }
            Ok(())
        });
    }
}
