//! Fast fault recovery (paper §3.5).
//!
//! Inference faults can't use training's checkpoint-then-restore (seconds
//! of model reload would blow every SLO).  xLLM's failover instead does:
//!
//! * **Fast request migration** — for each request on the failed instance,
//!   decide per-request between *recomputing* its KV (re-running prefill
//!   over the accumulated context on the target) and *migrating* a KV
//!   replica from the global cache (DRAM/SSD copy survives HBM loss) —
//!   whichever is predicted cheaper ("evaluates KV recomputation or
//!   migration costs ... and makes optimal global rescheduling
//!   decisions").
//! * **Fast instance recovery** — the restarted instance masks weight
//!   reload behind the cluster's continued serving; recovery time is
//!   modelled and reported.
//!
//! The detector is heartbeat-based (service::meta) with a short suspicion
//! timeout.

use crate::service::kvstore::{Tier, TransferEngine};
use crate::sim::CostModel;

/// How to restore one interrupted request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Re-run prefill over the context on the target instance.
    Recompute,
    /// Pull the KV replica (DRAM/SSD copy or remote replica) to the target.
    Migrate,
    /// Nothing recoverable (no replica, zero context): restart from input.
    Restart,
}

/// A request interrupted by an instance failure.
#[derive(Debug, Clone, Copy)]
pub struct InterruptedRequest {
    pub request: u64,
    /// Context tokens accumulated (prefilled + decoded).
    pub context_tokens: u64,
    /// Tier of the surviving KV replica, if any (HBM copies die with the
    /// instance; DRAM/SSD/remote copies survive).
    pub replica_tier: Option<Tier>,
}

/// Cost-based recovery decision (per request).
pub fn plan_recovery(
    req: &InterruptedRequest,
    cost: &CostModel,
    xfer: &TransferEngine,
) -> (RecoveryAction, f64) {
    if req.context_tokens == 0 {
        return (RecoveryAction::Restart, 0.0);
    }
    let recompute_s = cost.prefill_s(req.context_tokens, 0);
    match req.replica_tier {
        None | Some(Tier::Hbm) => (RecoveryAction::Recompute, recompute_s),
        Some(tier) => {
            let bytes = req.context_tokens as f64 * cost.model.kv_bytes_per_token();
            // stage from the tier, then ship to the target instance
            let migrate_s = xfer.load_to_hbm_s(tier, bytes) + xfer.migrate_s(bytes);
            if migrate_s < recompute_s {
                (RecoveryAction::Migrate, migrate_s)
            } else {
                (RecoveryAction::Recompute, recompute_s)
            }
        }
    }
}

/// Heartbeat-based failure detector.
#[derive(Debug, Clone)]
pub struct FailureDetector {
    /// Instance considered failed after this many seconds of silence.
    pub timeout_s: f64,
    last_seen: Vec<f64>,
    pub detected: Vec<usize>,
}

impl FailureDetector {
    pub fn new(n_instances: usize, timeout_s: f64) -> FailureDetector {
        FailureDetector { timeout_s, last_seen: vec![0.0; n_instances], detected: Vec::new() }
    }

    pub fn heartbeat(&mut self, instance: usize, now_s: f64) {
        self.last_seen[instance] = now_s;
        self.detected.retain(|&i| i != instance);
    }

    /// Poll for failures; returns newly detected instance ids.
    pub fn poll(&mut self, now_s: f64) -> Vec<usize> {
        let mut new = Vec::new();
        for (i, &t) in self.last_seen.iter().enumerate() {
            if now_s - t > self.timeout_s && !self.detected.contains(&i) {
                self.detected.push(i);
                new.push(i);
            }
        }
        new
    }

    /// Detection latency bound: worst case time from crash to detection.
    pub fn detection_bound_s(&self, heartbeat_interval_s: f64) -> f64 {
        self.timeout_s + heartbeat_interval_s
    }
}

/// Instance recovery time model: restart + weight load masked by overlap.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryModel {
    /// Process restart + runtime init.
    pub restart_s: f64,
    /// Weight bytes / load bandwidth.
    pub load_bw: f64,
    /// Fraction of the load masked by pipelined init (paper: "efficient
    /// masking of computation and communication").
    pub masked_fraction: f64,
}

impl Default for RecoveryModel {
    fn default() -> Self {
        RecoveryModel { restart_s: 1.0, load_bw: 10e9, masked_fraction: 0.7 }
    }
}

impl RecoveryModel {
    pub fn recovery_s(&self, weight_bytes: f64) -> f64 {
        self.restart_s + (1.0 - self.masked_fraction) * weight_bytes / self.load_bw
    }

    /// The checkpoint-reload baseline (no masking, full reload + restore).
    pub fn baseline_s(&self, weight_bytes: f64) -> f64 {
        self.restart_s + weight_bytes / self.load_bw + 0.5 * weight_bytes / self.load_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn cost() -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1))
    }

    #[test]
    fn replica_absent_recomputes_replica_present_migrates() {
        let c = cost();
        let x = TransferEngine::default();
        let none = InterruptedRequest {
            request: 1,
            context_tokens: 64,
            replica_tier: None,
        };
        let long = InterruptedRequest {
            request: 2,
            context_tokens: 120_000,
            replica_tier: Some(Tier::Dram),
        };
        let (a_none, _) = plan_recovery(&none, &c, &x);
        let (a_long, t_long) = plan_recovery(&long, &c, &x);
        assert_eq!(a_none, RecoveryAction::Recompute);
        assert_eq!(a_long, RecoveryAction::Migrate);
        assert!(t_long < c.prefill_s(120_000, 0));
    }

    #[test]
    fn hbm_only_replica_died_with_instance() {
        let c = cost();
        let x = TransferEngine::default();
        let r = InterruptedRequest {
            request: 3,
            context_tokens: 50_000,
            replica_tier: Some(Tier::Hbm),
        };
        let (a, _) = plan_recovery(&r, &c, &x);
        assert_eq!(a, RecoveryAction::Recompute);
    }

    #[test]
    fn zero_context_restarts() {
        let c = cost();
        let x = TransferEngine::default();
        let r = InterruptedRequest { request: 4, context_tokens: 0, replica_tier: None };
        assert_eq!(plan_recovery(&r, &c, &x).0, RecoveryAction::Restart);
    }

    #[test]
    fn detector_fires_after_timeout_and_clears_on_heartbeat() {
        let mut d = FailureDetector::new(3, 1.0);
        d.heartbeat(0, 0.0);
        d.heartbeat(1, 0.0);
        d.heartbeat(2, 0.0);
        assert!(d.poll(0.5).is_empty());
        d.heartbeat(0, 1.0);
        d.heartbeat(1, 1.0);
        let new = d.poll(1.9); // 2 silent for 1.9s > 1.0s; 0/1 fresh
        assert_eq!(new, vec![2]);
        assert!(d.poll(1.95).is_empty(), "no duplicate detection");
        d.heartbeat(2, 2.0);
        assert!(d.detected.is_empty());
    }

    #[test]
    fn masked_recovery_beats_checkpoint_baseline() {
        let m = RecoveryModel::default();
        let w = 16e9; // 8B params fp16
        assert!(m.recovery_s(w) < m.baseline_s(w) * 0.5);
    }

    #[test]
    fn property_recovery_picks_cheaper_option() {
        crate::testutil::check("fault-optimal", 96, |rng| {
            let c = cost();
            let x = TransferEngine::default();
            let r = InterruptedRequest {
                request: 0,
                context_tokens: rng.range(1, 200_000),
                replica_tier: match rng.range(0, 3) {
                    0 => None,
                    1 => Some(Tier::Dram),
                    _ => Some(Tier::Ssd),
                },
            };
            let (action, t) = plan_recovery(&r, &c, &x);
            let recompute = c.prefill_s(r.context_tokens, 0);
            match action {
                RecoveryAction::Recompute => {
                    if let Some(tier) = r.replica_tier {
                        if tier != Tier::Hbm {
                            let bytes =
                                r.context_tokens as f64 * c.model.kv_bytes_per_token();
                            let mig = x.load_to_hbm_s(tier, bytes) + x.migrate_s(bytes);
                            crate::prop_assert!(
                                recompute <= mig + 1e-12,
                                "chose recompute but migrate was cheaper"
                            );
                        }
                    }
                    crate::prop_assert!((t - recompute).abs() < 1e-12);
                }
                RecoveryAction::Migrate => {
                    crate::prop_assert!(t <= recompute, "chose migrate but it was dearer");
                }
                RecoveryAction::Restart => {
                    crate::prop_assert!(r.context_tokens == 0);
                }
            }
            Ok(())
        });
    }
}
