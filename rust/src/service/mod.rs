//! xLLM-Service (paper §3): cluster-level scheduling and management.
//!
//! * [`colocation`]   — online-offline co-location policy (§3.1).
//! * [`epd`]          — hybrid Encode-Prefill-Decode disaggregation (§3.3);
//!   the dynamic PD disaggregation policy (§3.2) lives in
//!   `coordinator::scheduler` + `coordinator::pools`.
//! * [`kvstore`]      — global multi-level KV cache management (§3.4).
//! * [`radix`]        — token-granular radix indexes: the local
//!   structural trie inside [`kvstore::TieredCache`] and the cluster
//!   radix tree with per-replica tier bitsets behind
//!   [`controlplane::GlobalPrefixIndex`].
//! * [`meta`]         — the ETCD-substitute metadata service (§3.4).
//! * [`fault`]        — fast fault recovery (§3.5).
//! * [`controlplane`] — the distributed control plane composing the
//!   above across N orchestrator replicas: instance registry with
//!   heartbeat leases, global prefix-cache index, cache-aware routing,
//!   and lease-expiry failover with re-dispatch (§3.4–§3.5).
//! * [`fleet`]        — the executor-agnostic fleet runtime: the
//!   [`fleet::ReplicaFactory`] seam builds N replicas (roofline or real
//!   PJRT) behind one control plane, single-threaded or with
//!   per-replica stepping threads.

pub mod colocation;
pub mod controlplane;
pub mod epd;
pub mod fault;
pub mod fleet;
pub mod kvstore;
pub mod meta;
pub mod radix;

pub use colocation::{ColocationConfig, PoolChoice};
pub use controlplane::{
    ControlCounters, ControlPlane, ControlPlaneConfig, FleetResult, GlobalPrefixIndex,
    InstanceRegistry, LoadReport, RoutePolicy,
};
pub use epd::{EpdProfile, EpdStrategy};
pub use fault::{FailureDetector, RecoveryAction};
pub use fleet::{run_fleet_with, ReplicaFactory};
pub use kvstore::{hash_chain, prefix_tokens, Tier, TieredCache, TransferEngine};
pub use meta::{MetaEvent, MetaStore};
pub use radix::{ClusterRadix, ReplicaSet, TokenRadix};
