//! Metadata service (the paper's ETCD substitute, §3.4).
//!
//! In-process replicated-KV abstraction providing what the global KV cache
//! manager needs: service registration with TTL leases, heartbeat-driven
//! liveness, load-info synchronization, and versioned global cache state.
//! Watchers receive ordered change notifications (the aggregation events
//! instances push "at regular intervals ... via ETCD heartbeat
//! mechanisms").

use std::collections::HashMap;

/// A registered instance's advertised state.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceRecord {
    pub instance: usize,
    /// Pool/role advertisement.
    pub role: String,
    /// Load metrics (tokens resident, free KV, etc.).
    pub kv_used: u64,
    pub kv_capacity: u64,
    pub last_heartbeat_s: f64,
}

/// A change event delivered to watchers.
#[derive(Debug, Clone, PartialEq)]
pub enum MetaEvent {
    Registered(usize),
    Updated(usize),
    Expired(usize),
    /// Explicit removal by the control plane (clean delete — distinct
    /// from a lease lapsing).
    Deregistered(usize),
    CacheIndexUpdated { instance: usize, version: u64 },
}

/// The metadata store: registration + leases + a versioned KV index.
#[derive(Debug, Default)]
pub struct MetaStore {
    instances: HashMap<usize, InstanceRecord>,
    /// Lease TTL: instances missing heartbeats this long are expired.
    ttl_s: f64,
    /// Monotonic version per instance's published cache index.
    cache_versions: HashMap<usize, u64>,
    /// Ordered event log (watchers read from an offset).
    events: Vec<MetaEvent>,
}

impl MetaStore {
    pub fn new(ttl_s: f64) -> MetaStore {
        MetaStore { ttl_s, ..Default::default() }
    }

    /// Register (or re-register) an instance.
    pub fn register(&mut self, rec: InstanceRecord) {
        let id = rec.instance;
        let new = !self.instances.contains_key(&id);
        self.instances.insert(id, rec);
        self.events.push(if new { MetaEvent::Registered(id) } else { MetaEvent::Updated(id) });
    }

    /// Heartbeat: refresh the lease and load info.
    pub fn heartbeat(&mut self, instance: usize, kv_used: u64, now_s: f64) -> bool {
        match self.instances.get_mut(&instance) {
            Some(r) => {
                r.kv_used = kv_used;
                r.last_heartbeat_s = now_s;
                self.events.push(MetaEvent::Updated(instance));
                true
            }
            None => false,
        }
    }

    /// Expire instances whose lease lapsed; returns the expired ids.
    pub fn sweep(&mut self, now_s: f64) -> Vec<usize> {
        let dead: Vec<usize> = self
            .instances
            .iter()
            .filter(|(_, r)| now_s - r.last_heartbeat_s > self.ttl_s)
            .map(|(id, _)| *id)
            .collect();
        for id in &dead {
            self.instances.remove(id);
            self.cache_versions.remove(id);
            self.events.push(MetaEvent::Expired(*id));
        }
        dead
    }

    /// Remove an instance without waiting for its lease to lapse (the
    /// control plane already knows it is gone).  Returns false if the
    /// instance was not registered.
    pub fn deregister(&mut self, instance: usize) -> bool {
        if self.instances.remove(&instance).is_some() {
            self.cache_versions.remove(&instance);
            self.events.push(MetaEvent::Deregistered(instance));
            true
        } else {
            false
        }
    }

    /// Publish a new cache-index version for an instance (the aggregated
    /// KV load/offload events of the interval).
    pub fn publish_cache_index(&mut self, instance: usize) -> u64 {
        let v = self.cache_versions.entry(instance).or_insert(0);
        *v += 1;
        let version = *v;
        self.events.push(MetaEvent::CacheIndexUpdated { instance, version });
        version
    }

    pub fn cache_version(&self, instance: usize) -> u64 {
        self.cache_versions.get(&instance).copied().unwrap_or(0)
    }

    pub fn get(&self, instance: usize) -> Option<&InstanceRecord> {
        self.instances.get(&instance)
    }

    pub fn alive(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.instances.keys().copied().collect();
        ids.sort();
        ids
    }

    /// Read events from `offset`; returns (new offset, events).
    pub fn watch(&self, offset: usize) -> (usize, &[MetaEvent]) {
        (self.events.len(), &self.events[offset.min(self.events.len())..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, t: f64) -> InstanceRecord {
        InstanceRecord {
            instance: id,
            role: "decode".to_string(),
            kv_used: 0,
            kv_capacity: 1000,
            last_heartbeat_s: t,
        }
    }

    #[test]
    fn register_heartbeat_sweep() {
        let mut m = MetaStore::new(5.0);
        m.register(rec(1, 0.0));
        m.register(rec(2, 0.0));
        assert_eq!(m.alive(), vec![1, 2]);
        m.heartbeat(1, 42, 4.0);
        let dead = m.sweep(6.0);
        assert_eq!(dead, vec![2]);
        assert_eq!(m.alive(), vec![1]);
        assert_eq!(m.get(1).unwrap().kv_used, 42);
    }

    #[test]
    fn heartbeat_unknown_instance_fails() {
        let mut m = MetaStore::new(5.0);
        assert!(!m.heartbeat(9, 0, 1.0));
    }

    #[test]
    fn watch_sees_ordered_events() {
        let mut m = MetaStore::new(5.0);
        m.register(rec(1, 0.0));
        let (off, ev) = m.watch(0);
        assert_eq!(ev, &[MetaEvent::Registered(1)]);
        m.publish_cache_index(1);
        m.heartbeat(1, 7, 1.0);
        let (_, ev2) = m.watch(off);
        assert_eq!(ev2.len(), 2);
        assert!(matches!(ev2[0], MetaEvent::CacheIndexUpdated { instance: 1, version: 1 }));
    }

    #[test]
    fn deregister_removes_without_expiry() {
        let mut m = MetaStore::new(5.0);
        m.register(rec(1, 0.0));
        m.register(rec(2, 0.0));
        assert!(m.deregister(1));
        assert!(!m.deregister(1), "already gone");
        assert_eq!(m.alive(), vec![2]);
        // no spurious Expired for a deregistered instance
        let dead = m.sweep(100.0);
        assert_eq!(dead, vec![2]);
        let (_, ev) = m.watch(0);
        assert!(ev.contains(&MetaEvent::Deregistered(1)));
        assert!(!ev.contains(&MetaEvent::Expired(1)));
    }

    #[test]
    fn cache_versions_monotonic() {
        let mut m = MetaStore::new(5.0);
        m.register(rec(3, 0.0));
        assert_eq!(m.publish_cache_index(3), 1);
        assert_eq!(m.publish_cache_index(3), 2);
        assert_eq!(m.cache_version(3), 2);
        m.sweep(100.0);
        assert_eq!(m.cache_version(3), 0, "expiry clears versions");
    }
}
