//! Hybrid Encode-Prefill-Decode disaggregation (paper §3.3).
//!
//! Multimodal requests add an Encode phase (vision tower).  The policy
//! space is which phases co-locate on an instance:
//!
//! * `EP-D`  — Encode fused with Prefill (runs in the P pool), Decode
//!   separate.
//! * `ED-P`  — Encode fused with Decode (runs in the D pool), Prefill
//!   separate.
//! * `E-P-D` — all three phases on separate pools.
//!
//! The **EPD profiler** binary-searches, per strategy, (1) the maximum
//! encode batch size and (2) the prefill/decode token budget such that a
//! worst-case iteration still meets the TPOT SLO; it then picks the
//! strategy maximizing predicted goodput under the measured workload mix
//! (the paper's "automatically selects the optimal disaggregation strategy
//! based on pre-profiling").
//!
//! Dual-stream parallelism (vision stream ∥ language stream) halves the
//! exposed encode time on instances that run Encode alongside LM phases.

use crate::sim::CostModel;

/// The three disaggregation strategies (+ the fused baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpdStrategy {
    /// Everything on every instance (no disaggregation — ablation base).
    Fused,
    /// Encode+Prefill in the P pool; Decode separate.
    EpD,
    /// Encode+Decode in the D pool; Prefill separate.
    EdP,
    /// Three separate pools.
    EPD,
}

pub const ALL_STRATEGIES: [EpdStrategy; 4] =
    [EpdStrategy::Fused, EpdStrategy::EpD, EpdStrategy::EdP, EpdStrategy::EPD];

/// Profiler output for one strategy.
#[derive(Debug, Clone, Copy)]
pub struct EpdProfile {
    pub strategy: EpdStrategy,
    /// Max images per encode batch under the TPOT SLO.
    pub max_encode_batch: u64,
    /// Prefill token budget per iteration under the TPOT SLO.
    pub token_budget: u64,
    /// Predicted goodput score (relative).
    pub score: f64,
}

/// Binary-search the largest `x` in [lo, hi] with `ok(x)` (monotone).
fn bsearch_max<F: Fn(u64) -> bool>(lo: u64, hi: u64, ok: F) -> u64 {
    if !ok(lo) {
        return 0;
    }
    let (mut lo, mut hi) = (lo, hi);
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        if ok(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Profile one strategy: the iteration that must meet TPOT depends on
/// which phases share an instance with decode.
pub fn profile_strategy(
    strategy: EpdStrategy,
    cost: &CostModel,
    patches_per_image: u64,
    decode_seqs: u64,
    decode_kv: u64,
    tpot_slo_s: f64,
) -> EpdProfile {
    let base = cost.decode_step_s(decode_seqs.max(1), decode_kv);
    // encode batch limit: only binds when encode shares with decode
    // (ED-P, Fused); dual-stream hides half the encode cost
    let encode_shares_decode = matches!(strategy, EpdStrategy::EdP | EpdStrategy::Fused);
    let max_encode_batch = if encode_shares_decode {
        bsearch_max(1, 64, |b| {
            base + 0.5 * cost.encode_s(b * patches_per_image) <= tpot_slo_s
        })
    } else {
        // encode never delays decode; capped by encoder throughput alone
        64
    };
    // prefill token budget: binds when prefill shares with decode
    let prefill_shares_decode = matches!(strategy, EpdStrategy::Fused);
    let token_budget = if prefill_shares_decode {
        bsearch_max(16, 8192, |t| base + cost.prefill_s(t, 0) <= tpot_slo_s)
    } else {
        8192
    };

    // goodput score: phase parallelism (more separation = more parallel
    // capacity) minus migration overhead (more separation = more KV/image
    // hops)
    let parallelism = match strategy {
        EpdStrategy::Fused => 1.0,
        EpdStrategy::EpD => 1.8,
        EpdStrategy::EdP => 1.6,
        EpdStrategy::EPD => 2.2,
    };
    let hops = match strategy {
        EpdStrategy::Fused => 0.0,
        EpdStrategy::EpD | EpdStrategy::EdP => 1.0,
        EpdStrategy::EPD => 2.0,
    };
    let hop_cost = cost.kv_transfer_s(2048) * hops;
    let effective_budget = token_budget.min(8192) as f64;
    let score = parallelism * (effective_budget / 8192.0).max(0.1)
        * (max_encode_batch as f64).max(1.0).min(16.0).sqrt()
        / (1.0 + 10.0 * hop_cost);
    EpdProfile { strategy, max_encode_batch, token_budget, score }
}

/// The EPD profiler: evaluate all strategies, pick the best score.
pub fn profile_all(
    cost: &CostModel,
    patches_per_image: u64,
    decode_seqs: u64,
    decode_kv: u64,
    tpot_slo_s: f64,
) -> (EpdProfile, Vec<EpdProfile>) {
    let profiles: Vec<EpdProfile> = ALL_STRATEGIES
        .iter()
        .map(|&s| profile_strategy(s, cost, patches_per_image, decode_seqs, decode_kv, tpot_slo_s))
        .collect();
    let best = *profiles
        .iter()
        .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
        .unwrap();
    (best, profiles)
}

/// Which pool runs each phase under a strategy (instance placement).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhasePlacement {
    /// Pool index: 0 = P pool, 1 = D pool, 2 = E pool.
    pub encode_pool: u8,
    pub prefill_pool: u8,
    pub decode_pool: u8,
}

pub fn placement(strategy: EpdStrategy) -> PhasePlacement {
    match strategy {
        EpdStrategy::Fused => PhasePlacement { encode_pool: 0, prefill_pool: 0, decode_pool: 0 },
        EpdStrategy::EpD => PhasePlacement { encode_pool: 0, prefill_pool: 0, decode_pool: 1 },
        EpdStrategy::EdP => PhasePlacement { encode_pool: 1, prefill_pool: 0, decode_pool: 1 },
        EpdStrategy::EPD => PhasePlacement { encode_pool: 2, prefill_pool: 0, decode_pool: 1 },
    }
}

/// Dual-stream exposure: fraction of encode time visible to the language
/// stream when the two run on separate device streams (§3.3).
pub fn dual_stream_encode_exposure() -> f64 {
    0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn cost() -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen2-7B").unwrap(), EngineFeatures::xllm(1))
    }

    #[test]
    fn bsearch_finds_boundary() {
        assert_eq!(bsearch_max(1, 100, |x| x <= 37), 37);
        assert_eq!(bsearch_max(1, 100, |_| true), 100);
        assert_eq!(bsearch_max(1, 100, |_| false), 0);
    }

    #[test]
    fn profiles_respect_tpot() {
        let c = cost();
        let slo = 0.05;
        let p = profile_strategy(EpdStrategy::Fused, &c, 576, 16, 16 * 1024, slo);
        if p.max_encode_batch > 0 {
            let t = c.decode_step_s(16, 16 * 1024)
                + 0.5 * c.encode_s(p.max_encode_batch * 576);
            assert!(t <= slo + 1e-9, "encode batch violates TPOT: {t}");
        }
        if p.token_budget > 0 {
            let t = c.decode_step_s(16, 16 * 1024) + c.prefill_s(p.token_budget, 0);
            assert!(t <= slo + 1e-9, "token budget violates TPOT: {t}");
        }
    }

    #[test]
    fn separated_strategies_get_bigger_budgets() {
        let c = cost();
        let fused = profile_strategy(EpdStrategy::Fused, &c, 576, 16, 16 * 1024, 0.05);
        let epd = profile_strategy(EpdStrategy::EPD, &c, 576, 16, 16 * 1024, 0.05);
        assert!(epd.token_budget >= fused.token_budget);
        assert!(epd.max_encode_batch >= fused.max_encode_batch);
    }

    #[test]
    fn profiler_picks_a_disaggregated_strategy_under_load() {
        let c = cost();
        let (best, all) = profile_all(&c, 576, 16, 16 * 1024, 0.05);
        assert_eq!(all.len(), 4);
        assert_ne!(best.strategy, EpdStrategy::Fused, "disaggregation should win under load");
    }

    #[test]
    fn placement_matrix() {
        assert_eq!(placement(EpdStrategy::EpD).encode_pool, 0);
        assert_eq!(placement(EpdStrategy::EpD).decode_pool, 1);
        assert_eq!(placement(EpdStrategy::EdP).encode_pool, 1);
        assert_eq!(placement(EpdStrategy::EPD).encode_pool, 2);
    }

    #[test]
    fn property_profile_budgets_monotone_in_slo() {
        crate::testutil::check("epd-monotone", 32, |rng| {
            let c = cost();
            let slo_small = 0.02 + rng.f64() * 0.02;
            let slo_big = slo_small * 2.0;
            for s in ALL_STRATEGIES {
                let a = profile_strategy(s, &c, 576, 8, 8 * 1024, slo_small);
                let b = profile_strategy(s, &c, 576, 8, 8 * 1024, slo_big);
                crate::prop_assert!(
                    b.max_encode_batch >= a.max_encode_batch,
                    "encode batch not monotone for {s:?}"
                );
                crate::prop_assert!(
                    b.token_budget >= a.token_budget,
                    "token budget not monotone for {s:?}"
                );
            }
            Ok(())
        });
    }
}
