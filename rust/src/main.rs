//! xLLM launcher: serve (real PJRT engine), simulate (cluster sim), info.
//!
//! ```text
//! xllm serve    --requests 16 --prompt-len 64 --max-new 24 --batch 8
//! xllm simulate --scenario sharegpt-2048 --model Qwen3-8B --instances 4 \
//!               --rate 2.0 --horizon 60 --mode pd --tpot 0.05
//! xllm fleet    --replicas 3 --instances 1 --scenario skewed-prefix \
//!               --rate 2.0 --horizon 40 --routing cache-aware \
//!               --fail-replica 0 --fail-at 10
//! xllm fleet    --scenario tide --rate 6 --horizon 40 --replicas 1 \
//!               --autoscale --capacity-target 4096 --min-replicas 1 \
//!               --max-replicas 6
//! xllm fleet    --scenario tide --rate 6 --horizon 40 --replicas 2 \
//!               --pipeline-depth 2 --host-overhead 0.002
//! xllm fleet    --scenario tide --rate 6 --horizon 40 --replicas 2 \
//!               --threads 2 --pipeline-depth 2
//! xllm fleet    --backend pjrt --replicas 2 --scenario skewed-prefix \
//!               --rate 1 --horizon 10        # needs artifacts/; skips otherwise
//! xllm models | scenarios | info
//! ```
//!
//! `--engine-policies eplb,op-overlap,graph` (serve, simulate, fleet)
//! switches the §4 executor-level engine policies on individually
//! (`all` / `none`; default `none` — the seed behavior, bit for bit);
//! `--engine-features xllm|vllm|mindie` (simulate) is an alias of
//! `--framework`; `--pipeline-depth N` (serve, simulate, fleet) keeps
//! N iterations in flight per instance (§4.2 async scheduling; 1 =
//! blocking);
//! `--host-overhead S` (simulate, fleet) models the per-iteration host
//! planning cost the pipeline hides; `--threads N` (fleet) steps the
//! replicas on N worker threads between control events (1 = the
//! deterministic single-queue interleave); `--backend pjrt` (fleet)
//! runs N real `PjrtExecutor` replicas over the AOT artifacts behind
//! the same control plane; `--shard tp=T,pp=P[,mb=M]` (serve, simulate,
//! fleet) sizes each replica's device group — T-way tensor parallel per
//! stage × P pipeline stages fed by M micro-batches (`--tp N` stays as
//! the tensor-only shorthand); `--device-budget N` (fleet, with
//! `--autoscale`) caps total fleet devices: the scaler trades replica
//! count against shard width and never exceeds `Σ tp×pp ≤ N`;
//! `--token-granular` (fleet) switches the cluster index to the radix
//! tree over token ids — token-exact prefix matching and admission,
//! incremental heartbeat publishes, sub-chain rebalance ranges (off =
//! block-aligned chains, bit-identical to prior builds);
//! `--requests N` (fleet, roofline) streams N open-loop arrivals
//! through the fleet instead of materializing a horizon-bounded
//! workload — reports run sketch-only, so memory stays O(live
//! requests) even at millions of arrivals; `--scale-policy
//! slo|backlog` (fleet, with `--autoscale`) picks the capacity signal:
//! token-backlog thresholds (default) or predicted-TTFT SLO violation
//! (`--slo-ttft S` sets the defended target).
//!
//! Observability (serve, simulate, fleet): `--trace-out PATH` records
//! the request-lifecycle trace and writes Perfetto-loadable Chrome
//! trace JSON; `--metrics-out PATH` writes the unified metrics registry
//! as Prometheus text exposition.  Tracing is off unless requested —
//! untraced runs stay bit-identical to pre-observability builds.
//! `--quiet` / `-v` gate the stderr progress log.

use std::path::Path;

use anyhow::{bail, Result};

use xllm::config::{Args, ServeConfig};
use xllm::coordinator::orchestrator::ServingMode;
use xllm::coordinator::DispatchPolicy;
use xllm::engine::EnginePolicies;
use xllm::metrics::Slo;
use xllm::model;
use xllm::obs::{self, chrome_trace_json, prometheus_text, MetricsRegistry, TraceHandle};
use xllm::server::{synth_prompt, GenRequest, Server};
use xllm::sim::cluster::{ClusterConfig, ClusterSim};
use xllm::sim::EngineFeatures;
use xllm::util::json::Json;
use xllm::util::Rng;
use xllm::workload::scenarios::{scenario, SCENARIO_NAMES};

fn main() {
    let args = Args::from_env();
    // --quiet / -v gate every progress notice (stderr only; command
    // stdout stays the machine-readable JSON result)
    if args.has_flag("quiet") {
        obs::log::set_verbosity(obs::log::QUIET);
    } else if args.has_flag("-v") || args.has_flag("verbose") {
        obs::log::set_verbosity(obs::log::DEBUG);
    }
    let code = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("models") => {
            for name in model::CATALOG_NAMES {
                let m = model::catalog(name).unwrap();
                println!(
                    "{name:24} params={:>8.2}B active={:>7.2}B layers={} moe={}",
                    m.params / 1e9,
                    m.active_params / 1e9,
                    m.n_layers,
                    m.is_moe
                );
            }
            Ok(())
        }
        Some("scenarios") => {
            for s in SCENARIO_NAMES {
                println!("{s}");
            }
            Ok(())
        }
        Some("info") => cmd_info(&args),
        other => {
            eprintln!(
                "xllm {} — decoupled service-engine LLM inference (paper reproduction)\n\
                 usage: xllm <serve|simulate|fleet|models|scenarios|info> [--key value ...]\n\
                 unknown subcommand: {other:?}",
                xllm::version()
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--trace-out PATH` / `--metrics-out PATH` (serve, simulate, fleet).
/// The recording trace handle exists only when `--trace-out` was given —
/// the default stays the zero-overhead no-op sink, so untraced runs are
/// bit-identical to pre-observability builds.
fn obs_outputs(args: &Args) -> (TraceHandle, Option<String>, Option<String>) {
    let trace_out = args.get("trace-out").map(str::to_string);
    let metrics_out = args.get("metrics-out").map(str::to_string);
    let trace = if trace_out.is_some() { TraceHandle::recording() } else { TraceHandle::off() };
    (trace, trace_out, metrics_out)
}

/// Drain the recorded events into a Perfetto-loadable Chrome trace file.
fn write_trace(path: &str, trace: &TraceHandle) -> Result<()> {
    let events = trace.drain();
    std::fs::write(path, chrome_trace_json(&events))?;
    obs::log::info(format!("# trace: {} events -> {path}", events.len()));
    Ok(())
}

/// Write the registry as Prometheus text exposition.
fn write_metrics(path: &str, reg: &MetricsRegistry) -> Result<()> {
    std::fs::write(path, prometheus_text(reg))?;
    obs::log::info(format!("# metrics -> {path}"));
    Ok(())
}

/// Mean per-phase latency breakdown (queue/prefill/handoff/decode) as a
/// JSON object for the command result.
fn phase_seconds_json(report: &xllm::metrics::ServingReport) -> Json {
    let mut pj = Json::obj();
    for (name, s) in report.phase_summaries() {
        pj = pj.set(name, s.mean());
    }
    pj
}

/// `--shard tp=..,pp=..,mb=..` (serve, simulate, fleet).  Without it,
/// `--tp N` keeps working as the tensor-only shorthand.
fn shard_from_args(args: &Args) -> Result<model::ShardSpec> {
    match args.get("shard") {
        Some(s) => model::ShardSpec::parse(s).map_err(|e| anyhow::anyhow!(e)),
        None => Ok(model::ShardSpec::tp(args.get_u64("tp", 1) as u32)),
    }
}

/// The replica device-group shape as a JSON object for command results.
fn shard_json(shard: model::ShardSpec) -> Json {
    Json::obj()
        .set("tp", shard.tp)
        .set("pp", shard.pp)
        .set("micro_batches", shard.micro_batches)
        .set("devices", shard.devices())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let n_requests = args.get_u64("requests", 16) as usize;
    let prompt_len = args.get_u64("prompt-len", 64) as usize;
    let max_new = args.get_u64("max-new", 24) as usize;
    let batch = args.get_u64("batch", 8) as usize;
    let speculative = args.has_flag("speculative");
    let shard = shard_from_args(args)?;

    let cfg = ServeConfig {
        artifacts_dir: artifacts.clone(),
        max_batch: batch,
        max_output_tokens: max_new,
        speculative,
        shard,
        // ≥ 2 moves the engine onto a worker thread (async pipeline §4.2)
        pipeline_depth: args.get_u64("pipeline-depth", 1).max(1) as usize,
        policies: EnginePolicies::parse(&args.get_or("engine-policies", "none"))
            .map_err(|e| anyhow::anyhow!(e))?,
        ..ServeConfig::default()
    };
    let (trace, trace_out, metrics_out) = obs_outputs(args);
    let mut server = Server::new(Path::new(&artifacts), cfg)?;
    if trace.enabled() {
        server.set_trace(trace.clone());
    }
    for i in 0..n_requests {
        server.submit(GenRequest {
            id: i as u64,
            prompt: synth_prompt(i as u64, prompt_len),
            max_new_tokens: max_new,
        });
    }
    let t0 = std::time::Instant::now();
    let results = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    let report = server.report.clone();
    let out = Json::obj()
        .set("requests", results.len())
        .set("wall_s", wall)
        .set("tokens_generated", server.stats.tokens_generated)
        .set("throughput_tok_s", server.stats.tokens_generated as f64 / wall)
        .set("mean_ttft_s", report.ttft_summary().mean())
        .set("p99_ttft_s", report.ttft_summary().percentile(99.0))
        .set("mean_tpot_s", report.tpot_summary().mean())
        .set("prefills", server.stats.prefills)
        .set("decode_steps", server.stats.decode_steps)
        .set("spec_tokens_per_round", server.stats.spec.tokens_per_round())
        .set("page_maps", server.page_stats().maps)
        .set("page_reuse", server.page_stats().remaps_from_reusable)
        .set("graph_compiles", server.graph_stats().compiles)
        .set("graph_hits", server.graph_stats().hits)
        .set("graph_full_hits", server.stats.graph_full_hits)
        .set("graph_padded_hits", server.stats.graph_padded_hits)
        .set("graph_eager_fallbacks", server.stats.graph_eager_fallbacks)
        .set("calibration_updates", server.stats.calibration_updates)
        .set("shard", shard_json(shard))
        .set("phase_seconds", phase_seconds_json(&report));
    println!("{}", out.to_string());
    if let Some(r) = results.first() {
        obs::log::info(format!("# sample generation (req {}): {:?}", r.id, &r.tokens));
    }
    if let Some(p) = &metrics_out {
        let mut reg = MetricsRegistry::new();
        report.export_metrics(&mut reg);
        server.stats.export_metrics(&mut reg);
        write_metrics(p, &reg)?;
    }
    if let Some(p) = &trace_out {
        write_trace(p, &trace)?;
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let scenario_name = args.get_or("scenario", "sharegpt-2048");
    let model_name = args.get_or("model", "Qwen3-8B");
    let n = args.get_u64("instances", 4) as usize;
    let rate = args.get_f64("rate", 1.0);
    let horizon = args.get_f64("horizon", 60.0);
    let shard = shard_from_args(args)?;
    let mode = args.get_or("mode", "colocated");
    // `--engine-features` is the paper-facing alias of `--framework`
    let framework = args
        .get("engine-features")
        .map(str::to_string)
        .unwrap_or_else(|| args.get_or("framework", "xllm"));
    let tpot = args.get_f64("tpot", f64::INFINITY);
    let ttft = args.get_f64("ttft", f64::INFINITY);
    let hw = match args.get_or("hw", "910B").as_str() {
        "910B" => model::ascend_910b(),
        "910C" => model::ascend_910c(),
        "cpu" => model::cpu_host(),
        other => bail!("unknown hw {other}"),
    };
    let spec = model::catalog(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name} (see `xllm models`)"))?;
    let features = match framework.as_str() {
        "xllm" => EngineFeatures::xllm(shard.tp),
        "vllm" => EngineFeatures::vllm(shard.tp),
        "mindie" => EngineFeatures::mindie(shard.tp),
        other => bail!("unknown framework {other}"),
    }
    .with_shard(shard);
    let sc = scenario(&scenario_name)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario {scenario_name}"))?;

    let mut cfg = ClusterConfig::new(n, hw, spec, features);
    cfg.slo = Slo::interactive(ttft, tpot);
    cfg.mode = match mode.as_str() {
        "colocated" => ServingMode::Colocated,
        "pd" => ServingMode::Disaggregated {
            n_prefill: args.get_u64("prefill-instances", (n as u64 / 3).max(1)) as usize,
            dynamic: true,
        },
        "pd-static" => ServingMode::Disaggregated {
            n_prefill: args.get_u64("prefill-instances", (n as u64 / 3).max(1)) as usize,
            dynamic: false,
        },
        other => bail!("unknown mode {other}"),
    };
    cfg.dispatch = match args.get_or("dispatch", "slo-aware").as_str() {
        "round-robin" => DispatchPolicy::RoundRobin,
        "minimal-load" => DispatchPolicy::MinimalLoad,
        _ => DispatchPolicy::SloAware,
    };
    cfg.prefix_cache = args.has_flag("prefix-cache");
    cfg.pipeline_depth = args.get_u64("pipeline-depth", 1).max(1) as usize;
    cfg.host_overhead_s = args.get_f64("host-overhead", 0.0).max(0.0);
    cfg.policies = EnginePolicies::parse(&args.get_or("engine-policies", "none"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let policies_label = cfg.policies.label();

    let mut rng = Rng::new(args.get_u64("seed", 7));
    let workload = sc.generate(horizon, rate, &mut rng);
    let n_reqs = workload.len();
    let pipeline_depth = cfg.pipeline_depth;
    let (trace, trace_out, metrics_out) = obs_outputs(args);
    let mut sim = ClusterSim::new(cfg);
    if trace.enabled() {
        sim.set_trace(trace.clone());
    }
    let (res, exec) = sim.run_with_executor(workload);
    let slo = Slo::interactive(ttft, tpot);
    let report = res.report.clone();
    let out = Json::obj()
        .set("scenario", scenario_name)
        .set("model", model_name)
        .set("framework", framework)
        .set("engine_policies", policies_label)
        .set("instances", n)
        .set("shard", shard_json(shard))
        .set("requests", n_reqs)
        .set("completed", report.n_completed())
        .set("output_tok_s", report.output_throughput())
        .set("total_tok_s", report.total_throughput())
        .set("request_rate", report.request_rate())
        .set("mean_ttft_s", report.ttft_summary().mean())
        .set("mean_tpot_s", report.tpot_summary().mean())
        .set("mean_e2e_s", report.e2e_summary().mean())
        .set("slo_attainment", report.slo_attainment(&slo))
        .set("goodput_req_s", report.goodput(&slo))
        .set("role_flips", res.role_flips)
        .set("migrations", res.migrations)
        .set("preemptions", res.preemptions)
        .set("iterations", res.iterations)
        .set("pipeline_depth", pipeline_depth)
        .set("phase_seconds", phase_seconds_json(&report));
    println!("{}", out.to_string());
    if let Some(p) = &metrics_out {
        let mut reg = MetricsRegistry::new();
        report.export_metrics(&mut reg);
        res.export_metrics(&mut reg);
        exec.policy_counters().unwrap_or_default().export_metrics(&mut reg);
        write_metrics(p, &reg)?;
    }
    if let Some(p) = &trace_out {
        write_trace(p, &trace)?;
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use xllm::server::PjrtReplicaFactory;
    use xllm::service::controlplane::{
        ControlPlaneConfig, RoutePolicy, ScalePolicy, ScalerConfig,
    };
    use xllm::service::fleet::run_fleet_with;
    use xllm::sim::fleet::{run_fleet, run_fleet_stream, FleetConfig};

    let scenario_name = args.get_or("scenario", "skewed-prefix");
    let model_name = args.get_or("model", "Qwen3-8B");
    let n_replicas = args.get_u64("replicas", 3) as usize;
    let n_instances = args.get_u64("instances", 1) as usize;
    let rate = args.get_f64("rate", 2.0);
    let horizon = args.get_f64("horizon", 40.0);
    let backend = args.get_or("backend", "roofline");
    let shard = shard_from_args(args)?;
    let pipeline_depth = args.get_u64("pipeline-depth", 1).max(1) as usize;
    let policies = EnginePolicies::parse(&args.get_or("engine-policies", "none"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let sc = scenario(&scenario_name)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario {scenario_name}"))?;

    // control-plane policy is backend-agnostic: the same routing,
    // leases, scaler, and stepping threads drive roofline and real
    // PJRT replicas alike
    let mut control = ControlPlaneConfig {
        routing: match args.get_or("routing", "cache-aware").as_str() {
            "round-robin" => RoutePolicy::RoundRobin,
            _ => RoutePolicy::CacheAware,
        },
        threads: args.get_u64("threads", 1).max(1) as usize,
        // token-granular KV admission: radix cluster index, incremental
        // heartbeat publishes, exact matched-token routing/charging
        token_granular: args.has_flag("token-granular"),
        ..ControlPlaneConfig::default()
    };
    let (trace, trace_out, metrics_out) = obs_outputs(args);
    control.trace = trace.clone();
    let fail_at = args.get_f64("fail-at", f64::NAN);
    if fail_at.is_finite() {
        control.replica_faults.push((fail_at, args.get_u64("fail-replica", 0) as usize));
    }
    if args.has_flag("autoscale") {
        let d = ScalerConfig::default();
        control.scaler = Some(ScalerConfig {
            policy: match args.get_or("scale-policy", "backlog").as_str() {
                "slo" => ScalePolicy::Slo,
                _ => ScalePolicy::Backlog,
            },
            slo_ttft_target_s: args.get_f64("slo-ttft", d.slo_ttft_target_s),
            capacity_target_tokens: args
                .get_u64("capacity-target", d.capacity_target_tokens),
            min_replicas: args.get_u64("min-replicas", 1) as usize,
            max_replicas: args.get_u64("max-replicas", d.max_replicas as u64) as usize,
            cooldown_s: args.get_f64("cooldown", d.cooldown_s),
            hot_prefix_routes: args.get_u64("hot-prefix-routes", d.hot_prefix_routes),
            warm_start_chains: args
                .get_u64("warm-start-chains", d.warm_start_chains as u64)
                as usize,
            device_budget: args.get_u64("device-budget", d.device_budget),
            ..d
        });
    }

    // --requests N switches to the open-loop streaming path: arrivals
    // are pulled one at a time and the report runs sketch-only, so a
    // million-request run holds O(live requests) memory, not O(N)
    let requests_cap = args.get_u64("requests", 0) as usize;
    let mut rng = Rng::new(args.get_u64("seed", 7));
    let workload = if requests_cap == 0 {
        sc.generate(horizon, rate, &mut rng)
    } else {
        Vec::new()
    };
    let threads = control.threads;

    let res = match backend.as_str() {
        "pjrt" => {
            if requests_cap > 0 {
                bail!("--requests streaming is roofline-only (the AOT engine workload must be clamped up front)");
            }
            // real engines: N PjrtExecutor replicas behind the same
            // control plane (skips gracefully without artifacts)
            let artifacts = args.get_or("artifacts", "artifacts");
            let dir = Path::new(&artifacts);
            if !dir.join("manifest.txt").exists() {
                obs::log::info(format!(
                    "# skipping pjrt fleet: {artifacts}/ not built (run `make artifacts`)"
                ));
                return Ok(());
            }
            let serve_cfg = ServeConfig {
                artifacts_dir: artifacts.clone(),
                max_batch: args.get_u64("batch", 8) as usize,
                max_output_tokens: args.get_u64("max-new", 24) as usize,
                speculative: args.has_flag("speculative"),
                pipeline_depth,
                // finer than the 64-token sim default: the tiny AOT
                // model's prompts must fully cover a block before its
                // KV can be stashed/shipped between replicas
                prefix_block_tokens: args.get_u64("block-tokens", 16).max(1),
                policies,
                shard,
                ..ServeConfig::default()
            };
            // the global index granularity must match the replicas'
            control.block_tokens = serve_cfg.prefix_block_tokens;
            let factory = PjrtReplicaFactory::new(dir, serve_cfg)?;
            // scenario specs are clamped to the AOT engine's limits so
            // the planner and the real engine agree on request shapes
            let workload = factory.clamp_workload(workload);
            run_fleet_with(control, n_replicas, factory, workload)
        }
        "roofline" => {
            let spec = model::catalog(&model_name).ok_or_else(|| {
                anyhow::anyhow!("unknown model {model_name} (see `xllm models`)")
            })?;
            let mut template = ClusterConfig::new(
                n_instances,
                model::ascend_910b(),
                spec,
                EngineFeatures::xllm(1),
            )
            .with_shard(shard);
            template.prefix_cache = true;
            template.token_granular = control.token_granular;
            template.pipeline_depth = pipeline_depth;
            template.host_overhead_s = args.get_f64("host-overhead", 0.0).max(0.0);
            template.policies = policies;
            let mut cfg = FleetConfig::new(template, n_replicas);
            cfg.control = control;
            if requests_cap > 0 {
                run_fleet_stream(cfg, sc.stream_unbounded(rate, &mut rng).with_limit(requests_cap))
            } else {
                run_fleet(cfg, workload)
            }
        }
        other => bail!("unknown fleet backend {other} (roofline|pjrt)"),
    };
    let report = &res.report;
    let streaming = !report.retains_outcomes();
    // retained runs keep the exact per-outcome summaries (bit-identical
    // to prior builds); streaming runs read the sketch — means exact,
    // p99 within one log-bucket width
    let (mean_ttft, p99_ttft, mean_e2e) = if streaming {
        (report.sketch.ttft_mean(), report.sketch.ttft_p(99.0), report.sketch.e2e_mean())
    } else {
        (
            report.ttft_summary().mean(),
            report.ttft_summary().percentile(99.0),
            report.e2e_summary().mean(),
        )
    };
    let phase_seconds = if streaming {
        let mut pj = Json::obj();
        for (name, mean_s) in report.sketch.phase_means() {
            pj = pj.set(name, mean_s);
        }
        pj
    } else {
        phase_seconds_json(report)
    };
    let mut goodput = Json::obj();
    for t in report.tier_goodput() {
        goodput = goodput.set(
            &format!("tier{}", t.tier),
            Json::obj()
                .set("total", t.total)
                .set("good", t.good)
                .set("attainment", t.attainment)
                .set("goodput_per_s", t.goodput_per_s),
        );
    }
    let out = Json::obj()
        .set("scenario", scenario_name)
        .set("replicas", n_replicas)
        .set("instances_per_replica", n_instances)
        .set("shard", shard_json(shard))
        .set("requests", res.submitted)
        .set("streamed", streaming)
        .set("completed", report.n_completed())
        .set("output_tok_s", report.output_throughput())
        .set("mean_ttft_s", mean_ttft)
        .set("p99_ttft_s", p99_ttft)
        .set("mean_e2e_s", mean_e2e)
        .set("goodput", goodput)
        .set("live_high_water", res.live_high_water)
        .set("replica_seconds", res.replica_seconds)
        .set("goodput_per_replica_s", res.goodput_per_replica_second())
        .set("cluster_prefix_hits", res.per_replica.iter().map(|r| r.prefix_hits).sum::<u64>())
        .set("cluster_prefix_hit_tokens", res.prefix_hit_tokens())
        .set("admission_overcommit_tokens", res.admission_overcommit_tokens())
        .set("index_published_entries", res.counters.index_published_entries)
        .set("token_granular", args.has_flag("token-granular"))
        .set("routed_by_cache_hit", res.counters.routed_by_cache_hit)
        .set("failovers", res.counters.failovers)
        .set("redispatched_requests", res.counters.redispatched_requests)
        .set("redispatched_tokens", res.counters.redispatched_tokens)
        .set("offline_steered", res.counters.offline_steered)
        .set("unroutable", res.counters.unroutable)
        .set("scale_policy", args.get_or("scale-policy", "backlog"))
        .set("slo_violations_predicted", res.counters.slo_violations_predicted)
        .set("scale_ups", res.counters.scale_ups)
        .set("scale_downs", res.counters.scale_downs)
        .set("kv_rebalances", res.counters.kv_rebalances)
        .set("warm_starts", res.counters.warm_starts)
        .set("kv_blocks_shipped", res.counters.kv_blocks_shipped)
        .set("replicas_final", res.n_replicas_final)
        .set("replicas_total", res.per_replica.len())
        .set("pipeline_depth", pipeline_depth)
        .set("engine_policies", policies.label())
        .set("backend", backend)
        .set("threads", threads)
        .set("truncated", res.truncated)
        .set("phase_seconds", phase_seconds);
    println!("{}", out.to_string());
    if let Some(p) = &metrics_out {
        let mut reg = MetricsRegistry::new();
        res.report.export_metrics(&mut reg);
        res.counters.export_metrics(&mut reg);
        for (r, rep) in res.per_replica.iter().enumerate() {
            rep.export_metrics_replica(&mut reg, Some(r));
        }
        reg.set_gauge("xllm_replicas_final", res.n_replicas_final as f64);
        reg.set_gauge("xllm_replicas_total", res.per_replica.len() as f64);
        write_metrics(p, &reg)?;
    }
    if let Some(p) = &trace_out {
        write_trace(p, &trace)?;
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let manifest = xllm::runtime::Manifest::load(Path::new(&artifacts))?;
    println!("weights: {} ({} tensors)", manifest.weights_file, manifest.n_tensors);
    for m in &manifest.models {
        println!("model {}: {:?}", m.name, m.fields);
    }
    for g in &manifest.graphs {
        println!("graph {:20} kind={:?} dims={:?}", g.name, g.kind, g.dims);
    }
    Ok(())
}
