//! xTensor memory management (paper §4.3): "logically contiguous,
//! physically discrete" KV cache storage.
//!
//! * A pool of fixed-size physical pages, each carrying the paper's triple
//!   state ⟨PageID, Status, OwnerSession⟩ with Status ∈ {Free, Allocated,
//!   Mapped, Reusable}.
//! * Each request gets a contiguous *virtual* range of `MaxSeqLen` tokens
//!   at creation; physical pages are mapped on demand as the sequence
//!   grows (Eq. 2 translation is `translate`).
//! * **Physical page reuse**: on completion pages are marked Reusable,
//!   not unmapped; a new request whose demand matches a reusable set gets
//!   it remapped wholesale, skipping expensive map/unmap.
//! * **Asynchronous pre-mapping**: during the current token's decode the
//!   pages for the next token are predicted and mapped ahead of time, so
//!   the mapping latency hides behind compute.
//!
//! On this testbed the "pages" index into a host arena rather than NPU
//! HBM; map/unmap costs are modelled (counted) so benches can report the
//! operation savings exactly as the ablation would.

use std::collections::HashMap;

/// Page status (paper's Status field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageStatus {
    Free,
    Allocated,
    Mapped,
    Reusable,
}

/// Physical page record ⟨PageID, Status, OwnerSession⟩.
#[derive(Debug, Clone, Copy)]
pub struct Page {
    pub id: u32,
    pub status: PageStatus,
    pub owner: Option<u64>,
}

/// Map/unmap operation counters (the §4.3 overhead the design avoids).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MapStats {
    pub maps: u64,
    pub unmaps: u64,
    pub remaps_from_reusable: u64,
    pub premapped_hits: u64,
    pub oom_rejections: u64,
}

/// A request's virtual address space: MaxSeqLen tokens, contiguous.
#[derive(Debug, Clone)]
struct Session {
    /// Mapped pages in virtual order (index = virtual page number).
    pages: Vec<u32>,
    /// Tokens written.
    len: u64,
    /// Pages pre-mapped beyond `len` (async pre-mapping).
    premapped: u32,
}

/// The xTensor manager for one instance.
#[derive(Debug)]
pub struct XTensorManager {
    page_tokens: u64,
    max_seq: u64,
    pages: Vec<Page>,
    free: Vec<u32>,
    /// Reusable sets from completed sessions, keyed by page count.
    reusable: HashMap<u32, Vec<Vec<u32>>>,
    sessions: HashMap<u64, Session>,
    pub stats: MapStats,
}

impl XTensorManager {
    /// `total_pages` physical pages of `page_tokens` tokens each;
    /// `max_seq` bounds each session's virtual range.
    pub fn new(total_pages: u32, page_tokens: u64, max_seq: u64) -> XTensorManager {
        XTensorManager {
            page_tokens,
            max_seq,
            pages: (0..total_pages)
                .map(|id| Page { id, status: PageStatus::Free, owner: None })
                .collect(),
            free: (0..total_pages).rev().collect(),
            reusable: HashMap::new(),
            sessions: HashMap::new(),
            stats: MapStats::default(),
        }
    }

    pub fn total_pages(&self) -> u32 {
        self.pages.len() as u32
    }

    pub fn free_pages(&self) -> u32 {
        (self.free.len() + self.reusable.values().map(|v| v.iter().map(|s| s.len()).sum::<usize>()).sum::<usize>())
            as u32
    }

    fn pages_for(&self, tokens: u64) -> u32 {
        (tokens.div_ceil(self.page_tokens)) as u32
    }

    /// Open a session (virtual allocation only — no physical pages yet;
    /// the paper's "virtual address space ... not actually associated with
    /// physical pages during allocation").
    pub fn open(&mut self, session: u64) {
        self.sessions.insert(session, Session { pages: Vec::new(), len: 0, premapped: 0 });
    }

    /// Open a session that will need `expected_tokens`, preferring a
    /// matching Reusable page set (fast remap, no map/unmap ops).
    pub fn open_with_reuse(&mut self, session: u64, expected_tokens: u64) {
        let need = self.pages_for(expected_tokens.min(self.max_seq));
        let set = match self.reusable.get_mut(&need) {
            Some(sets) => {
                let set = sets.pop();
                if sets.is_empty() {
                    self.reusable.remove(&need);
                }
                set
            }
            None => None,
        };
        if let Some(set) = set {
            for &pid in &set {
                let p = &mut self.pages[pid as usize];
                p.status = PageStatus::Mapped;
                p.owner = Some(session);
            }
            self.stats.remaps_from_reusable += 1;
            self.sessions.insert(session, Session { pages: set, len: 0, premapped: need });
            return;
        }
        self.open(session);
    }

    fn grab_page(&mut self, session: u64) -> Option<u32> {
        // free list first, then cannibalize any reusable set
        if let Some(pid) = self.free.pop() {
            let p = &mut self.pages[pid as usize];
            p.status = PageStatus::Mapped;
            p.owner = Some(session);
            self.stats.maps += 1;
            return Some(pid);
        }
        // find a non-empty reusable set (defensively skipping empties)
        let key = self
            .reusable
            .iter()
            .find(|(_, sets)| sets.iter().any(|s| !s.is_empty()))
            .map(|(k, _)| *k)?;
        let sets = self.reusable.get_mut(&key).unwrap();
        sets.retain(|s| !s.is_empty());
        let mut set = sets.pop().unwrap();
        if sets.is_empty() {
            self.reusable.remove(&key);
        }
        let pid = set.pop().unwrap();
        // the rest of the broken set returns to the free list (unmap cost)
        for other in set {
            self.pages[other as usize].status = PageStatus::Free;
            self.pages[other as usize].owner = None;
            self.stats.unmaps += 1;
            self.free.push(other);
        }
        let p = &mut self.pages[pid as usize];
        p.status = PageStatus::Mapped;
        p.owner = Some(session);
        self.stats.maps += 1;
        Some(pid)
    }

    /// Append `tokens` to the session, mapping pages on demand.
    /// Returns false (and maps nothing) on out-of-memory.
    pub fn extend(&mut self, session: u64, tokens: u64) -> bool {
        let (cur_len, have) = match self.sessions.get(&session) {
            Some(s) => (s.len, s.pages.len() as u32),
            None => return false,
        };
        let new_len = (cur_len + tokens).min(self.max_seq);
        let need_total = self.pages_for(new_len);
        let need_new = need_total.saturating_sub(have);
        if need_new > 0 {
            // check feasibility first (no partial maps on OOM)
            if (self.free.len() as u32)
                + self
                    .reusable
                    .values()
                    .map(|v| v.iter().map(|s| s.len() as u32).sum::<u32>())
                    .sum::<u32>()
                < need_new
            {
                self.stats.oom_rejections += 1;
                return false;
            }
            let mut grabbed = Vec::with_capacity(need_new as usize);
            for _ in 0..need_new {
                match self.grab_page(session) {
                    Some(p) => grabbed.push(p),
                    None => {
                        // roll back (should not happen after feasibility check)
                        for p in grabbed {
                            self.release_page(p);
                        }
                        self.stats.oom_rejections += 1;
                        return false;
                    }
                }
            }
            let s = self.sessions.get_mut(&session).unwrap();
            s.pages.extend(grabbed);
        }
        let s = self.sessions.get_mut(&session).unwrap();
        let covered = (s.premapped as u64) * self.page_tokens;
        if covered >= new_len && need_new == 0 {
            self.stats.premapped_hits += 1;
        }
        s.len = new_len;
        s.premapped = s.pages.len() as u32;
        true
    }

    /// Asynchronously pre-map pages for the next `tokens` tokens (called
    /// while the current step computes; cost hidden behind the device).
    pub fn premap(&mut self, session: u64, tokens: u64) -> bool {
        let (len, have) = match self.sessions.get(&session) {
            Some(s) => (s.len, s.pages.len() as u32),
            None => return false,
        };
        let target = self.pages_for((len + tokens).min(self.max_seq));
        let need = target.saturating_sub(have);
        for _ in 0..need {
            match self.grab_page(session) {
                Some(p) => {
                    let s = self.sessions.get_mut(&session).unwrap();
                    s.pages.push(p);
                    s.premapped = s.pages.len() as u32;
                }
                None => return false,
            }
        }
        true
    }

    fn release_page(&mut self, pid: u32) {
        let p = &mut self.pages[pid as usize];
        p.status = PageStatus::Free;
        p.owner = None;
        self.stats.unmaps += 1;
        self.free.push(pid);
    }

    /// Close a session, marking its pages Reusable (fast path for the next
    /// request of similar length) rather than unmapping.
    pub fn close(&mut self, session: u64) {
        if let Some(s) = self.sessions.remove(&session) {
            let n = s.pages.len() as u32;
            if n == 0 {
                return;
            }
            for &pid in &s.pages {
                let p = &mut self.pages[pid as usize];
                p.status = PageStatus::Reusable;
                p.owner = None;
            }
            self.reusable.entry(n).or_default().push(s.pages);
        }
    }

    /// Close a session and *eagerly unmap* (the naive baseline the paper
    /// improves on; used by the ablation bench).
    pub fn close_eager(&mut self, session: u64) {
        if let Some(s) = self.sessions.remove(&session) {
            for pid in s.pages {
                self.release_page(pid);
            }
        }
    }

    /// Eq. (2): translate a virtual token address to (physical page,
    /// offset within page).
    pub fn translate(&self, session: u64, virt_token: u64) -> Option<(u32, u64)> {
        let s = self.sessions.get(&session)?;
        if virt_token >= s.len {
            return None;
        }
        let vpage = (virt_token / self.page_tokens) as usize;
        let offset = virt_token % self.page_tokens;
        s.pages.get(vpage).map(|&p| (p, offset))
    }

    pub fn session_len(&self, session: u64) -> Option<u64> {
        self.sessions.get(&session).map(|s| s.len)
    }

    /// Tokens resident across all sessions.
    pub fn resident_tokens(&self) -> u64 {
        self.sessions.values().map(|s| s.len).sum()
    }

    /// Invariant check for property tests: no page owned twice, all mapped
    /// pages belong to a live session, free+mapped+reusable == total.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.pages.len()];
        for (sid, s) in &self.sessions {
            for &pid in &s.pages {
                let p = &self.pages[pid as usize];
                if seen[pid as usize] {
                    return Err(format!("page {pid} mapped twice"));
                }
                seen[pid as usize] = true;
                if p.status != PageStatus::Mapped {
                    return Err(format!("session {sid} holds page {pid} with status {:?}", p.status));
                }
                if p.owner != Some(*sid) {
                    return Err(format!("page {pid} owner mismatch"));
                }
            }
        }
        for pid in &self.free {
            if seen[*pid as usize] {
                return Err(format!("page {pid} both free and mapped"));
            }
            seen[*pid as usize] = true;
            if self.pages[*pid as usize].status != PageStatus::Free {
                return Err(format!("free-list page {pid} not Free"));
            }
        }
        for sets in self.reusable.values() {
            for set in sets {
                for &pid in set {
                    if seen[pid as usize] {
                        return Err(format!("page {pid} in reusable set and elsewhere"));
                    }
                    seen[pid as usize] = true;
                    if self.pages[pid as usize].status != PageStatus::Reusable {
                        return Err(format!("reusable-set page {pid} not Reusable"));
                    }
                }
            }
        }
        if !seen.iter().all(|&x| x) {
            return Err("page leaked (not free, mapped, or reusable)".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_demand_mapping_grows_with_sequence() {
        let mut m = XTensorManager::new(16, 16, 256);
        m.open(1);
        assert!(m.extend(1, 10));
        assert_eq!(m.stats.maps, 1); // one 16-token page covers 10
        assert!(m.extend(1, 10)); // 20 tokens -> 2 pages
        assert_eq!(m.stats.maps, 2);
        assert_eq!(m.session_len(1), Some(20));
        m.check_invariants().unwrap();
    }

    #[test]
    fn translate_eq2() {
        let mut m = XTensorManager::new(8, 16, 256);
        m.open(1);
        m.extend(1, 40);
        let (p0, o0) = m.translate(1, 0).unwrap();
        let (p1, o1) = m.translate(1, 17).unwrap();
        let (p2, o2) = m.translate(1, 39).unwrap();
        assert_eq!(o0, 0);
        assert_eq!(o1, 1);
        assert_eq!(o2, 7);
        assert_ne!(p0, p1);
        assert_ne!(p1, p2);
        assert!(m.translate(1, 40).is_none(), "beyond len");
    }

    #[test]
    fn reuse_skips_map_unmap() {
        let mut m = XTensorManager::new(16, 16, 256);
        m.open(1);
        m.extend(1, 64); // 4 pages
        let maps_before = m.stats.maps;
        m.close(1); // pages -> Reusable, no unmaps
        assert_eq!(m.stats.unmaps, 0);
        m.open_with_reuse(2, 64);
        assert_eq!(m.stats.remaps_from_reusable, 1);
        assert!(m.extend(2, 64));
        assert_eq!(m.stats.maps, maps_before, "no new maps needed");
        m.check_invariants().unwrap();
    }

    #[test]
    fn eager_close_pays_unmaps() {
        let mut m = XTensorManager::new(16, 16, 256);
        m.open(1);
        m.extend(1, 64);
        m.close_eager(1);
        assert_eq!(m.stats.unmaps, 4);
        m.check_invariants().unwrap();
    }

    #[test]
    fn premap_hides_next_token_mapping() {
        let mut m = XTensorManager::new(16, 4, 256);
        m.open(1);
        m.extend(1, 4); // page 0 full
        assert!(m.premap(1, 1)); // maps page for token 5 ahead of time
        let maps = m.stats.maps;
        assert!(m.extend(1, 1)); // no new map needed
        assert_eq!(m.stats.maps, maps);
        assert!(m.stats.premapped_hits >= 1);
    }

    #[test]
    fn oom_rejects_without_partial_maps() {
        let mut m = XTensorManager::new(2, 16, 256);
        m.open(1);
        assert!(m.extend(1, 32)); // both pages
        m.open(2);
        assert!(!m.extend(2, 1));
        assert_eq!(m.stats.oom_rejections, 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn reusable_sets_cannibalized_under_pressure() {
        let mut m = XTensorManager::new(4, 16, 256);
        m.open(1);
        m.extend(1, 64); // all 4 pages
        m.close(1); // one reusable set of 4
        m.open(2);
        assert!(m.extend(2, 16)); // needs 1 page -> breaks the set
        m.check_invariants().unwrap();
        assert!(m.extend(2, 48)); // grabs the rest
        m.check_invariants().unwrap();
    }

    #[test]
    fn property_invariants_under_random_workload() {
        crate::testutil::check("xtensor-invariants", 128, |rng| {
            let mut m = XTensorManager::new(32, 8, 128);
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                match rng.range(0, 3) {
                    0 => {
                        next_id += 1;
                        if rng.chance(0.5) {
                            m.open_with_reuse(next_id, rng.range(1, 128));
                        } else {
                            m.open(next_id);
                        }
                        live.push(next_id);
                    }
                    1 if !live.is_empty() => {
                        let sid = live[rng.index(live.len())];
                        m.extend(sid, rng.range(1, 24));
                    }
                    2 if !live.is_empty() => {
                        let idx = rng.index(live.len());
                        let sid = live.swap_remove(idx);
                        if rng.chance(0.7) {
                            m.close(sid);
                        } else {
                            m.close_eager(sid);
                        }
                    }
                    _ => {}
                }
                m.check_invariants()?;
            }
            Ok(())
        });
    }
}
