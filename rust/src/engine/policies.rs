//! Executor-level engine policy switches (paper §4).
//!
//! Each flag activates one of the formerly dormant engine modules on
//! the serving hot path:
//!
//! * `eplb`       — dynamic expert-parallel load balancing (§4.4.2):
//!   [`crate::engine::eplb`] routing tables re-planned on the
//!   orchestrator's control cadence, with staged double-buffer weight
//!   swaps; the achieved imbalance scales the MoE iteration cost.
//! * `dp_balance` — hierarchical DP load balance (§4.4.3):
//!   [`crate::engine::dpbalance::balanced_cores`] vs
//!   [`crate::engine::dpbalance::round_robin_cores`] straggler factors
//!   scale the attention share of decode.
//! * `op_overlap` — operator-layer cube/vector overlap, Eq. (1)
//!   (§4.1): [`crate::engine::opoverlap::allocate`] vs
//!   [`crate::engine::opoverlap::serial_makespan`] shrinks the
//!   overlappable share of the step.
//! * `graph_mode` — adaptive graph-vs-eager launch per batch shape
//!   (§4.2): [`crate::runtime::graph::select_mode`] over the bucket
//!   list, with warm-graph launch savings and per-bucket compile cost.
//!
//! The default is **all off**, and every consumer treats that as "no
//! policy state allocated at all" — behavior stays bit-identical to
//! the pre-policy executors (the golden parity fixtures enforce it).

/// Which engine policies run on the executor hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct EnginePolicies {
    /// Dynamic expert-parallel load balancing (§4.4.2).
    pub eplb: bool,
    /// Hierarchical DP load balance (§4.4.3).
    pub dp_balance: bool,
    /// Operator-layer cube/vector overlap (§4.1 Eq. (1)).
    pub op_overlap: bool,
    /// Adaptive graph-vs-eager launch selection (§4.2).
    pub graph_mode: bool,
}

impl EnginePolicies {
    /// Every policy enabled.
    pub fn all() -> EnginePolicies {
        EnginePolicies { eplb: true, dp_balance: true, op_overlap: true, graph_mode: true }
    }

    /// Is any policy enabled?  (False ⇒ consumers allocate no policy
    /// state and the hot path is untouched.)
    pub fn any(&self) -> bool {
        self.eplb || self.dp_balance || self.op_overlap || self.graph_mode
    }

    /// Parse a CLI spec: a comma-separated list of
    /// `eplb|dp-balance|op-overlap|graph`, or the shorthands
    /// `all`/`none`.  Underscore spellings are accepted.
    pub fn parse(spec: &str) -> Result<EnginePolicies, String> {
        let mut p = EnginePolicies::default();
        for part in spec.split(',') {
            match part.trim() {
                "" | "none" => {}
                "all" => p = EnginePolicies::all(),
                "eplb" => p.eplb = true,
                "dp-balance" | "dp_balance" => p.dp_balance = true,
                "op-overlap" | "op_overlap" => p.op_overlap = true,
                "graph" | "graph-mode" | "graph_mode" => p.graph_mode = true,
                other => {
                    return Err(format!(
                        "unknown engine policy {other:?} \
                         (eplb|dp-balance|op-overlap|graph|all|none)"
                    ))
                }
            }
        }
        Ok(p)
    }

    /// Canonical spec string (round-trips through [`Self::parse`]).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.eplb {
            parts.push("eplb");
        }
        if self.dp_balance {
            parts.push("dp-balance");
        }
        if self.op_overlap {
            parts.push("op-overlap");
        }
        if self.graph_mode {
            parts.push("graph");
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(",")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_all_off() {
        let p = EnginePolicies::default();
        assert!(!p.any());
        assert_eq!(p.label(), "none");
    }

    #[test]
    fn parse_individual_and_combined() {
        let p = EnginePolicies::parse("eplb,graph").unwrap();
        assert!(p.eplb && p.graph_mode && !p.dp_balance && !p.op_overlap);
        assert_eq!(EnginePolicies::parse("all").unwrap(), EnginePolicies::all());
        assert_eq!(EnginePolicies::parse("none").unwrap(), EnginePolicies::default());
        assert_eq!(
            EnginePolicies::parse("dp_balance,op_overlap").unwrap(),
            EnginePolicies::parse("dp-balance,op-overlap").unwrap()
        );
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(EnginePolicies::parse("warp-drive").is_err());
    }

    #[test]
    fn label_round_trips() {
        for p in [
            EnginePolicies::default(),
            EnginePolicies::all(),
            EnginePolicies { eplb: true, ..Default::default() },
            EnginePolicies { dp_balance: true, graph_mode: true, ..Default::default() },
        ] {
            assert_eq!(EnginePolicies::parse(&p.label()).unwrap(), p);
        }
    }
}
