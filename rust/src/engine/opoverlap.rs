//! Operator-layer matrix/vector unit overlap (paper §4.1, Eq. (1)).
//!
//! Solves the dynamic resource-allocation problem: given matrix operators
//! with workloads `W_i` (to run on Cube units) and vector operators with
//! workloads `W_j` (Vector units), allocate unit counts `x_i`, `y_j` with
//! `Σx_i ≤ N_cube`, `Σy_j ≤ N_vector` minimizing the *alignment loss*
//! `L_align = max_{i,j} |T_i − T_j|` where `T = W / (γ · units)` — i.e.
//! make all concurrent kernels finish together so neither unit class
//! idles.
//!
//! Solver: all operators finish at a common time `T` iff operator k gets
//! `units_k = W_k / (γ_k · T)`.  Feasibility per class is monotone in `T`
//! (larger T → fewer units), so binary-search the smallest feasible `T`
//! with integer rounding, then greedily hand out leftover units to the
//! slowest operators.

/// A kernel awaiting units: workload in (γ-normalized) work units.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpLoad {
    pub workload: f64,
}

/// Allocation result for one class (same order as the input slice).
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub cube_units: Vec<u32>,
    pub vector_units: Vec<u32>,
    /// Per-op completion times under the allocation.
    pub cube_times: Vec<f64>,
    pub vector_times: Vec<f64>,
    /// max |T_i − T_j| across classes (the paper's alignment loss).
    pub alignment_loss: f64,
    /// Makespan (time until every unit is free).
    pub makespan: f64,
}

fn units_needed(w: f64, gamma: f64, t: f64) -> u32 {
    if w <= 0.0 {
        return 0;
    }
    (w / (gamma * t)).ceil().max(1.0) as u32
}

fn feasible(ops: &[OpLoad], gamma: f64, t: f64, total: u32) -> bool {
    let sum: u64 = ops.iter().map(|o| units_needed(o.workload, gamma, t) as u64).sum();
    sum <= total as u64
}

fn allocate_class(ops: &[OpLoad], gamma: f64, total: u32, t: f64) -> Vec<u32> {
    let mut alloc: Vec<u32> =
        ops.iter().map(|o| units_needed(o.workload, gamma, t)).collect();
    // distribute leftover units to the current slowest op
    let mut used: u32 = alloc.iter().sum();
    while used < total && !ops.is_empty() {
        let (slowest, _) = alloc
            .iter()
            .enumerate()
            .map(|(i, &u)| (i, ops[i].workload / (gamma * u.max(1) as f64)))
            .fold((0, f64::NEG_INFINITY), |acc, (i, t)| if t > acc.1 { (i, t) } else { acc });
        alloc[slowest] += 1;
        used += 1;
    }
    alloc
}

/// Solve Eq. (1).  `gamma_cube`/`gamma_vector` are per-unit peak rates.
pub fn allocate(
    cube_ops: &[OpLoad],
    vector_ops: &[OpLoad],
    gamma_cube: f64,
    gamma_vector: f64,
    n_cube: u32,
    n_vector: u32,
) -> Allocation {
    assert!(cube_ops.len() as u64 <= n_cube as u64, "more cube ops than units");
    assert!(vector_ops.len() as u64 <= n_vector as u64, "more vector ops than units");

    // binary search the smallest common finish time T feasible for BOTH
    // classes simultaneously
    let mut lo = 1e-9;
    let mut hi = 1.0;
    while !(feasible(cube_ops, gamma_cube, hi, n_cube)
        && feasible(vector_ops, gamma_vector, hi, n_vector))
    {
        hi *= 2.0;
        if hi > 1e12 {
            break;
        }
    }
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        if feasible(cube_ops, gamma_cube, mid, n_cube)
            && feasible(vector_ops, gamma_vector, mid, n_vector)
        {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let t = hi;

    let cube_units = allocate_class(cube_ops, gamma_cube, n_cube, t);
    let vector_units = allocate_class(vector_ops, gamma_vector, n_vector, t);
    let cube_times: Vec<f64> = cube_ops
        .iter()
        .zip(&cube_units)
        .map(|(o, &u)| if u == 0 { 0.0 } else { o.workload / (gamma_cube * u as f64) })
        .collect();
    let vector_times: Vec<f64> = vector_ops
        .iter()
        .zip(&vector_units)
        .map(|(o, &u)| if u == 0 { 0.0 } else { o.workload / (gamma_vector * u as f64) })
        .collect();

    let mut loss: f64 = 0.0;
    for &ti in &cube_times {
        for &tj in &vector_times {
            loss = loss.max((ti - tj).abs());
        }
    }
    let makespan = cube_times
        .iter()
        .chain(vector_times.iter())
        .cloned()
        .fold(0.0, f64::max);
    Allocation { cube_units, vector_units, cube_times, vector_times, alignment_loss: loss, makespan }
}

/// Serial baseline: run every matrix op (all cube units), then every
/// vector op (all vector units) — what the paper's "serial scheduling of
/// matrix and vector computation units" does.
pub fn serial_makespan(
    cube_ops: &[OpLoad],
    vector_ops: &[OpLoad],
    gamma_cube: f64,
    gamma_vector: f64,
    n_cube: u32,
    n_vector: u32,
) -> f64 {
    let c: f64 = cube_ops.iter().map(|o| o.workload / (gamma_cube * n_cube as f64)).sum();
    let v: f64 =
        vector_ops.iter().map(|o| o.workload / (gamma_vector * n_vector as f64)).sum();
    c + v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops(ws: &[f64]) -> Vec<OpLoad> {
        ws.iter().map(|&w| OpLoad { workload: w }).collect()
    }

    #[test]
    fn balanced_allocation_aligns_completion() {
        let a = allocate(&ops(&[100.0, 100.0]), &ops(&[50.0]), 10.0, 5.0, 8, 4);
        assert!(a.alignment_loss < 0.5 * a.makespan, "loss={} makespan={}", a.alignment_loss, a.makespan);
        assert!(a.cube_units.iter().sum::<u32>() <= 8);
        assert!(a.vector_units.iter().sum::<u32>() <= 4);
    }

    #[test]
    fn heavier_ops_get_more_units() {
        let a = allocate(&ops(&[300.0, 100.0]), &ops(&[10.0]), 10.0, 5.0, 8, 2);
        assert!(a.cube_units[0] > a.cube_units[1]);
    }

    #[test]
    fn overlap_beats_serial() {
        let c = ops(&[200.0, 150.0, 100.0]);
        let v = ops(&[80.0, 60.0]);
        let a = allocate(&c, &v, 10.0, 5.0, 12, 8);
        let serial = serial_makespan(&c, &v, 10.0, 5.0, 12, 8);
        assert!(
            a.makespan < serial,
            "overlap {} should beat serial {serial}",
            a.makespan
        );
    }

    #[test]
    fn single_op_each_uses_all_units() {
        let a = allocate(&ops(&[100.0]), &ops(&[100.0]), 1.0, 1.0, 4, 4);
        assert_eq!(a.cube_units, vec![4]);
        assert_eq!(a.vector_units, vec![4]);
    }

    #[test]
    fn empty_vector_class_is_fine() {
        let a = allocate(&ops(&[100.0]), &[], 1.0, 1.0, 4, 4);
        assert_eq!(a.alignment_loss, 0.0);
        assert!(a.makespan > 0.0);
    }

    #[test]
    fn property_budgets_respected_and_loss_bounded() {
        crate::testutil::check("opoverlap-budget", 128, |rng| {
            let nc = rng.range(2, 24) as u32;
            let nv = rng.range(2, 48) as u32;
            let n_cube_ops = rng.range(1, (nc as u64).min(6)) as usize;
            let n_vec_ops = rng.range(1, (nv as u64).min(6)) as usize;
            let c: Vec<OpLoad> =
                (0..n_cube_ops).map(|_| OpLoad { workload: rng.f64() * 1000.0 + 1.0 }).collect();
            let v: Vec<OpLoad> =
                (0..n_vec_ops).map(|_| OpLoad { workload: rng.f64() * 500.0 + 1.0 }).collect();
            let a = allocate(&c, &v, 10.0, 5.0, nc, nv);
            crate::prop_assert!(
                a.cube_units.iter().sum::<u32>() <= nc,
                "cube budget exceeded"
            );
            crate::prop_assert!(
                a.vector_units.iter().sum::<u32>() <= nv,
                "vector budget exceeded"
            );
            crate::prop_assert!(a.cube_units.iter().all(|&u| u >= 1), "op starved");
            // alignment loss never exceeds the makespan
            crate::prop_assert!(a.alignment_loss <= a.makespan + 1e-9);
            Ok(())
        });
    }
}
