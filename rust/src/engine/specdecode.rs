//! Optimized speculative decoding (paper §4.4.1).
//!
//! Two halves:
//!
//! * **Acceptance machinery** (used by the real PJRT server): the draft
//!   model proposes `m` tokens; the target model scores all `m` (+1 bonus)
//!   in ONE verify pass (the multi-Q Pallas kernel); greedy acceptance
//!   keeps the longest prefix where draft == target-argmax, then appends
//!   the target's own token — guaranteeing ≥1 token/round and exact
//!   equivalence to non-speculative greedy decoding.
//! * **Analytic model** (used by the simulator/fig20): expected accepted
//!   tokens per round under a per-token acceptance rate, and the resulting
//!   TPOT/throughput against the verify-step cost from the roofline model.

/// Speculative decoding configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecConfig {
    /// Draft tokens proposed per round (the verify graph scores m).
    pub m: usize,
    /// Per-token draft acceptance probability (simulation parameter;
    /// EAGLE/MTP-class drafts see 0.6–0.8 on natural text).
    pub acceptance: f64,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { m: 4, acceptance: 0.7 }
    }
}

/// Counters for a speculative decoding session.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpecStats {
    pub rounds: u64,
    pub proposed: u64,
    pub accepted: u64,
    pub bonus: u64,
}

impl SpecStats {
    /// Mean tokens emitted per verify round.
    pub fn tokens_per_round(&self) -> f64 {
        if self.rounds == 0 {
            return 0.0;
        }
        (self.accepted + self.bonus) as f64 / self.rounds as f64
    }

    /// Fraction of proposed draft tokens accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }
}

/// Greedy acceptance: longest prefix of `draft` matching the target's
/// argmax at each position, then the target token at the first mismatch
/// (or after the last accepted draft token) as the bonus.
///
/// `target_argmax[j]` is the target model's greedy token for the position
/// *following* draft token j-1 (i.e. `target_argmax[0]` is what the target
/// would emit where `draft[0]` was proposed).
///
/// Returns `(n_accepted_draft_tokens, emitted_tokens)` where
/// `emitted_tokens = draft[..n] ++ [target_argmax[n]]` — identical to what
/// plain greedy decoding would have produced.
pub fn accept_greedy(draft: &[i32], target_argmax: &[i32]) -> (usize, Vec<i32>) {
    debug_assert!(target_argmax.len() >= draft.len());
    let mut n = 0;
    while n < draft.len() && draft[n] == target_argmax[n] {
        n += 1;
    }
    let mut emitted = draft[..n].to_vec();
    // bonus token: the target's own continuation (position n's argmax)
    if n < target_argmax.len() {
        emitted.push(target_argmax[n]);
    }
    (n, emitted)
}

/// Expected emitted tokens per round under i.i.d. acceptance `p`:
/// `E = sum_{k=0..m-1} p^k` accepted-prefix mass + 1 bonus
/// = `(1 - p^m)/(1 - p) ... + p^m * m` collapsed to the closed form below.
pub fn expected_tokens_per_round(m: usize, p: f64) -> f64 {
    // P(accept exactly k) = p^k (1-p) for k < m;  P(accept m) = p^m.
    // tokens emitted = k + 1 (bonus) for k < m; m + 1 for k = m.
    let mut e = 0.0;
    for k in 0..m {
        e += (k as f64 + 1.0) * p.powi(k as i32) * (1.0 - p);
    }
    e += (m as f64 + 1.0) * p.powi(m as i32);
    e
}

/// Verify-step cost multiplier vs a plain decode step: scoring m+1 tokens
/// reuses the weight stream (memory-bound decode) but adds compute and
/// KV-write traffic; calibrated against the multi-Q kernel's arithmetic.
pub fn verify_cost_multiplier(m: usize) -> f64 {
    1.0 + 0.12 * m as f64
}

/// Draft-step cost relative to the target decode step (small draft model).
pub fn draft_cost_fraction() -> f64 {
    0.15
}

/// Effective per-token decode speedup of speculative decoding under the
/// analytic model (>1 = faster than plain decode).
pub fn speedup(cfg: &SpecConfig) -> f64 {
    let tokens = expected_tokens_per_round(cfg.m, cfg.acceptance);
    let cost = verify_cost_multiplier(cfg.m) + draft_cost_fraction() * cfg.m as f64;
    tokens / cost
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_full_match() {
        let (n, emitted) = accept_greedy(&[1, 2, 3], &[1, 2, 3, 9]);
        assert_eq!(n, 3);
        assert_eq!(emitted, vec![1, 2, 3, 9]);
    }

    #[test]
    fn accept_partial_match_takes_target_token() {
        let (n, emitted) = accept_greedy(&[1, 2, 3], &[1, 7, 8, 9]);
        assert_eq!(n, 1);
        assert_eq!(emitted, vec![1, 7]);
    }

    #[test]
    fn accept_no_match_still_emits_one() {
        let (n, emitted) = accept_greedy(&[5, 6], &[1, 2, 3]);
        assert_eq!(n, 0);
        assert_eq!(emitted, vec![1]);
    }

    #[test]
    fn expected_tokens_bounds() {
        // p=0: exactly 1 token (the bonus)
        assert!((expected_tokens_per_round(4, 0.0) - 1.0).abs() < 1e-12);
        // p=1: all m + bonus
        assert!((expected_tokens_per_round(4, 1.0) - 5.0).abs() < 1e-12);
        // monotone in p
        let a = expected_tokens_per_round(4, 0.3);
        let b = expected_tokens_per_round(4, 0.7);
        assert!(b > a);
        // monotone in m
        assert!(expected_tokens_per_round(6, 0.7) > expected_tokens_per_round(2, 0.7));
    }

    #[test]
    fn speedup_positive_for_good_drafts() {
        let s = speedup(&SpecConfig { m: 4, acceptance: 0.7 });
        assert!(s > 1.2, "speedup={s}");
        // terrible drafts should not help
        let bad = speedup(&SpecConfig { m: 4, acceptance: 0.05 });
        assert!(bad < 1.0, "bad-draft speedup={bad}");
    }

    #[test]
    fn stats_aggregation() {
        let mut st = SpecStats::default();
        for (n, m) in [(3usize, 4usize), (0, 4), (4, 4)] {
            st.rounds += 1;
            st.proposed += m as u64;
            st.accepted += n as u64;
            st.bonus += 1;
        }
        assert!((st.tokens_per_round() - (7.0 + 3.0) / 3.0).abs() < 1e-12);
        assert!((st.acceptance_rate() - 7.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn property_acceptance_is_exact_greedy_equivalence() {
        // emulate a target model with a fixed greedy continuation and any
        // draft: emitted stream must be a prefix of the target's stream
        crate::testutil::check("spec-greedy-equiv", 128, |rng| {
            let target: Vec<i32> = (0..8).map(|_| rng.range(0, 9) as i32).collect();
            let m = rng.range(1, 6) as usize;
            let draft: Vec<i32> = (0..m).map(|_| rng.range(0, 9) as i32).collect();
            let (n, emitted) = accept_greedy(&draft, &target[..=m.min(target.len() - 1)]);
            crate::prop_assert!(n <= m);
            // emitted must equal the target greedy stream prefix
            for (i, &t) in emitted.iter().enumerate() {
                crate::prop_assert!(
                    t == target[i],
                    "emitted[{i}]={t} != target[{i}]={}",
                    target[i]
                );
            }
            crate::prop_assert!(!emitted.is_empty(), "must emit at least one token");
            Ok(())
        });
    }
}
