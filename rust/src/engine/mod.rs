//! xLLM-Engine (paper §4): per-instance execution optimizations.
//!
//! * [`pipeline`]   — multi-layer pipeline execution (§4.1): async CPU/
//!   device overlap, dual-stream micro-batch comm/comp overlap.
//! * [`opoverlap`]  — operator-layer Cube/Vector allocation, Eq. (1).
//! * [`xtensor`]    — "logically contiguous, physically discrete" KV
//!   memory management (§4.3).
//! * [`specdecode`] — optimized speculative decoding (§4.4.1).
//! * [`eplb`]       — dynamic expert-parallel load balance (§4.4.2).
//! * [`dpbalance`]  — hierarchical DP load balance (§4.4.3).
//! * [`genrec`]     — generative-recommendation beam search (§4.5).
//! * [`policies`]   — executor-level switches threading eplb /
//!   dpbalance / opoverlap / graph mode into the serving hot path.
//!
//! The adaptive graph mode (§4.2) lives in `runtime::graph` because it
//! wraps the PJRT executable cache directly.

pub mod dpbalance;
pub mod eplb;
pub mod genrec;
pub mod opoverlap;
pub mod pipeline;
pub mod policies;
pub mod specdecode;
pub mod xtensor;

pub use policies::EnginePolicies;
pub use specdecode::SpecConfig;
pub use xtensor::XTensorManager;
