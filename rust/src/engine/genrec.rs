//! Generative recommendation (paper §4.5): optimized beam search with
//! min-heap early termination, resource reuse, and valid-item filtering.
//!
//! Host side (§4.5.1): selecting the next `beam_width` hypotheses from
//! `beam_width × top_k` candidates is a *partial* sort.  Because each
//! sequence's candidate expansions arrive sorted by log-prob descending
//! (they come from a per-sequence top-k), a size-`beam_width` min-heap
//! plus per-sequence early termination (stop scanning a sequence once its
//! next candidate can't beat the heap minimum) avoids most comparisons.
//! Buffers are preallocated once and reused across steps (resource reuse).
//!
//! Device side (§4.5.2): a token trie of *valid items* (OneRec-style: an
//! ordered triple of token ids = one item) produces an additive mask that
//! pushes invalid continuations to -inf before sampling, so only real
//! items can be emitted.

use std::collections::{BinaryHeap, HashMap};

/// A candidate continuation: (parent beam index, token, total log-prob).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub parent: usize,
    pub token: u32,
    pub log_prob: f64,
}

/// Heap entry ordered by log-prob ascending (min-heap via Reverse logic).
#[derive(Debug, Clone, Copy, PartialEq)]
struct HeapItem(Candidate);

impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reverse: BinaryHeap is max-heap; we want the min on top
        other
            .0
            .log_prob
            .partial_cmp(&self.0.log_prob)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.0.parent.cmp(&self.0.parent))
            .then_with(|| other.0.token.cmp(&self.0.token))
    }
}

/// Work counters proving the early-termination savings.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BeamStats {
    pub candidates_examined: u64,
    pub candidates_total: u64,
    pub early_breaks: u64,
}

/// Reusable beam-search step executor (buffers persist across steps).
#[derive(Debug)]
pub struct BeamSearcher {
    pub beam_width: usize,
    heap: BinaryHeap<HeapItem>,
    out: Vec<Candidate>,
    pub stats: BeamStats,
}

impl BeamSearcher {
    pub fn new(beam_width: usize) -> BeamSearcher {
        BeamSearcher {
            beam_width,
            heap: BinaryHeap::with_capacity(beam_width + 1),
            out: Vec::with_capacity(beam_width),
            stats: BeamStats::default(),
        }
    }

    /// Naive baseline: flatten all candidates, full sort, take top-W.
    pub fn step_naive(&mut self, expansions: &[Vec<(u32, f64)>]) -> Vec<Candidate> {
        let mut all: Vec<Candidate> = Vec::new();
        for (parent, cands) in expansions.iter().enumerate() {
            for &(token, lp) in cands {
                all.push(Candidate { parent, token, log_prob: lp });
                self.stats.candidates_examined += 1;
                self.stats.candidates_total += 1;
            }
        }
        all.sort_by(|a, b| {
            b.log_prob
                .partial_cmp(&a.log_prob)
                .unwrap()
                .then_with(|| a.parent.cmp(&b.parent))
                .then_with(|| a.token.cmp(&b.token))
        });
        all.truncate(self.beam_width);
        all
    }

    /// Optimized step: min-heap + per-sequence early termination.
    ///
    /// `expansions[parent]` MUST be sorted by log-prob descending (the
    /// natural output order of a top-k over logits).
    pub fn step_optimized(&mut self, expansions: &[Vec<(u32, f64)>]) -> Vec<Candidate> {
        self.heap.clear();
        for (parent, cands) in expansions.iter().enumerate() {
            self.stats.candidates_total += cands.len() as u64;
            for &(token, lp) in cands {
                debug_assert!(
                    cands.windows(2).all(|w| w[0].1 >= w[1].1),
                    "expansions must be sorted descending"
                );
                if self.heap.len() == self.beam_width {
                    let min = self.heap.peek().unwrap().0.log_prob;
                    if lp <= min {
                        // all remaining candidates of this sequence are
                        // smaller still: stop scanning it
                        self.stats.early_breaks += 1;
                        break;
                    }
                }
                self.stats.candidates_examined += 1;
                self.heap.push(HeapItem(Candidate { parent, token, log_prob: lp }));
                if self.heap.len() > self.beam_width {
                    self.heap.pop();
                }
            }
        }
        // extract ascending, reverse to descending
        self.out.clear();
        while let Some(HeapItem(c)) = self.heap.pop() {
            self.out.push(c);
        }
        self.out.reverse();
        self.out.clone()
    }
}

/// Trie over fixed-arity item codes (OneRec: 3 tokens = 1 item).
#[derive(Debug, Default)]
pub struct ValidItemTrie {
    /// prefix (as vec) -> set of allowed next tokens.
    children: HashMap<Vec<u32>, Vec<u32>>,
    pub n_items: usize,
    pub code_len: usize,
}

impl ValidItemTrie {
    /// Build from a catalog of items, each an exact `code_len`-token code.
    pub fn new(items: &[Vec<u32>]) -> ValidItemTrie {
        let code_len = items.first().map(|i| i.len()).unwrap_or(0);
        let mut children: HashMap<Vec<u32>, Vec<u32>> = HashMap::new();
        for item in items {
            assert_eq!(item.len(), code_len, "ragged item code");
            for d in 0..code_len {
                let prefix = item[..d].to_vec();
                let entry = children.entry(prefix).or_default();
                if !entry.contains(&item[d]) {
                    entry.push(item[d]);
                }
            }
        }
        ValidItemTrie { children, n_items: items.len(), code_len }
    }

    /// Allowed next tokens after `prefix` (empty = none: invalid prefix).
    pub fn allowed(&self, prefix: &[u32]) -> &[u32] {
        self.children.get(prefix).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Additive mask over the vocab: 0.0 for allowed tokens, −inf else —
    /// what the device adds to logits before the sampler (§4.5.2).
    pub fn mask(&self, prefix: &[u32], vocab: usize) -> Vec<f64> {
        let mut m = vec![f64::NEG_INFINITY; vocab];
        for &t in self.allowed(prefix) {
            if (t as usize) < vocab {
                m[t as usize] = 0.0;
            }
        }
        m
    }

    /// Is the full code a valid item?
    pub fn is_valid_item(&self, code: &[u32]) -> bool {
        if code.len() != self.code_len {
            return false;
        }
        self.children
            .get(&code[..self.code_len - 1].to_vec())
            .map(|next| next.contains(&code[self.code_len - 1]))
            .unwrap_or(false)
    }
}

/// Heap-based partial top-k over a large logits row (O(V log k) instead
/// of the naive O(V log V) full sort) — the §4.5.1 host optimization for
/// the vocab-sized candidate extraction that feeds each beam step.
pub fn topk_desc_partial(logits: &[f64], k: usize) -> Vec<(u32, f64)> {
    let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
    for (i, &lp) in logits.iter().enumerate() {
        if !lp.is_finite() {
            continue;
        }
        if heap.len() == k {
            if lp <= heap.peek().unwrap().0.log_prob {
                continue;
            }
            heap.pop();
        }
        heap.push(HeapItem(Candidate { parent: 0, token: i as u32, log_prob: lp }));
    }
    let mut out: Vec<(u32, f64)> = Vec::with_capacity(heap.len());
    while let Some(HeapItem(c)) = heap.pop() {
        out.push((c.token, c.log_prob));
    }
    out.reverse();
    out
}

/// Top-k extraction from a (masked) logits row, sorted descending — the
/// per-sequence expansion feed for the beam step.
pub fn topk_desc(logits: &[f64], k: usize) -> Vec<(u32, f64)> {
    let mut idx: Vec<u32> = (0..logits.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        logits[b as usize]
            .partial_cmp(&logits[a as usize])
            .unwrap()
            .then_with(|| a.cmp(&b))
    });
    idx.truncate(k);
    idx.into_iter()
        .map(|i| (i, logits[i as usize]))
        .filter(|(_, lp)| lp.is_finite())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_expansions(rng: &mut Rng, beams: usize, k: usize) -> Vec<Vec<(u32, f64)>> {
        (0..beams)
            .map(|_| {
                let mut v: Vec<(u32, f64)> =
                    (0..k).map(|t| (t as u32, rng.f64() * -10.0)).collect();
                v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                v
            })
            .collect()
    }

    #[test]
    fn optimized_equals_naive() {
        crate::testutil::check("beam-equivalence", 128, |rng| {
            let beams = rng.range(1, 16) as usize;
            let k = rng.range(1, 32) as usize;
            let w = rng.range(1, 16) as usize;
            let exp = random_expansions(rng, beams, k);
            let mut a = BeamSearcher::new(w);
            let mut b = BeamSearcher::new(w);
            let naive = a.step_naive(&exp);
            let opt = b.step_optimized(&exp);
            crate::prop_assert!(naive.len() == opt.len(), "lengths differ");
            for (x, y) in naive.iter().zip(&opt) {
                crate::prop_assert!(
                    (x.log_prob - y.log_prob).abs() < 1e-12
                        && x.parent == y.parent
                        && x.token == y.token,
                    "selection differs: {x:?} vs {y:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn early_termination_saves_work() {
        let mut rng = Rng::new(9);
        // large beam/topk like the paper's beam_width=128, top_k large
        let exp = random_expansions(&mut rng, 128, 128);
        let mut s = BeamSearcher::new(128);
        s.step_optimized(&exp);
        assert!(
            s.stats.candidates_examined < s.stats.candidates_total / 2,
            "examined {}/{} — early termination ineffective",
            s.stats.candidates_examined,
            s.stats.candidates_total
        );
        assert!(s.stats.early_breaks > 0);
    }

    #[test]
    fn results_sorted_descending() {
        let mut rng = Rng::new(3);
        let exp = random_expansions(&mut rng, 8, 16);
        let mut s = BeamSearcher::new(6);
        let out = s.step_optimized(&exp);
        for w in out.windows(2) {
            assert!(w[0].log_prob >= w[1].log_prob);
        }
        assert_eq!(out.len(), 6);
    }

    #[test]
    fn trie_masks_invalid_items() {
        let items = vec![vec![1, 2, 3], vec![1, 2, 4], vec![5, 6, 7]];
        let trie = ValidItemTrie::new(&items);
        assert_eq!(trie.code_len, 3);
        let m0 = trie.mask(&[], 10);
        assert_eq!(m0[1], 0.0);
        assert_eq!(m0[5], 0.0);
        assert!(m0[2].is_infinite());
        let m1 = trie.mask(&[1, 2], 10);
        assert_eq!(m1[3], 0.0);
        assert_eq!(m1[4], 0.0);
        assert!(m1[7].is_infinite());
        assert!(trie.is_valid_item(&[1, 2, 3]));
        assert!(!trie.is_valid_item(&[1, 2, 9]));
        assert!(!trie.is_valid_item(&[1, 2]));
    }

    #[test]
    fn masked_beam_search_only_emits_valid_items() {
        let items = vec![vec![1, 2, 3], vec![4, 5, 6], vec![4, 5, 9]];
        let trie = ValidItemTrie::new(&items);
        let vocab = 12;
        let mut rng = Rng::new(7);
        // simulate 3 decode steps with random logits + trie mask
        let mut beams: Vec<(Vec<u32>, f64)> = vec![(vec![], 0.0)];
        for _ in 0..3 {
            let mut exp: Vec<Vec<(u32, f64)>> = Vec::new();
            for (prefix, lp) in &beams {
                let logits: Vec<f64> = (0..vocab).map(|_| rng.f64() * -5.0).collect();
                let mask = trie.mask(prefix, vocab);
                let masked: Vec<f64> =
                    logits.iter().zip(&mask).map(|(l, m)| l + m + lp).collect();
                exp.push(topk_desc(&masked, 4));
            }
            let mut s = BeamSearcher::new(2);
            let picks = s.step_optimized(&exp);
            beams = picks
                .iter()
                .map(|c| {
                    let mut seq = beams[c.parent].0.clone();
                    seq.push(c.token);
                    (seq, c.log_prob)
                })
                .collect();
        }
        for (seq, _) in &beams {
            assert!(trie.is_valid_item(seq), "emitted invalid item {seq:?}");
        }
    }

    #[test]
    fn topk_desc_filters_neg_inf() {
        let logits = vec![0.5, f64::NEG_INFINITY, -0.2, f64::NEG_INFINITY];
        let t = topk_desc(&logits, 4);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, 0);
        assert_eq!(t[1].0, 2);
    }
}

#[cfg(test)]
mod partial_topk_tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn partial_topk_matches_full_sort() {
        crate::testutil::check("topk-partial-equiv", 64, |rng| {
            let v: Vec<f64> = (0..rng.range(10, 2000)).map(|_| rng.f64() * -30.0).collect();
            let k = rng.range(1, 64) as usize;
            let a = topk_desc(&v, k);
            let b = topk_desc_partial(&v, k);
            crate::prop_assert!(a.len() == b.len(), "lengths differ");
            for (x, y) in a.iter().zip(&b) {
                crate::prop_assert!(
                    (x.1 - y.1).abs() < 1e-12,
                    "values differ: {x:?} vs {y:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn partial_topk_skips_neg_inf() {
        let v = vec![1.0, f64::NEG_INFINITY, 0.5, f64::NEG_INFINITY, 2.0];
        let t = topk_desc_partial(&v, 5);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].0, 4);
    }

    #[test]
    fn partial_topk_is_faster_on_large_vocab() {
        let mut rng = Rng::new(1);
        let v: Vec<f64> = (0..150_000).map(|_| rng.f64() * -20.0).collect();
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            std::hint::black_box(topk_desc_partial(&v, 64));
        }
        let partial = t0.elapsed();
        let t1 = std::time::Instant::now();
        for _ in 0..3 {
            std::hint::black_box(topk_desc(&v, 64));
        }
        let full = t1.elapsed();
        assert!(partial < full, "partial {partial:?} !< full {full:?}");
    }
}
