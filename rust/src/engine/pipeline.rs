//! Multi-layer pipeline execution engine (paper §4.1).
//!
//! * **Framework layer** — [`AsyncPipeline`]: a real two-stage std::thread
//!   pipeline overlapping CPU batch preparation (with placeholder tokens)
//!   against device execution; this is what the PJRT server uses, and what
//!   Table 6 ablates.
//! * **Model layer** — [`simulate_dual_stream`]: a two-resource list
//!   scheduler over per-layer MoE micro-batch tasks (Dispatch → Expert
//!   Forward → Combine) reproducing the Table 7 comm/comp overlap
//!   accounting.
//! * The operator layer lives in [`super::opoverlap`].

use std::sync::mpsc;
use std::thread;

/// Outcome of a pipelined run.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineReport {
    pub iterations: u64,
    pub wall_s: f64,
    /// Total CPU preparation time (hidden when async).
    pub prep_s: f64,
    /// Total device execution time.
    pub exec_s: f64,
}

/// Run `n` iterations where `prepare(i)` builds input i on the CPU and
/// `execute(i, input)` runs it on the device, *serially* (the baseline:
/// prepare-then-compute).
pub fn run_serial<T, P, E>(n: u64, mut prepare: P, mut execute: E) -> PipelineReport
where
    P: FnMut(u64) -> T,
    E: FnMut(u64, T),
{
    let t0 = std::time::Instant::now();
    let mut prep_s = 0.0;
    let mut exec_s = 0.0;
    for i in 0..n {
        let p0 = std::time::Instant::now();
        let input = prepare(i);
        prep_s += p0.elapsed().as_secs_f64();
        let e0 = std::time::Instant::now();
        execute(i, input);
        exec_s += e0.elapsed().as_secs_f64();
    }
    PipelineReport { iterations: n, wall_s: t0.elapsed().as_secs_f64(), prep_s, exec_s }
}

/// Run `n` iterations with the paper's asynchronous scheduling: while the
/// device executes iteration i, the CPU prepares iteration i+1 using
/// placeholder tokens (the prepared input cannot depend on i's output —
/// exactly the placeholder-token contract; the caller swaps real tokens in
/// cheaply inside `execute`).
///
/// Implementation: a bounded (depth-1) channel between a producer thread
/// (CPU scheduling) and the consumer (device).  Threads are scoped, so the
/// closures may borrow locals.
pub fn run_async<T, P, E>(n: u64, prepare: P, mut execute: E) -> PipelineReport
where
    T: Send,
    P: FnMut(u64) -> T + Send,
    E: FnMut(u64, T),
{
    let t0 = std::time::Instant::now();
    let (tx, rx) = mpsc::sync_channel::<(u64, T)>(1);
    let mut exec_s = 0.0;
    thread::scope(|s| {
        s.spawn(move || {
            let mut prepare = prepare;
            for i in 0..n {
                let input = prepare(i);
                if tx.send((i, input)).is_err() {
                    break;
                }
            }
        });
        for _ in 0..n {
            let (i, input) = rx.recv().expect("producer died");
            let e0 = std::time::Instant::now();
            execute(i, input);
            exec_s += e0.elapsed().as_secs_f64();
        }
    });
    PipelineReport { iterations: n, wall_s: t0.elapsed().as_secs_f64(), prep_s: 0.0, exec_s }
}

// ---------------------------------------------------------------------
// Model layer: dual-stream micro-batch simulation (Table 7)
// ---------------------------------------------------------------------

/// Result of the per-layer dual-stream schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamReport {
    /// Wall time of the layer stack.
    pub total_s: f64,
    /// Communication time not hidden behind compute.
    pub exposed_comm_s: f64,
    /// Total communication issued.
    pub total_comm_s: f64,
    /// Total compute issued.
    pub total_compute_s: f64,
}

impl StreamReport {
    pub fn overlap_ratio(&self) -> f64 {
        if self.total_comm_s <= 0.0 {
            return 0.0;
        }
        1.0 - self.exposed_comm_s / self.total_comm_s
    }
}

/// Single-stream baseline: per layer, Dispatch → ExpertForward → Combine
/// strictly serial.  `comm_s`/`compute_s` are per-layer totals.
pub fn simulate_single_stream(n_layers: u32, compute_s: f64, comm_s: f64) -> StreamReport {
    let total = (compute_s + comm_s) * n_layers as f64;
    StreamReport {
        total_s: total,
        exposed_comm_s: comm_s * n_layers as f64,
        total_comm_s: comm_s * n_layers as f64,
        total_compute_s: compute_s * n_layers as f64,
    }
}

/// Fraction of a decoder layer's compute that is attention/shared (runs
/// before the MoE dispatch); the rest is expert FFN (between dispatch and
/// combine).  DeepSeek-style layers are roughly 40/60.
const ATTN_COMPUTE_FRACTION: f64 = 0.4;

/// Dual-stream schedule with `n_micro` micro-batches: the communication
/// stream runs micro-batch k's Dispatch/Combine while the computation
/// stream runs another micro-batch's Attention/ExpertForward (paper Fig 7).
///
/// Splitting inflates both sides (smaller batches are less efficient):
/// `compute_inflation`/`comm_inflation` (paper Table 7 measures 13→17 ms
/// compute and 9.3→12.4 ms comm for n=2, i.e. ~1.31x / ~1.33x).
///
/// The schedule is simulated exactly with a two-resource list scheduler
/// over the task DAG: per layer l and micro-batch k,
/// `attn(l,k) → disp(l,k) → expert(l,k) → comb(l,k) → attn(l+1,k)`;
/// Attention/ExpertForward run on the compute stream, Dispatch/Combine on
/// the communication stream.
pub fn simulate_dual_stream(
    n_layers: u32,
    compute_s: f64,
    comm_s: f64,
    n_micro: u32,
    compute_inflation: f64,
    comm_inflation: f64,
) -> StreamReport {
    assert!(n_micro >= 1);
    let nm = n_micro as usize;
    // per-micro-batch task durations (per layer)
    let attn_mb = ATTN_COMPUTE_FRACTION * compute_s * compute_inflation / nm as f64;
    let exp_mb = (1.0 - ATTN_COMPUTE_FRACTION) * compute_s * compute_inflation / nm as f64;
    let disp_mb = 0.5 * comm_s * comm_inflation / nm as f64;
    let comb_mb = disp_mb;

    // earliest-start list scheduling over two resources
    let mut comm_free = 0.0f64;
    let mut comp_free = 0.0f64;
    // ready[k] = time micro-batch k may start its next task
    let mut ready = vec![0.0f64; nm];
    let mut comm_busy = 0.0;
    let mut comp_busy = 0.0;

    for _layer in 0..n_layers {
        for k in 0..nm {
            // attention (compute stream)
            let start = ready[k].max(comp_free);
            comp_free = start + attn_mb;
            comp_busy += attn_mb;
            ready[k] = comp_free;
            // dispatch (comm stream) can begin as soon as attn(k) is done
            let start = ready[k].max(comm_free);
            comm_free = start + disp_mb;
            comm_busy += disp_mb;
            ready[k] = comm_free;
        }
        for k in 0..nm {
            // expert forward (compute stream)
            let start = ready[k].max(comp_free);
            comp_free = start + exp_mb;
            comp_busy += exp_mb;
            ready[k] = comp_free;
            // combine (comm stream)
            let start = ready[k].max(comm_free);
            comm_free = start + comb_mb;
            comm_busy += comb_mb;
            ready[k] = comm_free;
        }
        // layer-boundary stream synchronization: the residual add / norm
        // entering the next layer needs every micro-batch combined (the
        // imperfect-overlap term the paper measures as exposed comm)
        let barrier = comm_free.max(comp_free);
        comp_free = barrier;
        comm_free = barrier;
        for r in ready.iter_mut() {
            *r = barrier;
        }
    }
    let total = comm_free.max(comp_free);
    // exposed communication: wall time not covered by compute activity
    let exposed = (total - comp_busy).max(0.0);
    StreamReport {
        total_s: total,
        exposed_comm_s: exposed.min(comm_busy),
        total_comm_s: comm_busy,
        total_compute_s: comp_busy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn spin(d: Duration) {
        let t0 = std::time::Instant::now();
        while t0.elapsed() < d {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn async_pipeline_hides_prep_time() {
        // The device side is a sleep (accelerator busy, CPU free) so the
        // CPU prep genuinely overlaps even on a single-core host — the
        // same contract as the paper's CPU/NPU overlap.
        let prep = Duration::from_micros(500);
        let exec = Duration::from_millis(2);
        let n = 30;
        let serial = run_serial(n, |_| spin(prep), |_, _| std::thread::sleep(exec));
        let asynch = run_async(n, |_| spin(prep), |_, _| std::thread::sleep(exec));
        assert!(
            asynch.wall_s < serial.wall_s * 0.92,
            "async {} !< 0.92 * serial {}",
            asynch.wall_s,
            serial.wall_s
        );
        // async wall should approach the pure device time
        assert!(asynch.wall_s < n as f64 * 0.0025 + 0.05);
    }

    #[test]
    fn async_pipeline_preserves_order_and_count() {
        let mut seen = Vec::new();
        let r = run_async(20, |i| i * 2, |i, v| seen.push((i, v)));
        assert_eq!(r.iterations, 20);
        assert_eq!(seen.len(), 20);
        for (i, (idx, v)) in seen.iter().enumerate() {
            assert_eq!(*idx, i as u64);
            assert_eq!(*v, (i as u64) * 2);
        }
    }

    #[test]
    fn dual_stream_hides_most_comm() {
        // paper Table 7 shape: per-layer compute 13 ms, comm 9.3 ms
        let single = simulate_single_stream(61, 13.0e-3, 9.3e-3);
        let dual = simulate_dual_stream(61, 13.0e-3, 9.3e-3, 2, 17.0 / 13.0, 12.4 / 9.3);
        assert!(
            dual.overlap_ratio() > 0.6,
            "overlap {} should be large",
            dual.overlap_ratio()
        );
        assert!(
            dual.total_s < single.total_s,
            "dual {} !< single {}",
            dual.total_s,
            single.total_s
        );
        // net gain over 61 layers should be on the order of 100+ ms
        let gain_ms = (single.total_s - dual.total_s) * 1e3;
        assert!(gain_ms > 50.0, "gain {gain_ms} ms");
    }

    #[test]
    fn dual_stream_single_micro_batch_degenerates() {
        let single = simulate_single_stream(4, 10e-3, 5e-3);
        let dual = simulate_dual_stream(4, 10e-3, 5e-3, 1, 1.0, 1.0);
        // with one micro-batch there is no overlap opportunity
        assert!((dual.total_s - single.total_s).abs() < 1e-9);
    }

    #[test]
    fn dual_stream_conserves_work() {
        let r = simulate_dual_stream(8, 10e-3, 6e-3, 2, 1.2, 1.2);
        assert!((r.total_compute_s - 8.0 * 10e-3 * 1.2).abs() < 1e-9);
        assert!((r.total_comm_s - 8.0 * 6e-3 * 1.2).abs() < 1e-9);
        assert!(r.total_s >= r.total_compute_s.max(r.total_comm_s) - 1e-12);
        assert!(r.exposed_comm_s >= 0.0);
    }

    #[test]
    fn more_micro_batches_improve_overlap_until_inflation_wins() {
        let d2 = simulate_dual_stream(16, 10e-3, 8e-3, 2, 1.1, 1.1);
        let d4 = simulate_dual_stream(16, 10e-3, 8e-3, 4, 1.1, 1.1);
        assert!(d4.exposed_comm_s <= d2.exposed_comm_s + 1e-9);
        // but heavy inflation makes splitting lose
        let d4_bad = simulate_dual_stream(16, 10e-3, 8e-3, 4, 2.5, 2.5);
        let single = simulate_single_stream(16, 10e-3, 8e-3);
        assert!(d4_bad.total_s > single.total_s * 0.9);
    }
}
