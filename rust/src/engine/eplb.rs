//! Dynamic Expert-Parallel Load Balance (paper §4.4.2).
//!
//! Three cooperating pieces:
//!
//! * **Expert load statistics** — the router records per-expert token
//!   counts; workers aggregate periodically and report to the controller.
//! * **Routing-table recomputation** — the controller assigns experts
//!   (plus replicas of hot experts — "Expert Redundancy") to devices,
//!   balancing the expected token load per device (greedy LPT bin
//!   packing).
//! * **Double-buffer weight update** — new expert weights preload into the
//!   spare buffer on every worker; the controller broadcasts the switch
//!   only after *all* workers report readiness, so the flip is atomic and
//!   imperceptible (no serving pause).


/// Sliding expert load statistics (token counts per expert).
#[derive(Debug, Clone)]
pub struct ExpertStats {
    pub n_experts: usize,
    counts: Vec<u64>,
    /// Decayed history for stability across windows.
    ema: Vec<f64>,
    alpha: f64,
}

impl ExpertStats {
    pub fn new(n_experts: usize) -> ExpertStats {
        ExpertStats { n_experts, counts: vec![0; n_experts], ema: vec![0.0; n_experts], alpha: 0.3 }
    }

    /// Router hook: a token was dispatched to `expert`.
    pub fn record(&mut self, expert: usize, tokens: u64) {
        self.counts[expert] += tokens;
    }

    /// Close the statistics window, folding into the EMA.
    pub fn roll_window(&mut self) {
        for (e, c) in self.counts.iter_mut().enumerate() {
            self.ema[e] = (1.0 - self.alpha) * self.ema[e] + self.alpha * (*c as f64);
            *c = 0;
        }
    }

    /// Smoothed expected load per expert.
    pub fn load(&self) -> Vec<f64> {
        self.ema.clone()
    }

    pub fn window_counts(&self) -> &[u64] {
        &self.counts
    }
}

/// A routing table: which device hosts which expert replicas, and how a
/// token for expert `e` picks a device.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingTable {
    pub n_devices: usize,
    /// replica placements: expert -> devices hosting a copy.
    pub placements: Vec<Vec<usize>>,
    /// round-robin cursor per expert (interior mutability avoided: callers
    /// route via `route(expert, salt)`).
    pub version: u64,
}

impl RoutingTable {
    /// Device for a token of `expert`; `salt` spreads across replicas.
    pub fn route(&self, expert: usize, salt: u64) -> usize {
        let devs = &self.placements[expert];
        devs[(salt as usize) % devs.len()]
    }

    /// Expected tokens per device given per-expert loads.
    pub fn device_loads(&self, expert_load: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.n_devices];
        for (e, devs) in self.placements.iter().enumerate() {
            let share = expert_load[e] / devs.len() as f64;
            for &d in devs {
                out[d] += share;
            }
        }
        out
    }

    /// Max/mean device load (the imbalance factor the cost model uses).
    pub fn imbalance(&self, expert_load: &[f64]) -> f64 {
        let loads = self.device_loads(expert_load);
        let mean = loads.iter().sum::<f64>() / loads.len() as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        loads.iter().cloned().fold(0.0, f64::max) / mean
    }
}

/// Static baseline: expert e on device e % n_devices, no replicas.
pub fn static_table(n_experts: usize, n_devices: usize) -> RoutingTable {
    RoutingTable {
        n_devices,
        placements: (0..n_experts).map(|e| vec![e % n_devices]).collect(),
        version: 0,
    }
}

/// Controller: recompute the routing table from observed loads.
///
/// Greedy LPT: sort experts by load descending, give each its primary
/// device as the currently lightest; then spend `redundancy_budget` extra
/// replicas on the hottest experts (again to the lightest devices).
pub fn rebalance(
    expert_load: &[f64],
    n_devices: usize,
    redundancy_budget: usize,
    prev_version: u64,
) -> RoutingTable {
    let n = expert_load.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| expert_load[b].partial_cmp(&expert_load[a]).unwrap());

    let mut device_load = vec![0.0f64; n_devices];
    let mut placements = vec![Vec::new(); n];
    for &e in &order {
        let lightest = (0..n_devices)
            .min_by(|&a, &b| device_load[a].partial_cmp(&device_load[b]).unwrap())
            .unwrap();
        placements[e].push(lightest);
        device_load[lightest] += expert_load[e];
    }
    // replicas for the hottest experts
    for r in 0..redundancy_budget {
        let e = order[r % n.max(1)];
        // replica halves the per-device share: recompute marginal benefit
        let lightest = (0..n_devices)
            .min_by(|&a, &b| device_load[a].partial_cmp(&device_load[b]).unwrap())
            .unwrap();
        if placements[e].contains(&lightest) {
            continue;
        }
        // shift half the load to the replica
        let share = expert_load[e] / placements[e].len() as f64;
        let new_share = expert_load[e] / (placements[e].len() + 1) as f64;
        for &d in &placements[e] {
            device_load[d] -= share - new_share;
        }
        placements[e].push(lightest);
        device_load[lightest] += new_share;
    }
    RoutingTable { n_devices, placements, version: prev_version + 1 }
}

/// Double-buffer weight update protocol state per worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufferState {
    /// Serving from buffer A, B idle.
    ActiveA,
    /// Serving from buffer B, A idle.
    ActiveB,
}

/// Controller-side state machine for a fleet-wide atomic weight switch.
#[derive(Debug)]
pub struct WeightUpdateController {
    n_workers: usize,
    ready: Vec<bool>,
    pub table_version: u64,
    pub switches: u64,
}

impl WeightUpdateController {
    pub fn new(n_workers: usize) -> WeightUpdateController {
        WeightUpdateController { n_workers, ready: vec![false; n_workers], table_version: 0, switches: 0 }
    }

    /// Worker `w` finished preloading the new expert weights into its
    /// spare buffer.  Returns `true` when ALL workers are ready — the
    /// controller then broadcasts the atomic switch.
    pub fn worker_ready(&mut self, w: usize) -> bool {
        self.ready[w] = true;
        if self.ready.iter().all(|&r| r) {
            self.ready.iter_mut().for_each(|r| *r = false);
            self.table_version += 1;
            self.switches += 1;
            true
        } else {
            false
        }
    }

    pub fn pending(&self) -> usize {
        self.ready.iter().filter(|&&r| !r).count()
    }
}

/// Worker-side double buffer.
#[derive(Debug)]
pub struct DoubleBuffer {
    pub state: BufferState,
    /// Version loaded in the spare buffer (None = not preloaded).
    pub spare_version: Option<u64>,
    pub active_version: u64,
}

impl DoubleBuffer {
    pub fn new() -> DoubleBuffer {
        DoubleBuffer { state: BufferState::ActiveA, spare_version: None, active_version: 0 }
    }

    /// Preload new weights into the spare buffer (async; serving continues
    /// from the active buffer).
    pub fn preload(&mut self, version: u64) {
        self.spare_version = Some(version);
    }

    /// Atomic pointer switch on the controller's broadcast.
    pub fn switch(&mut self) -> Result<(), String> {
        match self.spare_version.take() {
            Some(v) => {
                self.active_version = v;
                self.state = match self.state {
                    BufferState::ActiveA => BufferState::ActiveB,
                    BufferState::ActiveB => BufferState::ActiveA,
                };
                Ok(())
            }
            None => Err("switch without preload".to_string()),
        }
    }
}

impl Default for DoubleBuffer {
    fn default() -> Self {
        Self::new()
    }
}

/// Full fleet simulation step used by tests/benches: returns imbalance
/// before/after one rebalance round on a skewed load.
pub fn rebalance_round(
    stats: &ExpertStats,
    n_devices: usize,
    redundancy: usize,
    prev: &RoutingTable,
) -> (f64, f64, RoutingTable) {
    let load = stats.load();
    let before = prev.imbalance(&load);
    let table = rebalance(&load, n_devices, redundancy, prev.version);
    let after = table.imbalance(&load);
    (before, after, table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn skewed_stats(n_experts: usize, rng: &mut Rng) -> ExpertStats {
        let mut s = ExpertStats::new(n_experts);
        for _ in 0..10_000 {
            let e = (rng.zipf(n_experts as u64, 1.2) - 1) as usize;
            s.record(e, 1);
        }
        s.roll_window();
        s
    }

    #[test]
    fn rebalance_reduces_imbalance_on_skew() {
        let mut rng = Rng::new(42);
        let stats = skewed_stats(32, &mut rng);
        let prev = static_table(32, 8);
        let (before, after, _) = rebalance_round(&stats, 8, 8, &prev);
        assert!(before > 1.5, "static should be imbalanced, got {before}");
        assert!(after < before * 0.7, "rebalance {after} !< {before}");
    }

    #[test]
    fn routing_spreads_over_replicas() {
        let table = RoutingTable { n_devices: 4, placements: vec![vec![0, 2]], version: 1 };
        let d0 = table.route(0, 0);
        let d1 = table.route(0, 1);
        assert_ne!(d0, d1);
        assert!([0, 2].contains(&d0) && [0, 2].contains(&d1));
    }

    #[test]
    fn ema_smooths_windows() {
        let mut s = ExpertStats::new(2);
        s.record(0, 100);
        s.roll_window();
        let l1 = s.load()[0];
        s.roll_window(); // empty window decays
        let l2 = s.load()[0];
        assert!(l2 < l1);
        assert!(l2 > 0.0);
    }

    #[test]
    fn double_buffer_atomic_switch_protocol() {
        let mut ctl = WeightUpdateController::new(3);
        let mut bufs: Vec<DoubleBuffer> = (0..3).map(|_| DoubleBuffer::new()).collect();
        for b in &mut bufs {
            b.preload(1);
        }
        assert!(!ctl.worker_ready(0));
        assert!(!ctl.worker_ready(1));
        assert_eq!(ctl.pending(), 1);
        assert!(ctl.worker_ready(2), "all ready -> broadcast");
        for b in &mut bufs {
            b.switch().unwrap();
            assert_eq!(b.active_version, 1);
        }
        // a second switch without preload must fail
        assert!(bufs[0].switch().is_err());
    }

    #[test]
    fn switch_flips_active_buffer() {
        let mut b = DoubleBuffer::new();
        assert_eq!(b.state, BufferState::ActiveA);
        b.preload(5);
        b.switch().unwrap();
        assert_eq!(b.state, BufferState::ActiveB);
        b.preload(6);
        b.switch().unwrap();
        assert_eq!(b.state, BufferState::ActiveA);
        assert_eq!(b.active_version, 6);
    }

    #[test]
    fn property_rebalance_never_worse_than_static() {
        crate::testutil::check("eplb-no-regression", 64, |rng| {
            let n_experts = rng.range(4, 64) as usize;
            let n_devices = rng.range(2, 16) as usize;
            let mut s = ExpertStats::new(n_experts);
            for _ in 0..5000 {
                let alpha = 1.0 + rng.f64();
                let e = (rng.zipf(n_experts as u64, alpha) - 1) as usize;
                s.record(e, 1);
            }
            s.roll_window();
            let prev = static_table(n_experts, n_devices);
            let (before, after, table) = rebalance_round(&s, n_devices, n_devices, &prev);
            crate::prop_assert!(
                after <= before * 1.05 + 1e-9,
                "rebalance regressed: {before} -> {after}"
            );
            // every expert placed on at least one valid device
            for devs in &table.placements {
                crate::prop_assert!(!devs.is_empty());
                for &d in devs {
                    crate::prop_assert!(d < n_devices);
                }
            }
            Ok(())
        });
    }
}
