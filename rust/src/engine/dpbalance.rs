//! Hierarchical DP load balance (paper §4.4.3): three defense layers
//! against attention-phase stragglers in large-DP MoE serving.
//!
//! * **Layer 1 — KV-cache-aware scheduling** (preventative): new requests
//!   go to the DP group with the most free KV capacity, not round-robin.
//! * **Layer 2 — reactive inter-DP migration** (macroscopic): when the
//!   token-load spread between groups exceeds a threshold, move work from
//!   the most- to the least-loaded group, at batch / sequence / MLA-block
//!   granularity, with the KV transfer overlapped with compute.
//! * **Layer 3 — intra-DP kernel-level rebalancing** (microscopic):
//!   within a group, requests are assigned to matrix-compute cores by
//!   sorted load (LPT) instead of round-robin, and ultra-long sequences
//!   are split across cores.

/// One DP group's load snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DpGroup {
    pub id: usize,
    /// Total KV tokens resident (the attention workload driver).
    pub kv_tokens: u64,
    pub kv_capacity: u64,
    pub n_requests: usize,
}

impl DpGroup {
    pub fn free(&self) -> u64 {
        self.kv_capacity.saturating_sub(self.kv_tokens)
    }
}

/// Layer 1: pick the group for a new request.
pub fn kv_aware_dispatch(groups: &[DpGroup]) -> usize {
    groups.iter().max_by_key(|g| g.free()).map(|g| g.id).expect("no DP groups")
}

/// Round-robin baseline for layer-1 comparisons.
pub fn round_robin_dispatch(counter: &mut usize, n_groups: usize) -> usize {
    let g = *counter % n_groups;
    *counter += 1;
    g
}

/// Migration granularity (paper Fig 12).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationGranularity {
    Batch,
    Sequence,
    /// Partial MLA block of one sequence.
    MlaBlock,
}

/// A planned migration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Migration {
    pub from: usize,
    pub to: usize,
    pub tokens: u64,
    pub granularity: MigrationGranularity,
}

/// Layer 2: plan inter-DP migrations until the spread is within
/// `tolerance` (fraction of mean), moving tokens from the most loaded to
/// the least loaded group each round.
pub fn plan_migrations(
    groups: &[DpGroup],
    tolerance: f64,
    max_migrations: usize,
    avg_seq_tokens: u64,
) -> Vec<Migration> {
    let mut load: Vec<(usize, u64)> = groups.iter().map(|g| (g.id, g.kv_tokens)).collect();
    let mut out = Vec::new();
    for _ in 0..max_migrations {
        let mean = load.iter().map(|(_, t)| *t as f64).sum::<f64>() / load.len() as f64;
        let (hi_idx, _) = load
            .iter()
            .enumerate()
            .max_by_key(|(_, (_, t))| *t)
            .map(|(i, _)| (i, ()))
            .unwrap();
        let (lo_idx, _) = load
            .iter()
            .enumerate()
            .min_by_key(|(_, (_, t))| *t)
            .map(|(i, _)| (i, ()))
            .unwrap();
        let spread = load[hi_idx].1 as f64 - load[lo_idx].1 as f64;
        if mean <= 0.0 || spread <= tolerance * mean {
            break;
        }
        // move half the spread; choose granularity by size
        let tokens = (spread / 2.0) as u64;
        let granularity = if tokens >= 4 * avg_seq_tokens {
            MigrationGranularity::Batch
        } else if tokens >= avg_seq_tokens {
            MigrationGranularity::Sequence
        } else {
            MigrationGranularity::MlaBlock
        };
        let tokens = tokens.max(1);
        out.push(Migration { from: load[hi_idx].0, to: load[lo_idx].0, tokens, granularity });
        load[hi_idx].1 -= tokens;
        load[lo_idx].1 += tokens;
    }
    out
}

/// Apply planned migrations to group snapshots (sim bookkeeping).
pub fn apply_migrations(groups: &mut [DpGroup], migrations: &[Migration]) {
    for m in migrations {
        if let Some(g) = groups.iter_mut().find(|g| g.id == m.from) {
            g.kv_tokens = g.kv_tokens.saturating_sub(m.tokens);
        }
        if let Some(g) = groups.iter_mut().find(|g| g.id == m.to) {
            g.kv_tokens += m.tokens;
        }
    }
}

/// Straggler factor: max group load / mean group load (>= 1).
pub fn straggler_factor(groups: &[DpGroup]) -> f64 {
    let mean =
        groups.iter().map(|g| g.kv_tokens as f64).sum::<f64>() / groups.len().max(1) as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    groups.iter().map(|g| g.kv_tokens as f64).fold(0.0, f64::max) / mean
}

// ---------------------------------------------------------------------
// Layer 3: intra-DP kernel-level core assignment
// ---------------------------------------------------------------------

/// Assignment of per-request token loads onto matrix compute cores.
#[derive(Debug, Clone)]
pub struct CoreAssignment {
    /// tokens per core.
    pub core_loads: Vec<u64>,
    /// number of sequence splits performed.
    pub splits: u64,
}

impl CoreAssignment {
    /// Max per-core load — the kernel completion time driver.
    pub fn makespan_tokens(&self) -> u64 {
        self.core_loads.iter().copied().max().unwrap_or(0)
    }
}

/// Baseline: "one request per tensor compute core", round-robin (§4.4.3).
pub fn round_robin_cores(requests: &[u64], n_cores: usize) -> CoreAssignment {
    let mut loads = vec![0u64; n_cores];
    for (i, &t) in requests.iter().enumerate() {
        loads[i % n_cores] += t;
    }
    CoreAssignment { core_loads: loads, splits: 0 }
}

/// xLLM layer 3: sort by load (LPT) and split sequences longer than
/// `split_threshold` tokens across the least-loaded cores.
pub fn balanced_cores(requests: &[u64], n_cores: usize, split_threshold: u64) -> CoreAssignment {
    let mut loads = vec![0u64; n_cores];
    let mut splits = 0u64;
    let mut work: Vec<u64> = Vec::new();
    for &t in requests {
        if t > split_threshold {
            // split into ceil(t / threshold) shards
            let shards = t.div_ceil(split_threshold);
            let base = t / shards;
            let mut rem = t % shards;
            for _ in 0..shards {
                let extra = if rem > 0 { rem -= 1; 1 } else { 0 };
                work.push(base + extra);
            }
            splits += shards - 1;
        } else {
            work.push(t);
        }
    }
    // LPT: heaviest first onto the lightest core
    work.sort_unstable_by(|a, b| b.cmp(a));
    for t in work {
        let lightest = (0..n_cores).min_by_key(|&c| loads[c]).unwrap();
        loads[lightest] += t;
    }
    CoreAssignment { core_loads: loads, splits }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn groups(loads: &[u64]) -> Vec<DpGroup> {
        loads
            .iter()
            .enumerate()
            .map(|(id, &kv)| DpGroup { id, kv_tokens: kv, kv_capacity: 1_000_000, n_requests: 1 })
            .collect()
    }

    #[test]
    fn layer1_picks_most_free() {
        let g = groups(&[900_000, 100, 500_000]);
        assert_eq!(kv_aware_dispatch(&g), 1);
    }

    #[test]
    fn layer2_closes_20k_gap() {
        // paper: a 20k-token difference between DP groups
        let mut g = groups(&[60_000, 40_000]);
        assert!(straggler_factor(&g) > 1.15);
        let m = plan_migrations(&g, 0.05, 10, 2000);
        assert!(!m.is_empty());
        apply_migrations(&mut g, &m);
        assert!(straggler_factor(&g) < 1.06, "factor={}", straggler_factor(&g));
    }

    #[test]
    fn layer2_granularity_by_size() {
        let g = groups(&[100_000, 0]);
        let m = plan_migrations(&g, 0.01, 1, 2000);
        assert_eq!(m[0].granularity, MigrationGranularity::Batch);
        let g2 = groups(&[3_000, 0]);
        let m2 = plan_migrations(&g2, 0.01, 1, 2000);
        assert_eq!(m2[0].granularity, MigrationGranularity::MlaBlock);
    }

    #[test]
    fn layer2_balanced_groups_need_nothing() {
        let g = groups(&[50_000, 50_200, 49_900]);
        assert!(plan_migrations(&g, 0.05, 10, 2000).is_empty());
    }

    #[test]
    fn layer3_paper_case_32k_to_1300() {
        // paper: a 32k-token request on one core reduced to ~1.3k by
        // reorder + split (across ~24 cores with other short requests)
        let mut reqs = vec![32_000u64];
        reqs.extend(std::iter::repeat(200).take(23));
        let rr = round_robin_cores(&reqs, 24);
        assert_eq!(rr.makespan_tokens(), 32_000);
        let bal = balanced_cores(&reqs, 24, 1_500);
        assert!(
            bal.makespan_tokens() <= 1_700,
            "balanced makespan {} should be ~1.5k",
            bal.makespan_tokens()
        );
        assert!(bal.splits >= 20);
    }

    #[test]
    fn layer3_conserves_tokens() {
        crate::testutil::check("cores-conserve", 128, |rng| {
            let n_cores = rng.range(2, 32) as usize;
            let reqs: Vec<u64> = (0..rng.range(1, 40)).map(|_| rng.range(1, 40_000)).collect();
            let total: u64 = reqs.iter().sum();
            let bal = balanced_cores(&reqs, n_cores, 2_000);
            crate::prop_assert!(
                bal.core_loads.iter().sum::<u64>() == total,
                "tokens lost in balancing"
            );
            let rr = round_robin_cores(&reqs, n_cores);
            crate::prop_assert!(rr.core_loads.iter().sum::<u64>() == total);
            crate::prop_assert!(
                bal.makespan_tokens() <= rr.makespan_tokens(),
                "balanced {} worse than rr {}",
                bal.makespan_tokens(),
                rr.makespan_tokens()
            );
            Ok(())
        });
    }

    #[test]
    fn property_migrations_conserve_and_converge() {
        crate::testutil::check("dp-migrate", 128, |rng| {
            let n = rng.range(2, 16) as usize;
            let mut g: Vec<DpGroup> = (0..n)
                .map(|id| DpGroup {
                    id,
                    kv_tokens: rng.range(0, 100_000),
                    kv_capacity: 1_000_000,
                    n_requests: 1,
                })
                .collect();
            let before_total: u64 = g.iter().map(|x| x.kv_tokens).sum();
            let m = plan_migrations(&g, 0.10, 32, 2000);
            apply_migrations(&mut g, &m);
            let after_total: u64 = g.iter().map(|x| x.kv_tokens).sum();
            crate::prop_assert!(before_total == after_total, "tokens not conserved");
            if before_total > 1000 {
                crate::prop_assert!(
                    straggler_factor(&g) < 1.2 + 1e-9,
                    "did not converge: {}",
                    straggler_factor(&g)
                );
            }
            Ok(())
        });
    }
}
