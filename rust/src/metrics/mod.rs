//! Serving metrics: TTFT / TPOT / E2E collection, SLO attainment, goodput.
//!
//! These are the quantities every paper table and figure reports: token
//! throughput under a TPOT (or E2E) constraint, request rate, SLO
//! attainment, and goodput (requests/s that met their SLO).

use crate::util::Summary;

/// SLO targets for a request class (seconds). `f64::INFINITY` = unconstrained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Time to first token.
    pub ttft_s: f64,
    /// Time per output token (mean over the request).
    pub tpot_s: f64,
    /// End-to-end completion latency.
    pub e2e_s: f64,
}

impl Slo {
    pub const UNCONSTRAINED: Slo =
        Slo { ttft_s: f64::INFINITY, tpot_s: f64::INFINITY, e2e_s: f64::INFINITY };

    /// Paper main-results setting: TPOT bound only.
    pub fn tpot(tpot_s: f64) -> Slo {
        Slo { ttft_s: f64::INFINITY, tpot_s, e2e_s: f64::INFINITY }
    }

    /// Scenario setting: end-to-end bound only (merchant/customer-service).
    pub fn e2e(e2e_s: f64) -> Slo {
        Slo { ttft_s: f64::INFINITY, tpot_s: f64::INFINITY, e2e_s }
    }

    /// Interactive setting: TTFT + TPOT (the PD-disaggregation experiments).
    pub fn interactive(ttft_s: f64, tpot_s: f64) -> Slo {
        Slo { ttft_s, tpot_s, e2e_s: f64::INFINITY }
    }
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    /// True if the request was dropped/failed rather than completed.
    pub failed: bool,
}

impl RequestOutcome {
    pub fn ttft(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    pub fn e2e(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Mean time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64
    }

    pub fn meets(&self, slo: &Slo) -> bool {
        !self.failed
            && self.ttft() <= slo.ttft_s
            && self.tpot() <= slo.tpot_s
            && self.e2e() <= slo.e2e_s
    }
}

/// Aggregated serving metrics over a run.
#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub outcomes: Vec<RequestOutcome>,
}

impl ServingReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, o: RequestOutcome) {
        self.outcomes.push(o);
    }

    /// Fold another report's outcomes into this one (cluster-level
    /// aggregation: the control plane merges per-replica reports).
    pub fn merge(&mut self, other: &ServingReport) {
        self.outcomes.extend(other.outcomes.iter().copied());
    }

    pub fn n_requests(&self) -> usize {
        self.outcomes.len()
    }

    pub fn n_completed(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.failed).count()
    }

    fn horizon(&self) -> f64 {
        let start = self.outcomes.iter().map(|o| o.arrival_s).fold(f64::INFINITY, f64::min);
        let end = self.outcomes.iter().map(|o| o.finish_s).fold(0.0, f64::max);
        (end - start).max(1e-9)
    }

    /// Output-token throughput (tokens/s over the run horizon).
    pub fn output_throughput(&self) -> f64 {
        let toks: u64 = self.outcomes.iter().filter(|o| !o.failed).map(|o| o.output_tokens).sum();
        toks as f64 / self.horizon()
    }

    /// Total-token (input+output) throughput.
    pub fn total_throughput(&self) -> f64 {
        let toks: u64 = self
            .outcomes
            .iter()
            .filter(|o| !o.failed)
            .map(|o| o.input_tokens + o.output_tokens)
            .sum();
        toks as f64 / self.horizon()
    }

    /// Completed requests per second.
    pub fn request_rate(&self) -> f64 {
        self.n_completed() as f64 / self.horizon()
    }

    /// Fraction of requests that met the SLO.
    pub fn slo_attainment(&self, slo: &Slo) -> f64 {
        if self.outcomes.is_empty() {
            return 1.0;
        }
        self.outcomes.iter().filter(|o| o.meets(slo)).count() as f64 / self.outcomes.len() as f64
    }

    /// Goodput: SLO-meeting requests per second (DistServe's metric).
    pub fn goodput(&self, slo: &Slo) -> f64 {
        self.outcomes.iter().filter(|o| o.meets(slo)).count() as f64 / self.horizon()
    }

    pub fn ttft_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            s.add(o.ttft());
        }
        s
    }

    pub fn tpot_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed && o.output_tokens > 1) {
            s.add(o.tpot());
        }
        s
    }

    pub fn e2e_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            s.add(o.e2e());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(arr: f64, ft: f64, fin: f64, inp: u64, out: u64) -> RequestOutcome {
        RequestOutcome {
            arrival_s: arr,
            first_token_s: ft,
            finish_s: fin,
            input_tokens: inp,
            output_tokens: out,
            failed: false,
        }
    }

    #[test]
    fn ttft_tpot_e2e() {
        let o = outcome(1.0, 1.5, 2.5, 100, 11);
        assert!((o.ttft() - 0.5).abs() < 1e-12);
        assert!((o.e2e() - 1.5).abs() < 1e-12);
        assert!((o.tpot() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn slo_meets() {
        let o = outcome(0.0, 0.4, 1.4, 10, 11);
        assert!(o.meets(&Slo::interactive(0.5, 0.11)));
        assert!(!o.meets(&Slo::interactive(0.3, 0.11)));
        assert!(!o.meets(&Slo::interactive(0.5, 0.09)));
        assert!(o.meets(&Slo::UNCONSTRAINED));
    }

    #[test]
    fn throughput_over_horizon() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 10, 50));
        assert!((r.output_throughput() - 50.0).abs() < 1e-9);
        assert!((r.request_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn goodput_counts_only_slo_met() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 2)); // tpot=0.9
        r.record(outcome(0.0, 0.1, 0.2, 10, 2)); // tpot=0.1
        let slo = Slo::tpot(0.5);
        assert!((r.slo_attainment(&slo) - 0.5).abs() < 1e-9);
        assert!((r.goodput(&slo) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_aggregates_outcomes() {
        let mut a = ServingReport::new();
        a.record(outcome(0.0, 0.1, 1.0, 10, 50));
        let mut b = ServingReport::new();
        b.record(outcome(1.0, 1.1, 2.0, 10, 50));
        b.record(outcome(1.0, 1.2, 3.0, 10, 50));
        a.merge(&b);
        assert_eq!(a.n_requests(), 3);
        assert_eq!(b.n_requests(), 2, "merge must not drain the source");
        // throughput spans the merged horizon (0.0 .. 3.0)
        assert!((a.output_throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn failed_requests_excluded_from_throughput() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        let mut bad = outcome(0.0, 0.1, 1.0, 10, 50);
        bad.failed = true;
        r.record(bad);
        assert!((r.output_throughput() - 50.0).abs() < 1e-9);
        assert_eq!(r.n_completed(), 1);
    }
}
