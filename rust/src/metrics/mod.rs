//! Serving metrics: TTFT / TPOT / E2E collection, SLO attainment, goodput.
//!
//! These are the quantities every paper table and figure reports: token
//! throughput under a TPOT (or E2E) constraint, request rate, SLO
//! attainment, and goodput (requests/s that met their SLO).

use crate::obs::{Histogram, MetricsRegistry, LATENCY_BUCKETS_S, TPOT_BUCKETS_S};
use crate::util::Summary;

/// Number of tenant service tiers (see [`tier_slo`]).
pub const N_TIERS: usize = 3;

/// Per-tier TTFT/TPOT targets for multi-tenant goodput accounting
/// (§3.1 enterprise traffic): tier 0 is premium interactive, tier 1
/// standard, tier 2 (and anything higher) relaxed best-effort.
pub fn tier_slo(tier: u8) -> Slo {
    match tier {
        0 => Slo::interactive(1.0, 0.05),
        1 => Slo::interactive(2.5, 0.1),
        _ => Slo::interactive(10.0, 0.25),
    }
}

/// SLO targets for a request class (seconds). `f64::INFINITY` = unconstrained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Time to first token.
    pub ttft_s: f64,
    /// Time per output token (mean over the request).
    pub tpot_s: f64,
    /// End-to-end completion latency.
    pub e2e_s: f64,
}

impl Slo {
    pub const UNCONSTRAINED: Slo =
        Slo { ttft_s: f64::INFINITY, tpot_s: f64::INFINITY, e2e_s: f64::INFINITY };

    /// Paper main-results setting: TPOT bound only.
    pub fn tpot(tpot_s: f64) -> Slo {
        Slo { ttft_s: f64::INFINITY, tpot_s, e2e_s: f64::INFINITY }
    }

    /// Scenario setting: end-to-end bound only (merchant/customer-service).
    pub fn e2e(e2e_s: f64) -> Slo {
        Slo { ttft_s: f64::INFINITY, tpot_s: f64::INFINITY, e2e_s }
    }

    /// Interactive setting: TTFT + TPOT (the PD-disaggregation experiments).
    pub fn interactive(ttft_s: f64, tpot_s: f64) -> Slo {
        Slo { ttft_s, tpot_s, e2e_s: f64::INFINITY }
    }
}

/// Where one request's time went, in seconds (§3 phase attribution).
///
/// `queue_s` is the residual: everything not attributable to prefill,
/// handoff, or decode — dispatch wait, encode time, and fault-recovery
/// re-queueing all land there.  Components are clamped non-negative
/// (recovery recompute can restart prefill after the first token), so
/// the four fields sum to at most the E2E latency.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub queue_s: f64,
    pub prefill_s: f64,
    pub handoff_s: f64,
    pub decode_s: f64,
}

impl PhaseBreakdown {
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.handoff_s + self.decode_s
    }
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    /// True if the request was dropped/failed rather than completed.
    pub failed: bool,
    /// Prompt tokens served from the prefix cache instead of being
    /// prefilled (token-exact under token-granular matching, block-
    /// rounded otherwise; 0 when the cache is off or missed).
    pub prefix_hit_tokens: u64,
    /// Per-phase latency attribution (queue/prefill/handoff/decode).
    pub phases: PhaseBreakdown,
    /// Tenant tier (indexes [`tier_slo`] for per-tier goodput).
    pub tier: u8,
}

impl RequestOutcome {
    pub fn ttft(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    pub fn e2e(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Mean time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64
    }

    pub fn meets(&self, slo: &Slo) -> bool {
        !self.failed
            && self.ttft() <= slo.ttft_s
            && self.tpot() <= slo.tpot_s
            && self.e2e() <= slo.e2e_s
    }
}

/// Mergeable fixed-bucket log-histogram sketch of a report: everything
/// the fleet JSON and exposition need, in O(1) memory per report no
/// matter how many requests pass through.  Updated on *every* record
/// (retaining reports carry both representations), and exact for
/// counts, token sums, horizon endpoints, and per-tier goodput; only
/// the latency quantiles are approximate (within one bucket width —
/// the estimate is the upper bound of the bucket holding the rank).
#[derive(Debug, Clone)]
pub struct ReportSketch {
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e: Histogram,
    /// Canonical phase order: queue, prefill, handoff, decode.
    pub phases: [Histogram; 4],
    pub n_requests: u64,
    pub n_failed: u64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub prefix_hit_tokens: u64,
    /// Earliest arrival over ALL outcomes (failed included), `INFINITY`
    /// when empty — mirrors the exact horizon fold.
    pub min_arrival_s: f64,
    /// Latest finish over completed outcomes, `0.0` when empty.
    pub max_finish_s: f64,
    /// Requests per tier (completed or failed).
    pub tier_total: [u64; N_TIERS],
    /// Completed requests per tier meeting their own tier's SLO,
    /// evaluated exactly at record time.
    pub tier_good: [u64; N_TIERS],
}

impl Default for ReportSketch {
    fn default() -> Self {
        ReportSketch {
            ttft: Histogram::new(LATENCY_BUCKETS_S),
            tpot: Histogram::new(TPOT_BUCKETS_S),
            e2e: Histogram::new(LATENCY_BUCKETS_S),
            phases: [
                Histogram::new(LATENCY_BUCKETS_S),
                Histogram::new(LATENCY_BUCKETS_S),
                Histogram::new(LATENCY_BUCKETS_S),
                Histogram::new(LATENCY_BUCKETS_S),
            ],
            n_requests: 0,
            n_failed: 0,
            input_tokens: 0,
            output_tokens: 0,
            prefix_hit_tokens: 0,
            min_arrival_s: f64::INFINITY,
            max_finish_s: 0.0,
            tier_total: [0; N_TIERS],
            tier_good: [0; N_TIERS],
        }
    }
}

impl ReportSketch {
    fn record(&mut self, o: &RequestOutcome) {
        self.n_requests += 1;
        self.min_arrival_s = self.min_arrival_s.min(o.arrival_s);
        let tier = (o.tier as usize).min(N_TIERS - 1);
        self.tier_total[tier] += 1;
        if o.failed {
            self.n_failed += 1;
            return;
        }
        self.max_finish_s = self.max_finish_s.max(o.finish_s);
        self.input_tokens += o.input_tokens;
        self.output_tokens += o.output_tokens;
        self.prefix_hit_tokens += o.prefix_hit_tokens;
        self.ttft.observe(o.ttft());
        self.e2e.observe(o.e2e());
        if o.output_tokens > 1 {
            self.tpot.observe(o.tpot());
        }
        self.phases[0].observe(o.phases.queue_s);
        self.phases[1].observe(o.phases.prefill_s);
        self.phases[2].observe(o.phases.handoff_s);
        self.phases[3].observe(o.phases.decode_s);
        if o.meets(&tier_slo(o.tier)) {
            self.tier_good[tier] += 1;
        }
    }

    fn merge(&mut self, other: &ReportSketch) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        for (a, b) in self.phases.iter_mut().zip(other.phases.iter()) {
            a.merge(b);
        }
        self.n_requests += other.n_requests;
        self.n_failed += other.n_failed;
        self.input_tokens += other.input_tokens;
        self.output_tokens += other.output_tokens;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.min_arrival_s = self.min_arrival_s.min(other.min_arrival_s);
        self.max_finish_s = self.max_finish_s.max(other.max_finish_s);
        for t in 0..N_TIERS {
            self.tier_total[t] += other.tier_total[t];
            self.tier_good[t] += other.tier_good[t];
        }
    }

    /// Sketch TTFT quantile (`q` in [0, 100]; upper-bucket-bound
    /// estimate, within one bucket width of exact).
    pub fn ttft_p(&self, q: f64) -> f64 {
        self.ttft.quantile(q)
    }

    pub fn tpot_p(&self, q: f64) -> f64 {
        self.tpot.quantile(q)
    }

    pub fn e2e_p(&self, q: f64) -> f64 {
        self.e2e.quantile(q)
    }

    /// Exact means (histogram sums are exact).
    pub fn ttft_mean(&self) -> f64 {
        self.ttft.mean()
    }

    pub fn tpot_mean(&self) -> f64 {
        self.tpot.mean()
    }

    pub fn e2e_mean(&self) -> f64 {
        self.e2e.mean()
    }

    /// Mean per-phase seconds in canonical order, named.
    pub fn phase_means(&self) -> [(&'static str, f64); 4] {
        [
            ("queue", self.phases[0].mean()),
            ("prefill", self.phases[1].mean()),
            ("handoff", self.phases[2].mean()),
            ("decode", self.phases[3].mean()),
        ]
    }
}

/// One tier's goodput row for reports and fleet JSON.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierGoodput {
    pub tier: u8,
    /// Requests of this tier seen (completed or failed).
    pub total: u64,
    /// Completed requests that met the tier's own SLO.
    pub good: u64,
    /// `good / total` (1.0 when the tier saw no traffic).
    pub attainment: f64,
    /// `good / horizon` — SLO-meeting requests per second.
    pub goodput_per_s: f64,
}

/// Aggregated serving metrics over a run.
///
/// Two representations live here: the per-request `outcomes` vector
/// (retained by default — exact summaries, golden paths untouched) and
/// a constant-size [`ReportSketch`] that is ALWAYS updated.  A report
/// created with [`ServingReport::streaming`] skips outcome retention,
/// so a million-request run carries a few histograms instead of a
/// million records; counts, throughputs, horizon, and per-tier goodput
/// come from the sketch either way (the sketch is exact for all of
/// them), and only the `*_summary()` sample accessors need retention.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub outcomes: Vec<RequestOutcome>,
    pub sketch: ReportSketch,
    retain: bool,
}

impl Default for ServingReport {
    fn default() -> Self {
        ServingReport { outcomes: Vec::new(), sketch: ReportSketch::default(), retain: true }
    }
}

impl ServingReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// O(1)-memory report: the sketch only, no per-request retention.
    pub fn streaming() -> Self {
        ServingReport { outcomes: Vec::new(), sketch: ReportSketch::default(), retain: false }
    }

    /// Switch an (empty or populated) report to streaming mode,
    /// dropping any retained outcomes.
    pub fn set_streaming(&mut self) {
        self.retain = false;
        self.outcomes = Vec::new();
    }

    /// True when per-request outcomes are retained (exact summaries
    /// available); false for O(1) streaming reports.
    pub fn retains_outcomes(&self) -> bool {
        self.retain
    }

    pub fn record(&mut self, o: RequestOutcome) {
        self.sketch.record(&o);
        if self.retain {
            self.outcomes.push(o);
        }
    }

    /// Fold another report's outcomes into this one (cluster-level
    /// aggregation: the control plane merges per-replica reports).
    /// Sketches merge unconditionally; outcomes only into a retaining
    /// report (merging a streaming source into a retaining sink keeps
    /// the sink's exact accessors consistent with its *own* outcomes
    /// only — fleets in streaming mode use streaming sinks).
    pub fn merge(&mut self, other: &ServingReport) {
        self.sketch.merge(&other.sketch);
        if self.retain {
            self.outcomes.extend(other.outcomes.iter().copied());
        }
    }

    pub fn n_requests(&self) -> usize {
        self.sketch.n_requests as usize
    }

    pub fn n_completed(&self) -> usize {
        (self.sketch.n_requests - self.sketch.n_failed) as usize
    }

    /// Serving horizon: first arrival to last completion.
    pub fn horizon(&self) -> f64 {
        // failed requests contribute no useful work, so their (possibly
        // very late) failure time must not stretch the horizon and
        // deflate every throughput/goodput rate computed over it
        // (the sketch tracks min-arrival over ALL outcomes and
        // max-finish over completed ones, matching the historical fold)
        (self.sketch.max_finish_s - self.sketch.min_arrival_s).max(1e-9)
    }

    /// Output-token throughput (tokens/s over the run horizon).
    pub fn output_throughput(&self) -> f64 {
        self.sketch.output_tokens as f64 / self.horizon()
    }

    /// Total-token (input+output) throughput.
    pub fn total_throughput(&self) -> f64 {
        (self.sketch.input_tokens + self.sketch.output_tokens) as f64 / self.horizon()
    }

    /// Completed requests per second.
    pub fn request_rate(&self) -> f64 {
        self.n_completed() as f64 / self.horizon()
    }

    /// Fraction of requests that met the SLO.  Exact over retained
    /// outcomes; a streaming report falls back to per-tier attainment
    /// against each request's OWN tier SLO (the argument is ignored —
    /// in the streaming world the tier target is the SLO).
    pub fn slo_attainment(&self, slo: &Slo) -> f64 {
        if self.retain {
            if self.outcomes.is_empty() {
                return 1.0;
            }
            return self.outcomes.iter().filter(|o| o.meets(slo)).count() as f64
                / self.outcomes.len() as f64;
        }
        if self.sketch.n_requests == 0 {
            return 1.0;
        }
        let good: u64 = self.sketch.tier_good.iter().sum();
        good as f64 / self.sketch.n_requests as f64
    }

    /// Goodput: SLO-meeting requests per second (DistServe's metric).
    /// Streaming fallback mirrors [`Self::slo_attainment`].
    pub fn goodput(&self, slo: &Slo) -> f64 {
        if self.retain {
            return self.outcomes.iter().filter(|o| o.meets(slo)).count() as f64 / self.horizon();
        }
        let good: u64 = self.sketch.tier_good.iter().sum();
        good as f64 / self.horizon()
    }

    /// Per-tier goodput rows (only tiers that saw traffic), from the
    /// exact at-record-time counters — identical in retaining and
    /// streaming modes.
    pub fn tier_goodput(&self) -> Vec<TierGoodput> {
        let horizon = self.horizon();
        (0..N_TIERS)
            .filter(|&t| self.sketch.tier_total[t] > 0)
            .map(|t| TierGoodput {
                tier: t as u8,
                total: self.sketch.tier_total[t],
                good: self.sketch.tier_good[t],
                attainment: self.sketch.tier_good[t] as f64 / self.sketch.tier_total[t] as f64,
                goodput_per_s: self.sketch.tier_good[t] as f64 / horizon,
            })
            .collect()
    }

    /// Total prompt tokens served from prefix caches across completed
    /// requests (the cluster hit-token rate numerator).
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.sketch.prefix_hit_tokens
    }

    pub fn ttft_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            s.add(o.ttft());
        }
        s
    }

    pub fn tpot_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed && o.output_tokens > 1) {
            s.add(o.tpot());
        }
        s
    }

    pub fn e2e_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            s.add(o.e2e());
        }
        s
    }

    /// Per-phase latency distributions over completed requests, in
    /// canonical order: `[queue, prefill, handoff, decode]`, each named.
    pub fn phase_summaries(&self) -> [(&'static str, Summary); 4] {
        let mut out = [
            ("queue", Summary::new()),
            ("prefill", Summary::new()),
            ("handoff", Summary::new()),
            ("decode", Summary::new()),
        ];
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            out[0].1.add(o.phases.queue_s);
            out[1].1.add(o.phases.prefill_s);
            out[2].1.add(o.phases.handoff_s);
            out[3].1.add(o.phases.decode_s);
        }
        out
    }

    /// Export request-level metrics into the unified registry under
    /// their stable names (DESIGN.md §Observability).  Entirely
    /// sketch-driven, so the export is O(buckets) regardless of request
    /// count and identical between retaining and streaming reports:
    /// the sketch histograms observed the same values in the same
    /// sequential order the old per-outcome loop replayed.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc("xllm_requests_total", self.n_requests() as u64);
        reg.inc("xllm_requests_completed_total", self.n_completed() as u64);
        reg.inc("xllm_requests_failed_total", (self.n_requests() - self.n_completed()) as u64);
        reg.merge_histogram("xllm_ttft_seconds", &self.sketch.ttft);
        reg.merge_histogram("xllm_e2e_seconds", &self.sketch.e2e);
        reg.merge_histogram("xllm_tpot_seconds", &self.sketch.tpot);
        reg.merge_histogram("xllm_phase_queue_seconds", &self.sketch.phases[0]);
        reg.merge_histogram("xllm_phase_prefill_seconds", &self.sketch.phases[1]);
        reg.merge_histogram("xllm_phase_handoff_seconds", &self.sketch.phases[2]);
        reg.merge_histogram("xllm_phase_decode_seconds", &self.sketch.phases[3]);
        reg.inc("xllm_tokens_input_total", self.sketch.input_tokens);
        reg.inc("xllm_tokens_output_total", self.sketch.output_tokens);
        reg.inc("xllm_tokens_prefix_hit_total", self.prefix_hit_tokens());
        reg.set_gauge("xllm_output_tokens_per_second", self.output_throughput());
        for tg in self.tier_goodput() {
            reg.inc(&format!("xllm_goodput_requests_total{{tier=\"{}\"}}", tg.tier), tg.good);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(arr: f64, ft: f64, fin: f64, inp: u64, out: u64) -> RequestOutcome {
        RequestOutcome {
            arrival_s: arr,
            first_token_s: ft,
            finish_s: fin,
            input_tokens: inp,
            output_tokens: out,
            failed: false,
            prefix_hit_tokens: 0,
            phases: PhaseBreakdown::default(),
            tier: 0,
        }
    }

    #[test]
    fn ttft_tpot_e2e() {
        let o = outcome(1.0, 1.5, 2.5, 100, 11);
        assert!((o.ttft() - 0.5).abs() < 1e-12);
        assert!((o.e2e() - 1.5).abs() < 1e-12);
        assert!((o.tpot() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn slo_meets() {
        let o = outcome(0.0, 0.4, 1.4, 10, 11);
        assert!(o.meets(&Slo::interactive(0.5, 0.11)));
        assert!(!o.meets(&Slo::interactive(0.3, 0.11)));
        assert!(!o.meets(&Slo::interactive(0.5, 0.09)));
        assert!(o.meets(&Slo::UNCONSTRAINED));
    }

    #[test]
    fn throughput_over_horizon() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 10, 50));
        assert!((r.output_throughput() - 50.0).abs() < 1e-9);
        assert!((r.request_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn goodput_counts_only_slo_met() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 2)); // tpot=0.9
        r.record(outcome(0.0, 0.1, 0.2, 10, 2)); // tpot=0.1
        let slo = Slo::tpot(0.5);
        assert!((r.slo_attainment(&slo) - 0.5).abs() < 1e-9);
        assert!((r.goodput(&slo) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_aggregates_outcomes() {
        let mut a = ServingReport::new();
        a.record(outcome(0.0, 0.1, 1.0, 10, 50));
        let mut b = ServingReport::new();
        b.record(outcome(1.0, 1.1, 2.0, 10, 50));
        b.record(outcome(1.0, 1.2, 3.0, 10, 50));
        a.merge(&b);
        assert_eq!(a.n_requests(), 3);
        assert_eq!(b.n_requests(), 2, "merge must not drain the source");
        // throughput spans the merged horizon (0.0 .. 3.0)
        assert!((a.output_throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn failed_requests_excluded_from_throughput() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        let mut bad = outcome(0.0, 0.1, 1.0, 10, 50);
        bad.failed = true;
        r.record(bad);
        assert!((r.output_throughput() - 50.0).abs() < 1e-9);
        assert_eq!(r.n_completed(), 1);
    }

    #[test]
    fn late_failure_does_not_deflate_throughput() {
        // regression: horizon() used to take max(finish_s) over ALL
        // outcomes, so one request failing long after the last real
        // completion stretched the horizon and sank every rate
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 10, 50));
        let before = r.output_throughput();
        let mut bad = outcome(0.5, 0.5, 100.0, 10, 0); // fails at t=100
        bad.failed = true;
        r.record(bad);
        assert!(
            (r.output_throughput() - before).abs() < 1e-12,
            "a late failure changed throughput: {} -> {}",
            before,
            r.output_throughput()
        );
        assert!((r.request_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn phase_summaries_cover_completed_only() {
        let mut r = ServingReport::new();
        let mut a = outcome(0.0, 0.5, 1.5, 10, 5);
        a.phases =
            PhaseBreakdown { queue_s: 0.1, prefill_s: 0.4, handoff_s: 0.0, decode_s: 1.0 };
        r.record(a);
        let mut bad = outcome(0.0, 0.1, 9.0, 10, 0);
        bad.failed = true;
        bad.phases.queue_s = 9.0;
        r.record(bad);
        let phases = r.phase_summaries();
        assert_eq!(phases[0].0, "queue");
        assert_eq!(phases[0].1.len(), 1, "failed request excluded");
        assert!((phases[0].1.mean() - 0.1).abs() < 1e-12);
        assert!((phases[3].1.mean() - 1.0).abs() < 1e-12);
        assert!((a.phases.total_s() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn export_metrics_reconciles_with_report() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 20, 30));
        let mut bad = outcome(0.0, 0.1, 1.0, 5, 0);
        bad.failed = true;
        r.record(bad);
        let mut reg = MetricsRegistry::new();
        r.export_metrics(&mut reg);
        assert_eq!(reg.counter("xllm_requests_total"), 3);
        assert_eq!(reg.counter("xllm_requests_completed_total"), 2);
        assert_eq!(reg.counter("xllm_requests_failed_total"), 1);
        assert_eq!(reg.counter("xllm_tokens_input_total"), 30);
        assert_eq!(reg.counter("xllm_tokens_output_total"), 80);
        assert_eq!(reg.histogram("xllm_ttft_seconds").unwrap().count, 2);
        assert_eq!(reg.histogram("xllm_phase_decode_seconds").unwrap().count, 2);
        assert_eq!(reg.counter("xllm_goodput_requests_total{tier=\"0\"}"), 2);
    }

    /// Bucket index of `v` in `bounds` (Inf slot = bounds.len()).
    fn bucket_of(bounds: &[f64], v: f64) -> usize {
        bounds.iter().position(|&b| v <= b).unwrap_or(bounds.len())
    }

    #[test]
    fn sketch_quantiles_land_within_one_bucket_of_exact() {
        let mut r = ServingReport::new();
        // spread of TTFTs across several latency buckets
        let ttfts = [0.003, 0.02, 0.04, 0.08, 0.15, 0.3, 0.7, 1.2, 2.5, 7.0];
        for (i, &ft) in ttfts.iter().enumerate() {
            r.record(outcome(0.0, ft, ft + 1.0, 10, 20 + i as u64));
        }
        for q in [50.0, 90.0, 99.0] {
            let exact = {
                let mut s = r.ttft_summary();
                s.percentile(q)
            };
            let approx = r.sketch.ttft_p(q);
            let (be, ba) =
                (bucket_of(LATENCY_BUCKETS_S, exact), bucket_of(LATENCY_BUCKETS_S, approx));
            assert!(
                (be as i64 - ba as i64).abs() <= 1,
                "p{q}: exact {exact} (bucket {be}) vs sketch {approx} (bucket {ba})"
            );
            assert!(approx >= exact, "upper-bound estimate must not undershoot");
        }
        // histogram sums are exact, so the sketch mean is the exact mean
        assert!((r.sketch.ttft_mean() - r.ttft_summary().mean()).abs() < 1e-12);
    }

    #[test]
    fn streaming_report_matches_retaining_aggregates_without_outcomes() {
        let mut exact = ServingReport::new();
        let mut stream = ServingReport::streaming();
        for i in 0..100u64 {
            let mut o = outcome(i as f64 * 0.1, i as f64 * 0.1 + 0.2, i as f64 * 0.1 + 1.0, 10, 20);
            o.tier = (i % 3) as u8;
            if i % 10 == 9 {
                o.failed = true;
            }
            exact.record(o);
            stream.record(o);
        }
        assert!(stream.outcomes.is_empty(), "streaming report must not retain outcomes");
        assert!(!stream.retains_outcomes());
        assert_eq!(stream.n_requests(), exact.n_requests());
        assert_eq!(stream.n_completed(), exact.n_completed());
        assert_eq!(stream.prefix_hit_tokens(), exact.prefix_hit_tokens());
        assert!((stream.output_throughput() - exact.output_throughput()).abs() < 1e-12);
        assert!((stream.request_rate() - exact.request_rate()).abs() < 1e-12);
        assert_eq!(stream.tier_goodput(), exact.tier_goodput());
        // merging streaming reports composes sketches exactly
        let mut merged = ServingReport::streaming();
        merged.merge(&stream);
        merged.merge(&ServingReport::streaming());
        assert_eq!(merged.n_requests(), stream.n_requests());
        assert_eq!(merged.sketch.ttft.count, stream.sketch.ttft.count);
        assert_eq!(merged.tier_goodput(), stream.tier_goodput());
    }

    #[test]
    fn tier_goodput_scores_each_tier_against_its_own_slo() {
        let mut r = ServingReport::new();
        // tier 0 (1.0s TTFT / 50ms TPOT): one hit, one TTFT miss
        let mut a = outcome(0.0, 0.5, 0.9, 10, 20);
        a.tier = 0;
        r.record(a);
        let mut b = outcome(0.0, 2.0, 2.4, 10, 20);
        b.tier = 0;
        r.record(b);
        // tier 2 (10s / 250ms): the same slow request is good
        let mut c = outcome(0.0, 2.0, 2.4, 10, 20);
        c.tier = 2;
        r.record(c);
        let rows = r.tier_goodput();
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].tier, rows[0].total, rows[0].good), (0, 2, 1));
        assert_eq!((rows[1].tier, rows[1].total, rows[1].good), (2, 1, 1));
        assert!((rows[0].attainment - 0.5).abs() < 1e-12);
        // tiers out of range clamp into the best-effort bucket
        let mut d = outcome(0.0, 0.1, 0.5, 10, 20);
        d.tier = 9;
        r.record(d);
        assert_eq!(r.sketch.tier_total[N_TIERS - 1], 2);
    }
}
