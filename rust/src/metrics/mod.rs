//! Serving metrics: TTFT / TPOT / E2E collection, SLO attainment, goodput.
//!
//! These are the quantities every paper table and figure reports: token
//! throughput under a TPOT (or E2E) constraint, request rate, SLO
//! attainment, and goodput (requests/s that met their SLO).

use crate::obs::{MetricsRegistry, LATENCY_BUCKETS_S, TPOT_BUCKETS_S};
use crate::util::Summary;

/// SLO targets for a request class (seconds). `f64::INFINITY` = unconstrained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Time to first token.
    pub ttft_s: f64,
    /// Time per output token (mean over the request).
    pub tpot_s: f64,
    /// End-to-end completion latency.
    pub e2e_s: f64,
}

impl Slo {
    pub const UNCONSTRAINED: Slo =
        Slo { ttft_s: f64::INFINITY, tpot_s: f64::INFINITY, e2e_s: f64::INFINITY };

    /// Paper main-results setting: TPOT bound only.
    pub fn tpot(tpot_s: f64) -> Slo {
        Slo { ttft_s: f64::INFINITY, tpot_s, e2e_s: f64::INFINITY }
    }

    /// Scenario setting: end-to-end bound only (merchant/customer-service).
    pub fn e2e(e2e_s: f64) -> Slo {
        Slo { ttft_s: f64::INFINITY, tpot_s: f64::INFINITY, e2e_s }
    }

    /// Interactive setting: TTFT + TPOT (the PD-disaggregation experiments).
    pub fn interactive(ttft_s: f64, tpot_s: f64) -> Slo {
        Slo { ttft_s, tpot_s, e2e_s: f64::INFINITY }
    }
}

/// Where one request's time went, in seconds (§3 phase attribution).
///
/// `queue_s` is the residual: everything not attributable to prefill,
/// handoff, or decode — dispatch wait, encode time, and fault-recovery
/// re-queueing all land there.  Components are clamped non-negative
/// (recovery recompute can restart prefill after the first token), so
/// the four fields sum to at most the E2E latency.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub queue_s: f64,
    pub prefill_s: f64,
    pub handoff_s: f64,
    pub decode_s: f64,
}

impl PhaseBreakdown {
    pub fn total_s(&self) -> f64 {
        self.queue_s + self.prefill_s + self.handoff_s + self.decode_s
    }
}

/// Completion record for one request.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    /// True if the request was dropped/failed rather than completed.
    pub failed: bool,
    /// Prompt tokens served from the prefix cache instead of being
    /// prefilled (token-exact under token-granular matching, block-
    /// rounded otherwise; 0 when the cache is off or missed).
    pub prefix_hit_tokens: u64,
    /// Per-phase latency attribution (queue/prefill/handoff/decode).
    pub phases: PhaseBreakdown,
}

impl RequestOutcome {
    pub fn ttft(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    pub fn e2e(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    /// Mean time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish_s - self.first_token_s) / (self.output_tokens - 1) as f64
    }

    pub fn meets(&self, slo: &Slo) -> bool {
        !self.failed
            && self.ttft() <= slo.ttft_s
            && self.tpot() <= slo.tpot_s
            && self.e2e() <= slo.e2e_s
    }
}

/// Aggregated serving metrics over a run.
#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub outcomes: Vec<RequestOutcome>,
}

impl ServingReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, o: RequestOutcome) {
        self.outcomes.push(o);
    }

    /// Fold another report's outcomes into this one (cluster-level
    /// aggregation: the control plane merges per-replica reports).
    pub fn merge(&mut self, other: &ServingReport) {
        self.outcomes.extend(other.outcomes.iter().copied());
    }

    pub fn n_requests(&self) -> usize {
        self.outcomes.len()
    }

    pub fn n_completed(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.failed).count()
    }

    fn horizon(&self) -> f64 {
        let start = self.outcomes.iter().map(|o| o.arrival_s).fold(f64::INFINITY, f64::min);
        // failed requests contribute no useful work, so their (possibly
        // very late) failure time must not stretch the horizon and
        // deflate every throughput/goodput rate computed over it
        let end = self
            .outcomes
            .iter()
            .filter(|o| !o.failed)
            .map(|o| o.finish_s)
            .fold(0.0, f64::max);
        (end - start).max(1e-9)
    }

    /// Output-token throughput (tokens/s over the run horizon).
    pub fn output_throughput(&self) -> f64 {
        let toks: u64 = self.outcomes.iter().filter(|o| !o.failed).map(|o| o.output_tokens).sum();
        toks as f64 / self.horizon()
    }

    /// Total-token (input+output) throughput.
    pub fn total_throughput(&self) -> f64 {
        let toks: u64 = self
            .outcomes
            .iter()
            .filter(|o| !o.failed)
            .map(|o| o.input_tokens + o.output_tokens)
            .sum();
        toks as f64 / self.horizon()
    }

    /// Completed requests per second.
    pub fn request_rate(&self) -> f64 {
        self.n_completed() as f64 / self.horizon()
    }

    /// Fraction of requests that met the SLO.
    pub fn slo_attainment(&self, slo: &Slo) -> f64 {
        if self.outcomes.is_empty() {
            return 1.0;
        }
        self.outcomes.iter().filter(|o| o.meets(slo)).count() as f64 / self.outcomes.len() as f64
    }

    /// Goodput: SLO-meeting requests per second (DistServe's metric).
    pub fn goodput(&self, slo: &Slo) -> f64 {
        self.outcomes.iter().filter(|o| o.meets(slo)).count() as f64 / self.horizon()
    }

    /// Total prompt tokens served from prefix caches across completed
    /// requests (the cluster hit-token rate numerator).
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.outcomes.iter().filter(|o| !o.failed).map(|o| o.prefix_hit_tokens).sum()
    }

    pub fn ttft_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            s.add(o.ttft());
        }
        s
    }

    pub fn tpot_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed && o.output_tokens > 1) {
            s.add(o.tpot());
        }
        s
    }

    pub fn e2e_summary(&self) -> Summary {
        let mut s = Summary::new();
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            s.add(o.e2e());
        }
        s
    }

    /// Per-phase latency distributions over completed requests, in
    /// canonical order: `[queue, prefill, handoff, decode]`, each named.
    pub fn phase_summaries(&self) -> [(&'static str, Summary); 4] {
        let mut out = [
            ("queue", Summary::new()),
            ("prefill", Summary::new()),
            ("handoff", Summary::new()),
            ("decode", Summary::new()),
        ];
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            out[0].1.add(o.phases.queue_s);
            out[1].1.add(o.phases.prefill_s);
            out[2].1.add(o.phases.handoff_s);
            out[3].1.add(o.phases.decode_s);
        }
        out
    }

    /// Export request-level metrics into the unified registry under
    /// their stable names (DESIGN.md §Observability).
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc("xllm_requests_total", self.n_requests() as u64);
        reg.inc("xllm_requests_completed_total", self.n_completed() as u64);
        reg.inc("xllm_requests_failed_total", (self.n_requests() - self.n_completed()) as u64);
        let (mut inp, mut out) = (0u64, 0u64);
        for o in self.outcomes.iter().filter(|o| !o.failed) {
            inp += o.input_tokens;
            out += o.output_tokens;
            reg.observe("xllm_ttft_seconds", LATENCY_BUCKETS_S, o.ttft());
            reg.observe("xllm_e2e_seconds", LATENCY_BUCKETS_S, o.e2e());
            if o.output_tokens > 1 {
                reg.observe("xllm_tpot_seconds", TPOT_BUCKETS_S, o.tpot());
            }
            reg.observe("xllm_phase_queue_seconds", LATENCY_BUCKETS_S, o.phases.queue_s);
            reg.observe("xllm_phase_prefill_seconds", LATENCY_BUCKETS_S, o.phases.prefill_s);
            reg.observe("xllm_phase_handoff_seconds", LATENCY_BUCKETS_S, o.phases.handoff_s);
            reg.observe("xllm_phase_decode_seconds", LATENCY_BUCKETS_S, o.phases.decode_s);
        }
        reg.inc("xllm_tokens_input_total", inp);
        reg.inc("xllm_tokens_output_total", out);
        reg.inc("xllm_tokens_prefix_hit_total", self.prefix_hit_tokens());
        reg.set_gauge("xllm_output_tokens_per_second", self.output_throughput());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(arr: f64, ft: f64, fin: f64, inp: u64, out: u64) -> RequestOutcome {
        RequestOutcome {
            arrival_s: arr,
            first_token_s: ft,
            finish_s: fin,
            input_tokens: inp,
            output_tokens: out,
            failed: false,
            prefix_hit_tokens: 0,
            phases: PhaseBreakdown::default(),
        }
    }

    #[test]
    fn ttft_tpot_e2e() {
        let o = outcome(1.0, 1.5, 2.5, 100, 11);
        assert!((o.ttft() - 0.5).abs() < 1e-12);
        assert!((o.e2e() - 1.5).abs() < 1e-12);
        assert!((o.tpot() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn slo_meets() {
        let o = outcome(0.0, 0.4, 1.4, 10, 11);
        assert!(o.meets(&Slo::interactive(0.5, 0.11)));
        assert!(!o.meets(&Slo::interactive(0.3, 0.11)));
        assert!(!o.meets(&Slo::interactive(0.5, 0.09)));
        assert!(o.meets(&Slo::UNCONSTRAINED));
    }

    #[test]
    fn throughput_over_horizon() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 10, 50));
        assert!((r.output_throughput() - 50.0).abs() < 1e-9);
        assert!((r.request_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn goodput_counts_only_slo_met() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 2)); // tpot=0.9
        r.record(outcome(0.0, 0.1, 0.2, 10, 2)); // tpot=0.1
        let slo = Slo::tpot(0.5);
        assert!((r.slo_attainment(&slo) - 0.5).abs() < 1e-9);
        assert!((r.goodput(&slo) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_aggregates_outcomes() {
        let mut a = ServingReport::new();
        a.record(outcome(0.0, 0.1, 1.0, 10, 50));
        let mut b = ServingReport::new();
        b.record(outcome(1.0, 1.1, 2.0, 10, 50));
        b.record(outcome(1.0, 1.2, 3.0, 10, 50));
        a.merge(&b);
        assert_eq!(a.n_requests(), 3);
        assert_eq!(b.n_requests(), 2, "merge must not drain the source");
        // throughput spans the merged horizon (0.0 .. 3.0)
        assert!((a.output_throughput() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn failed_requests_excluded_from_throughput() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        let mut bad = outcome(0.0, 0.1, 1.0, 10, 50);
        bad.failed = true;
        r.record(bad);
        assert!((r.output_throughput() - 50.0).abs() < 1e-9);
        assert_eq!(r.n_completed(), 1);
    }

    #[test]
    fn late_failure_does_not_deflate_throughput() {
        // regression: horizon() used to take max(finish_s) over ALL
        // outcomes, so one request failing long after the last real
        // completion stretched the horizon and sank every rate
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 10, 50));
        let before = r.output_throughput();
        let mut bad = outcome(0.5, 0.5, 100.0, 10, 0); // fails at t=100
        bad.failed = true;
        r.record(bad);
        assert!(
            (r.output_throughput() - before).abs() < 1e-12,
            "a late failure changed throughput: {} -> {}",
            before,
            r.output_throughput()
        );
        assert!((r.request_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn phase_summaries_cover_completed_only() {
        let mut r = ServingReport::new();
        let mut a = outcome(0.0, 0.5, 1.5, 10, 5);
        a.phases =
            PhaseBreakdown { queue_s: 0.1, prefill_s: 0.4, handoff_s: 0.0, decode_s: 1.0 };
        r.record(a);
        let mut bad = outcome(0.0, 0.1, 9.0, 10, 0);
        bad.failed = true;
        bad.phases.queue_s = 9.0;
        r.record(bad);
        let phases = r.phase_summaries();
        assert_eq!(phases[0].0, "queue");
        assert_eq!(phases[0].1.len(), 1, "failed request excluded");
        assert!((phases[0].1.mean() - 0.1).abs() < 1e-12);
        assert!((phases[3].1.mean() - 1.0).abs() < 1e-12);
        assert!((a.phases.total_s() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn export_metrics_reconciles_with_report() {
        let mut r = ServingReport::new();
        r.record(outcome(0.0, 0.1, 1.0, 10, 50));
        r.record(outcome(0.0, 0.2, 2.0, 20, 30));
        let mut bad = outcome(0.0, 0.1, 1.0, 5, 0);
        bad.failed = true;
        r.record(bad);
        let mut reg = MetricsRegistry::new();
        r.export_metrics(&mut reg);
        assert_eq!(reg.counter("xllm_requests_total"), 3);
        assert_eq!(reg.counter("xllm_requests_completed_total"), 2);
        assert_eq!(reg.counter("xllm_requests_failed_total"), 1);
        assert_eq!(reg.counter("xllm_tokens_input_total"), 30);
        assert_eq!(reg.counter("xllm_tokens_output_total"), 80);
        assert_eq!(reg.histogram("xllm_ttft_seconds").unwrap().count, 2);
        assert_eq!(reg.histogram("xllm_phase_decode_seconds").unwrap().count, 2);
    }
}
