//! Runtime: load AOT artifacts and execute them on the PJRT CPU client.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Graphs were lowered with `return_tuple=True`, so every output is a
//! tuple literal that we decompose host-side.
//!
//! The runtime is the only module touching the `xla` crate; everything
//! above it (coordinator, engine, service) works with plain host vectors.

pub mod graph;
pub mod manifest;
pub mod weights;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use graph::{select_mode, GraphCache, GraphStats, LaunchMode};
pub use manifest::{GraphInfo, GraphKind, Manifest};
pub use weights::WeightStore;

/// Dimensions of an AOT-compiled decoder model (from the manifest).
#[derive(Debug, Clone, Copy)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
}

/// Host-side batched KV cache in the decode layout [L, B, H, Smax, Dh].
///
/// This is the *logically contiguous* view the graphs consume; the xTensor
/// manager (engine::xtensor) owns which request occupies which batch slot
/// and which physical pages back it.
#[derive(Debug, Clone)]
pub struct BatchKv {
    pub dims: ModelDims,
    pub batch: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl BatchKv {
    pub fn zeros(dims: ModelDims, batch: usize) -> BatchKv {
        let n = dims.n_layers * batch * dims.n_heads * dims.max_seq * dims.d_head;
        BatchKv { dims, batch, k: vec![0.0; n], v: vec![0.0; n] }
    }

    fn slot_offset(&self, l: usize, b: usize, h: usize, s: usize) -> usize {
        let d = &self.dims;
        (((l * self.batch + b) * d.n_heads + h) * d.max_seq + s) * d.d_head
    }

    /// Copy a prefill KV ([L, H, S, Dh] over bucket length `s_bucket`,
    /// valid length `len`) into batch slot `slot`.
    pub fn write_prefill(&mut self, slot: usize, pk: &[f32], pv: &[f32], s_bucket: usize, len: usize) {
        let d = self.dims;
        assert!(slot < self.batch, "slot {slot} out of range");
        assert!(len <= d.max_seq);
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                for s in 0..len {
                    let src = ((l * d.n_heads + h) * s_bucket + s) * d.d_head;
                    let dst = self.slot_offset(l, slot, h, s);
                    self.k[dst..dst + d.d_head].copy_from_slice(&pk[src..src + d.d_head]);
                    self.v[dst..dst + d.d_head].copy_from_slice(&pv[src..src + d.d_head]);
                }
            }
        }
    }

    /// Overwrite tokens `[start, start+len)` of `slot` from flat
    /// `[L, H, len, Dh]` K/V buffers (KV-block import: a prefix block
    /// migrated from a peer replica lands over the recomputed region).
    pub fn write_range(&mut self, slot: usize, start: usize, len: usize, k: &[f32], v: &[f32]) {
        let d = self.dims;
        assert!(slot < self.batch, "slot {slot} out of range");
        assert!(start + len <= d.max_seq, "range {start}+{len} exceeds max_seq");
        let n = d.n_layers * d.n_heads * len * d.d_head;
        assert!(k.len() >= n && v.len() >= n, "short KV block buffer");
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                for s in 0..len {
                    let src = ((l * d.n_heads + h) * len + s) * d.d_head;
                    let dst = self.slot_offset(l, slot, h, start + s);
                    self.k[dst..dst + d.d_head].copy_from_slice(&k[src..src + d.d_head]);
                    self.v[dst..dst + d.d_head].copy_from_slice(&v[src..src + d.d_head]);
                }
            }
        }
    }

    /// Zero a slot (request completed; slot reusable).
    pub fn clear_slot(&mut self, slot: usize) {
        let d = self.dims;
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                let off = self.slot_offset(l, slot, h, 0);
                let n = d.max_seq * d.d_head;
                self.k[off..off + n].fill(0.0);
                self.v[off..off + n].fill(0.0);
            }
        }
    }

    /// Copy one slot's valid prefix (length `len`) from `other[src_slot]`.
    pub fn copy_slot_from(&mut self, slot: usize, other: &BatchKv, src_slot: usize, len: usize) {
        let d = self.dims;
        for l in 0..d.n_layers {
            for h in 0..d.n_heads {
                for s in 0..len.min(d.max_seq) {
                    let dst = self.slot_offset(l, slot, h, s);
                    let src = other.slot_offset(l, src_slot, h, s);
                    self.k[dst..dst + d.d_head].copy_from_slice(&other.k[src..src + d.d_head]);
                    self.v[dst..dst + d.d_head].copy_from_slice(&other.v[src..src + d.d_head]);
                }
            }
        }
    }
}

/// Output of a prefill execution.
pub struct PrefillOutput {
    /// Logits at the last *valid* position, length `vocab`.
    pub last_logits: Vec<f32>,
    /// Full prefill KV [L, H, S_bucket, Dh].
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub bucket_s: usize,
}

/// Output of a decode step.
pub struct DecodeOutput {
    /// Logits [B_bucket, vocab].
    pub logits: Vec<f32>,
    pub bucket_b: usize,
}

/// Output of a speculative-verify step.
pub struct VerifyOutput {
    /// Logits [B_bucket, M, vocab].
    pub logits: Vec<f32>,
    pub bucket_b: usize,
    pub m: usize,
}

/// The PJRT-backed inference runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    pub weights: WeightStore,
    dir: PathBuf,
    cache: GraphCache,
    /// Per-set weight literals, in HLO parameter order.
    weight_literals: HashMap<String, Vec<xla::Literal>>,
    /// Reusable input literals keyed by "graph/arg" (perf: the decode hot
    /// path refills these via copy_raw_from instead of allocating fresh
    /// literals each step — see EXPERIMENTS.md §Perf).
    scratch: HashMap<String, xla::Literal>,
}

fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal shape {:?} does not match data len {}", dims, data.len());
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

impl Runtime {
    /// Fetch (or create) a reusable f32 input literal and fill it.
    fn scratch_f32(&mut self, key: &str, data: &[f32], dims: &[usize]) -> Result<&xla::Literal> {
        if !self.scratch.contains_key(key) {
            self.scratch.insert(
                key.to_string(),
                xla::Literal::create_from_shape(xla::PrimitiveType::F32, dims),
            );
        }
        let lit = self.scratch.get_mut(key).unwrap();
        lit.copy_raw_from(data).map_err(|e| anyhow::anyhow!("scratch fill {key}: {e:?}"))?;
        Ok(self.scratch.get(key).unwrap())
    }

    /// Load artifacts from `dir` and create a PJRT CPU client.
    ///
    /// Compilation is lazy per graph (first use) through the graph cache;
    /// call [`Runtime::warmup`] to pre-compile everything.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let manifest = Manifest::load(dir)?;
        let weights = WeightStore::load(&dir.join(&manifest.weights_file))?;
        let mut weight_literals = HashMap::new();
        let mut sets: Vec<String> = manifest
            .graphs
            .iter()
            .map(|g| g.weights_set.clone())
            .collect();
        sets.sort();
        sets.dedup();
        for set in sets {
            let mut lits = Vec::new();
            for t in weights.set(&set) {
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                lits.push(lit_f32(&t.data, &dims)?);
            }
            if lits.is_empty() {
                bail!("weight set {set} referenced by manifest but absent in weights.bin");
            }
            weight_literals.insert(set, lits);
        }
        Ok(Runtime {
            client,
            manifest,
            weights,
            dir: dir.to_path_buf(),
            cache: GraphCache::new(32),
            weight_literals,
            scratch: HashMap::new(),
        })
    }

    /// Model dims for a weight set, from the manifest `model` record.
    pub fn model_dims(&self, set: &str) -> Result<ModelDims> {
        let m = self
            .manifest
            .model(set)
            .with_context(|| format!("no model record for {set}"))?;
        Ok(ModelDims {
            vocab: m.require("vocab")? as usize,
            d_model: m.require("d_model")? as usize,
            n_layers: m.require("n_layers")? as usize,
            n_heads: m.require("n_heads")? as usize,
            d_head: m.require("d_head")? as usize,
            max_seq: m.require("max_seq")? as usize,
        })
    }

    /// Pre-compile every graph in the manifest (dev warmup path).
    pub fn warmup(&mut self) -> Result<()> {
        let graphs: Vec<(String, String)> = self
            .manifest
            .graphs
            .iter()
            .map(|g| (g.name.clone(), g.file.clone()))
            .collect();
        for (name, file) in graphs {
            self.cache.get_or_compile(&self.client, &self.dir, &name, &file)?;
        }
        Ok(())
    }

    pub fn graph_stats(&self) -> GraphStats {
        self.cache.stats
    }

    /// Execute a graph by name with the given extra inputs (weights are
    /// prepended automatically) and return the decomposed output tuple.
    fn run(&mut self, graph_name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let g = self
            .manifest
            .graph(graph_name)
            .with_context(|| format!("unknown graph {graph_name}"))?
            .clone();
        let wl = self
            .weight_literals
            .get(&g.weights_set)
            .with_context(|| format!("no weights for set {}", g.weights_set))?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(wl.len() + inputs.len());
        args.extend(wl.iter());
        args.extend(inputs.iter());
        let exe = self.cache.get_or_compile(&self.client, &self.dir, &g.name, &g.file)?;
        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("executing {graph_name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {graph_name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {graph_name}: {e:?}"))
    }

    /// Prefill a prompt (auto bucket selection + padding).
    pub fn prefill(&mut self, set: &str, tokens: &[i32]) -> Result<PrefillOutput> {
        let dims = self.model_dims(set)?;
        let g = self
            .manifest
            .prefill_bucket(set, tokens.len() as u64)
            .with_context(|| format!("no prefill bucket fits {} tokens", tokens.len()))?
            .clone();
        let s = g.dim("s").unwrap() as usize;
        let mut padded = tokens.to_vec();
        padded.resize(s, 0);
        let out = self.run(&g.name, &[lit_i32(&padded, &[s as i64])?])?;
        let logits: Vec<f32> = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("prefill logits: {e:?}"))?;
        let k = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("prefill k: {e:?}"))?;
        let v = out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("prefill v: {e:?}"))?;
        let last = tokens.len() - 1;
        let last_logits = logits[last * dims.vocab..(last + 1) * dims.vocab].to_vec();
        Ok(PrefillOutput { last_logits, k, v, bucket_s: s })
    }

    /// One decode step over a batch cache.  `tokens`/`pos` are per active
    /// slot; inactive slots should carry pos=0/token=0 (their logits are
    /// ignored by the caller).  The cache is updated in place.
    pub fn decode(
        &mut self,
        set: &str,
        kv: &mut BatchKv,
        tokens: &[i32],
        pos: &[i32],
    ) -> Result<DecodeOutput> {
        let dims = kv.dims;
        let b = kv.batch;
        if tokens.len() != b || pos.len() != b {
            bail!("decode: tokens/pos length {} != batch {b}", tokens.len());
        }
        let g = self
            .manifest
            .decode_bucket(set, b as u64)
            .with_context(|| format!("no decode bucket fits batch {b}"))?
            .clone();
        let gb = g.dim("b").unwrap() as usize;
        if gb != b {
            bail!("decode: BatchKv batch {b} must equal a bucket size (have {gb})");
        }
        let cache_dims = [
            dims.n_layers,
            b,
            dims.n_heads,
            dims.max_seq,
            dims.d_head,
        ];
        // hot path: refill persistent scratch literals instead of
        // allocating fresh ones per step (§Perf)
        let gname = g.name.clone();
        self.scratch_f32(&format!("{gname}/k"), &kv.k, &cache_dims)?;
        self.scratch_f32(&format!("{gname}/v"), &kv.v, &cache_dims)?;
        let args = [
            lit_i32(tokens, &[b as i64])?,
            lit_i32(pos, &[b as i64])?,
        ];
        let out = {
            let wl = self
                .weight_literals
                .get(&g.weights_set)
                .with_context(|| format!("no weights for set {}", g.weights_set))?;
            let mut full: Vec<&xla::Literal> = Vec::with_capacity(wl.len() + 4);
            full.extend(wl.iter());
            full.push(&args[0]);
            full.push(&args[1]);
            full.push(self.scratch.get(&format!("{gname}/k")).unwrap());
            full.push(self.scratch.get(&format!("{gname}/v")).unwrap());
            let exe = self.cache.get_or_compile(&self.client, &self.dir, &g.name, &g.file)?;
            let result = exe
                .execute::<&xla::Literal>(&full)
                .map_err(|e| anyhow::anyhow!("executing {gname}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching result of {gname}: {e:?}"))?;
            lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {gname}: {e:?}"))?
        };
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("decode logits: {e:?}"))?;
        // copy outputs into the existing buffers (no per-step allocation)
        out[1]
            .copy_raw_to(&mut kv.k)
            .map_err(|e| anyhow::anyhow!("decode k out: {e:?}"))?;
        out[2]
            .copy_raw_to(&mut kv.v)
            .map_err(|e| anyhow::anyhow!("decode v out: {e:?}"))?;
        Ok(DecodeOutput { logits, bucket_b: b })
    }

    /// Speculative verify: score `m` candidate tokens per sequence.
    pub fn verify(
        &mut self,
        set: &str,
        kv: &mut BatchKv,
        tokens: &[i32], // [B * M]
        pos: &[i32],    // [B]
    ) -> Result<VerifyOutput> {
        let dims = kv.dims;
        let b = kv.batch;
        let g = self
            .manifest
            .verify_bucket(set, b as u64)
            .with_context(|| format!("no verify bucket fits batch {b}"))?
            .clone();
        let gb = g.dim("b").unwrap() as usize;
        let m = g.dim("m").unwrap() as usize;
        if gb != b {
            bail!("verify: BatchKv batch {b} must equal bucket {gb}");
        }
        if tokens.len() != b * m {
            bail!("verify: tokens len {} != b*m {}", tokens.len(), b * m);
        }
        let cache_dims = [
            dims.n_layers as i64,
            b as i64,
            dims.n_heads as i64,
            dims.max_seq as i64,
            dims.d_head as i64,
        ];
        let out = self.run(
            &g.name,
            &[
                lit_i32(tokens, &[b as i64, m as i64])?,
                lit_i32(pos, &[b as i64])?,
                lit_f32(&kv.k, &cache_dims)?,
                lit_f32(&kv.v, &cache_dims)?,
            ],
        )?;
        let logits = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("verify logits: {e:?}"))?;
        kv.k = out[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("verify k: {e:?}"))?;
        kv.v = out[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("verify v: {e:?}"))?;
        Ok(VerifyOutput { logits, bucket_b: b, m })
    }

    /// Run the vision encoder on one image's patch features.
    pub fn encode(&mut self, patches: &[f32]) -> Result<Vec<f32>> {
        let g = (*self
            .manifest
            .graphs_of(GraphKind::Encode, "enc")
            .first()
            .context("no encode graph")?)
        .clone();
        let np = g.dim("np").unwrap() as i64;
        let dp = g.dim("dp").unwrap() as i64;
        let out = self.run(&g.name, &[lit_f32(patches, &[np, dp])?])?;
        out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("encode out: {e:?}"))
    }

    /// Run the standalone MoE block (EPLB demo path).
    pub fn moe(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        let g = (*self
            .manifest
            .graphs_of(GraphKind::Moe, "moe")
            .first()
            .context("no moe graph")?)
        .clone();
        let t = g.dim("t").unwrap() as i64;
        let d = g.dim("d").unwrap() as i64;
        let out = self.run(&g.name, &[lit_f32(x, &[t, d])?])?;
        out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("moe out: {e:?}"))
    }
}

/// Greedy argmax over a logits row.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut bestv = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > bestv {
            bestv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { vocab: 256, d_model: 64, n_layers: 2, n_heads: 4, d_head: 16, max_seq: 8 }
    }

    #[test]
    fn batchkv_write_and_clear() {
        let d = dims();
        let mut kv = BatchKv::zeros(d, 2);
        let s_bucket = 4;
        let n = d.n_layers * d.n_heads * s_bucket * d.d_head;
        let pk: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let pv: Vec<f32> = (0..n).map(|i| (i * 2) as f32).collect();
        kv.write_prefill(1, &pk, &pv, s_bucket, 3);
        // slot 0 untouched
        assert!(kv.k.iter().take(d.n_heads * d.max_seq * d.d_head).all(|&x| x == 0.0));
        // spot check: l=0,h=0,s=0,d=5 of slot 1
        let off = kv.slot_offset(0, 1, 0, 0);
        assert_eq!(kv.k[off + 5], pk[5]);
        // position 3 (beyond len) must stay zero
        let off3 = kv.slot_offset(0, 1, 0, 3);
        assert!(kv.k[off3..off3 + d.d_head].iter().all(|&x| x == 0.0));
        kv.clear_slot(1);
        assert!(kv.k.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batchkv_copy_slot() {
        let d = dims();
        let mut a = BatchKv::zeros(d, 2);
        let s_bucket = 4;
        let n = d.n_layers * d.n_heads * s_bucket * d.d_head;
        let pk: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        a.write_prefill(0, &pk, &pk, s_bucket, 4);
        let mut b = BatchKv::zeros(d, 4);
        b.copy_slot_from(2, &a, 0, 4);
        let src = a.slot_offset(1, 0, 2, 3);
        let dst = b.slot_offset(1, 2, 2, 3);
        assert_eq!(a.k[src..src + d.d_head], b.k[dst..dst + d.d_head]);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
