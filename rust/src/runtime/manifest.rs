//! Parser for `artifacts/manifest.txt` (written by python/compile/aot.py).
//!
//! Line-oriented `kind key=value ...` records:
//!
//! ```text
//! model  name=tiny vocab=256 d_model=64 n_layers=2 ...
//! weights file=weights.bin n_tensors=27
//! graph  name=decode_b4 file=decode_b4.hlo.txt weights=tiny kind=decode b=4 smax=160
//! ```

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One `model` record (dims of an AOT-compiled model).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub fields: HashMap<String, u64>,
}

impl ModelInfo {
    pub fn get(&self, key: &str) -> Option<u64> {
        self.fields.get(key).copied()
    }

    pub fn require(&self, key: &str) -> Result<u64> {
        self.get(key).with_context(|| format!("model {} missing field {key}", self.name))
    }
}

/// Graph kinds the runtime understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GraphKind {
    Prefill,
    Decode,
    Verify,
    Encode,
    Moe,
}

impl GraphKind {
    fn parse(s: &str) -> Result<GraphKind> {
        Ok(match s {
            "prefill" => GraphKind::Prefill,
            "decode" => GraphKind::Decode,
            "verify" => GraphKind::Verify,
            "encode" => GraphKind::Encode,
            "moe" => GraphKind::Moe,
            other => bail!("unknown graph kind {other}"),
        })
    }
}

/// One `graph` record (an AOT-lowered HLO module).
#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub name: String,
    pub file: String,
    pub weights_set: String,
    pub kind: GraphKind,
    pub dims: HashMap<String, u64>,
}

impl GraphInfo {
    pub fn dim(&self, key: &str) -> Option<u64> {
        self.dims.get(key).copied()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub models: Vec<ModelInfo>,
    pub graphs: Vec<GraphInfo>,
    pub weights_file: String,
    pub n_tensors: u64,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts.next().unwrap();
            let kv: HashMap<&str, &str> = parts
                .map(|p| {
                    p.split_once('=')
                        .with_context(|| format!("line {}: bad token {p}", lineno + 1))
                })
                .collect::<Result<_>>()?;
            match kind {
                "model" => {
                    let name = kv.get("name").context("model without name")?.to_string();
                    let fields = kv
                        .iter()
                        .filter(|(k, _)| **k != "name")
                        .filter_map(|(k, v)| v.parse().ok().map(|n| (k.to_string(), n)))
                        .collect();
                    m.models.push(ModelInfo { name, fields });
                }
                "weights" => {
                    m.weights_file = kv.get("file").context("weights without file")?.to_string();
                    m.n_tensors =
                        kv.get("n_tensors").and_then(|v| v.parse().ok()).unwrap_or(0);
                }
                "graph" => {
                    let name = kv.get("name").context("graph without name")?.to_string();
                    let file = kv.get("file").context("graph without file")?.to_string();
                    let weights_set =
                        kv.get("weights").context("graph without weights")?.to_string();
                    let gkind = GraphKind::parse(kv.get("kind").context("graph without kind")?)?;
                    let dims = kv
                        .iter()
                        .filter(|(k, _)| !matches!(**k, "name" | "file" | "weights" | "kind"))
                        .filter_map(|(k, v)| v.parse().ok().map(|n| (k.to_string(), n)))
                        .collect();
                    m.graphs.push(GraphInfo { name, file, weights_set, kind: gkind, dims });
                }
                other => bail!("line {}: unknown record kind {other}", lineno + 1),
            }
        }
        Ok(m)
    }

    pub fn model(&self, name: &str) -> Option<&ModelInfo> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn graph(&self, name: &str) -> Option<&GraphInfo> {
        self.graphs.iter().find(|g| g.name == name)
    }

    /// Graphs of a kind for a weight set, e.g. all decode buckets.
    pub fn graphs_of(&self, kind: GraphKind, weights_set: &str) -> Vec<&GraphInfo> {
        self.graphs
            .iter()
            .filter(|g| g.kind == kind && g.weights_set == weights_set)
            .collect()
    }

    /// Smallest prefill bucket with s >= `len`, for a weight set.
    pub fn prefill_bucket(&self, weights_set: &str, len: u64) -> Option<&GraphInfo> {
        self.graphs_of(GraphKind::Prefill, weights_set)
            .into_iter()
            .filter(|g| g.dim("s").unwrap_or(0) >= len)
            .min_by_key(|g| g.dim("s").unwrap_or(u64::MAX))
    }

    /// Smallest decode bucket with b >= `batch`.
    pub fn decode_bucket(&self, weights_set: &str, batch: u64) -> Option<&GraphInfo> {
        self.graphs_of(GraphKind::Decode, weights_set)
            .into_iter()
            .filter(|g| g.dim("b").unwrap_or(0) >= batch)
            .min_by_key(|g| g.dim("b").unwrap_or(u64::MAX))
    }

    /// Smallest verify bucket with b >= `batch` (m fixed by AOT).
    pub fn verify_bucket(&self, weights_set: &str, batch: u64) -> Option<&GraphInfo> {
        self.graphs_of(GraphKind::Verify, weights_set)
            .into_iter()
            .filter(|g| g.dim("b").unwrap_or(0) >= batch)
            .min_by_key(|g| g.dim("b").unwrap_or(u64::MAX))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model name=tiny vocab=256 d_model=64 n_layers=2 n_heads=4 d_head=16 d_ff=256 max_seq=160 n_params=130624
weights file=weights.bin n_tensors=27
graph name=prefill_s16 file=prefill_s16.hlo.txt weights=tiny kind=prefill s=16
graph name=prefill_s64 file=prefill_s64.hlo.txt weights=tiny kind=prefill s=64
graph name=decode_b1 file=decode_b1.hlo.txt weights=tiny kind=decode b=1 smax=160
graph name=decode_b4 file=decode_b4.hlo.txt weights=tiny kind=decode b=4 smax=160
graph name=verify_b1_m4 file=verify_b1_m4.hlo.txt weights=tiny kind=verify b=1 m=4 smax=160
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models.len(), 1);
        assert_eq!(m.graphs.len(), 5);
        assert_eq!(m.weights_file, "weights.bin");
        assert_eq!(m.n_tensors, 27);
        assert_eq!(m.model("tiny").unwrap().require("max_seq").unwrap(), 160);
    }

    #[test]
    fn bucket_selection_picks_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.prefill_bucket("tiny", 10).unwrap().name, "prefill_s16");
        assert_eq!(m.prefill_bucket("tiny", 16).unwrap().name, "prefill_s16");
        assert_eq!(m.prefill_bucket("tiny", 17).unwrap().name, "prefill_s64");
        assert!(m.prefill_bucket("tiny", 65).is_none());
        assert_eq!(m.decode_bucket("tiny", 3).unwrap().name, "decode_b4");
        assert_eq!(m.verify_bucket("tiny", 1).unwrap().dim("m"), Some(4));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus name=x").is_err());
        assert!(Manifest::parse("graph name=a").is_err());
    }
}
