//! Adaptive Graph Mode (paper §4.2): multi-graph caching over shape buckets.
//!
//! The paper's ACLGraph-based design pre-compiles kernel sequences into
//! replayable graphs, parameterizes dynamic dims, and keeps a small cache
//! of compiled graphs (M compiled graphs << N requests, Table 1).  On this
//! testbed every AOT bucket in `artifacts/` *is* one such pre-compiled
//! graph (one PJRT executable per (kind, shape-bucket)); this module is
//! the cache + the launch-mode selection policy:
//!
//! * exact bucket hit           -> `FullGraph` (single launch)
//! * padded bucket hit          -> `PartialGraph` (single launch + padding
//!   waste, the analog of parameterized dims re-used across shapes)
//! * no bucket (shape too big)  -> `Eager` fallback (the caller splits the
//!   work, e.g. chunked prefill)
//!
//! An LRU cap bounds resident graphs (the paper's "manageable number of
//! pre-compilations"); evictions force re-compilation on next use.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

/// How a step was (or would be) launched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchMode {
    /// Exact-shape pre-compiled graph: one launch.
    FullGraph,
    /// Bucketed (padded) pre-compiled graph: one launch, some padded work.
    PartialGraph { padded_from: u64, bucket: u64 },
    /// No graph fits: per-op dispatch (caller must split / fall back).
    Eager,
}

/// Select the launch mode for a requested dynamic dim against the sorted
/// list of available bucket sizes.
pub fn select_mode(requested: u64, buckets: &[u64]) -> LaunchMode {
    let mut best: Option<u64> = None;
    for &b in buckets {
        if b >= requested && best.map(|x| b < x).unwrap_or(true) {
            best = Some(b);
        }
    }
    match best {
        Some(b) if b == requested => LaunchMode::FullGraph,
        Some(b) => LaunchMode::PartialGraph { padded_from: requested, bucket: b },
        None => LaunchMode::Eager,
    }
}

/// Cache statistics (reported by `bench table8` and the server).
#[derive(Debug, Clone, Copy, Default)]
pub struct GraphStats {
    pub compiles: u64,
    pub hits: u64,
    pub evictions: u64,
    pub launches: u64,
    pub compile_time_s: f64,
}

struct CachedGraph {
    exe: xla::PjRtLoadedExecutable,
    last_used: u64,
}

/// LRU cache of compiled PJRT executables keyed by graph name.
pub struct GraphCache {
    entries: HashMap<String, CachedGraph>,
    tick: u64,
    max_graphs: usize,
    pub stats: GraphStats,
}

impl GraphCache {
    /// `max_graphs` caps resident compiled graphs (LRU beyond that).
    pub fn new(max_graphs: usize) -> Self {
        GraphCache { entries: HashMap::new(), tick: 0, max_graphs: max_graphs.max(1), stats: GraphStats::default() }
    }

    /// Fetch a compiled executable, compiling `<dir>/<file>` on miss.
    pub fn get_or_compile(
        &mut self,
        client: &xla::PjRtClient,
        dir: &Path,
        name: &str,
        file: &str,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        self.tick += 1;
        let tick = self.tick;
        if self.entries.contains_key(name) {
            self.stats.hits += 1;
            self.stats.launches += 1;
            let e = self.entries.get_mut(name).unwrap();
            e.last_used = tick;
            return Ok(&e.exe);
        }
        // evict LRU if at cap
        if self.entries.len() >= self.max_graphs {
            if let Some(victim) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        let t0 = Instant::now();
        let path = dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("loading HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.launches += 1;
        self.stats.compile_time_s += t0.elapsed().as_secs_f64();
        self.entries.insert(name.to_string(), CachedGraph { exe, last_used: tick });
        Ok(&self.entries[name].exe)
    }

    pub fn resident(&self) -> usize {
        self.entries.len()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_exact_padded_eager() {
        let buckets = [16u64, 32, 64, 128];
        assert_eq!(select_mode(32, &buckets), LaunchMode::FullGraph);
        assert_eq!(
            select_mode(33, &buckets),
            LaunchMode::PartialGraph { padded_from: 33, bucket: 64 }
        );
        assert_eq!(select_mode(129, &buckets), LaunchMode::Eager);
        assert_eq!(select_mode(1, &buckets), LaunchMode::PartialGraph { padded_from: 1, bucket: 16 });
    }

    #[test]
    fn select_smallest_fitting_bucket() {
        crate::testutil::quickcheck("bucket-min-fit", |rng| {
            let mut buckets: Vec<u64> = (0..5).map(|_| rng.range(1, 256)).collect();
            buckets.sort();
            buckets.dedup();
            let req = rng.range(1, 300);
            match select_mode(req, &buckets) {
                LaunchMode::FullGraph => {
                    crate::prop_assert!(buckets.contains(&req));
                }
                LaunchMode::PartialGraph { padded_from, bucket } => {
                    crate::prop_assert!(padded_from == req);
                    crate::prop_assert!(bucket >= req);
                    crate::prop_assert!(
                        buckets.iter().all(|&b| b < req || b >= bucket),
                        "not the smallest fit"
                    );
                }
                LaunchMode::Eager => {
                    crate::prop_assert!(buckets.iter().all(|&b| b < req));
                }
            }
            Ok(())
        });
    }
}
