//! Parser for `artifacts/weights.bin` (written by python/compile/aot.py).
//!
//! Format (little-endian):
//! ```text
//! magic   b"XLLMW001"
//! u32     n_tensors
//! per tensor:
//!   u32   name_len;  name bytes (e.g. "tiny/embed")
//!   u32   ndim;  u32 dims[ndim]
//!   f32   data[prod(dims)]
//! ```
//! Tensor order within a weight-set prefix (e.g. `tiny/`) is the HLO
//! parameter order of every graph compiled against that set.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One weight tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// All weight tensors, in file order.
#[derive(Debug, Clone, Default)]
pub struct WeightStore {
    pub tensors: Vec<Tensor>,
}

impl WeightStore {
    /// Load `<path>` and validate framing.
    pub fn load(path: &Path) -> Result<WeightStore> {
        let data = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        WeightStore::parse(&data)
    }

    pub fn parse(data: &[u8]) -> Result<WeightStore> {
        if data.len() < 12 || &data[..8] != b"XLLMW001" {
            bail!("weights.bin: bad magic");
        }
        let mut off = 8usize;
        let n = read_u32(data, &mut off)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for i in 0..n {
            let name_len = read_u32(data, &mut off)? as usize;
            if off + name_len > data.len() {
                bail!("weights.bin: tensor {i} name overruns file");
            }
            let name = std::str::from_utf8(&data[off..off + name_len])
                .context("tensor name not utf-8")?
                .to_string();
            off += name_len;
            let ndim = read_u32(data, &mut off)? as usize;
            if ndim > 8 {
                bail!("weights.bin: tensor {name} has implausible ndim {ndim}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(data, &mut off)? as usize);
            }
            let count: usize = dims.iter().product();
            let bytes = count * 4;
            if off + bytes > data.len() {
                bail!("weights.bin: tensor {name} data overruns file");
            }
            let mut vals = vec![0f32; count];
            for (j, v) in vals.iter_mut().enumerate() {
                let b = &data[off + j * 4..off + j * 4 + 4];
                *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            off += bytes;
            tensors.push(Tensor { name, dims, data: vals });
        }
        if off != data.len() {
            bail!("weights.bin: {} trailing bytes", data.len() - off);
        }
        Ok(WeightStore { tensors })
    }

    /// Tensors of a weight set (prefix before '/'), in file order.
    pub fn set(&self, set_name: &str) -> Vec<&Tensor> {
        let prefix = format!("{set_name}/");
        self.tensors.iter().filter(|t| t.name.starts_with(&prefix)).collect()
    }

    pub fn get(&self, full_name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == full_name)
    }

    /// Total parameter count of a set.
    pub fn param_count(&self, set_name: &str) -> usize {
        self.set(set_name).iter().map(|t| t.element_count()).sum()
    }
}

fn read_u32(data: &[u8], off: &mut usize) -> Result<u32> {
    if *off + 4 > data.len() {
        bail!("weights.bin: truncated at offset {off}");
    }
    let v = u32::from_le_bytes([data[*off], data[*off + 1], data[*off + 2], data[*off + 3]]);
    *off += 4;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"XLLMW001");
        out.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a/x": dims [2,3]
        out.extend_from_slice(&3u32.to_le_bytes());
        out.extend_from_slice(b"a/x");
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&3u32.to_le_bytes());
        for i in 0..6 {
            out.extend_from_slice(&(i as f32).to_le_bytes());
        }
        // tensor "b/y": dims [4]
        out.extend_from_slice(&3u32.to_le_bytes());
        out.extend_from_slice(b"b/y");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&4u32.to_le_bytes());
        for i in 0..4 {
            out.extend_from_slice(&(10.0 + i as f32).to_le_bytes());
        }
        out
    }

    #[test]
    fn parses_and_indexes() {
        let ws = WeightStore::parse(&sample()).unwrap();
        assert_eq!(ws.tensors.len(), 2);
        let a = ws.get("a/x").unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(a.data[5], 5.0);
        assert_eq!(ws.set("b").len(), 1);
        assert_eq!(ws.param_count("a"), 6);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(WeightStore::parse(b"NOTMAGIC").is_err());
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let mut s = sample();
        s.truncate(s.len() - 2);
        assert!(WeightStore::parse(&s).is_err());
        let mut s2 = sample();
        s2.extend_from_slice(&[0, 0]);
        assert!(WeightStore::parse(&s2).is_err());
    }
}
