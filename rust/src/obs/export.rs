//! Exporters: Chrome trace-event JSON (Perfetto-loadable) and
//! Prometheus text-format exposition.
//!
//! # Chrome trace layout
//!
//! One *process* (pid) per fleet replica (`pid = replica + 1`; pid 0 is
//! the control plane / standalone run), one *thread* (tid) per instance
//! within the replica (`tid = instance + 1`; tid 0 carries
//! replica-level events).  Lifecycle spans become `"X"` complete events
//! (internal Begin/End pairs are matched per `(replica, request,
//! phase)` in emission order — an `X` needs no cross-track pairing, so
//! a span that *ends* on a different instance than it began still
//! renders), instants become `"i"` events, and metadata events name
//! every process/thread.  Timestamps are virtual-clock microseconds.
//!
//! Event order in the output is deterministic regardless of how a
//! threaded fleet interleaved its emissions: events are sorted by
//! `(time, pid, tid, request, kind)` before serialization.

use crate::obs::metrics::MetricsRegistry;
use crate::obs::trace::{SpanPhase, TraceEvent, TraceEventKind};
use crate::util::json::Json;
use std::collections::BTreeMap;

fn pid(ev: &TraceEvent) -> usize {
    ev.replica.map_or(0, |r| r + 1)
}

fn tid(ev: &TraceEvent) -> usize {
    ev.instance.map_or(0, |i| i + 1)
}

fn kind_rank(k: &TraceEventKind) -> (u8, u8) {
    match k {
        TraceEventKind::Begin(p) => (0, *p as u8),
        TraceEventKind::Complete(p, _) => (1, *p as u8),
        TraceEventKind::Instant(i) => (2, *i as u8),
        TraceEventKind::End(p) => (3, *p as u8),
    }
}

/// Render a recorded event stream as Chrome trace-event JSON.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut evs: Vec<&TraceEvent> = events.iter().collect();
    evs.sort_by(|a, b| {
        a.t_s
            .total_cmp(&b.t_s)
            .then_with(|| pid(a).cmp(&pid(b)))
            .then_with(|| tid(a).cmp(&tid(b)))
            .then_with(|| a.req.cmp(&b.req))
            .then_with(|| kind_rank(&a.kind).cmp(&kind_rank(&b.kind)))
            .then_with(|| a.seq.cmp(&b.seq))
    });

    // match Begin/End pairs into X events per (replica, req, phase) —
    // pairing follows each replica's emission order (sink seq), which a
    // shared threaded sink preserves per replica
    let mut spans: Vec<(usize, usize, Option<u64>, SpanPhase, f64, f64)> = Vec::new();
    let mut open: BTreeMap<(usize, u64, u8), (f64, usize, usize, SpanPhase)> = BTreeMap::new();
    let mut pairing: Vec<&TraceEvent> = events.iter().collect();
    pairing.sort_by_key(|e| (pid(e), e.seq));
    let mut t_max = 0.0f64;
    for ev in &pairing {
        t_max = t_max.max(ev.t_s);
        let key = |p: &SpanPhase| (pid(ev), ev.req.unwrap_or(u64::MAX), *p as u8);
        match &ev.kind {
            TraceEventKind::Begin(p) => {
                open.insert(key(p), (ev.t_s, pid(ev), tid(ev), *p));
            }
            TraceEventKind::End(p) => {
                if let Some((t0, epid, etid, phase)) = open.remove(&key(p)) {
                    spans.push((epid, etid, ev.req, phase, t0, ev.t_s - t0));
                }
            }
            TraceEventKind::Complete(p, d) => {
                spans.push((pid(ev), tid(ev), ev.req, *p, ev.t_s, *d));
                t_max = t_max.max(ev.t_s + d);
            }
            TraceEventKind::Instant(_) => {}
        }
    }
    // unclosed spans (truncated run): extend to the last event time
    for ((_, rq, _), (t0, epid, etid, phase)) in open {
        let req = if rq == u64::MAX { None } else { Some(rq) };
        spans.push((epid, etid, req, phase, t0, (t_max - t0).max(0.0)));
    }
    spans.sort_by(|a, b| {
        a.4.total_cmp(&b.4)
            .then_with(|| a.0.cmp(&b.0))
            .then_with(|| a.1.cmp(&b.1))
            .then_with(|| a.2.cmp(&b.2))
            .then_with(|| (a.3 as u8).cmp(&(b.3 as u8)))
    });

    let us = |t: f64| (t * 1e6).round();
    let mut arr = Json::arr();

    // metadata: name every (pid) process and (pid, tid) thread seen
    let mut pids: Vec<usize> = Vec::new();
    let mut tids: Vec<(usize, usize)> = Vec::new();
    for ev in &evs {
        if !pids.contains(&pid(ev)) {
            pids.push(pid(ev));
        }
        if !tids.contains(&(pid(ev), tid(ev))) {
            tids.push((pid(ev), tid(ev)));
        }
    }
    pids.sort_unstable();
    tids.sort_unstable();
    for p in pids {
        let name =
            if p == 0 { "control-plane".to_string() } else { format!("replica {}", p - 1) };
        arr = arr.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "process_name")
                .set("pid", p)
                .set("tid", 0u64)
                .set("args", Json::obj().set("name", name)),
        );
    }
    for (p, t) in tids {
        let name = if t == 0 { "events".to_string() } else { format!("instance {}", t - 1) };
        arr = arr.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "thread_name")
                .set("pid", p)
                .set("tid", t)
                .set("args", Json::obj().set("name", name)),
        );
    }

    for (epid, etid, req, phase, t0, dur) in spans {
        let mut args = Json::obj();
        if let Some(r) = req {
            args = args.set("req", r);
        }
        arr = arr.push(
            Json::obj()
                .set("ph", "X")
                .set("name", phase.name())
                .set("cat", "lifecycle")
                .set("pid", epid)
                .set("tid", etid)
                .set("ts", us(t0))
                .set("dur", us(t0 + dur) - us(t0))
                .set("args", args),
        );
    }
    for ev in &evs {
        if let TraceEventKind::Instant(k) = ev.kind {
            let mut args = Json::obj();
            if let Some(r) = ev.req {
                args = args.set("req", r);
            }
            arr = arr.push(
                Json::obj()
                    .set("ph", "i")
                    .set("name", k.name())
                    .set("cat", "lifecycle")
                    .set("s", "t")
                    .set("pid", pid(ev))
                    .set("tid", tid(ev))
                    .set("ts", us(ev.t_s))
                    .set("args", args),
            );
        }
    }

    Json::obj()
        .set("traceEvents", arr)
        .set("displayTimeUnit", "ms")
        .to_string()
}

fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Metric family for the `# TYPE` line: registry names may carry an
/// inline label set (`name{label="v"}`), which belongs on the sample
/// line but not the type declaration.
fn family(name: &str) -> &str {
    name.split('{').next().unwrap_or(name)
}

/// Render the registry as Prometheus text exposition format.
pub fn prometheus_text(reg: &MetricsRegistry) -> String {
    let mut out = String::new();
    let mut typed: Vec<String> = Vec::new();
    let mut type_line = |out: &mut String, name: &str, kind: &str| {
        let fam = family(name);
        if !typed.iter().any(|t| t == fam) {
            typed.push(fam.to_string());
            out.push_str(&format!("# TYPE {fam} {kind}\n"));
        }
    };
    for (name, v) in reg.counters() {
        type_line(&mut out, name, "counter");
        out.push_str(&format!("{name} {v}\n"));
    }
    for (name, v) in reg.gauges() {
        type_line(&mut out, name, "gauge");
        out.push_str(&format!("{name} {}\n", fmt_f64(v)));
    }
    for (name, h) in reg.histograms() {
        type_line(&mut out, name, "histogram");
        for (i, b) in h.bounds.iter().enumerate() {
            out.push_str(&format!(
                "{name}_bucket{{le=\"{}\"}} {}\n",
                fmt_f64(*b),
                h.cumulative(i)
            ));
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", fmt_f64(h.sum)));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::LATENCY_BUCKETS_S;
    use crate::obs::trace::{InstantKind, TraceHandle};

    #[test]
    fn chrome_trace_pairs_spans_and_names_tracks() {
        let h = TraceHandle::recording();
        let r0 = h.for_replica(0);
        r0.instant(0.0, Some(0), Some(1), InstantKind::Arrival);
        r0.begin(0.0, Some(0), Some(1), SpanPhase::Queue);
        r0.end(0.25, Some(0), Some(1), SpanPhase::Queue);
        r0.begin(0.25, Some(0), Some(1), SpanPhase::Prefill);
        r0.end(0.75, Some(1), Some(1), SpanPhase::Prefill); // ends elsewhere
        r0.complete(0.75, Some(1), Some(1), SpanPhase::KvHandoff, 0.05);
        h.instant(1.0, None, None, InstantKind::ScaleUp);
        let json = chrome_trace_json(&h.drain());
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"replica 0\""));
        assert!(json.contains("\"control-plane\""));
        assert!(json.contains("\"instance 0\""));
        // the prefill Begin/End pair becomes one X of 500ms on pid 1
        assert!(json.contains("\"ph\":\"X\",\"name\":\"prefill\""));
        assert!(json.contains("\"dur\":500000"));
        assert!(json.contains("\"ph\":\"i\",\"name\":\"scale_up\""));
        assert!(json.contains("\"ph\":\"X\",\"name\":\"kv_handoff\""));
    }

    #[test]
    fn chrome_trace_is_deterministic_under_interleaving() {
        let build = |flip: bool| {
            let h = TraceHandle::recording();
            let (a, b) = (h.for_replica(0), h.for_replica(1));
            let emit_a = || {
                a.begin(0.1, Some(0), Some(1), SpanPhase::Prefill);
                a.end(0.2, Some(0), Some(1), SpanPhase::Prefill);
            };
            let emit_b = || {
                b.begin(0.1, Some(0), Some(5), SpanPhase::Decode);
                b.end(0.3, Some(0), Some(5), SpanPhase::Decode);
            };
            if flip {
                emit_b();
                emit_a();
            } else {
                emit_a();
                emit_b();
            }
            chrome_trace_json(&h.drain())
        };
        assert_eq!(build(false), build(true), "sink interleaving must not change the export");
    }

    #[test]
    fn prometheus_text_format() {
        let mut reg = MetricsRegistry::new();
        reg.inc("xllm_requests_total", 42);
        reg.set_gauge("xllm_replicas_final", 3.0);
        reg.observe("xllm_ttft_seconds", LATENCY_BUCKETS_S, 0.2);
        reg.observe("xllm_ttft_seconds", LATENCY_BUCKETS_S, 99.0);
        let text = prometheus_text(&reg);
        assert!(text.contains("# TYPE xllm_requests_total counter\nxllm_requests_total 42\n"));
        assert!(text.contains("# TYPE xllm_replicas_final gauge\nxllm_replicas_final 3\n"));
        assert!(text.contains("# TYPE xllm_ttft_seconds histogram\n"));
        assert!(text.contains("xllm_ttft_seconds_bucket{le=\"0.25\"} 1\n"));
        assert!(text.contains("xllm_ttft_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("xllm_ttft_seconds_count 2\n"));
        // every line is either a comment or `name[{labels}] value`
        for line in text.lines() {
            assert!(
                line.starts_with("# ") || line.split(' ').count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }

    #[test]
    fn labeled_counters_share_one_type_line() {
        // per-tier goodput counters carry an inline label set; the
        // exposition must declare the family ONCE and keep the labels on
        // the sample lines (duplicate TYPE lines are a scrape error)
        let mut reg = MetricsRegistry::new();
        reg.inc("xllm_goodput_requests_total{tier=\"0\"}", 10);
        reg.inc("xllm_goodput_requests_total{tier=\"1\"}", 7);
        reg.inc("xllm_goodput_requests_total{tier=\"2\"}", 3);
        reg.inc("xllm_slo_violations_predicted_total", 2);
        let text = prometheus_text(&reg);
        let type_lines = text
            .lines()
            .filter(|l| l.starts_with("# TYPE xllm_goodput_requests_total "))
            .count();
        assert_eq!(type_lines, 1, "one TYPE line per family, not per label set:\n{text}");
        assert!(text.contains("# TYPE xllm_goodput_requests_total counter\n"));
        assert!(text.contains("xllm_goodput_requests_total{tier=\"0\"} 10\n"));
        assert!(text.contains("xllm_goodput_requests_total{tier=\"1\"} 7\n"));
        assert!(text.contains("xllm_goodput_requests_total{tier=\"2\"} 3\n"));
        assert!(text.contains(
            "# TYPE xllm_slo_violations_predicted_total counter\n\
             xllm_slo_violations_predicted_total 2\n"
        ));
        for line in text.lines() {
            assert!(
                line.starts_with("# ") || line.split(' ').count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }
}
