//! Request-lifecycle tracing: typed span/instant events on the sim clock.
//!
//! The orchestrator, executors, and control plane emit [`TraceEvent`]s
//! through a [`TraceHandle`].  The default handle is *off*: every
//! emission method is a single `Option` check and returns immediately,
//! so sink-off runs are bit-identical to a build without tracing (the
//! events never exist and nothing else observes them).  A recording
//! handle shares one [`RecordingSink`] across all replicas (threaded
//! fleets included — the sink sits behind a mutex and each replica's
//! own events stay in its emission order).
//!
//! Span discipline per request: at most one lifecycle span open at a
//! time, phases paired Begin/End in emission order.  Spans whose
//! duration is known at emission (KV handoff, device iterations) are
//! recorded as [`TraceEventKind::Complete`] and never open anything.
//! [`check_nesting`] verifies the discipline; the integration tests pin
//! it across preemption, fault recovery, and fleet failover.

use std::sync::{Arc, Mutex};

/// Lifecycle span phases, in canonical request order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanPhase {
    /// Waiting for dispatch (arrival → first submitted work; re-opened
    /// after encode completes and after fault-recovery recompute).
    Queue,
    /// Multimodal image encode.
    Encode,
    /// Chunked prefill (first chunk submit → last chunk complete).
    Prefill,
    /// Cross-instance KV transfer (always a `Complete` span).
    KvHandoff,
    /// Decode (first decode submit → completion).
    Decode,
    /// One device iteration on an instance (always a `Complete` span,
    /// request-agnostic: the instance-utilization track).
    Iteration,
}

impl SpanPhase {
    pub fn name(self) -> &'static str {
        match self {
            SpanPhase::Queue => "queue",
            SpanPhase::Encode => "encode",
            SpanPhase::Prefill => "prefill",
            SpanPhase::KvHandoff => "kv_handoff",
            SpanPhase::Decode => "decode",
            SpanPhase::Iteration => "iteration",
        }
    }
}

/// Point events: lifecycle milestones and control-plane actions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum InstantKind {
    Arrival,
    FirstToken,
    Completion,
    Failure,
    /// A planned request was pushed out of its batch (co-location
    /// admission control / batcher preemption / recovery recompute).
    Preemption,
    /// A request's KV moved to another instance or replica.
    Migration,
    /// An instance changed pool role (P↔D).
    RoleFlip,
    /// An instance fault fired (sim-level fault injection).
    Fault,
    /// A faulted instance came back.
    Recovery,
    ScaleUp,
    ScaleDown,
    /// A replica's lease expired and its work was re-dispatched.
    Failover,
    /// Planned hot-prefix KV rebalancing started staging.
    Rebalance,
    /// A spawned replica was pre-staged with hot chains.
    WarmStart,
    /// Executor policy: EPLB routing table re-plan committed.
    EplbReplan,
    /// Executor policy: online decode-cost calibration update.
    Calibration,
}

impl InstantKind {
    pub fn name(self) -> &'static str {
        match self {
            InstantKind::Arrival => "arrival",
            InstantKind::FirstToken => "first_token",
            InstantKind::Completion => "completion",
            InstantKind::Failure => "failure",
            InstantKind::Preemption => "preemption",
            InstantKind::Migration => "migration",
            InstantKind::RoleFlip => "role_flip",
            InstantKind::Fault => "fault",
            InstantKind::Recovery => "recovery",
            InstantKind::ScaleUp => "scale_up",
            InstantKind::ScaleDown => "scale_down",
            InstantKind::Failover => "failover",
            InstantKind::Rebalance => "rebalance",
            InstantKind::WarmStart => "warm_start",
            InstantKind::EplbReplan => "eplb_replan",
            InstantKind::Calibration => "calibration",
        }
    }
}

/// What a [`TraceEvent`] records.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEventKind {
    Begin(SpanPhase),
    End(SpanPhase),
    /// A span whose duration is known at emission: `t_s` is the start,
    /// the payload the duration in (virtual) seconds.
    Complete(SpanPhase, f64),
    Instant(InstantKind),
}

/// One trace event on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Virtual time of the event (span start for `Complete`).
    pub t_s: f64,
    /// Monotonic per-sink record number — preserves each replica's
    /// emission order through the shared sink (ties on `t_s` resolve by
    /// `seq` within a replica).
    pub seq: u64,
    /// Fleet replica that emitted the event (`None` = control plane or
    /// a standalone run).
    pub replica: Option<usize>,
    /// Instance within the replica, where attributable.
    pub instance: Option<usize>,
    /// Request the event belongs to (`None` for instance/fleet events).
    pub req: Option<u64>,
    pub kind: TraceEventKind,
}

/// Consumer of trace events.  `Send` so a shared sink can sit behind
/// replicas stepping on worker threads.
pub trait TraceSink: Send {
    fn record(&mut self, ev: TraceEvent);
    /// Take every event recorded so far (exporters call this once).
    fn drain(&mut self) -> Vec<TraceEvent> {
        Vec::new()
    }
}

/// The default recording sink: an in-memory event log.
#[derive(Default)]
pub struct RecordingSink {
    pub events: Vec<TraceEvent>,
}

impl TraceSink for RecordingSink {
    fn record(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    fn drain(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }
}

/// Cheap, cloneable emission handle.  Off by default — every emission
/// is one `Option` check, no allocation, no lock.  Cloning shares the
/// underlying sink; [`TraceHandle::for_replica`] stamps a replica id
/// onto the clone handed to that replica's orchestrator.
#[derive(Clone, Default)]
pub struct TraceHandle {
    sink: Option<Arc<Mutex<SinkState>>>,
    replica: Option<usize>,
}

struct SinkState {
    sink: Box<dyn TraceSink>,
    next_seq: u64,
}

impl std::fmt::Debug for TraceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TraceHandle({}, replica: {:?})",
            if self.sink.is_some() { "on" } else { "off" },
            self.replica
        )
    }
}

impl TraceHandle {
    /// The no-op handle (also what `Default` gives you).
    pub fn off() -> TraceHandle {
        TraceHandle::default()
    }

    /// A handle recording into a fresh in-memory [`RecordingSink`].
    pub fn recording() -> TraceHandle {
        TraceHandle::with_sink(Box::new(RecordingSink::default()))
    }

    /// A handle recording into a caller-supplied sink.
    pub fn with_sink(sink: Box<dyn TraceSink>) -> TraceHandle {
        TraceHandle {
            sink: Some(Arc::new(Mutex::new(SinkState { sink, next_seq: 0 }))),
            replica: None,
        }
    }

    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Clone with the replica attribution stamped on (fleet use).
    pub fn for_replica(&self, id: usize) -> TraceHandle {
        TraceHandle { sink: self.sink.clone(), replica: Some(id) }
    }

    fn emit(&self, t_s: f64, instance: Option<usize>, req: Option<u64>, kind: TraceEventKind) {
        let Some(sink) = &self.sink else { return };
        let mut st = sink.lock().expect("trace sink lock");
        let seq = st.next_seq;
        st.next_seq += 1;
        st.sink.record(TraceEvent { t_s, seq, replica: self.replica, instance, req, kind });
    }

    pub fn begin(&self, t_s: f64, instance: Option<usize>, req: Option<u64>, phase: SpanPhase) {
        self.emit(t_s, instance, req, TraceEventKind::Begin(phase));
    }

    pub fn end(&self, t_s: f64, instance: Option<usize>, req: Option<u64>, phase: SpanPhase) {
        self.emit(t_s, instance, req, TraceEventKind::End(phase));
    }

    /// Record a span with a known duration (start `t_s`, length `dur_s`).
    pub fn complete(
        &self,
        t_s: f64,
        instance: Option<usize>,
        req: Option<u64>,
        phase: SpanPhase,
        dur_s: f64,
    ) {
        self.emit(t_s, instance, req, TraceEventKind::Complete(phase, dur_s));
    }

    pub fn instant(&self, t_s: f64, instance: Option<usize>, req: Option<u64>, kind: InstantKind) {
        self.emit(t_s, instance, req, TraceEventKind::Instant(kind));
    }

    /// Drain the shared sink (all replicas' events).
    pub fn drain(&self) -> Vec<TraceEvent> {
        match &self.sink {
            Some(s) => s.lock().expect("trace sink lock").sink.drain(),
            None => Vec::new(),
        }
    }
}

/// Verify the span discipline over a recorded event stream: per
/// `(replica, request)`, spans pair Begin→End in emission order with at
/// most one open at a time, `End.t ≥ Begin.t`, `Complete` durations are
/// non-negative, and nothing is left open.  Returns the first violation
/// as a readable message.
pub fn check_nesting(events: &[TraceEvent]) -> Result<(), String> {
    use std::collections::BTreeMap;
    // (replica+1 or 0, req) -> (open phase, begin time, begin seq)
    let mut open: BTreeMap<(usize, u64), (SpanPhase, f64, u64)> = BTreeMap::new();
    let mut by_key: BTreeMap<(usize, u64), Vec<&TraceEvent>> = BTreeMap::new();
    for ev in events {
        if let TraceEventKind::Complete(p, d) = ev.kind {
            if d < 0.0 {
                return Err(format!("negative {} span duration {d} at t={}", p.name(), ev.t_s));
            }
        }
        let Some(req) = ev.req else { continue };
        by_key.entry((ev.replica.map_or(0, |r| r + 1), req)).or_default().push(ev);
    }
    for (key, mut evs) in by_key {
        // each replica's events are recorded in emission order; sort by
        // the sink seq so shared-sink interleaving cannot reorder a
        // single request's lifecycle
        evs.sort_by_key(|e| e.seq);
        for ev in evs {
            match ev.kind {
                TraceEventKind::Begin(p) => {
                    if let Some((prev, t0, _)) = open.get(&key) {
                        return Err(format!(
                            "request {key:?}: Begin({}) at t={} while {} open since t={t0}",
                            p.name(),
                            ev.t_s,
                            prev.name()
                        ));
                    }
                    open.insert(key, (p, ev.t_s, ev.seq));
                }
                TraceEventKind::End(p) => match open.remove(&key) {
                    Some((prev, t0, _)) if prev == p => {
                        if ev.t_s < t0 - 1e-12 {
                            return Err(format!(
                                "request {key:?}: {} span ends at t={} before its begin t={t0}",
                                p.name(),
                                ev.t_s
                            ));
                        }
                    }
                    Some((prev, t0, s)) => {
                        open.insert(key, (prev, t0, s));
                        return Err(format!(
                            "request {key:?}: End({}) at t={} does not match open span {}",
                            p.name(),
                            ev.t_s,
                            prev.name()
                        ));
                    }
                    None => {
                        return Err(format!(
                            "request {key:?}: orphan End({}) at t={}",
                            p.name(),
                            ev.t_s
                        ));
                    }
                },
                TraceEventKind::Complete(..) | TraceEventKind::Instant(..) => {}
            }
        }
    }
    if let Some((key, (p, t0, _))) = open.into_iter().next() {
        return Err(format!("request {key:?}: {} span opened at t={t0} never closed", p.name()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, seq: u64, req: u64, kind: TraceEventKind) -> TraceEvent {
        TraceEvent { t_s: t, seq, replica: None, instance: Some(0), req: Some(req), kind }
    }

    #[test]
    fn off_handle_records_nothing() {
        let h = TraceHandle::off();
        assert!(!h.enabled());
        h.begin(0.0, None, Some(1), SpanPhase::Queue);
        h.instant(0.0, None, Some(1), InstantKind::Arrival);
        assert!(h.drain().is_empty());
    }

    #[test]
    fn recording_preserves_emission_order_and_stamps_seq() {
        let h = TraceHandle::recording();
        let r0 = h.for_replica(0);
        r0.begin(0.5, Some(1), Some(7), SpanPhase::Prefill);
        r0.end(0.9, Some(1), Some(7), SpanPhase::Prefill);
        h.instant(1.0, None, None, InstantKind::ScaleUp);
        let evs = h.drain();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].seq, 0);
        assert_eq!(evs[1].seq, 1);
        assert_eq!(evs[0].replica, Some(0));
        assert_eq!(evs[2].replica, None);
        assert!(h.drain().is_empty(), "drain takes the events");
    }

    #[test]
    fn nesting_accepts_a_clean_lifecycle() {
        let evs = vec![
            ev(0.0, 0, 1, TraceEventKind::Instant(InstantKind::Arrival)),
            ev(0.0, 1, 1, TraceEventKind::Begin(SpanPhase::Queue)),
            ev(0.2, 2, 1, TraceEventKind::End(SpanPhase::Queue)),
            ev(0.2, 3, 1, TraceEventKind::Begin(SpanPhase::Prefill)),
            ev(0.5, 4, 1, TraceEventKind::End(SpanPhase::Prefill)),
            ev(0.5, 5, 1, TraceEventKind::Complete(SpanPhase::KvHandoff, 0.01)),
            ev(0.6, 6, 1, TraceEventKind::Begin(SpanPhase::Decode)),
            ev(1.0, 7, 1, TraceEventKind::End(SpanPhase::Decode)),
            ev(1.0, 8, 1, TraceEventKind::Instant(InstantKind::Completion)),
        ];
        check_nesting(&evs).unwrap();
    }

    #[test]
    fn nesting_rejects_overlap_orphan_and_unclosed() {
        let overlap = vec![
            ev(0.0, 0, 1, TraceEventKind::Begin(SpanPhase::Queue)),
            ev(0.1, 1, 1, TraceEventKind::Begin(SpanPhase::Prefill)),
        ];
        assert!(check_nesting(&overlap).is_err());
        let orphan = vec![ev(0.0, 0, 1, TraceEventKind::End(SpanPhase::Decode))];
        assert!(check_nesting(&orphan).is_err());
        let unclosed = vec![ev(0.0, 0, 1, TraceEventKind::Begin(SpanPhase::Queue))];
        assert!(check_nesting(&unclosed).is_err());
        let mismatch = vec![
            ev(0.0, 0, 1, TraceEventKind::Begin(SpanPhase::Queue)),
            ev(0.1, 1, 1, TraceEventKind::End(SpanPhase::Decode)),
        ];
        assert!(check_nesting(&mismatch).is_err());
    }
}
