//! The unified metrics registry: counters, gauges, and fixed-bucket
//! histograms under stable `xllm_*` names.
//!
//! Deterministic by construction — no wall clock, insertion via sorted
//! maps, fixed bucket bounds — so two runs of the same seed export the
//! same text byte for byte.  The legacy counter structs
//! (`ControlCounters`, `ServerStats`, `PolicyCounters`) stay the
//! increment surface; each exports into the registry under its stable
//! names post-run and can be reconstructed from a registry as a view
//! (round-trip pinned by tests).

use std::collections::BTreeMap;

/// Bucket bounds (seconds) for request-level latencies: TTFT, E2E, and
/// the per-phase breakdown.
pub const LATENCY_BUCKETS_S: &[f64] =
    &[0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0];

/// Bucket bounds (seconds) for per-token latency (TPOT).
pub const TPOT_BUCKETS_S: &[f64] =
    &[0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0];

/// A fixed-bucket cumulative histogram (Prometheus semantics: bucket
/// counts are cumulative over `le` bounds, plus `+Inf`, `sum`, `count`).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Upper bounds of the finite buckets, ascending.
    pub bounds: Vec<f64>,
    /// Non-cumulative counts per finite bucket plus a final overflow
    /// bucket (`+Inf`); cumulated at export time.
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    /// Cumulative count at the bucket with upper bound `self.bounds[i]`.
    pub fn cumulative(&self, i: usize) -> u64 {
        self.counts[..=i].iter().sum()
    }

    /// Fold `other` (same bounds) into this histogram.  Sums and counts
    /// add bucket-wise, so merging per-replica histograms yields the
    /// histogram of the union of observations.
    pub fn merge(&mut self, other: &Histogram) {
        debug_assert_eq!(self.bounds, other.bounds, "merging histograms with different bounds");
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// Quantile estimate for `q` in [0, 100]: the upper bound of the
    /// first bucket whose cumulative count reaches the ceil-rank.  Never
    /// undershoots the exact sample quantile and is within one bucket
    /// width of it; observations in the overflow bucket report the last
    /// finite bound.  Empty histogram → 0.0.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.bounds[i.min(self.bounds.len() - 1)];
            }
        }
        self.bounds[self.bounds.len() - 1]
    }

    /// Exact mean of all observations (`sum` is exact, only bucket
    /// placement is lossy).  Empty histogram → 0.0.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }
}

/// The unified registry.  Names should be `snake_case` with an `xllm_`
/// prefix and a `_total` suffix for counters (Prometheus conventions).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Add `v` to the gauge (fleet aggregation over replicas).
    pub fn add_gauge(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    /// Observe `v` into the named histogram, creating it with `bounds`
    /// on first use (later calls keep the original bounds).
    pub fn observe(&mut self, name: &str, bounds: &[f64], v: f64) {
        self.histograms.entry(name.to_string()).or_insert_with(|| Histogram::new(bounds)).observe(v);
    }

    /// Fold a pre-aggregated histogram (e.g. a report sketch) into the
    /// named registry histogram, creating it with matching bounds on
    /// first use.  O(buckets) — this is how streaming reports export
    /// without replaying per-request observations.
    pub fn merge_histogram(&mut self, name: &str, h: &Histogram) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(&h.bounds))
            .merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(&[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(0.5);
        h.observe(100.0);
        assert_eq!(h.count, 4);
        assert_eq!(h.cumulative(0), 1);
        assert_eq!(h.cumulative(1), 3);
        assert_eq!(h.counts[2], 1, "overflow lands in +Inf");
        assert!((h.sum - 101.05).abs() < 1e-9);
    }

    #[test]
    fn registry_accumulates_and_reads_back() {
        let mut r = MetricsRegistry::new();
        r.inc("xllm_requests_total", 3);
        r.inc("xllm_requests_total", 2);
        r.set_gauge("xllm_replicas_final", 4.0);
        r.observe("xllm_ttft_seconds", LATENCY_BUCKETS_S, 0.2);
        assert_eq!(r.counter("xllm_requests_total"), 5);
        assert_eq!(r.counter("missing"), 0);
        assert!((r.gauge("xllm_replicas_final") - 4.0).abs() < 1e-12);
        assert_eq!(r.histogram("xllm_ttft_seconds").unwrap().count, 1);
    }

    #[test]
    fn histogram_merge_is_bucketwise_addition() {
        let mut a = Histogram::new(&[0.1, 1.0]);
        a.observe(0.05);
        a.observe(0.5);
        let mut b = Histogram::new(&[0.1, 1.0]);
        b.observe(0.5);
        b.observe(100.0);
        a.merge(&b);
        assert_eq!(a.count, 4);
        assert_eq!(a.counts, vec![1, 2, 1]);
        assert!((a.sum - 101.05).abs() < 1e-9);
        // merging through the registry creates-then-folds
        let mut r = MetricsRegistry::new();
        r.merge_histogram("h", &a);
        r.merge_histogram("h", &b);
        assert_eq!(r.histogram("h").unwrap().count, 6);
    }

    #[test]
    fn histogram_quantile_upper_bounds_the_rank_bucket() {
        let mut h = Histogram::new(&[0.1, 1.0, 10.0]);
        for _ in 0..9 {
            h.observe(0.05); // bucket le=0.1
        }
        h.observe(5.0); // bucket le=10.0
        assert!((h.quantile(50.0) - 0.1).abs() < 1e-12);
        assert!((h.quantile(90.0) - 0.1).abs() < 1e-12);
        assert!((h.quantile(99.0) - 10.0).abs() < 1e-12);
        // overflow observations clamp to the last finite bound
        let mut o = Histogram::new(&[0.1]);
        o.observe(99.0);
        assert!((o.quantile(99.0) - 0.1).abs() < 1e-12);
        // empty histogram is safe
        assert_eq!(Histogram::new(&[1.0]).quantile(50.0), 0.0);
        assert_eq!(Histogram::new(&[1.0]).mean(), 0.0);
        assert!((h.mean() - (9.0 * 0.05 + 5.0) / 10.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_iteration_order() {
        let mut r = MetricsRegistry::new();
        r.inc("b_total", 1);
        r.inc("a_total", 1);
        let names: Vec<&str> = r.counters().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["a_total", "b_total"], "sorted, insertion-order independent");
    }
}
