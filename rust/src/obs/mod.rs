//! Cluster-wide observability: request-lifecycle tracing, the unified
//! metrics registry, and the Perfetto/Prometheus exporters.
//!
//! Three pieces (DESIGN.md §Observability):
//!
//! * [`trace`] — a [`TraceSink`] behind a cloneable [`TraceHandle`]
//!   that the orchestrator, executors, and control plane emit typed
//!   lifecycle events through.  Off by default with zero overhead: the
//!   handle is an `Option` check and emission never touches simulation
//!   state, so sink-off runs are bit-identical to the pre-tracing code
//!   (pinned by `tests/obs_trace.rs`).
//! * [`metrics`] — a deterministic [`MetricsRegistry`] (counters,
//!   gauges, fixed-bucket histograms; no wall clock) that the legacy
//!   counter structs (`ControlCounters`, `ServerStats`,
//!   `PolicyCounters`) export into under stable `xllm_*` names.
//! * [`export`] — Chrome trace-event JSON (one track per
//!   replica/instance, loadable in Perfetto) and Prometheus text
//!   exposition, wired to `--trace-out` / `--metrics-out` on the
//!   `simulate` / `serve` / `fleet` subcommands.
//!
//! [`log`] is the small verbosity-gated stderr logger behind
//! `--quiet` / `-v`.

pub mod export;
pub mod log;
pub mod metrics;
pub mod trace;

pub use export::{chrome_trace_json, prometheus_text};
pub use metrics::{Histogram, MetricsRegistry, LATENCY_BUCKETS_S, TPOT_BUCKETS_S};
pub use trace::{
    check_nesting, InstantKind, RecordingSink, SpanPhase, TraceEvent, TraceEventKind, TraceHandle,
    TraceSink,
};
