//! Verbosity-gated progress logging (stderr).
//!
//! One global level, set once by the CLI from `--quiet` / `-v`:
//! `0` = errors only, `1` = default progress notices, `2` = verbose.
//! Everything goes to stderr so command stdout (the JSON result) stays
//! machine-readable — logging never touches simulation state, so it
//! cannot perturb determinism.

use std::sync::atomic::{AtomicU8, Ordering};

pub const QUIET: u8 = 0;
pub const INFO: u8 = 1;
pub const DEBUG: u8 = 2;

static VERBOSITY: AtomicU8 = AtomicU8::new(INFO);

/// Set the global verbosity (CLI: `--quiet` → 0, default → 1, `-v` → 2).
pub fn set_verbosity(level: u8) {
    VERBOSITY.store(level, Ordering::Relaxed);
}

pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

/// Default-visible progress notice (suppressed by `--quiet`).
pub fn info(msg: impl AsRef<str>) {
    if verbosity() >= INFO {
        eprintln!("{}", msg.as_ref());
    }
}

/// Verbose-only detail (shown with `-v`).
pub fn debug(msg: impl AsRef<str>) {
    if verbosity() >= DEBUG {
        eprintln!("{}", msg.as_ref());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verbosity_round_trips() {
        let prev = verbosity();
        set_verbosity(QUIET);
        assert_eq!(verbosity(), QUIET);
        set_verbosity(DEBUG);
        assert_eq!(verbosity(), DEBUG);
        set_verbosity(prev);
    }
}
