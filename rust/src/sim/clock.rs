//! Discrete-event simulation clock.
//!
//! The cluster simulator (the Ascend-testbed substitute) is a classic
//! event-queue design: events carry a timestamp and an opaque payload; the
//! driver pops them in time order.  Determinism: ties are broken by
//! insertion sequence number, so identical runs produce identical traces.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in seconds.
pub type SimTime = f64;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// An event queue advancing simulated time.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }

    /// Current simulated time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute time `at` (clamped to now if in the past).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let t = if at < self.now { self.now } else { at };
        self.heap.push(Entry { time: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.schedule_at(self.now + delay.max(0.0), event);
    }

    /// Timestamp of the next event without popping it (control-plane
    /// drivers interleave several queues by comparing heads).
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    /// Jump the clock forward to `t` without processing anything (never
    /// backwards).  Used when a replica spawned mid-run must align its
    /// fresh local clock with fleet time before any event is scheduled.
    pub fn advance_to(&mut self, t: SimTime) {
        if t > self.now {
            self.now = t;
        }
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now, "time went backwards");
            self.now = e.time;
            self.processed += 1;
            (e.time, e.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        assert_eq!(q.next().unwrap(), (1.0, "a"));
        assert_eq!(q.next().unwrap(), (2.0, "b"));
        assert_eq!(q.next().unwrap(), (3.0, "c"));
        assert!(q.next().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, "first");
        q.schedule_at(1.0, "second");
        assert_eq!(q.next().unwrap().1, "first");
        assert_eq!(q.next().unwrap().1, "second");
    }

    #[test]
    fn clock_advances_and_relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1u32);
        q.next();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(2.5, 2u32);
        assert_eq!(q.next().unwrap(), (7.5, 2u32));
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.schedule_at(2.0, "b");
        q.schedule_at(1.0, "a");
        assert_eq!(q.peek_time(), Some(1.0));
        assert_eq!(q.now(), 0.0, "peek must not advance the clock");
        assert_eq!(q.next().unwrap(), (1.0, "a"));
        assert_eq!(q.peek_time(), Some(2.0));
    }

    #[test]
    fn advance_to_is_monotonic() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.advance_to(5.0);
        assert_eq!(q.now(), 5.0);
        q.advance_to(2.0);
        assert_eq!(q.now(), 5.0, "clock never moves backwards");
        // events scheduled relative to the advanced clock land after it
        q.schedule_in(1.0, "x");
        assert_eq!(q.next().unwrap(), (6.0, "x"));
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "x");
        q.next();
        q.schedule_at(3.0, "late");
        let (t, _) = q.next().unwrap();
        assert_eq!(t, 10.0);
    }

    #[test]
    fn property_monotonic_time() {
        crate::testutil::quickcheck("monotonic-time", |rng| {
            let mut q = EventQueue::new();
            for _ in 0..100 {
                q.schedule_at(rng.f64() * 100.0, ());
            }
            let mut last = 0.0;
            while let Some((t, _)) = q.next() {
                crate::prop_assert!(t >= last, "t={t} < last={last}");
                last = t;
            }
            Ok(())
        });
    }
}
