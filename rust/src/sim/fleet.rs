//! Multi-replica fleet simulation: N roofline clusters under one
//! control plane.
//!
//! [`FleetConfig`] stamps `n_replicas` copies of a [`ClusterConfig`]
//! template (each replica is a full orchestrator over its own
//! [`RooflineExecutor`], with `template.n_instances` engine instances)
//! and wires them into a [`ControlPlane`] — the first configuration in
//! the repo where traffic is served across more than one engine.  This
//! is the fleet-scope analogue of `sim::cluster::run`: paper-shaped
//! experiments (cache-aware vs round-robin routing, replica failure
//! mid-run) are configurations of this driver plus a scenario from
//! `workload::scenarios` (e.g. `skewed-prefix`).

use crate::service::controlplane::{
    ControlPlane, ControlPlaneConfig, FleetResult, RoutePolicy, ScalerConfig,
};
use crate::sim::cluster::ClusterConfig;
use crate::sim::executor::RooflineExecutor;
use crate::sim::roofline::CostModel;
use crate::workload::RequestSpec;

pub use crate::coordinator::orchestrator::Orchestrator;

/// Fleet configuration: a per-replica cluster template + control-plane
/// policy.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Per-replica cluster (hardware, model, features, serving mode,
    /// instance count, prefix cache, seed).
    pub template: ClusterConfig,
    /// Replicas at start (the autoscaler may grow/shrink from here).
    pub n_replicas: usize,
    pub routing: RoutePolicy,
    pub heartbeat_s: f64,
    pub lease_ttl_s: f64,
    /// Whole-replica crash injections: (time, replica).
    pub replica_faults: Vec<(f64, usize)>,
    /// Elastic autoscaling + planned KV rebalancing (None = fixed fleet).
    pub scaler: Option<ScalerConfig>,
}

impl FleetConfig {
    pub fn new(template: ClusterConfig, n_replicas: usize) -> FleetConfig {
        // policy defaults come from the control plane, not re-hardcoded
        let d = ControlPlaneConfig::default();
        FleetConfig {
            template,
            n_replicas,
            routing: d.routing,
            heartbeat_s: d.heartbeat_s,
            lease_ttl_s: d.lease_ttl_s,
            replica_faults: Vec::new(),
            scaler: d.scaler,
        }
    }

    fn control_plane_config(&self) -> ControlPlaneConfig {
        ControlPlaneConfig {
            routing: self.routing,
            heartbeat_s: self.heartbeat_s,
            lease_ttl_s: self.lease_ttl_s,
            replica_faults: self.replica_faults.clone(),
            block_tokens: self.template.orchestrator_config().prefix_block_tokens,
            colocation: self
                .template
                .colocation
                .map(|(_, c)| c)
                .unwrap_or_default(),
            scaler: self.scaler,
            ..ControlPlaneConfig::default()
        }
    }
}

/// Stamp one replica from the template (also the scale-up factory: the
/// per-replica seed offset keeps speculative draws independent even for
/// replicas spawned mid-run).  The template's `pipeline_depth` and
/// `host_overhead_s` carry through, so a fleet of async-pipelined
/// replicas keeps one in-flight iteration per instance per replica —
/// the control plane interleaves their concurrently pending completion
/// events deterministically by `next_event_time`.
fn stamp_replica(template: &ClusterConfig, i: usize) -> Orchestrator<RooflineExecutor> {
    let cost =
        CostModel::new(template.hw.clone(), template.model.clone(), template.features.clone());
    let executor =
        RooflineExecutor::new(cost, template.spec, template.seed.wrapping_add(i as u64))
            .with_host_overhead(template.host_overhead_s);
    Orchestrator::new(template.orchestrator_config(), executor)
}

/// Build the replicas and run the workload through the control plane.
pub fn run_fleet(cfg: FleetConfig, workload: Vec<RequestSpec>) -> FleetResult {
    let replicas: Vec<Orchestrator<RooflineExecutor>> =
        (0..cfg.n_replicas).map(|i| stamp_replica(&cfg.template, i)).collect();
    let cp_cfg = cfg.control_plane_config();
    let template = cfg.template;
    ControlPlane::new(cp_cfg, replicas)
        .with_spawner(move |i| stamp_replica(&template, i))
        .run(workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;
    use crate::util::Rng;
    use crate::workload::scenario;

    fn template(n_instances: usize) -> ClusterConfig {
        let mut cfg = ClusterConfig::new(
            n_instances,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.prefix_cache = true;
        cfg
    }

    #[test]
    fn fleet_serves_a_scenario_end_to_end() {
        let mut rng = Rng::new(21);
        let w = scenario("skewed-prefix").unwrap().generate(20.0, 2.0, &mut rng);
        let n = w.len();
        let res = run_fleet(FleetConfig::new(template(1), 3), w);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n);
        assert!(res.prefix_hits() > 0, "skewed prefixes must hit the caches");
        assert!(res.counters.routed_by_cache_hit > 0);
        assert!(!res.truncated);
    }

    #[test]
    fn offline_traffic_is_steered_across_replicas() {
        // constructed so the heartbeat after t=0 sees one offline-only
        // replica and two online-busy replicas: the offline arrival at
        // t=0.4 must then be narrowed to the relaxed replica (§3.1
        // tide rule at fleet scope)
        let w = vec![
            RequestSpec::text(0.0, 4096, 512).offline(), // lands on the least-loaded (one replica)
            RequestSpec::text(0.05, 2048, 1024),         // online pins a second replica
            RequestSpec::text(0.10, 2048, 1024),         // online pins the third
            RequestSpec::text(0.40, 2048, 256).offline(), // must steer to the offline replica
        ];
        let n = w.len();
        let res = run_fleet(FleetConfig::new(template(1), 3), w);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n);
        assert!(
            res.counters.offline_steered > 0,
            "mixed load must trigger the cross-replica tide rule: {:?}",
            res.counters
        );
    }
}
