//! Multi-replica fleet simulation: N roofline clusters under one
//! control plane.
//!
//! [`FleetConfig`] stamps `n_replicas` copies of a [`ClusterConfig`]
//! template (each replica is a full orchestrator over its own
//! [`RooflineExecutor`], with `template.n_instances` engine instances)
//! and runs them through the shared executor-agnostic fleet runtime
//! ([`crate::service::fleet::run_fleet_with`]).  This is the roofline
//! instantiation of the [`ReplicaFactory`] seam — the real-engine
//! instantiation is `server::PjrtReplicaFactory` (`xllm fleet
//! --backend pjrt`); both drive the exact same
//! registry/index/router/scaler control plane.  Paper-shaped
//! experiments (cache-aware vs round-robin routing, replica failure
//! mid-run) are configurations of this driver plus a scenario from
//! `workload::scenarios` (e.g. `skewed-prefix`).

use crate::service::controlplane::{ControlPlaneConfig, FleetResult};
use crate::service::fleet::{run_fleet_stream_with, run_fleet_with, ReplicaFactory};
use crate::sim::cluster::ClusterConfig;
use crate::sim::executor::RooflineExecutor;
use crate::sim::roofline::CostModel;
use crate::workload::RequestSpec;

pub use crate::coordinator::orchestrator::Orchestrator;

/// Fleet configuration: a per-replica cluster template + the embedded
/// control-plane policy.
///
/// The policy is a whole [`ControlPlaneConfig`] rather than a copied
/// subset, so every control-plane knob (routing, leases, faults,
/// scaler, stepping threads — and any future ones) flows to the fleet
/// path automatically; only the template-derived fields
/// (`block_tokens`, `colocation`) are stamped over it at run time.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Per-replica cluster (hardware, model, features, serving mode,
    /// instance count, prefix cache, seed).
    pub template: ClusterConfig,
    /// Replicas at start (the autoscaler may grow/shrink from here).
    pub n_replicas: usize,
    /// Control-plane policy (routing, heartbeat/lease timing, replica
    /// faults, elastic scaler, stepping threads, …).
    pub control: ControlPlaneConfig,
}

impl FleetConfig {
    pub fn new(template: ClusterConfig, n_replicas: usize) -> FleetConfig {
        FleetConfig { template, n_replicas, control: ControlPlaneConfig::default() }
    }

    /// The embedded policy with the template-derived fields stamped in
    /// (prefix-chain granularity and co-location thresholds must match
    /// the replicas' own configuration).
    fn control_plane_config(&self) -> ControlPlaneConfig {
        ControlPlaneConfig {
            block_tokens: self.template.orchestrator_config().prefix_block_tokens,
            token_granular: self.control.token_granular || self.template.token_granular,
            colocation: self
                .template
                .colocation
                .map(|(_, c)| c)
                .unwrap_or_default(),
            ..self.control.clone()
        }
    }
}

/// Stamps one roofline replica per id from the cluster template (the
/// per-replica seed offset keeps speculative draws independent even for
/// replicas spawned mid-run).  The template's `pipeline_depth` and
/// `host_overhead_s` carry through, so a fleet of async-pipelined
/// replicas keeps one in-flight iteration per instance per replica —
/// the control plane interleaves their concurrently pending completion
/// events deterministically by `next_event_time`.
pub struct RooflineReplicaFactory {
    pub template: ClusterConfig,
}

impl ReplicaFactory for RooflineReplicaFactory {
    type Exec = RooflineExecutor;

    fn build(&mut self, id: usize) -> Orchestrator<RooflineExecutor> {
        let t = &self.template;
        let cost = CostModel::new(t.hw.clone(), t.model.clone(), t.features.clone());
        let executor = RooflineExecutor::new(cost, t.spec, t.seed.wrapping_add(id as u64))
            .with_host_overhead(t.host_overhead_s)
            .with_policies(t.policies);
        Orchestrator::new(t.orchestrator_config(), executor)
    }

    /// Roofline replicas CAN reshape: a scale-up with a wider shard
    /// stamps the replica from a re-sharded template (kv capacity and
    /// the roofline's tp/pp terms follow the new device group).
    fn try_build_sharded(
        &mut self,
        id: usize,
        shard: crate::model::ShardSpec,
    ) -> Option<Orchestrator<RooflineExecutor>> {
        let t = self.template.clone().with_shard(shard);
        let cost = CostModel::new(t.hw.clone(), t.model.clone(), t.features.clone());
        let executor = RooflineExecutor::new(cost, t.spec, t.seed.wrapping_add(id as u64))
            .with_host_overhead(t.host_overhead_s)
            .with_policies(t.policies);
        Some(Orchestrator::new(t.orchestrator_config(), executor))
    }
}

/// Build the replicas and run the workload through the control plane.
pub fn run_fleet(cfg: FleetConfig, workload: Vec<RequestSpec>) -> FleetResult {
    let cp_cfg = cfg.control_plane_config();
    let factory = RooflineReplicaFactory { template: cfg.template };
    run_fleet_with(cp_cfg, cfg.n_replicas, factory, workload)
}

/// [`run_fleet`] over a pull-based arrival stream: requests are pulled
/// one at a time and every report runs in sketch-only streaming mode, so
/// fleet memory stays O(live requests) regardless of how many arrivals
/// the stream yields — the million-request entry point (`xllm fleet
/// --requests N`).
pub fn run_fleet_stream(
    cfg: FleetConfig,
    stream: impl Iterator<Item = RequestSpec> + Send + 'static,
) -> FleetResult {
    let cp_cfg = cfg.control_plane_config();
    let factory = RooflineReplicaFactory { template: cfg.template };
    run_fleet_stream_with(cp_cfg, cfg.n_replicas, factory, stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;
    use crate::util::Rng;
    use crate::workload::scenario;

    fn template(n_instances: usize) -> ClusterConfig {
        let mut cfg = ClusterConfig::new(
            n_instances,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.prefix_cache = true;
        cfg
    }

    #[test]
    fn fleet_serves_a_scenario_end_to_end() {
        let mut rng = Rng::new(21);
        let w = scenario("skewed-prefix").unwrap().generate(20.0, 2.0, &mut rng);
        let n = w.len();
        let res = run_fleet(FleetConfig::new(template(1), 3), w);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n);
        assert!(res.prefix_hits() > 0, "skewed prefixes must hit the caches");
        assert!(res.counters.routed_by_cache_hit > 0);
        assert!(!res.truncated);
    }

    #[test]
    fn offline_traffic_is_steered_across_replicas() {
        // constructed so the heartbeat after t=0 sees one offline-only
        // replica and two online-busy replicas: the offline arrival at
        // t=0.4 must then be narrowed to the relaxed replica (§3.1
        // tide rule at fleet scope)
        let w = vec![
            RequestSpec::text(0.0, 4096, 512).offline(), // lands on the least-loaded (one replica)
            RequestSpec::text(0.05, 2048, 1024),         // online pins a second replica
            RequestSpec::text(0.10, 2048, 1024),         // online pins the third
            RequestSpec::text(0.40, 2048, 256).offline(), // must steer to the offline replica
        ];
        let n = w.len();
        let res = run_fleet(FleetConfig::new(template(1), 3), w);
        assert!(res.all_accounted());
        assert_eq!(res.report.n_completed(), n);
        assert!(
            res.counters.offline_steered > 0,
            "mixed load must trigger the cross-replica tide rule: {:?}",
            res.counters
        );
    }

    #[test]
    fn streamed_fleet_matches_the_collected_fleet() {
        let sc = scenario("tide").unwrap();
        let mut rng = Rng::new(11);
        let w = sc.generate(20.0, 2.0, &mut rng);
        let n = w.len();
        let collected = run_fleet(FleetConfig::new(template(1), 2), w);

        let mut rng = Rng::new(11);
        let stream = sc.stream(20.0, 2.0, &mut rng);
        let streamed = run_fleet_stream(FleetConfig::new(template(1), 2), stream);

        assert!(streamed.all_accounted());
        assert_eq!(streamed.submitted, n);
        assert_eq!(streamed.report.n_completed(), collected.report.n_completed());
        assert!(
            !streamed.report.retains_outcomes(),
            "streaming runs must not retain per-request outcomes"
        );
        assert!(streamed.report.outcomes.is_empty());
        assert!((streamed.report.horizon() - collected.report.horizon()).abs() < 1e-9);
        assert_eq!(
            streamed.counters.routed_by_cache_hit,
            collected.counters.routed_by_cache_hit,
            "identical arrivals must route identically"
        );
        assert!(streamed.live_high_water <= n);
        assert!(streamed.replica_seconds > 0.0);
    }

    #[test]
    fn slo_scaling_beats_backlog_on_goodput_per_replica_second() {
        use crate::service::controlplane::{ScalePolicy, ScalerConfig};
        let sc = scenario("tide").unwrap();
        // the backlog policy's token-count rule is deliberately set
        // aggressive (one ~800-token prompt already exceeds the target)
        // so it over-provisions through the flood; the SLO policy spends
        // replicas only where predicted TTFT is actually at risk
        let mut backlog_cfg = FleetConfig::new(template(1), 1);
        backlog_cfg.control.scaler = Some(ScalerConfig {
            capacity_target_tokens: 512,
            min_replicas: 1,
            max_replicas: 4,
            cooldown_s: 0.5,
            ..Default::default()
        });
        let mut slo_cfg = backlog_cfg.clone();
        if let Some(s) = slo_cfg.control.scaler.as_mut() {
            s.policy = ScalePolicy::Slo;
            s.slo_ttft_target_s = 1.0;
        }

        let mut rng = Rng::new(42);
        let backlog = run_fleet_stream(backlog_cfg, sc.stream(40.0, 3.0, &mut rng));
        let mut rng = Rng::new(42);
        let slo = run_fleet_stream(slo_cfg, sc.stream(40.0, 3.0, &mut rng));

        assert!(backlog.all_accounted(), "backlog run lost requests");
        assert!(slo.all_accounted(), "slo run lost requests");
        assert!(
            backlog.counters.scale_ups >= 1,
            "the token-capacity rule must over-provision on tide: {:?}",
            backlog.counters
        );
        let (bg, sg) =
            (backlog.goodput_per_replica_second(), slo.goodput_per_replica_second());
        assert!(
            sg > bg,
            "SLO-aware scaling must beat backlog on goodput per replica-second: \
             slo={sg:.4} vs backlog={bg:.4} (replica_seconds {:.1} vs {:.1})",
            slo.replica_seconds,
            backlog.replica_seconds,
        );
    }

    #[test]
    fn threaded_fleet_matches_single_threaded_conservation() {
        let mut rng = Rng::new(33);
        let w = scenario("skewed-prefix").unwrap().generate(15.0, 2.0, &mut rng);
        let n = w.len();
        let single = run_fleet(FleetConfig::new(template(1), 3), w.clone());
        let mut cfg = FleetConfig::new(template(1), 3);
        cfg.control.threads = 2;
        let threaded = run_fleet(cfg, w);
        assert_eq!(single.report.n_completed(), n);
        assert_eq!(threaded.report.n_completed(), n);
        assert_eq!(threaded.counters.unroutable, single.counters.unroutable);
        assert_eq!(threaded.prefix_hits(), single.prefix_hits());
    }
}
