//! Roofline-cost [`Executor`]: the discrete-event simulation backend.
//!
//! Prices each planned iteration with [`CostModel`] exactly as the old
//! inline `ClusterSim` loop did — decode step from the roofline (with
//! speculative-decoding verify/draft multipliers), chunked prefill, and
//! encode with dual-stream overlap when a language stream runs in the
//! same iteration.  Speculative token emission is drawn per decode
//! request from a seeded RNG at iteration completion, preserving the
//! pre-refactor draw order (the golden parity tests depend on it).
//!
//! Under the two-phase contract the modelled price is known at submit
//! time, so the ticket's estimate *is* the outcome — virtual time stays
//! exact and deterministic at any pipeline depth.  The optional
//! `host_overhead_s` term models the orchestrator-side planning/dispatch
//! cost per iteration (the share §4.2 async scheduling hides); it
//! defaults to 0.0 so depth-1 runs reproduce the pre-async golden
//! fixtures bit for bit.  (The engine-internal CPU batch-prep time is
//! already part of the modelled step via `CostModel::exposed_sched` —
//! this term is specifically the host work *outside* the engine step.)

use crate::coordinator::orchestrator::{
    Executor, IterationOutcome, IterationTicket, IterationWork,
};
use crate::coordinator::pools::InstanceId;
use crate::coordinator::request::RequestId;
use crate::engine::specdecode::{
    draft_cost_fraction, expected_tokens_per_round, verify_cost_multiplier, SpecConfig,
};
use crate::service::epd::dual_stream_encode_exposure;
use crate::sim::roofline::CostModel;
use crate::util::Rng;

/// Price one planned iteration's device time with the roofline model
/// (shared with `server::PjrtExecutor`, which uses it as the submit-time
/// estimate while the real measurement is in flight).
pub fn model_device_s(cost: &CostModel, spec: Option<SpecConfig>, work: &IterationWork) -> f64 {
    let kv_tokens: u64 = work.decodes.iter().map(|d| d.context_tokens).sum();
    let n_decode = work.decodes.len() as u64;
    let mut duration = 0.0;
    if n_decode > 0 {
        let mut d = cost.decode_step_s(n_decode, kv_tokens);
        if let Some(spec) = spec {
            d *= verify_cost_multiplier(spec.m);
            d += d * draft_cost_fraction();
        }
        duration += d;
    }
    if work.prefill_tokens() > 0 {
        let ctx: u64 = work.prefills.iter().map(|p| p.context_tokens).sum();
        duration += cost.prefill_s(work.prefill_tokens(), ctx / work.prefills.len().max(1) as u64);
    }
    if !work.encodes.is_empty() {
        let patches: u64 = work.encodes.iter().map(|e| e.image_patches).sum();
        let enc = cost.encode_s(patches);
        // dual-stream: encode overlaps the language stream when fused
        duration += if n_decode > 0 || work.prefill_tokens() > 0 {
            enc * dual_stream_encode_exposure()
        } else {
            enc
        };
    }
    duration
}

/// Discrete-event executor over the roofline cost model.
pub struct RooflineExecutor {
    cost: CostModel,
    spec: Option<SpecConfig>,
    rng: Rng,
    /// Host-side planning/dispatch cost charged per iteration as
    /// [`IterationOutcome::host_s`] (default 0.0 — the pre-async
    /// contract).
    host_overhead_s: f64,
    seq: u64,
}

impl RooflineExecutor {
    pub fn new(cost: CostModel, spec: Option<SpecConfig>, seed: u64) -> RooflineExecutor {
        RooflineExecutor { cost, spec, rng: Rng::new(seed), host_overhead_s: 0.0, seq: 0 }
    }

    /// Model a nonzero per-iteration host overhead, the share the async
    /// pipeline hides in virtual time at depth ≥ 2.
    pub fn with_host_overhead(mut self, host_s: f64) -> RooflineExecutor {
        self.host_overhead_s = host_s.max(0.0);
        self
    }
}

impl Executor for RooflineExecutor {
    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn submit_iteration(
        &mut self,
        instance: InstanceId,
        _now_s: f64,
        work: &IterationWork,
    ) -> IterationTicket {
        let device_s = model_device_s(&self.cost, self.spec, work);
        let host_s = if work.is_empty() { 0.0 } else { self.host_overhead_s };
        self.seq += 1;
        IterationTicket { instance, seq: self.seq, est: IterationOutcome { host_s, device_s } }
    }

    fn poll_complete(&mut self, ticket: IterationTicket) -> IterationOutcome {
        // modelled prices are exact at submit time: the estimate is the
        // outcome, at any pipeline depth
        ticket.est
    }

    fn decode_emission(&mut self, _instance: InstanceId, _req: RequestId) -> u64 {
        match self.spec {
            Some(spec) => {
                let expect = expected_tokens_per_round(spec.m, spec.acceptance);
                let frac = expect.fract();
                let mut t = expect.trunc() as u64;
                if self.rng.chance(frac) {
                    t += 1;
                }
                t.max(1)
            }
            None => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orchestrator::{DecodeWork, PrefillWork};
    use crate::model::{ascend_910b, catalog};
    use crate::sim::roofline::EngineFeatures;

    fn exec(spec: Option<SpecConfig>) -> RooflineExecutor {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        RooflineExecutor::new(cost, spec, 42)
    }

    #[test]
    fn empty_work_costs_nothing() {
        let mut e = exec(None);
        assert_eq!(e.begin_iteration(0, 0.0, &IterationWork::default()), 0.0);
    }

    #[test]
    fn duration_matches_cost_model() {
        let mut e = exec(None);
        let work = IterationWork {
            decodes: vec![DecodeWork { req: 1, context_tokens: 512 }],
            prefills: vec![PrefillWork { req: 2, tokens: 256, context_tokens: 0 }],
            encodes: vec![],
        };
        let want = e.cost.decode_step_s(1, 512) + e.cost.prefill_s(256, 0);
        let got = e.begin_iteration(0, 0.0, &work);
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
    }

    #[test]
    fn default_kv_hooks_are_cost_only() {
        use crate::coordinator::orchestrator::KvChainPayload;
        // the roofline backend ships no real blocks: movement stays a
        // pure `TransferEngine` cost at the control plane, so golden
        // fixtures are untouched by the export/import seam
        let mut e = exec(None);
        assert!(e.export_chain(&[1, 2, 3]).is_none());
        e.import_chain(KvChainPayload::default()); // no-op by contract
        e.admitted(0, &crate::workload::RequestSpec::text(0.0, 64, 4)); // no-op
        assert_eq!(e.begin_iteration(0, 0.0, &IterationWork::default()), 0.0);
    }

    #[test]
    fn plain_decode_emits_one_token() {
        let mut e = exec(None);
        for _ in 0..10 {
            assert_eq!(e.decode_emission(0, 7), 1);
        }
    }

    #[test]
    fn spec_decode_emits_expected_rate() {
        let spec = SpecConfig { m: 4, acceptance: 0.75 };
        let mut e = exec(Some(spec));
        let n = 10_000u64;
        let total: u64 = (0..n).map(|_| e.decode_emission(0, 7)).sum();
        let expect = expected_tokens_per_round(spec.m, spec.acceptance);
        let mean = total as f64 / n as f64;
        assert!(
            (mean - expect).abs() < 0.05,
            "mean emission {mean} far from expectation {expect}"
        );
    }
}
