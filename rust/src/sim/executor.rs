//! Roofline-cost [`Executor`]: the discrete-event simulation backend.
//!
//! Prices each planned iteration with [`CostModel`] exactly as the old
//! inline `ClusterSim` loop did — decode step from the roofline (with
//! speculative-decoding verify/draft multipliers), chunked prefill, and
//! encode with dual-stream overlap when a language stream runs in the
//! same iteration.  Speculative token emission is drawn per decode
//! request from a seeded RNG at iteration completion, preserving the
//! pre-refactor draw order (the golden parity tests depend on it).
//!
//! Under the two-phase contract the modelled price is known at submit
//! time, so the ticket's estimate *is* the outcome — virtual time stays
//! exact and deterministic at any pipeline depth.  The optional
//! `host_overhead_s` term models the orchestrator-side planning/dispatch
//! cost per iteration (the share §4.2 async scheduling hides); it
//! defaults to 0.0 so depth-1 runs reproduce the pre-async golden
//! fixtures bit for bit.  (The engine-internal CPU batch-prep time is
//! already part of the modelled step via `CostModel::exposed_sched` —
//! this term is specifically the host work *outside* the engine step.)

use std::collections::HashSet;

use crate::coordinator::orchestrator::{
    Executor, IterationOutcome, IterationTicket, IterationWork,
};
use crate::coordinator::pools::InstanceId;
use crate::coordinator::request::RequestId;
use crate::engine::dpbalance::{
    balanced_cores, round_robin_cores, straggler_factor, CoreAssignment, DpGroup,
};
use crate::engine::eplb::{
    rebalance_round, static_table, DoubleBuffer, ExpertStats, RoutingTable,
    WeightUpdateController,
};
use crate::engine::opoverlap::{allocate, serial_makespan, OpLoad};
use crate::engine::policies::EnginePolicies;
use crate::engine::specdecode::{
    draft_cost_fraction, expected_tokens_per_round, verify_cost_multiplier, SpecConfig,
};
use crate::obs::{InstantKind, MetricsRegistry, TraceHandle};
use crate::runtime::{select_mode, LaunchMode};
use crate::service::epd::dual_stream_encode_exposure;
use crate::sim::roofline::CostModel;
use crate::util::Rng;

// ---------------------------------------------------------------------
// Engine-policy tuning constants
// ---------------------------------------------------------------------

/// XOR salt deriving the policy RNG stream from the executor seed: the
/// emission RNG's draw order is pinned by the golden fixtures and must
/// never observe a policy-dependent draw.
const POLICY_RNG_SALT: u64 = 0x9E37_79B9_7F4A_7C15;
/// Dynamic EPLB can at best recover this fraction of the step (floor on
/// the imbalance-vs-assumption cost multiplier).
const EPLB_MIN_FACTOR: f64 = 0.75;
/// Zipf skew of simulated expert routing (hot-expert traffic, §4.4.2).
const EXPERT_ZIPF_ALPHA: f64 = 1.2;
/// Sequences longer than this are split across cores by the balanced
/// layer-3 assignment (§4.4.3).
const DP_CORE_SPLIT_TOKENS: u64 = 512;
/// Share of the decode step governed by per-core attention stragglers.
const DP_ATTENTION_SHARE: f64 = 0.30;
/// Floor on the balanced/round-robin straggler ratio.
const DP_MIN_RATIO: f64 = 0.5;
/// Share of the decode step where Cube/Vector overlap (Eq. 1) applies.
const OP_OVERLAP_SHARE: f64 = 0.25;
/// Floor on the overlapped/serial makespan ratio.
const OP_MIN_RATIO: f64 = 0.4;
/// Fraction of the memory-bound time treated as vector-unit work.
const VECTOR_WORK_SHARE: f64 = 0.35;
/// Pre-compiled decode batch buckets mirrored from the PJRT manifest.
const SIM_DECODE_BUCKETS: [u64; 10] = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512];
/// One-time cost of compiling a cold graph bucket (§4.2).
const GRAPH_COMPILE_PENALTY_S: f64 = 2e-3;
/// A warm graph hit never removes more than this fraction of the step.
const GRAPH_GAIN_CAP: f64 = 0.3;

/// Dynamic EPLB state: routing table + expert stats + the staged
/// double-buffer weight-swap machinery (§4.4.2).
struct EplbState {
    stats: ExpertStats,
    table: RoutingTable,
    controller: WeightUpdateController,
    buffers: Vec<DoubleBuffer>,
    /// Current decode-cost multiplier: achieved imbalance relative to
    /// the static assumption baked into the roofline (≤ 1.0).
    factor: f64,
    replans: u64,
}

/// Per-executor policy state, present only when at least one
/// [`EnginePolicies`] switch is on — `None` keeps the seed behavior
/// bit-identical.
struct PolicyState {
    policies: EnginePolicies,
    rng: Rng,
    eplb: Option<EplbState>,
    warm_buckets: HashSet<u64>,
    graph_hits: u64,
    graph_compiles: u64,
    graph_fallbacks: u64,
}

/// Straggler factor of a layer-3 core assignment (per-core token loads
/// viewed as DP groups with unbounded capacity).
fn core_straggler(a: &CoreAssignment) -> f64 {
    let groups: Vec<DpGroup> = a
        .core_loads
        .iter()
        .enumerate()
        .map(|(id, &load)| DpGroup {
            id,
            kv_tokens: load,
            kv_capacity: u64::MAX,
            n_requests: 0,
        })
        .collect();
    straggler_factor(&groups)
}

impl PolicyState {
    /// Apply the enabled policies to one iteration's modelled device
    /// time.  Decode-shaped policies (DP balance, op overlap, graph
    /// mode) only act on iterations that decode; the EPLB imbalance
    /// factor applies to every MoE forward pass, prefill included.
    fn scale_device_s(&mut self, cost: &CostModel, work: &IterationWork, device_s: f64) -> f64 {
        let n_decode = work.decodes.len() as u64;
        if n_decode == 0 {
            return match &self.eplb {
                Some(e) => device_s * e.factor,
                None => device_s,
            };
        }
        let mut scaled = device_s;

        if let Some(e) = &mut self.eplb {
            // route this iteration's decode tokens through a zipf-skewed
            // expert distribution so the rebalancer sees hot experts
            let n_experts = e.stats.n_experts.max(1) as u64;
            let per_tok = cost.model.experts_per_tok.max(1) as u64;
            for _ in 0..n_decode {
                let ex = (self.rng.zipf(n_experts, EXPERT_ZIPF_ALPHA) - 1) as usize;
                e.stats.record(ex, per_tok);
            }
            scaled *= e.factor;
        }

        if self.policies.dp_balance && work.decodes.len() >= 2 {
            let reqs: Vec<u64> =
                work.decodes.iter().map(|d| d.context_tokens.max(1)).collect();
            let n_cores = cost.hw.n_cube.max(1) as usize;
            let rr = core_straggler(&round_robin_cores(&reqs, n_cores));
            let bal = core_straggler(&balanced_cores(&reqs, n_cores, DP_CORE_SPLIT_TOKENS));
            if rr > 0.0 {
                let ratio = (bal / rr).clamp(DP_MIN_RATIO, 1.0);
                scaled *= 1.0 - DP_ATTENTION_SHARE + DP_ATTENTION_SHARE * ratio;
            }
        }

        if self.policies.op_overlap {
            let kv_tokens: u64 = work.decodes.iter().map(|d| d.context_tokens).sum();
            let step = cost.decode_step(n_decode, kv_tokens);
            let n_cube = cost.hw.n_cube.max(2);
            let n_vector = cost.hw.n_vector.max(2);
            let cube_work = step.compute_s * n_cube as f64;
            let vector_work = step.memory_s * VECTOR_WORK_SHARE * n_vector as f64;
            let cube_ops =
                [OpLoad { workload: 0.65 * cube_work }, OpLoad { workload: 0.35 * cube_work }];
            let vector_ops =
                [OpLoad { workload: 0.7 * vector_work }, OpLoad { workload: 0.3 * vector_work }];
            let serial = serial_makespan(&cube_ops, &vector_ops, 1.0, 1.0, n_cube, n_vector);
            if serial > 0.0 {
                let overlapped =
                    allocate(&cube_ops, &vector_ops, 1.0, 1.0, n_cube, n_vector).makespan;
                let ratio = (overlapped / serial).clamp(OP_MIN_RATIO, 1.0);
                scaled *= 1.0 - OP_OVERLAP_SHARE + OP_OVERLAP_SHARE * ratio;
            }
        }

        if self.policies.graph_mode {
            match select_mode(n_decode, &SIM_DECODE_BUCKETS) {
                LaunchMode::Eager => self.graph_fallbacks += 1,
                mode => {
                    let bucket = match mode {
                        LaunchMode::PartialGraph { bucket, .. } => bucket,
                        _ => n_decode,
                    };
                    if self.warm_buckets.insert(bucket) {
                        self.graph_compiles += 1;
                        scaled += GRAPH_COMPILE_PENALTY_S;
                    } else {
                        self.graph_hits += 1;
                        scaled -= cost.graph_warm_gain_s().min(GRAPH_GAIN_CAP * scaled);
                    }
                }
            }
        }
        scaled
    }
}

/// Observable counters from the executor's policy layer (surfaced by
/// the `simulate` CLI and the policy integration tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PolicyCounters {
    pub eplb_replans: u64,
    pub weight_switches: u64,
    pub graph_compiles: u64,
    pub graph_hits: u64,
    pub graph_fallbacks: u64,
}

impl PolicyCounters {
    /// Export into the unified registry under stable `xllm_policy_*`
    /// names.
    pub fn export_metrics(&self, reg: &mut MetricsRegistry) {
        reg.inc("xllm_policy_eplb_replans_total", self.eplb_replans);
        reg.inc("xllm_policy_weight_switches_total", self.weight_switches);
        reg.inc("xllm_policy_graph_compiles_total", self.graph_compiles);
        reg.inc("xllm_policy_graph_hits_total", self.graph_hits);
        reg.inc("xllm_policy_graph_fallbacks_total", self.graph_fallbacks);
    }

    /// Reconstruct the legacy counter view from a registry (round-trip
    /// of [`PolicyCounters::export_metrics`]).
    pub fn from_registry(reg: &MetricsRegistry) -> PolicyCounters {
        PolicyCounters {
            eplb_replans: reg.counter("xllm_policy_eplb_replans_total"),
            weight_switches: reg.counter("xllm_policy_weight_switches_total"),
            graph_compiles: reg.counter("xllm_policy_graph_compiles_total"),
            graph_hits: reg.counter("xllm_policy_graph_hits_total"),
            graph_fallbacks: reg.counter("xllm_policy_graph_fallbacks_total"),
        }
    }
}

/// Price one planned iteration's device time with the roofline model
/// (shared with `server::PjrtExecutor`, which uses it as the submit-time
/// estimate while the real measurement is in flight).
pub fn model_device_s(cost: &CostModel, spec: Option<SpecConfig>, work: &IterationWork) -> f64 {
    let kv_tokens: u64 = work.decodes.iter().map(|d| d.context_tokens).sum();
    let n_decode = work.decodes.len() as u64;
    let mut duration = 0.0;
    if n_decode > 0 {
        let mut d = cost.decode_step_s(n_decode, kv_tokens);
        if let Some(spec) = spec {
            d *= verify_cost_multiplier(spec.m);
            d += d * draft_cost_fraction();
        }
        duration += d;
    }
    if work.prefill_tokens() > 0 {
        let ctx: u64 = work.prefills.iter().map(|p| p.context_tokens).sum();
        duration += cost.prefill_s(work.prefill_tokens(), ctx / work.prefills.len().max(1) as u64);
    }
    if !work.encodes.is_empty() {
        let patches: u64 = work.encodes.iter().map(|e| e.image_patches).sum();
        let enc = cost.encode_s(patches);
        // dual-stream: encode overlaps the language stream when fused
        duration += if n_decode > 0 || work.prefill_tokens() > 0 {
            enc * dual_stream_encode_exposure()
        } else {
            enc
        };
    }
    duration
}

/// Discrete-event executor over the roofline cost model.
pub struct RooflineExecutor {
    cost: CostModel,
    spec: Option<SpecConfig>,
    rng: Rng,
    /// Host-side planning/dispatch cost charged per iteration as
    /// [`IterationOutcome::host_s`] (default 0.0 — the pre-async
    /// contract).
    host_overhead_s: f64,
    seq: u64,
    /// Seed kept for deriving the (independent) policy RNG stream.
    seed: u64,
    /// Engine-policy state; `None` (the default) prices every iteration
    /// exactly as the seed executor did, bit for bit.
    policy: Option<PolicyState>,
    /// Policy-event trace emission (EPLB replans); off by default.
    trace: TraceHandle,
}

impl RooflineExecutor {
    pub fn new(cost: CostModel, spec: Option<SpecConfig>, seed: u64) -> RooflineExecutor {
        RooflineExecutor {
            cost,
            spec,
            rng: Rng::new(seed),
            host_overhead_s: 0.0,
            seq: 0,
            seed,
            policy: None,
            trace: TraceHandle::off(),
        }
    }

    /// Model a nonzero per-iteration host overhead, the share the async
    /// pipeline hides in virtual time at depth ≥ 2.
    pub fn with_host_overhead(mut self, host_s: f64) -> RooflineExecutor {
        self.host_overhead_s = host_s.max(0.0);
        self
    }

    /// Enable executor-level engine policies (§4).  With every switch
    /// off this is a no-op: no policy state is allocated and pricing
    /// stays bit-identical to the policy-free executor.  EPLB state is
    /// only built when the model is MoE and at least two devices share
    /// the expert placement.
    pub fn with_policies(mut self, policies: EnginePolicies) -> RooflineExecutor {
        if !policies.any() {
            return self;
        }
        let n_devices = self.cost.features.shard.devices().max(1) as usize;
        let eplb = if policies.eplb && self.cost.model.is_moe && n_devices >= 2 {
            let n_experts = self.cost.model.n_experts.max(1) as usize;
            Some(EplbState {
                stats: ExpertStats::new(n_experts),
                table: static_table(n_experts, n_devices),
                controller: WeightUpdateController::new(n_devices),
                buffers: (0..n_devices).map(|_| DoubleBuffer::new()).collect(),
                factor: 1.0,
                replans: 0,
            })
        } else {
            None
        };
        self.policy = Some(PolicyState {
            policies,
            rng: Rng::new(self.seed ^ POLICY_RNG_SALT),
            eplb,
            warm_buckets: HashSet::new(),
            graph_hits: 0,
            graph_compiles: 0,
            graph_fallbacks: 0,
        });
        self
    }

    /// Policy-layer counters, `None` when no policy is enabled.
    pub fn policy_counters(&self) -> Option<PolicyCounters> {
        self.policy.as_ref().map(|p| PolicyCounters {
            eplb_replans: p.eplb.as_ref().map_or(0, |e| e.replans),
            weight_switches: p.eplb.as_ref().map_or(0, |e| e.controller.switches),
            graph_compiles: p.graph_compiles,
            graph_hits: p.graph_hits,
            graph_fallbacks: p.graph_fallbacks,
        })
    }
}

impl Executor for RooflineExecutor {
    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn submit_iteration(
        &mut self,
        instance: InstanceId,
        _now_s: f64,
        work: &IterationWork,
    ) -> IterationTicket {
        let mut device_s = model_device_s(&self.cost, self.spec, work);
        if let Some(p) = &mut self.policy {
            device_s = p.scale_device_s(&self.cost, work, device_s);
        }
        let host_s = if work.is_empty() { 0.0 } else { self.host_overhead_s };
        // pp drain tail: the window where the first pipeline stage is
        // already free for the next iteration's micro-batches (exactly
        // 0.0 at pp == 1 — the unsharded timeline is untouched)
        let ramp_s = device_s * self.cost.pp_ramp_fraction();
        self.seq += 1;
        IterationTicket {
            instance,
            seq: self.seq,
            est: IterationOutcome { host_s, device_s, ramp_s },
        }
    }

    fn poll_complete(&mut self, ticket: IterationTicket) -> IterationOutcome {
        // modelled prices are exact at submit time: the estimate is the
        // outcome, at any pipeline depth
        ticket.est
    }

    fn set_trace(&mut self, trace: TraceHandle) {
        self.trace = trace;
    }

    fn on_control_tick(&mut self, now_s: f64) {
        let Some(p) = &mut self.policy else { return };
        let Some(e) = &mut p.eplb else { return };
        // no routed traffic since the last tick: imbalance over an
        // all-zero window is meaningless, hold the current table
        if e.stats.window_counts().iter().all(|&c| c == 0) {
            return;
        }
        e.stats.roll_window();
        let n_devices = e.table.n_devices;
        let (before, after, table) = rebalance_round(&e.stats, n_devices, n_devices, &e.table);
        if after <= before {
            // stage the new placement: preload every worker's spare
            // buffer, switch all of them only once the controller has
            // seen every worker ready (§4.4.2 transactional swap)
            let mut switch_all = false;
            for (w, b) in e.buffers.iter_mut().enumerate() {
                b.preload(table.version);
                if e.controller.worker_ready(w) {
                    switch_all = true;
                }
            }
            if switch_all {
                for b in &mut e.buffers {
                    let _ = b.switch();
                }
            }
            e.table = table;
            e.replans += 1;
            self.trace.instant(now_s, None, None, InstantKind::EplbReplan);
        }
        // cost multiplier: achieved imbalance vs the static assumption
        // already priced into the roofline's MoE FLOP term
        let assumed = self.cost.moe_imbalance_assumed();
        e.factor = (e.table.imbalance(&e.stats.load()) / assumed).clamp(EPLB_MIN_FACTOR, 1.0);
    }

    fn decode_emission(&mut self, _instance: InstanceId, _req: RequestId) -> u64 {
        match self.spec {
            Some(spec) => {
                let expect = expected_tokens_per_round(spec.m, spec.acceptance);
                let frac = expect.fract();
                let mut t = expect.trunc() as u64;
                if self.rng.chance(frac) {
                    t += 1;
                }
                t.max(1)
            }
            None => 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orchestrator::{DecodeWork, PrefillWork};
    use crate::model::{ascend_910b, catalog};
    use crate::sim::roofline::EngineFeatures;

    fn exec(spec: Option<SpecConfig>) -> RooflineExecutor {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        RooflineExecutor::new(cost, spec, 42)
    }

    #[test]
    fn empty_work_costs_nothing() {
        let mut e = exec(None);
        assert_eq!(e.begin_iteration(0, 0.0, &IterationWork::default()), 0.0);
    }

    #[test]
    fn duration_matches_cost_model() {
        let mut e = exec(None);
        let work = IterationWork {
            decodes: vec![DecodeWork { req: 1, context_tokens: 512 }],
            prefills: vec![PrefillWork { req: 2, tokens: 256, context_tokens: 0 }],
            encodes: vec![],
        };
        let want = e.cost.decode_step_s(1, 512) + e.cost.prefill_s(256, 0);
        let got = e.begin_iteration(0, 0.0, &work);
        assert!((got - want).abs() < 1e-12, "got {got}, want {want}");
    }

    #[test]
    fn default_kv_hooks_are_cost_only() {
        use crate::coordinator::orchestrator::KvChainPayload;
        // the roofline backend ships no real blocks: movement stays a
        // pure `TransferEngine` cost at the control plane, so golden
        // fixtures are untouched by the export/import seam
        let mut e = exec(None);
        assert!(e.export_chain(&[1, 2, 3]).is_none());
        e.import_chain(KvChainPayload::default()); // no-op by contract
        e.admitted(0, &crate::workload::RequestSpec::text(0.0, 64, 4)); // no-op
        assert_eq!(e.begin_iteration(0, 0.0, &IterationWork::default()), 0.0);
    }

    fn moe_exec(policies: EnginePolicies) -> RooflineExecutor {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("DeepSeek-R1").unwrap(),
            EngineFeatures::xllm(16),
        );
        RooflineExecutor::new(cost, None, 42).with_policies(policies)
    }

    fn decode_work(n: u64) -> IterationWork {
        IterationWork {
            decodes: (0..n).map(|i| DecodeWork { req: i, context_tokens: 256 + 64 * i }).collect(),
            prefills: vec![],
            encodes: vec![],
        }
    }

    #[test]
    fn policies_off_prices_bit_identically() {
        let work = IterationWork {
            decodes: vec![DecodeWork { req: 1, context_tokens: 512 }],
            prefills: vec![PrefillWork { req: 2, tokens: 256, context_tokens: 0 }],
            encodes: vec![],
        };
        let mut plain = exec(None);
        let mut off = exec(None).with_policies(EnginePolicies::default());
        assert!(off.policy_counters().is_none(), "all-off must allocate no policy state");
        let a = plain.begin_iteration(0, 0.0, &work);
        let b = off.begin_iteration(0, 0.0, &work);
        assert_eq!(a.to_bits(), b.to_bits(), "all-off pricing must be bit-identical");
    }

    #[test]
    fn eplb_factor_never_regresses_and_replans() {
        let mut e = moe_exec(EnginePolicies { eplb: true, ..EnginePolicies::default() });
        let work = decode_work(32);
        let base = model_device_s(&e.cost, None, &work);
        for _ in 0..8 {
            e.begin_iteration(0, 0.0, &work);
            e.on_control_tick(0.0);
        }
        let priced = e.begin_iteration(0, 0.0, &work);
        assert!(priced <= base + 1e-12, "eplb must never regress: {priced} vs {base}");
        let c = e.policy_counters().unwrap();
        assert!(c.eplb_replans > 0, "skewed routing should trigger a re-plan");
        assert!(c.weight_switches > 0, "installed tables ride the staged weight swap");
    }

    #[test]
    fn graph_warm_hit_cheaper_than_cold_compile() {
        let mut e = moe_exec(EnginePolicies { graph_mode: true, ..EnginePolicies::default() });
        let work = decode_work(16); // exact bucket: full-graph launch
        let first = e.begin_iteration(0, 0.0, &work);
        let second = e.begin_iteration(0, 0.0, &work);
        assert!(second < first, "warm hit {second} should undercut cold compile {first}");
        let c = e.policy_counters().unwrap();
        assert_eq!(c.graph_compiles, 1);
        assert_eq!(c.graph_hits, 1);
        assert_eq!(c.graph_fallbacks, 0);
    }

    #[test]
    fn dp_and_overlap_scale_down_decode_steps() {
        let mut on = moe_exec(EnginePolicies {
            dp_balance: true,
            op_overlap: true,
            ..EnginePolicies::default()
        });
        let mut off = moe_exec(EnginePolicies::default());
        let work = decode_work(48); // skewed context lengths straggle round-robin cores
        let a = on.begin_iteration(0, 0.0, &work);
        let b = off.begin_iteration(0, 0.0, &work);
        assert!(a <= b, "balanced cores + Eq.(1) overlap must not slow decode: {a} vs {b}");
    }

    #[test]
    fn policy_counters_round_trip_the_registry() {
        let c = PolicyCounters {
            eplb_replans: 3,
            weight_switches: 2,
            graph_compiles: 5,
            graph_hits: 9,
            graph_fallbacks: 1,
        };
        let mut reg = MetricsRegistry::new();
        c.export_metrics(&mut reg);
        assert_eq!(PolicyCounters::from_registry(&reg), c);
    }

    #[test]
    fn plain_decode_emits_one_token() {
        let mut e = exec(None);
        for _ in 0..10 {
            assert_eq!(e.decode_emission(0, 7), 1);
        }
    }

    #[test]
    fn spec_decode_emits_expected_rate() {
        let spec = SpecConfig { m: 4, acceptance: 0.75 };
        let mut e = exec(Some(spec));
        let n = 10_000u64;
        let total: u64 = (0..n).map(|_| e.decode_emission(0, 7)).sum();
        let expect = expected_tokens_per_round(spec.m, spec.acceptance);
        let mean = total as f64 / n as f64;
        assert!(
            (mean - expect).abs() < 0.05,
            "mean emission {mean} far from expectation {expect}"
        );
    }
}
