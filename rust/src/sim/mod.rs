//! Discrete-event cluster simulation (the Ascend-testbed substitute).
//!
//! * [`clock`] — deterministic event queue.
//! * [`roofline`] — the paper's roofline + online-factor-learning cost
//!   model, parameterized by engine features so configuration ablations
//!   reproduce the baseline frameworks.
//! * [`cluster`] — multi-instance serving simulation driving the
//!   coordinator policies over simulated time.

pub mod clock;
pub mod cluster;
pub mod roofline;

pub use clock::{EventQueue, SimTime};
pub use roofline::{Bound, CostModel, EngineFeatures, GraphMode, StepBreakdown};
