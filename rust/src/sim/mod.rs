//! Discrete-event cluster simulation (the Ascend-testbed substitute).
//!
//! * [`clock`] — deterministic event queue.
//! * [`roofline`] — the paper's roofline + online-factor-learning cost
//!   model, parameterized by engine features so configuration ablations
//!   reproduce the baseline frameworks.
//! * [`executor`] — the roofline-cost [`crate::coordinator::Executor`]
//!   backend for the shared serving orchestrator.
//! * [`cluster`] — cluster configuration wiring the orchestrator +
//!   roofline executor into a multi-instance simulation.
//! * [`fleet`] — N replica clusters under one
//!   [`crate::service::controlplane::ControlPlane`] (registry, global
//!   prefix index, cache-aware routing, failover).

pub mod clock;
pub mod cluster;
pub mod executor;
pub mod fleet;
pub mod roofline;

pub use clock::{EventQueue, SimTime};
pub use executor::RooflineExecutor;
pub use fleet::{run_fleet, FleetConfig};
pub use crate::model::ShardSpec;
pub use roofline::{Bound, CostModel, EngineFeatures, GraphMode, StepBreakdown};
