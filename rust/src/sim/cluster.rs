//! Cluster serving simulation: the Ascend-testbed substitute.
//!
//! Drives the real coordinator/service/engine policy code over a
//! discrete-event clock with roofline step costs: request arrival →
//! (encode) → dispatch → chunked prefill iterations → KV handoff →
//! batched decode iterations → completion, with dynamic PD role
//! switching, online/offline co-location, speculative decoding, fault
//! injection, and the prefix cache all live.
//!
//! Every paper bench (fig14..fig23, tables 3–8) is a configuration of
//! [`ClusterConfig`] + a workload from `workload::scenarios`.

use std::collections::HashMap;

use crate::coordinator::{
    plan_iteration, plan_role_switches, BatchConfig, DispatchPolicy, ElasticPools,
    GlobalScheduler, InstanceId, InstanceState, InstanceView, Phase, Placement, PoolKind,
    Request, RequestId, RoleFlip,
};
use crate::engine::specdecode::{expected_tokens_per_round, verify_cost_multiplier, SpecConfig};
use crate::metrics::{ServingReport, Slo};
use crate::model::{HardwareSpec, ModelSpec};
use crate::service::colocation::{admit_offline_decodes, ColocationConfig};
use crate::service::epd::{dual_stream_encode_exposure, EpdStrategy};
use crate::service::fault::{plan_recovery, InterruptedRequest, RecoveryAction, RecoveryModel};
use crate::service::kvstore::{hash_chain, Tier, TieredCache, TransferEngine};
use crate::sim::clock::EventQueue;
use crate::sim::roofline::{CostModel, EngineFeatures};
use crate::util::Rng;
use crate::workload::RequestSpec;

/// How instances split work across phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingMode {
    /// Every instance serves prefill + decode (chunked continuous batch).
    Colocated,
    /// PD disaggregation with `n_prefill` initial prefill instances;
    /// `dynamic` enables SLO-aware role switching (§3.2).
    Disaggregated { n_prefill: usize, dynamic: bool },
}

/// Online-offline co-location variants (Fig 23).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColocationMode {
    /// Offline requests treated exactly like online (baseline P/D).
    BaselinePd,
    /// Offline dispatched only when no online request is waiting.
    OnlinePriority,
    /// The paper's policy: latency-constrained pools + admission control
    /// + preemption (xLLM-OOC).
    XllmOoc,
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_instances: usize,
    /// Dedicated encode instances (EPD E pool).
    pub n_encode: usize,
    pub hw: HardwareSpec,
    pub model: ModelSpec,
    pub features: EngineFeatures,
    pub mode: ServingMode,
    pub dispatch: DispatchPolicy,
    pub slo: Slo,
    pub batch: BatchConfig,
    pub colocation: Option<(ColocationMode, ColocationConfig)>,
    /// Multimodal phase placement (None = text-only serving).
    pub epd: Option<EpdStrategy>,
    pub spec: Option<SpecConfig>,
    /// Injected faults: (time, instance).
    pub faults: Vec<(f64, usize)>,
    pub recovery: RecoveryModel,
    pub monitor_interval_s: f64,
    /// Enable the global prefix cache (§3.4).
    pub prefix_cache: bool,
    pub seed: u64,
}

impl ClusterConfig {
    /// A sensible default: colocated serving, SLO-aware dispatch.
    pub fn new(
        n_instances: usize,
        hw: HardwareSpec,
        model: ModelSpec,
        features: EngineFeatures,
    ) -> Self {
        let kv_capacity = (hw.hbm_bytes * features.tp as f64 * 0.6
            / model.kv_bytes_per_token().max(1.0)) as u64;
        ClusterConfig {
            n_instances,
            n_encode: 0,
            hw,
            model,
            features,
            mode: ServingMode::Colocated,
            dispatch: DispatchPolicy::SloAware,
            slo: Slo::UNCONSTRAINED,
            batch: BatchConfig {
                kv_capacity_tokens: kv_capacity.max(4096),
                ..BatchConfig::default()
            },
            colocation: None,
            epd: None,
            spec: None,
            faults: Vec::new(),
            recovery: RecoveryModel::default(),
            monitor_interval_s: 0.25,
            prefix_cache: false,
            seed: 0xD15EA5E,
        }
    }
}

/// Simulation output: serving metrics + policy counters.
#[derive(Debug)]
pub struct SimResult {
    pub report: ServingReport,
    pub role_flips: u64,
    pub preemptions: u64,
    pub migrations: u64,
    pub recoveries: u64,
    pub prefix_hits: u64,
    pub iterations: u64,
    pub events: u64,
    /// Per-instance (iterations, tokens generated) for utilization checks.
    pub per_instance: Vec<(u64, u64)>,
}

#[derive(Debug, Clone)]
enum Ev {
    Arrive(usize),
    IterDone(InstanceId),
    KvReady(InstanceId),
    Monitor,
    Fault(usize),
    Recover(usize),
}

struct PlannedIteration {
    decode_ids: Vec<RequestId>,
    prefill_chunks: Vec<(RequestId, u64, u64)>,
    encode_ids: Vec<RequestId>,
    duration: f64,
}

/// The simulator itself.
pub struct ClusterSim {
    cfg: ClusterConfig,
    cost: CostModel,
    xfer: TransferEngine,
    queue: EventQueue<Ev>,
    instances: Vec<InstanceState>,
    pools: ElasticPools,
    scheduler: GlobalScheduler,
    requests: HashMap<RequestId, Request>,
    specs: Vec<RequestSpec>,
    current: HashMap<InstanceId, PlannedIteration>,
    /// Where each request's prefill ran (decode placement preference).
    prefill_home: HashMap<RequestId, InstanceId>,
    prefix_cache: TieredCache,
    report: ServingReport,
    rng: Rng,
    role_flips: u64,
    preemptions: u64,
    migrations: u64,
    recoveries: u64,
    prefix_hits: u64,
    iterations: u64,
}

impl ClusterSim {
    pub fn new(cfg: ClusterConfig) -> ClusterSim {
        let cost = CostModel::new(cfg.hw.clone(), cfg.model.clone(), cfg.features.clone());
        let (n_p, n_d) = match cfg.mode {
            ServingMode::Colocated => (0, cfg.n_instances),
            ServingMode::Disaggregated { n_prefill, .. } => {
                let p = n_prefill.min(cfg.n_instances);
                (p, cfg.n_instances - p)
            }
        };
        let pools = ElasticPools::new(n_p, n_d, cfg.n_encode);
        let instances: Vec<InstanceState> = (0..cfg.n_instances + cfg.n_encode)
            .map(|id| InstanceState::new(id, cost.clone(), cfg.batch))
            .collect();
        let scheduler = GlobalScheduler::new(cfg.dispatch);
        let seed = cfg.seed;
        ClusterSim {
            xfer: TransferEngine::default(),
            cost,
            queue: EventQueue::new(),
            instances,
            pools,
            scheduler,
            requests: HashMap::new(),
            specs: Vec::new(),
            current: HashMap::new(),
            prefill_home: HashMap::new(),
            prefix_cache: TieredCache::new(64, 1 << 22, 1 << 24, 1 << 26),
            report: ServingReport::new(),
            rng: Rng::new(seed),
            role_flips: 0,
            preemptions: 0,
            migrations: 0,
            recoveries: 0,
            prefix_hits: 0,
            iterations: 0,
            cfg,
        }
    }

    /// Run the workload to completion; returns metrics + counters.
    pub fn run(mut self, workload: Vec<RequestSpec>) -> SimResult {
        self.specs = workload;
        for (i, spec) in self.specs.iter().enumerate() {
            self.queue.schedule_at(spec.arrival_s, Ev::Arrive(i));
        }
        for (t, inst) in self.cfg.faults.clone() {
            self.queue.schedule_at(t, Ev::Fault(inst));
        }
        self.queue.schedule_at(self.cfg.monitor_interval_s, Ev::Monitor);

        // hard cap to guarantee termination on pathological configs
        let max_events = 200_000_000u64;
        while let Some((_, ev)) = self.queue.next() {
            match ev {
                Ev::Arrive(i) => self.on_arrive(i),
                Ev::IterDone(id) => self.on_iter_done(id),
                Ev::KvReady(id) => self.kick(id),
                Ev::Monitor => self.on_monitor(),
                Ev::Fault(id) => self.on_fault(id),
                Ev::Recover(id) => self.on_recover(id),
            }
            if self.queue.processed() > max_events {
                break;
            }
            if self.all_done() && self.queue.len() <= 1 {
                break; // only the monitor tick remains
            }
        }
        SimResult {
            report: self.report,
            role_flips: self.pools.flips.max(self.role_flips),
            preemptions: self.preemptions,
            migrations: self.migrations,
            recoveries: self.recoveries,
            prefix_hits: self.prefix_hits,
            iterations: self.iterations,
            events: self.queue.processed(),
            per_instance: self
                .instances
                .iter()
                .map(|i| (i.monitor.iterations, i.monitor.tokens_generated))
                .collect(),
        }
    }

    fn all_done(&self) -> bool {
        self.report.n_requests() >= self.specs.len()
    }

    fn view(&self, id: InstanceId) -> InstanceView {
        let inst = &self.instances[id];
        let queued_prefill_tokens: u64 = inst
            .prefill_queue
            .iter()
            .filter_map(|r| self.requests.get(r))
            .map(|r| r.prefill_remaining())
            .sum();
        let running_tokens: u64 = inst
            .running
            .iter()
            .filter_map(|r| self.requests.get(r))
            .map(|r| r.context_len())
            .sum();
        InstanceView {
            id,
            queued_prefill_tokens,
            running_tokens,
            n_running: inst.running.len(),
            n_queued: inst.prefill_queue.len(),
            kv_used: inst.kv_tokens,
            kv_capacity: inst.batch.kv_capacity_tokens,
            failed: inst.failed,
            ema_token_interval: inst.monitor.ema_token_interval,
            ema_ttft: inst.monitor.ema_ttft,
        }
    }

    fn views(&self, ids: &[InstanceId]) -> Vec<InstanceView> {
        ids.iter().map(|&i| self.view(i)).collect()
    }

    fn alive(&self, ids: Vec<InstanceId>) -> Vec<InstanceId> {
        ids.into_iter().filter(|&i| !self.instances[i].failed).collect()
    }

    // --- arrival -------------------------------------------------------

    fn on_arrive(&mut self, idx: usize) {
        let spec = self.specs[idx];
        let id = idx as RequestId;
        let mut req = Request::new(id, spec, self.cfg.slo);

        // prefix cache lookup (§3.4): shared system prompts skip prefill
        if self.cfg.prefix_cache && spec.shared_prefix > 0 {
            let tokens: Vec<u32> = (0..spec.shared_prefix as u32)
                .map(|t| ((spec.prefix_group as u32) << 16) | t)
                .collect();
            let chain = hash_chain(&tokens, self.prefix_cache.block_tokens as usize);
            let (blocks, _) = self.prefix_cache.match_prefix(&chain);
            let hit = (blocks as u64 * self.prefix_cache.block_tokens)
                .min(spec.shared_prefix)
                .min(spec.input_tokens.saturating_sub(1));
            if hit > 0 {
                req.prefix_hit_tokens = hit;
                self.prefix_hits += 1;
            }
            self.prefix_cache.insert_chain(&chain, Tier::Dram);
        }

        let multimodal = spec.is_multimodal();
        self.requests.insert(id, req);
        if multimodal && self.cfg.epd.is_some() {
            self.route_encode(id);
        } else {
            if multimodal {
                // no EPD support: encode fused into prefill on one instance
                self.requests.get_mut(&id).unwrap().finish_encode();
            }
            self.route_prefill(id);
        }
    }

    fn route_encode(&mut self, id: RequestId) {
        use crate::service::epd::placement;
        let strategy = self.cfg.epd.unwrap();
        let place = placement(strategy);
        let pool_ids = match place.encode_pool {
            0 => self.alive(self.pools.prefill_capable()),
            1 => self.alive(self.pools.decode_capable()),
            _ => self.alive(self.pools.encode_capable()),
        };
        let pool_ids = if pool_ids.is_empty() {
            self.alive((0..self.instances.len()).collect())
        } else {
            pool_ids
        };
        let target = pool_ids
            .into_iter()
            .min_by_key(|&i| self.instances[i].encode_queue.len())
            .expect("no instance for encode");
        self.instances[target].encode_queue.push_back(id);
        self.kick(target);
    }

    fn route_prefill(&mut self, id: RequestId) {
        let req = &self.requests[&id];
        let input = req.prefill_remaining();
        let is_online = req.is_online();

        let (primary_ids, fallback_ids) = match self.cfg.mode {
            ServingMode::Colocated => {
                (self.alive((0..self.cfg.n_instances).collect()), Vec::new())
            }
            ServingMode::Disaggregated { .. } => (
                self.alive(self.pools.of_kind(PoolKind::Prefill)),
                self.alive(self.pools.of_kind(PoolKind::DecodeToPrefill)),
            ),
        };
        let primary = self.views(&primary_ids);
        let fallback = self.views(&fallback_ids);
        let slo = if is_online { self.cfg.slo } else { Slo::UNCONSTRAINED };
        let placement =
            self.scheduler.place_prefill(&primary, &fallback, &self.cost, input, &slo);
        let target = match placement {
            Placement::Instance(i) => i,
            Placement::NeedFlip => {
                // dynamic PD: convert the lightest decode instance
                let flipped =
                    if let ServingMode::Disaggregated { dynamic: true, .. } = self.cfg.mode {
                        let candidates = self.alive(self.pools.decode_capable());
                        candidates
                            .into_iter()
                            .min_by_key(|&i| self.view(i).running_tokens)
                            .filter(|&i| self.pools.flip_to_prefill(i, 2))
                    } else {
                        None
                    };
                match flipped {
                    Some(i) => i,
                    None => {
                        // no flip possible: least-loaded anywhere
                        match primary
                            .iter()
                            .chain(fallback.iter())
                            .min_by_key(|v| v.queued_prefill_tokens)
                        {
                            Some(v) => v.id,
                            None => {
                                let now = self.queue.now();
                                let r = self.requests.get_mut(&id).unwrap();
                                r.fail(now);
                                if let Some(o) = r.outcome() {
                                    self.report.record(o);
                                }
                                return;
                            }
                        }
                    }
                }
            }
        };
        self.instances[target].prefill_queue.push_back(id);
        self.kick(target);
    }

    // --- iteration execution -------------------------------------------

    fn kick(&mut self, id: InstanceId) {
        let inst = &self.instances[id];
        if inst.busy || inst.failed || !inst.has_work() {
            return;
        }
        let pool = self.pools.kind(id);
        let colocated = matches!(self.cfg.mode, ServingMode::Colocated);

        let serves_prefill = colocated || pool.serves_prefill();
        // stateless instances (§3.2): pool membership steers NEW work, but
        // an instance always drains what it already holds (e.g. offline
        // decodes placed on latency-relaxed instances under co-location)
        let serves_decode = colocated || pool.serves_decode() || !inst.running.is_empty();
        let serves_encode = pool.serves_encode() || self.cfg.epd.is_some() || colocated;

        let running: Vec<&Request> = if serves_decode {
            inst.running.iter().filter_map(|r| self.requests.get(r)).collect()
        } else {
            Vec::new()
        };
        let queued: Vec<&Request> = if serves_prefill {
            inst.prefill_queue.iter().filter_map(|r| self.requests.get(r)).collect()
        } else {
            Vec::new()
        };
        let encodes: Vec<&Request> = if serves_encode {
            inst.encode_queue.iter().filter_map(|r| self.requests.get(r)).collect()
        } else {
            Vec::new()
        };
        if running.is_empty() && queued.is_empty() && encodes.is_empty() {
            return;
        }

        // online-priority co-location: offline prefill waits while any
        // online request is queued (dispatch-time priority, no runtime
        // admission control — the Fig 23 middle policy)
        let queued: Vec<&Request> =
            if let Some((ColocationMode::OnlinePriority, _)) = self.cfg.colocation {
                let any_online = queued.iter().any(|r| r.is_online());
                if any_online {
                    queued.into_iter().filter(|r| r.is_online()).collect()
                } else {
                    queued
                }
            } else {
                queued
            };

        let mut plan = plan_iteration(&running, &queued, &encodes, &inst.batch);

        // co-location admission control: cap offline decodes so the step
        // stays within the online TPOT budget (§3.1 Solution 1)
        if let Some((ColocationMode::XllmOoc, coloc)) = &self.cfg.colocation {
            let online: Vec<RequestId> = plan
                .decode_ids
                .iter()
                .copied()
                .filter(|r| self.requests[r].is_online())
                .collect();
            let offline: Vec<RequestId> = plan
                .decode_ids
                .iter()
                .copied()
                .filter(|r| !self.requests[r].is_online())
                .collect();
            if !offline.is_empty() {
                let online_kv: u64 =
                    online.iter().map(|r| self.requests[r].context_len()).sum();
                let mean_ctx = (offline
                    .iter()
                    .map(|r| self.requests[r].context_len())
                    .sum::<u64>()
                    / offline.len() as u64)
                    .max(1);
                let admit = admit_offline_decodes(
                    &self.cost,
                    online.len().max(1) as u64,
                    online_kv,
                    offline.len() as u64,
                    mean_ctx,
                    coloc,
                ) as usize;
                if admit < offline.len() {
                    self.preemptions += (offline.len() - admit) as u64;
                    let keep: Vec<RequestId> = offline.iter().copied().take(admit).collect();
                    plan.decode_ids = online.into_iter().chain(keep).collect();
                }
            }
        }
        self.preemptions += plan.preempted.len() as u64;

        if plan.is_empty() {
            return;
        }

        // iteration duration from the roofline model
        let kv_tokens: u64 =
            plan.decode_ids.iter().map(|r| self.requests[r].context_len()).sum();
        let n_decode = plan.decode_ids.len() as u64;
        let mut duration = 0.0;
        if n_decode > 0 {
            let mut d = self.cost.decode_step_s(n_decode, kv_tokens);
            if let Some(spec) = self.cfg.spec {
                d *= verify_cost_multiplier(spec.m);
                d += d * crate::engine::specdecode::draft_cost_fraction();
            }
            duration += d;
        }
        if plan.prefill_tokens() > 0 {
            let ctx: u64 = plan.prefill_chunks.iter().map(|(_, _, c)| *c).sum();
            duration += self
                .cost
                .prefill_s(plan.prefill_tokens(), ctx / plan.prefill_chunks.len().max(1) as u64);
        }
        if !plan.encode_ids.is_empty() {
            let patches: u64 = plan
                .encode_ids
                .iter()
                .map(|r| self.requests[r].spec.image_patches)
                .sum();
            let enc = self.cost.encode_s(patches);
            // dual-stream: encode overlaps the language stream when fused
            duration += if n_decode > 0 || plan.prefill_tokens() > 0 {
                enc * dual_stream_encode_exposure()
            } else {
                enc
            };
        }
        duration = duration.max(1e-6);

        let planned = PlannedIteration {
            decode_ids: plan.decode_ids,
            prefill_chunks: plan.prefill_chunks,
            encode_ids: plan.encode_ids,
            duration,
        };
        self.instances[id].busy = true;
        self.current.insert(id, planned);
        self.queue.schedule_in(duration, Ev::IterDone(id));
    }

    fn on_iter_done(&mut self, id: InstanceId) {
        let now = self.queue.now();
        let plan = match self.current.remove(&id) {
            Some(p) => p,
            None => return,
        };
        if self.instances[id].failed {
            self.instances[id].busy = false;
            return; // fault handler already migrated the work
        }
        // NOTE: busy stays true until bookkeeping completes, so re-entrant
        // kick() calls (e.g. from place_decode_for back onto this
        // instance) cannot snapshot a stale plan.
        self.iterations += 1;

        // encodes complete
        for rid in &plan.encode_ids {
            if let Some(r) = self.requests.get_mut(rid) {
                r.finish_encode();
            }
            self.instances[id].encode_queue.retain(|x| x != rid);
            self.route_prefill(*rid);
        }

        // prefill chunks advance
        for (rid, tokens, _) in &plan.prefill_chunks {
            let done = {
                let r = match self.requests.get_mut(rid) {
                    Some(r) => r,
                    None => continue,
                };
                self.instances[id].kv_tokens += tokens;
                r.advance_prefill(*tokens, now)
            };
            if done {
                let (finished, ttft, ctx, input) = {
                    let r = &self.requests[rid];
                    (
                        r.phase == Phase::Done,
                        r.first_token_s.unwrap_or(now) - r.spec.arrival_s,
                        r.context_len(),
                        r.spec.input_tokens,
                    )
                };
                self.instances[id].prefill_queue.retain(|x| x != rid);
                self.instances[id].monitor.observe_ttft(ttft);
                // feed the TTFT predictor (online factor learning)
                self.scheduler.predictor.observe(&self.cost, 0, input, ttft.max(1e-6));
                if finished {
                    self.instances[id].kv_tokens =
                        self.instances[id].kv_tokens.saturating_sub(ctx);
                    self.finish(*rid);
                } else {
                    self.prefill_home.insert(*rid, id);
                    self.place_decode_for(*rid, id, ctx);
                }
            }
        }

        // decodes advance
        let iter_dur = plan.duration;
        let mut finished: Vec<RequestId> = Vec::new();
        for rid in &plan.decode_ids {
            let tokens = match self.cfg.spec {
                Some(spec) => {
                    let expect = expected_tokens_per_round(spec.m, spec.acceptance);
                    let frac = expect.fract();
                    let mut t = expect.trunc() as u64;
                    if self.rng.chance(frac) {
                        t += 1;
                    }
                    t.max(1)
                }
                None => 1,
            };
            let done = {
                let r = match self.requests.get_mut(rid) {
                    Some(r) => r,
                    None => continue,
                };
                let emitted = tokens.min(r.decode_remaining());
                self.instances[id].kv_tokens += emitted;
                r.advance_decode(tokens, now)
            };
            let per_token = iter_dur / tokens as f64;
            self.instances[id].monitor.observe_token_interval(per_token);
            self.instances[id].monitor.observe_iteration(tokens);
            if done {
                finished.push(*rid);
            }
        }
        for rid in finished {
            let ctx = self.requests[&rid].context_len();
            self.instances[id].running.retain(|x| *x != rid);
            self.instances[id].kv_tokens =
                self.instances[id].kv_tokens.saturating_sub(ctx);
            self.finish(rid);
        }

        self.instances[id].busy = false;
        // layer-2 reactive workload migration (§4.4.3): at iteration
        // boundaries this instance's running set is in no executing plan,
        // so whole sequences can move to under-loaded peers safely.
        if self.cfg.features.dp_balance {
            self.rebalance_from(id);
        }
        self.kick(id);
    }

    /// Reactive inter-instance decode migration (paper §4.4.3 layer 2).
    ///
    /// If this instance's decode token load exceeds the cluster mean by
    /// more than the tolerance and a peer sits well below it, migrate the
    /// smallest running sequences over (KV transfer modelled via KvReady).
    fn rebalance_from(&mut self, id: InstanceId) {
        const TOLERANCE_HI: f64 = 1.25;
        const TOLERANCE_LO: f64 = 0.80;
        const MAX_MOVES: usize = 4;
        let colocated = matches!(self.cfg.mode, ServingMode::Colocated);
        let peers: Vec<InstanceId> = if colocated {
            self.alive((0..self.cfg.n_instances).collect())
        } else {
            self.alive(self.pools.decode_capable())
        };
        if peers.len() < 2 || !peers.contains(&id) {
            return;
        }
        let load = |s: &Self, i: InstanceId| -> u64 {
            s.instances[i]
                .running
                .iter()
                .filter_map(|r| s.requests.get(r))
                .map(|r| r.context_len())
                .sum()
        };
        let mine = load(self, id);
        let total: u64 = peers.iter().map(|&p| load(self, p)).sum();
        let mean = total as f64 / peers.len() as f64;
        if mean <= 0.0 || (mine as f64) < mean * TOLERANCE_HI {
            return;
        }
        // smallest sequences first: cheapest KV transfers
        let mut mine_reqs: Vec<(u64, RequestId)> = self.instances[id]
            .running
            .iter()
            .filter_map(|r| self.requests.get(r).map(|q| (q.context_len(), *r)))
            .collect();
        mine_reqs.sort();
        let mut moved = 0usize;
        let mut my_load = mine as f64;
        for (ctx, rid) in mine_reqs {
            if moved >= MAX_MOVES || my_load < mean * TOLERANCE_HI {
                break;
            }
            let target = peers
                .iter()
                .copied()
                .filter(|&p| p != id)
                .min_by_key(|&p| load(self, p));
            let target = match target {
                Some(t) if (load(self, t) as f64) < mean * TOLERANCE_LO => t,
                _ => break,
            };
            if self.instances[target].running.len() >= self.cfg.batch.max_decode_seqs
                || self.instances[target].kv_free() < ctx
            {
                break;
            }
            self.instances[id].running.retain(|x| *x != rid);
            self.instances[id].kv_tokens = self.instances[id].kv_tokens.saturating_sub(ctx);
            self.instances[target].running.push(rid);
            self.instances[target].kv_tokens += ctx;
            if let Some(r) = self.requests.get_mut(&rid) {
                r.migrations += 1;
            }
            self.migrations += 1;
            let delay = self.cost.kv_transfer_s(ctx);
            self.queue.schedule_in(delay, Ev::KvReady(target));
            my_load -= ctx as f64;
            moved += 1;
        }
    }

    /// Place a request that just finished prefill into a decode batch.
    fn place_decode_for(&mut self, rid: RequestId, home: InstanceId, ctx: u64) {
        let colocated = matches!(self.cfg.mode, ServingMode::Colocated);
        // §3.1 latency-constrained decoupling: under xLLM-OOC, OFFLINE
        // decode may run in either pool (it is not latency-strict), which
        // is the capacity the co-location policy exploits
        let offline_flexible = matches!(self.cfg.colocation, Some((ColocationMode::XllmOoc, _)))
            && self.requests.get(&rid).map(|r| !r.is_online()).unwrap_or(false);
        let candidates: Vec<InstanceId> = if colocated || offline_flexible {
            self.alive((0..self.cfg.n_instances).collect())
        } else {
            self.alive(self.pools.decode_capable())
        };
        let views = self.views(&candidates);
        let prefer = if colocated || self.pools.kind(home).serves_decode() {
            Some(home)
        } else {
            None
        };
        let target = self
            .scheduler
            .place_decode(&views, prefer, ctx, self.cfg.batch.max_decode_seqs)
            .or_else(|| candidates.first().copied());
        let target = match target {
            Some(t) => t,
            None => {
                let now = self.queue.now();
                let r = self.requests.get_mut(&rid).unwrap();
                r.fail(now);
                if let Some(o) = r.outcome() {
                    self.report.record(o);
                }
                return;
            }
        };
        if target == home {
            self.instances[home].running.push(rid);
            self.kick(home);
        } else {
            // KV transfer (migration queue, FCFS): the target gets the
            // request after the transfer delay
            let delay = self.cost.kv_transfer_s(ctx);
            self.migrations += 1;
            self.instances[home].kv_tokens =
                self.instances[home].kv_tokens.saturating_sub(ctx);
            self.instances[target].kv_tokens += ctx;
            self.instances[target].running.push(rid);
            self.requests.get_mut(&rid).unwrap().migrations += 1;
            self.queue.schedule_in(delay, Ev::KvReady(target));
        }
    }

    fn finish(&mut self, rid: RequestId) {
        self.prefill_home.remove(&rid);
        if let Some(r) = self.requests.get(&rid) {
            if let Some(o) = r.outcome() {
                self.report.record(o);
            }
        }
    }

    // --- monitoring / role switching -----------------------------------

    fn on_monitor(&mut self) {
        // settle drained transitional instances
        for id in 0..self.instances.len() {
            let kind = self.pools.kind(id);
            if matches!(kind, PoolKind::PrefillToDecode | PoolKind::DecodeToPrefill) {
                let drained = match kind {
                    PoolKind::PrefillToDecode => self.instances[id].prefill_queue.is_empty(),
                    PoolKind::DecodeToPrefill => self.instances[id].running.is_empty(),
                    _ => false,
                };
                if drained {
                    self.pools.settle(id);
                }
            }
        }
        // SLO-aware role switching
        if let ServingMode::Disaggregated { dynamic: true, .. } = self.cfg.mode {
            let views: Vec<InstanceView> =
                (0..self.instances.len()).map(|i| self.view(i)).collect();
            let flips = plan_role_switches(
                &views,
                &self.pools,
                &self.scheduler.predictor,
                &self.cost,
                &self.cfg.slo,
                0,
                2,
            );
            for f in flips {
                match f {
                    RoleFlip::ToPrefill(i) => {
                        self.pools.flip_to_prefill(i, 2);
                    }
                    RoleFlip::ToDecode(i) => {
                        self.pools.flip_to_decode(i);
                    }
                }
            }
        }
        // keep kicking idle instances with queued work (e.g. after flips)
        for id in 0..self.instances.len() {
            self.kick(id);
        }
        if !self.all_done() {
            self.queue.schedule_in(self.cfg.monitor_interval_s, Ev::Monitor);
        }
    }

    // --- faults ---------------------------------------------------------

    fn on_fault(&mut self, id: InstanceId) {
        let now = self.queue.now();
        self.instances[id].failed = true;
        self.instances[id].busy = false;
        self.current.remove(&id);
        let owned = self.instances[id].owned_requests();
        for rid in owned {
            self.instances[id].evict(rid);
            let (ctx, phase) = match self.requests.get(&rid) {
                Some(r) => (r.context_len(), r.phase),
                None => continue,
            };
            let interrupted = InterruptedRequest {
                request: rid,
                context_tokens: ctx,
                // decode-phase requests have a DRAM replica via the global
                // cache when prefix caching is on; otherwise HBM-only
                replica_tier: if self.cfg.prefix_cache {
                    Some(Tier::Dram)
                } else {
                    Some(Tier::Hbm)
                },
            };
            let (action, _delay) = plan_recovery(&interrupted, &self.cost, &self.xfer);
            self.recoveries += 1;
            match (phase, action) {
                (Phase::Decode, RecoveryAction::Migrate) => {
                    let home = self.prefill_home.get(&rid).copied().unwrap_or(id);
                    if let Some(r) = self.requests.get_mut(&rid) {
                        r.migrations += 1;
                    }
                    self.place_decode_for(rid, home, ctx);
                }
                (Phase::Decode, _) => {
                    // recompute: back to prefill from scratch
                    if let Some(r) = self.requests.get_mut(&rid) {
                        r.phase = Phase::Prefill;
                        r.prefilled = 0;
                        r.prefix_hit_tokens = 0;
                        r.preemptions += 1;
                    }
                    self.route_prefill(rid);
                }
                (Phase::Prefill, _) => {
                    if let Some(r) = self.requests.get_mut(&rid) {
                        r.prefilled = 0;
                    }
                    self.route_prefill(rid);
                }
                (Phase::Encode, _) => {
                    self.route_encode(rid);
                }
                _ => {}
            }
        }
        self.instances[id].kv_tokens = 0;
        let recovery_s = self.cfg.recovery.recovery_s(self.cfg.model.weight_bytes());
        self.queue.schedule_at(now + recovery_s, Ev::Recover(id));
    }

    fn on_recover(&mut self, id: InstanceId) {
        self.instances[id].failed = false;
        self.kick(id);
    }
}

/// Convenience: run a config over a workload.
pub fn run(cfg: ClusterConfig, workload: Vec<RequestSpec>) -> SimResult {
    ClusterSim::new(cfg).run(workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::workload::scenario;

    fn base_cfg(n: usize) -> ClusterConfig {
        ClusterConfig::new(
            n,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        )
    }

    fn workload(rate: f64, horizon: f64, seed: u64) -> Vec<RequestSpec> {
        let mut rng = Rng::new(seed);
        scenario("sharegpt").unwrap().generate(horizon, rate, &mut rng)
    }

    #[test]
    fn colocated_completes_all_requests() {
        let cfg = base_cfg(2);
        let w = workload(1.0, 30.0, 1);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_requests(), n);
        assert_eq!(res.report.n_completed(), n);
        assert!(res.report.output_throughput() > 0.0);
    }

    #[test]
    fn disaggregated_completes_and_migrates() {
        let mut cfg = base_cfg(4);
        cfg.mode = ServingMode::Disaggregated { n_prefill: 2, dynamic: false };
        let w = workload(1.5, 30.0, 2);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_completed(), n);
        assert!(res.migrations > 0, "PD handoff should migrate KV");
    }

    #[test]
    fn dynamic_pd_flips_roles_under_burst() {
        let mut cfg = base_cfg(4);
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
        cfg.slo = Slo::interactive(1.0, 0.2);
        let mut rng = Rng::new(3);
        let w = scenario("azure-code").unwrap().generate(60.0, 4.0, &mut rng);
        let res = run(cfg, w);
        assert!(res.role_flips > 0, "bursty load should trigger role flips");
        assert!(res.report.n_completed() > 0);
    }

    #[test]
    fn capacity_scales_with_instances() {
        // Heavy overload so capacity (not arrival rate) binds.  Raw
        // horizon throughput is tail-dominated (the last lone sequence
        // decodes at the single-instance weight-streaming rate on any
        // cluster size), so the scaling signal is mean E2E under load.
        let w1 = workload(60.0, 8.0, 4);
        let w2 = w1.clone();
        let r1 = run(base_cfg(1), w1);
        let r4 = run(base_cfg(4), w2);
        let e1 = r1.report.e2e_summary().mean();
        let e4 = r4.report.e2e_summary().mean();
        assert!(e4 < e1 / 2.5, "4-instance mean E2E {e4} !< {e1}/2.5");
        assert!(
            r4.report.output_throughput() > 1.3 * r1.report.output_throughput(),
            "4 instances {} !> 1.3x 1 instance {}",
            r4.report.output_throughput(),
            r1.report.output_throughput()
        );
        // the work must actually spread across instances
        let toks: Vec<u64> = r4.per_instance.iter().map(|&(_, t)| t).collect();
        let max = *toks.iter().max().unwrap() as f64;
        let min = *toks.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "imbalanced: {toks:?}");
    }

    #[test]
    fn fault_recovery_completes_requests() {
        let mut cfg = base_cfg(2);
        cfg.faults = vec![(5.0, 0)];
        let w = workload(1.0, 20.0, 5);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_requests(), n, "every request accounted for");
        assert!(res.report.n_completed() as f64 >= 0.9 * n as f64);
    }

    #[test]
    fn prefix_cache_reduces_ttft() {
        let mut rng = Rng::new(6);
        let w = scenario("customer-service").unwrap().generate(40.0, 1.5, &mut rng);
        let mut with = base_cfg(2);
        with.prefix_cache = true;
        let without = base_cfg(2);
        let rw = run(with, w.clone());
        let ro = run(without, w);
        assert!(rw.prefix_hits > 0);
        assert!(
            rw.report.ttft_summary().mean() < ro.report.ttft_summary().mean(),
            "prefix cache should cut TTFT: {} vs {}",
            rw.report.ttft_summary().mean(),
            ro.report.ttft_summary().mean()
        );
    }

    #[test]
    fn multimodal_epd_serves_textcaps() {
        let mut cfg = base_cfg(2);
        cfg.n_encode = 1;
        cfg.epd = Some(EpdStrategy::EPD);
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: false };
        let mut rng = Rng::new(7);
        let w = scenario("textcaps").unwrap().generate(20.0, 1.0, &mut rng);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_completed(), n);
    }

    #[test]
    fn speculative_decoding_raises_throughput() {
        let w = workload(4.0, 20.0, 8);
        let mut with = base_cfg(2);
        with.spec = Some(SpecConfig { m: 4, acceptance: 0.75 });
        let plain = base_cfg(2);
        let rs = run(with, w.clone());
        let rp = run(plain, w);
        assert!(
            rs.report.output_throughput() > 1.1 * rp.report.output_throughput(),
            "spec {} !> plain {}",
            rs.report.output_throughput(),
            rp.report.output_throughput()
        );
    }

    #[test]
    fn offline_colocation_completes_mixed_load() {
        let mut rng = Rng::new(9);
        let mut w = scenario("sharegpt").unwrap().generate(30.0, 3.0, &mut rng);
        let offline = scenario("offline-docs").unwrap().generate(30.0, 2.0, &mut rng);
        w.extend(offline);
        w.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let mut cfg = base_cfg(2);
        cfg.slo = Slo::tpot(0.08);
        cfg.colocation = Some((
            ColocationMode::XllmOoc,
            ColocationConfig { online_tpot_s: 0.08, ..Default::default() },
        ));
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_requests(), n);
        assert!(res.report.n_completed() > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = workload(2.0, 15.0, 10);
        let r1 = run(base_cfg(2), w.clone());
        let r2 = run(base_cfg(2), w);
        assert_eq!(r1.report.n_completed(), r2.report.n_completed());
        assert!((r1.report.output_throughput() - r2.report.output_throughput()).abs() < 1e-9);
        assert_eq!(r1.iterations, r2.iterations);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::workload::scenario;

    #[test]
    #[ignore]
    fn debug_scaling() {
        let mut rng = Rng::new(4);
        let w = scenario("sharegpt").unwrap().generate(10.0, 60.0, &mut rng);
        println!("requests: {}", w.len());
        for n in [1usize, 4] {
            let cfg = ClusterConfig::new(
                n,
                ascend_910b(),
                catalog("Qwen3-8B").unwrap(),
                EngineFeatures::xllm(1),
            );
            let sim = ClusterSim::new(cfg);
            // expose internals via run + inspect afterwards: run consumes,
            // so re-derive from the result only
            let res = sim.run(w.clone());
            let mut e2e = res.report.e2e_summary();
            println!(
                "n={} tput={:.0} iters={} completed={} mean_e2e={:.2} p99_ttft={:.2} per_inst={:?}",
                n,
                res.report.output_throughput(),
                res.iterations,
                res.report.n_completed(),
                e2e.mean(),
                res.report.ttft_summary().percentile(99.0),
                res.per_instance,
            );
        }
    }
}
