//! Cluster serving simulation: the Ascend-testbed substitute.
//!
//! Since the orchestrator refactor this module holds *configuration
//! only*: [`ClusterConfig`] describes the cluster (hardware, model,
//! engine features, serving mode, policies) and [`ClusterSim`] wires a
//! [`RooflineExecutor`] into the shared
//! [`coordinator::orchestrator::Orchestrator`] — the same request
//! lifecycle state machine the real PJRT server runs.  Dispatch,
//! chunked prefill, KV handoff, role switching, co-location admission,
//! and fault recovery all live in the orchestrator.
//!
//! Every paper bench (fig14..fig23, tables 3–8) is a configuration of
//! [`ClusterConfig`] + a workload from `workload::scenarios`.

use crate::coordinator::orchestrator::{Orchestrator, OrchestratorConfig, DEFAULT_MAX_EVENTS};
use crate::coordinator::{BatchConfig, DispatchPolicy};
use crate::engine::policies::EnginePolicies;
use crate::engine::specdecode::SpecConfig;
use crate::metrics::Slo;
use crate::model::{HardwareSpec, ModelSpec};
use crate::service::colocation::ColocationConfig;
use crate::service::epd::EpdStrategy;
use crate::service::fault::RecoveryModel;
use crate::sim::executor::RooflineExecutor;
use crate::sim::roofline::{CostModel, EngineFeatures};
use crate::workload::RequestSpec;

pub use crate::coordinator::orchestrator::RunResult as SimResult;
pub use crate::coordinator::orchestrator::{ColocationMode, ServingMode};

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_instances: usize,
    /// Dedicated encode instances (EPD E pool).
    pub n_encode: usize,
    pub hw: HardwareSpec,
    pub model: ModelSpec,
    pub features: EngineFeatures,
    pub mode: ServingMode,
    pub dispatch: DispatchPolicy,
    pub slo: Slo,
    pub batch: BatchConfig,
    pub colocation: Option<(ColocationMode, ColocationConfig)>,
    /// Multimodal phase placement (None = text-only serving).
    pub epd: Option<EpdStrategy>,
    pub spec: Option<SpecConfig>,
    /// Injected faults: (time, instance).
    pub faults: Vec<(f64, usize)>,
    pub recovery: RecoveryModel,
    pub monitor_interval_s: f64,
    /// Enable the global prefix cache (§3.4).
    pub prefix_cache: bool,
    /// Token-granular KV admission: prefix matches credit exact token
    /// counts via the cache's radix index, and the batcher admits
    /// prefill against real free KV tokens instead of the `max_seqs`
    /// slot heuristic.  Off (the default) keeps the block-aligned
    /// behavior bit-identical.
    pub token_granular: bool,
    /// Iterations kept in flight per instance (§4.2 async scheduling);
    /// 1 = the blocking contract.
    pub pipeline_depth: usize,
    /// Modelled host-side planning/dispatch cost per iteration — the
    /// share the async pipeline hides at depth ≥ 2.  Default 0.0 so
    /// depth-1 runs reproduce the pre-async golden fixtures exactly.
    pub host_overhead_s: f64,
    /// Termination cap on processed events (sets `SimResult::truncated`
    /// when hit instead of silently breaking out).
    pub max_events: u64,
    pub seed: u64,
    /// Executor-level engine policies (§4): EPLB, DP balance, op
    /// overlap, adaptive graph mode.  All off by default — the seed
    /// behavior, bit for bit.
    pub policies: EnginePolicies,
}

impl ClusterConfig {
    /// A sensible default: colocated serving, SLO-aware dispatch.
    pub fn new(
        n_instances: usize,
        hw: HardwareSpec,
        model: ModelSpec,
        features: EngineFeatures,
    ) -> Self {
        // KV pool spans the whole device group: tp shards each layer's
        // KV across tp devices, pp spreads the layers across stages
        let kv_capacity = (hw.hbm_bytes * features.shard.devices() as f64 * 0.6
            / model.kv_bytes_per_token().max(1.0)) as u64;
        ClusterConfig {
            n_instances,
            n_encode: 0,
            hw,
            model,
            features,
            mode: ServingMode::Colocated,
            dispatch: DispatchPolicy::SloAware,
            slo: Slo::UNCONSTRAINED,
            batch: BatchConfig {
                kv_capacity_tokens: kv_capacity.max(4096),
                ..BatchConfig::default()
            },
            colocation: None,
            epd: None,
            spec: None,
            faults: Vec::new(),
            recovery: RecoveryModel::default(),
            monitor_interval_s: 0.25,
            prefix_cache: false,
            token_granular: false,
            pipeline_depth: 1,
            host_overhead_s: 0.0,
            max_events: DEFAULT_MAX_EVENTS,
            seed: 0xD15EA5E,
            policies: EnginePolicies::default(),
        }
    }

    /// Re-shard the replica's device group: stamps `features.shard` and
    /// recomputes the KV capacity for the new device count (the shard
    /// must be set through here — or before `new` — so capacity and
    /// cost model never disagree on the group size).
    pub fn with_shard(mut self, shard: crate::model::ShardSpec) -> Self {
        self.features.shard = shard;
        let kv_capacity = (self.hw.hbm_bytes * shard.devices() as f64 * 0.6
            / self.model.kv_bytes_per_token().max(1.0)) as u64;
        self.batch.kv_capacity_tokens = kv_capacity.max(4096);
        self
    }

    /// Split into the executor-agnostic orchestrator configuration
    /// (also used by `sim::fleet` to stamp out per-replica clusters).
    pub fn orchestrator_config(&self) -> OrchestratorConfig {
        OrchestratorConfig {
            n_instances: self.n_instances,
            n_encode: self.n_encode,
            mode: self.mode,
            dispatch: self.dispatch,
            slo: self.slo,
            batch: BatchConfig {
                token_admission: self.batch.token_admission || self.token_granular,
                ..self.batch
            },
            colocation: self.colocation,
            epd: self.epd,
            faults: self.faults.clone(),
            recovery: self.recovery,
            monitor_interval_s: self.monitor_interval_s,
            prefix_cache: self.prefix_cache,
            prefix_token_granular: self.token_granular,
            pipeline_depth: self.pipeline_depth.max(1),
            max_events: self.max_events,
            ..OrchestratorConfig::default()
        }
    }
}

/// The simulator: the shared orchestrator over a roofline executor.
pub struct ClusterSim {
    orch: Orchestrator<RooflineExecutor>,
}

impl ClusterSim {
    pub fn new(cfg: ClusterConfig) -> ClusterSim {
        let cost = CostModel::new(cfg.hw.clone(), cfg.model.clone(), cfg.features.clone());
        let executor = RooflineExecutor::new(cost, cfg.spec, cfg.seed)
            .with_host_overhead(cfg.host_overhead_s)
            .with_policies(cfg.policies);
        ClusterSim { orch: Orchestrator::new(cfg.orchestrator_config(), executor) }
    }

    /// Install a lifecycle trace sink on the orchestrator + executor.
    pub fn set_trace(&mut self, trace: crate::obs::TraceHandle) {
        self.orch.set_trace(trace);
    }

    /// Run the workload to completion; returns metrics + counters.
    pub fn run(self, workload: Vec<RequestSpec>) -> SimResult {
        self.orch.run(workload).0
    }

    /// Like [`Self::run`] but also hands back the executor, so callers
    /// can inspect [`RooflineExecutor::policy_counters`].
    pub fn run_with_executor(self, workload: Vec<RequestSpec>) -> (SimResult, RooflineExecutor) {
        self.orch.run(workload)
    }
}

/// Convenience: run a config over a workload.
pub fn run(cfg: ClusterConfig, workload: Vec<RequestSpec>) -> SimResult {
    ClusterSim::new(cfg).run(workload)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::util::Rng;
    use crate::workload::scenario;

    fn base_cfg(n: usize) -> ClusterConfig {
        ClusterConfig::new(
            n,
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        )
    }

    fn workload(rate: f64, horizon: f64, seed: u64) -> Vec<RequestSpec> {
        let mut rng = Rng::new(seed);
        scenario("sharegpt").unwrap().generate(horizon, rate, &mut rng)
    }

    #[test]
    fn colocated_completes_all_requests() {
        let cfg = base_cfg(2);
        let w = workload(1.0, 30.0, 1);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_requests(), n);
        assert_eq!(res.report.n_completed(), n);
        assert!(res.report.output_throughput() > 0.0);
        assert!(!res.truncated);
    }

    #[test]
    fn disaggregated_completes_and_migrates() {
        let mut cfg = base_cfg(4);
        cfg.mode = ServingMode::Disaggregated { n_prefill: 2, dynamic: false };
        let w = workload(1.5, 30.0, 2);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_completed(), n);
        assert!(res.migrations > 0, "PD handoff should migrate KV");
    }

    #[test]
    fn dynamic_pd_flips_roles_under_burst() {
        let mut cfg = base_cfg(4);
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
        cfg.slo = Slo::interactive(1.0, 0.2);
        let mut rng = Rng::new(3);
        let w = scenario("azure-code").unwrap().generate(60.0, 4.0, &mut rng);
        let res = run(cfg, w);
        assert!(res.role_flips > 0, "bursty load should trigger role flips");
        assert!(res.report.n_completed() > 0);
    }

    #[test]
    fn capacity_scales_with_instances() {
        // Heavy overload so capacity (not arrival rate) binds.  Raw
        // horizon throughput is tail-dominated (the last lone sequence
        // decodes at the single-instance weight-streaming rate on any
        // cluster size), so the scaling signal is mean E2E under load.
        let w1 = workload(60.0, 8.0, 4);
        let w2 = w1.clone();
        let r1 = run(base_cfg(1), w1);
        let r4 = run(base_cfg(4), w2);
        let e1 = r1.report.e2e_summary().mean();
        let e4 = r4.report.e2e_summary().mean();
        assert!(e4 < e1 / 2.5, "4-instance mean E2E {e4} !< {e1}/2.5");
        assert!(
            r4.report.output_throughput() > 1.3 * r1.report.output_throughput(),
            "4 instances {} !> 1.3x 1 instance {}",
            r4.report.output_throughput(),
            r1.report.output_throughput()
        );
        // the work must actually spread across instances
        let toks: Vec<u64> = r4.per_instance.iter().map(|&(_, t)| t).collect();
        let max = *toks.iter().max().unwrap() as f64;
        let min = *toks.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) < 2.0, "imbalanced: {toks:?}");
    }

    #[test]
    fn shard_widens_kv_capacity_with_devices() {
        let base = base_cfg(1);
        let wide = base_cfg(1).with_shard(crate::model::ShardSpec::new(2, 2, 4));
        assert_eq!(wide.features.shard.devices(), 4);
        assert!(
            wide.batch.kv_capacity_tokens >= 3 * base.batch.kv_capacity_tokens,
            "4 devices should carry ~4x the KV pool: {} vs {}",
            wide.batch.kv_capacity_tokens,
            base.batch.kv_capacity_tokens
        );
    }

    #[test]
    fn fault_recovery_completes_requests() {
        let mut cfg = base_cfg(2);
        cfg.faults = vec![(5.0, 0)];
        let w = workload(1.0, 20.0, 5);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_requests(), n, "every request accounted for");
        assert!(res.report.n_completed() as f64 >= 0.9 * n as f64);
    }

    #[test]
    fn prefix_cache_reduces_ttft() {
        let mut rng = Rng::new(6);
        let w = scenario("customer-service").unwrap().generate(40.0, 1.5, &mut rng);
        let mut with = base_cfg(2);
        with.prefix_cache = true;
        let without = base_cfg(2);
        let rw = run(with, w.clone());
        let ro = run(without, w);
        assert!(rw.prefix_hits > 0);
        assert!(
            rw.report.ttft_summary().mean() < ro.report.ttft_summary().mean(),
            "prefix cache should cut TTFT: {} vs {}",
            rw.report.ttft_summary().mean(),
            ro.report.ttft_summary().mean()
        );
    }

    #[test]
    fn multimodal_epd_serves_textcaps() {
        let mut cfg = base_cfg(2);
        cfg.n_encode = 1;
        cfg.epd = Some(EpdStrategy::EPD);
        cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: false };
        let mut rng = Rng::new(7);
        let w = scenario("textcaps").unwrap().generate(20.0, 1.0, &mut rng);
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_completed(), n);
    }

    #[test]
    fn speculative_decoding_raises_throughput() {
        let w = workload(4.0, 20.0, 8);
        let mut with = base_cfg(2);
        with.spec = Some(SpecConfig { m: 4, acceptance: 0.75 });
        let plain = base_cfg(2);
        let rs = run(with, w.clone());
        let rp = run(plain, w);
        assert!(
            rs.report.output_throughput() > 1.1 * rp.report.output_throughput(),
            "spec {} !> plain {}",
            rs.report.output_throughput(),
            rp.report.output_throughput()
        );
    }

    #[test]
    fn offline_colocation_completes_mixed_load() {
        let mut rng = Rng::new(9);
        let mut w = scenario("sharegpt").unwrap().generate(30.0, 3.0, &mut rng);
        let offline = scenario("offline-docs").unwrap().generate(30.0, 2.0, &mut rng);
        w.extend(offline);
        w.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let mut cfg = base_cfg(2);
        cfg.slo = Slo::tpot(0.08);
        cfg.colocation = Some((
            ColocationMode::XllmOoc,
            ColocationConfig { online_tpot_s: 0.08, ..Default::default() },
        ));
        let n = w.len();
        let res = run(cfg, w);
        assert_eq!(res.report.n_requests(), n);
        assert!(res.report.n_completed() > 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = workload(2.0, 15.0, 10);
        let r1 = run(base_cfg(2), w.clone());
        let r2 = run(base_cfg(2), w);
        assert_eq!(r1.report.n_completed(), r2.report.n_completed());
        assert!((r1.report.output_throughput() - r2.report.output_throughput()).abs() < 1e-9);
        assert_eq!(r1.iterations, r2.iterations);
    }

    #[test]
    fn event_cap_surfaces_truncation() {
        let mut cfg = base_cfg(1);
        cfg.max_events = 50;
        let w = workload(4.0, 20.0, 11);
        let res = run(cfg, w);
        assert!(res.truncated, "50-event cap must truncate");
        assert!(
            res.report.n_completed() < res.report.n_requests() || res.report.n_requests() == 0,
            "a truncated run should not have drained everything"
        );
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::util::Rng;
    use crate::workload::scenario;

    #[test]
    #[ignore]
    fn debug_scaling() {
        let mut rng = Rng::new(4);
        let w = scenario("sharegpt").unwrap().generate(10.0, 60.0, &mut rng);
        crate::obs::log::info(format!("requests: {}", w.len()));
        for n in [1usize, 4] {
            let cfg = ClusterConfig::new(
                n,
                ascend_910b(),
                catalog("Qwen3-8B").unwrap(),
                EngineFeatures::xllm(1),
            );
            let sim = ClusterSim::new(cfg);
            let res = sim.run(w.clone());
            let e2e = res.report.e2e_summary();
            crate::obs::log::info(format!(
                "n={} tput={:.0} iters={} completed={} mean_e2e={:.2} p99_ttft={:.2} per_inst={:?}",
                n,
                res.report.output_throughput(),
                res.iterations,
                res.report.n_completed(),
                e2e.mean(),
                res.report.ttft_summary().percentile(99.0),
                res.per_instance,
            ));
        }
    }
}
