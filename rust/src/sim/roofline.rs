//! Roofline step-cost model with online factor learning (paper §3.1).
//!
//! The paper builds "an LLM inference performance model based on the
//! Roofline Model and online factor learning" to predict latency and
//! compute/memory utilization of prefill and decode.  This module is that
//! model, extended with the *engine feature flags* so the same mechanism
//! explains the ablations:
//!
//! * graph mode (§4.2) — kernel-launch overhead per step: `n_ops` launches
//!   in eager mode vs 1 (+ copies) in graph mode; Adaptive picks per shape.
//! * async scheduling (§4.1) — CPU batch-prep time exposed (sync) or
//!   hidden behind device compute (async).
//! * dual-stream (§4.1) — MoE All-to-All exposed vs 80%-overlapped, at the
//!   cost of micro-batch compute inflation (paper Table 7: 13→17 ms).
//! * paged attention vs xTensor (§4.3) — block-table indirection inflates
//!   attention memory traffic and adds vector work; xTensor removes it.
//! * EPLB (§4.4.2) / DP balance (§4.4.3) — imbalance factors multiply the
//!   expert-FFN / attention phase.
//!
//! All constants carry provenance notes; `bench calibrate` fits the two
//! learned factors against the real CPU-PJRT executables for the tiny
//! model, which is the "online factor learning" loop.

use crate::model::{HardwareSpec, ModelSpec, ShardSpec};

/// Graph execution mode (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphMode {
    /// N kernel launches per step.
    Eager,
    /// 1 launch; only valid for static shapes (we model it as always-hit
    /// after warmup on bucketed shapes).
    Full,
    /// Parameterized partial graphs + multi-graph cache: simple-shape
    /// modules replay as a graph, complex-shape modules run eager.
    Adaptive,
}

/// Engine feature configuration — what the ablations toggle.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineFeatures {
    pub graph_mode: GraphMode,
    /// Framework-layer scheduling/execution overlap (§4.1).
    pub async_sched: bool,
    /// Model-layer dual-stream micro-batch comm/comp overlap (§4.1).
    pub dual_stream: bool,
    /// Operator-layer cube/vector overlap (§4.1).
    pub op_overlap: bool,
    /// Block-table paged attention (true for vLLM-like baselines) versus
    /// xTensor contiguous virtual addressing (false).
    pub paged_attention: bool,
    /// Dynamic expert-parallel load balancing (§4.4.2).
    pub eplb: bool,
    /// Hierarchical DP load balance (§4.4.3).
    pub dp_balance: bool,
    /// Device-group layout of one replica: tensor-parallel degree,
    /// pipeline stages, micro-batches (the single source of truth for
    /// parallelism — the old `tp: u32` scalar survives only as the
    /// [`EngineFeatures::tp`] view).
    pub shard: ShardSpec,
    /// Data-parallel groups sharing a workload (MoE attention DP).
    pub dp_groups: u32,
}

impl EngineFeatures {
    /// Everything on — the xLLM configuration.
    pub fn xllm(tp: u32) -> Self {
        EngineFeatures {
            graph_mode: GraphMode::Adaptive,
            async_sched: true,
            dual_stream: true,
            op_overlap: true,
            paged_attention: false,
            eplb: true,
            dp_balance: true,
            shard: ShardSpec::tp(tp),
            dp_groups: 1,
        }
    }

    /// vLLM-Ascend-like baseline: eager-ish graph support, paged attention,
    /// synchronous scheduling, static routing.
    pub fn vllm(tp: u32) -> Self {
        EngineFeatures {
            graph_mode: GraphMode::Eager,
            async_sched: false,
            dual_stream: false,
            op_overlap: false,
            paged_attention: true,
            eplb: false,
            dp_balance: false,
            shard: ShardSpec::tp(tp),
            dp_groups: 1,
        }
    }

    /// MindIE-like baseline: graph mode and offline-tuned (static) expert
    /// placement, but no async scheduling overlap, no dual-stream, no
    /// dynamic DP balancing.
    pub fn mindie(tp: u32) -> Self {
        EngineFeatures {
            graph_mode: GraphMode::Full,
            async_sched: false,
            dual_stream: false,
            op_overlap: true,
            paged_attention: true,
            eplb: true, // statically tuned placement (no *dynamic* updates)
            dp_balance: false,
            shard: ShardSpec::tp(tp),
            dp_groups: 1,
        }
    }

    /// Deprecated scalar view of the tensor-parallel degree; read
    /// `shard.tp` (and `shard.pp`) in new code.
    pub fn tp(&self) -> u32 {
        self.shard.tp
    }

    /// Builder-style shard override for the presets.
    pub fn with_shard(mut self, shard: ShardSpec) -> Self {
        self.shard = shard;
        self
    }
}

/// Distinct kernel launches per transformer layer in eager mode.
/// (qkv, attn, o-proj, norms, ffn x2, residuals, rope, kv-write, ...) —
/// order-of-magnitude consistent with the paper's "many fine-grained
/// operators" premise.
const OPS_PER_LAYER: f64 = 30.0;
/// Fraction of per-op dispatch cost EXPOSED in eager mode: dispatch is
/// pipelined with device execution, so only about half the launch time
/// surfaces as bubbles (calibrated against Table 8's eager-vs-graph TPOT
/// deltas).
const EAGER_EXPOSED_FRACTION: f64 = 0.5;
/// Fraction of ops that stay eager under Partial/Adaptive graph mode
/// (complex-dynamic-shape custom ops awaiting §4.2 integration).
const ADAPTIVE_EAGER_FRACTION: f64 = 0.08;
/// Graph-launch + memcpy-in/out cost per step in graph mode (s).
const GRAPH_LAUNCH_S: f64 = 60e-6;
/// Full (static-shape) graph mode on dynamic workloads pads every shape
/// to its bucket maximum — the paper's "lack of dynamic adaptability"
/// (Table 1: low memory usage ✗, high flexibility ✗).
const FULL_GRAPH_PADDING_INFLATION: f64 = 1.08;
/// CPU scheduling + batch assembly time per iteration (s): base + per-seq.
/// Calibrated so a 1.5B model at high batch gains ~17% from hiding it
/// (paper Table 6).
const CPU_SCHED_BASE_S: f64 = 0.7e-3;
const CPU_SCHED_PER_SEQ_S: f64 = 8e-6;
/// Paged-attention block-table overhead: extra memory traffic on KV reads
/// plus gather math (paper §4.3 "frequent access to block tables
/// sacrifices computational efficiency").
const PAGED_KV_TRAFFIC_INFLATION: f64 = 1.18;
const PAGED_VECTOR_OVERHEAD_S_PER_KTOK: f64 = 2.0e-6;
/// Dual-stream: fraction of All-to-All hidden behind compute (paper
/// Table 7: 80%), and the compute inflation from splitting micro-batches
/// (13 ms -> 17 ms total => ~1.31x).
const DUAL_STREAM_OVERLAP: f64 = 0.80;
const DUAL_STREAM_COMPUTE_INFLATION: f64 = 17.0 / 13.0;
/// MoE EP imbalance multiplier on expert FFN time: hot experts make some
/// devices process ~2x mean tokens without balancing; EPLB holds it near
/// balanced (paper §4.4.2).
const EP_IMBALANCE_STATIC: f64 = 1.9;
const EP_IMBALANCE_EPLB: f64 = 1.15;
/// DP straggler inflation on the attention phase without hierarchical
/// balancing (paper §4.4.3: ~5% total throughput effect at scale).
const DP_STRAGGLER_STATIC: f64 = 1.35;
const DP_STRAGGLER_BALANCED: f64 = 1.05;
/// Compute efficiency (achieved/peak) for dense matmul phases.
const MATRIX_EFFICIENCY: f64 = 0.55;
/// Memory-bandwidth efficiency for streaming phases.
const MEM_EFFICIENCY: f64 = 0.80;
/// Op-overlap (cube/vector) gain on the compute term (§4.1 operator layer).
const OP_OVERLAP_GAIN: f64 = 0.92;

/// The cost model: hardware + model + features (+ learned factors).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub hw: HardwareSpec,
    pub model: ModelSpec,
    pub features: EngineFeatures,
    /// Online-learned multiplicative corrections (1.0 = pure roofline).
    pub flops_factor: f64,
    pub mem_factor: f64,
}

/// Breakdown of one decode iteration's cost (for the ablation tables).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepBreakdown {
    pub compute_s: f64,
    pub memory_s: f64,
    pub launch_s: f64,
    pub sched_exposed_s: f64,
    pub comm_exposed_s: f64,
    pub total_s: f64,
}

impl CostModel {
    pub fn new(hw: HardwareSpec, model: ModelSpec, features: EngineFeatures) -> Self {
        CostModel { hw, model, features, flops_factor: 1.0, mem_factor: 1.0 }
    }

    fn launch_overhead(&self, per_token_graphable: bool) -> f64 {
        let n_ops = OPS_PER_LAYER * self.model.n_layers as f64;
        let eager = EAGER_EXPOSED_FRACTION * n_ops * self.hw.kernel_launch_s;
        match self.features.graph_mode {
            GraphMode::Eager => eager,
            GraphMode::Full => GRAPH_LAUNCH_S,
            GraphMode::Adaptive => {
                if per_token_graphable {
                    GRAPH_LAUNCH_S + ADAPTIVE_EAGER_FRACTION * eager
                } else {
                    // complex shapes fall back to eager for the whole step
                    eager
                }
            }
        }
    }

    /// Device-time inflation from the graph mode's shape handling.
    fn graph_padding(&self) -> f64 {
        if self.features.graph_mode == GraphMode::Full {
            FULL_GRAPH_PADDING_INFLATION
        } else {
            1.0
        }
    }

    /// CPU scheduling time for an iteration over `n_seqs` sequences.
    pub fn cpu_sched_s(&self, n_seqs: u64) -> f64 {
        CPU_SCHED_BASE_S + CPU_SCHED_PER_SEQ_S * n_seqs as f64
    }

    fn exposed_sched(&self, device_time: f64, n_seqs: u64) -> f64 {
        let sched = self.cpu_sched_s(n_seqs);
        if self.features.async_sched {
            // overlapped with the device; only the excess is exposed
            (sched - device_time).max(0.0)
        } else {
            sched
        }
    }

    /// All-to-All communication time per step for MoE models (dispatch +
    /// combine over all layers), given tokens in the step.
    fn moe_comm_s(&self, tokens: f64) -> f64 {
        if !self.model.is_moe {
            return 0.0;
        }
        let bytes_per_layer = tokens * self.model.d_model as f64 * 2.0 /*fp16*/ * 2.0 /*disp+comb*/;
        let total = bytes_per_layer * self.model.n_layers as f64;
        total / (self.hw.net_bw * self.features.shard.tp as f64)
    }

    /// Tensor-parallel AllReduce time per step (2 reduces per layer over
    /// the activations).  Fully exposed without overlap machinery; largely
    /// hidden by dual-stream / graph-fused collectives — this term is why
    /// baselines stop scaling with accelerator count (Fig 17's "clear
    /// scaling bottleneck" for vLLM-Ascend).  Under pipeline parallelism
    /// the ring runs per pp stage over that stage's `n_layers / pp`
    /// layers; summed over all pp stages the reduced volume is identical,
    /// so the term depends on tp alone.
    fn tp_comm_s(&self, tokens: f64) -> f64 {
        let tp = self.features.shard.tp as f64;
        if tp <= 1.0 {
            return 0.0;
        }
        let bytes = tokens * self.model.d_model as f64 * 2.0 * 2.0 * self.model.n_layers as f64;
        let ring = 2.0 * (tp - 1.0) / tp;
        let raw = bytes * ring / self.hw.net_bw;
        let exposure = if self.features.dual_stream {
            0.2
        } else if self.features.graph_mode != GraphMode::Eager {
            0.5
        } else {
            1.0
        };
        raw * exposure
    }

    /// Inter-stage point-to-point activation transfer under pipeline
    /// parallelism: each token's activations (`d_model`, fp16) cross
    /// `pp - 1` stage boundaries per forward pass.  Exactly 0.0 at
    /// `pp == 1` — the single-stage replica pays nothing.
    fn pp_comm_s(&self, tokens: f64) -> f64 {
        let pp = self.features.shard.pp as f64;
        if pp <= 1.0 {
            return 0.0;
        }
        tokens * self.model.d_model as f64 * 2.0 * (pp - 1.0) / self.hw.net_bw
    }

    /// Pipeline-parallel makespan multiplier on the single-device step
    /// time: pp stages each do `1/pp` of the layers, and `m` micro-batches
    /// fill the pipeline, so the makespan is `(pp + m - 1)` stage-slots of
    /// `T / (pp * m)` each — `T * (pp + m - 1) / (pp * m)`.  Exactly 1.0
    /// at `pp == 1` (no stage split; micro-batching alone is a no-op on a
    /// sequential device), approaching the ideal `1/pp` as `m` grows.
    fn pipeline_bubble(&self) -> f64 {
        let shard = self.features.shard;
        if shard.pp <= 1 {
            return 1.0;
        }
        let pp = shard.pp as f64;
        let m = shard.micro_batches.max(1) as f64;
        (pp + m - 1.0) / (pp * m)
    }

    /// Fraction of a pp-pipelined iteration's device time that is drain
    /// tail: the last `pp - 1` of its `pp + m - 1` stage-slots, during
    /// which stage 0 has already gone idle and can start the *next*
    /// iteration's micro-batches.  The orchestrator timeline uses this
    /// as `IterationOutcome::ramp_s`'s share of `device_s` — the second
    /// pipelining axis riding the same per-instance frontiers.  0.0 at
    /// `pp == 1`.
    pub fn pp_ramp_fraction(&self) -> f64 {
        let shard = self.features.shard;
        if shard.pp <= 1 {
            return 0.0;
        }
        let pp = shard.pp as f64;
        let m = shard.micro_batches.max(1) as f64;
        (pp - 1.0) / (pp + m - 1.0)
    }

    fn imbalance(&self) -> f64 {
        let mut f = 1.0;
        if self.model.is_moe {
            f *= if self.features.eplb { EP_IMBALANCE_EPLB } else { EP_IMBALANCE_STATIC };
        }
        if self.features.dp_groups > 1 {
            f *= if self.features.dp_balance { DP_STRAGGLER_BALANCED } else { DP_STRAGGLER_STATIC };
        }
        f
    }

    fn matrix_rate(&self) -> f64 {
        let mut eff = MATRIX_EFFICIENCY;
        if self.features.op_overlap {
            eff /= OP_OVERLAP_GAIN; // overlap recovers some idle cube time
        }
        self.hw.matrix_flops * self.features.shard.tp as f64 * eff / self.flops_factor
    }

    fn mem_rate(&self) -> f64 {
        self.hw.hbm_bw * self.features.shard.tp as f64 * MEM_EFFICIENCY / self.mem_factor
    }

    /// Prefill cost for `new_tokens` prompt tokens (with `ctx` existing
    /// context, for chunked prefill).  Compute-bound in practice.
    pub fn prefill_s(&self, new_tokens: u64, ctx: u64) -> f64 {
        let t = new_tokens as f64;
        let flops = 2.0 * self.model.active_params * t
            + 2.0
                * (ctx as f64 + t / 2.0)
                * t
                * self.model.n_layers as f64
                * self.model.d_model as f64
                * 2.0;
        let compute = flops / self.matrix_rate();
        let memory = (self.model.active_weight_bytes() + t * self.model.kv_bytes_per_token())
            / self.mem_rate();
        let comm = self.moe_comm_s(t);
        let exposed_comm = if self.features.dual_stream {
            (1.0 - DUAL_STREAM_OVERLAP) * comm
        } else {
            comm
        };
        // imbalance (EP hot experts / DP stragglers) delays the whole
        // device iteration, whichever resource binds; pp stage-splits the
        // layers and micro-batching fills the pipeline (exact 1.0 / +0.0
        // no-ops at pp == 1, keeping the single-stage path bit-identical)
        let base = compute.max(memory)
            * self.imbalance()
            * if self.features.dual_stream && self.model.is_moe {
                DUAL_STREAM_COMPUTE_INFLATION
            } else {
                1.0
            }
            * self.pipeline_bubble();
        base + exposed_comm + self.tp_comm_s(t) + self.pp_comm_s(t) + self.launch_overhead(false)
    }

    /// One decode iteration for `n_seqs` sequences with `kv_tokens` total
    /// cached tokens across the batch.  Memory-bound in practice.
    pub fn decode_step(&self, n_seqs: u64, kv_tokens: u64) -> StepBreakdown {
        let b = n_seqs as f64;
        let flops = 2.0 * self.model.active_params * b;
        let compute = flops / self.matrix_rate();

        let mut kv_traffic = kv_tokens as f64 * self.model.kv_bytes_per_token();
        let mut vec_overhead = 0.0;
        if self.features.paged_attention {
            kv_traffic *= PAGED_KV_TRAFFIC_INFLATION;
            vec_overhead += PAGED_VECTOR_OVERHEAD_S_PER_KTOK * (kv_tokens as f64 / 1000.0);
        }
        let memory = (self.model.active_weight_bytes() + kv_traffic) / self.mem_rate();

        let comm = self.moe_comm_s(b);
        let exposed_comm = if self.features.dual_stream {
            (1.0 - DUAL_STREAM_OVERLAP) * comm
        } else {
            comm
        };

        let inflate = if self.features.dual_stream && self.model.is_moe {
            DUAL_STREAM_COMPUTE_INFLATION
        } else {
            1.0
        };
        // imbalance delays the whole iteration (straggler effect); the
        // pp bubble and activation-transfer terms are exact no-ops at
        // pp == 1 (×1.0 / +0.0), preserving single-stage bit-identity
        let device = compute.max(memory)
            * self.imbalance()
            * inflate
            * self.graph_padding()
            * self.pipeline_bubble()
            + vec_overhead
            + self.tp_comm_s(b)
            + self.pp_comm_s(b);
        let launch = self.launch_overhead(true);
        let sched = self.exposed_sched(device + launch, n_seqs);
        let total = device + launch + sched + exposed_comm;
        StepBreakdown {
            compute_s: compute,
            memory_s: memory,
            launch_s: launch,
            sched_exposed_s: sched,
            comm_exposed_s: exposed_comm,
            total_s: total,
        }
    }

    /// Decode step total (convenience).
    pub fn decode_step_s(&self, n_seqs: u64, kv_tokens: u64) -> f64 {
        self.decode_step(n_seqs, kv_tokens).total_s
    }

    /// Encoder (vision) cost for a multimodal request with `n_patches`
    /// patches — compute-bound MLP/ViT-ish workload (§3.3).
    pub fn encode_s(&self, n_patches: u64) -> f64 {
        // ViT-like: ~4x d_model^2 per patch-token per layer over ~1/4 the
        // LM's layer count; modelled as a fraction of LM prefill flops.
        let flops = 2.0 * self.model.active_params * 0.15 * n_patches as f64;
        flops / self.matrix_rate() + self.launch_overhead(false) * 0.5
    }

    /// KV transfer time between instances for `tokens` cached tokens.
    pub fn kv_transfer_s(&self, tokens: u64) -> f64 {
        tokens as f64 * self.model.kv_bytes_per_token() / self.hw.net_bw
    }

    /// Online factor learning (paper §3.1): given an observed step latency,
    /// nudge the corresponding roofline factor toward the observation.
    pub fn learn_decode(&mut self, n_seqs: u64, kv_tokens: u64, observed_s: f64) {
        let predicted = self.decode_step_s(n_seqs, kv_tokens);
        if predicted <= 0.0 || observed_s <= 0.0 {
            return;
        }
        let ratio = (observed_s / predicted).clamp(0.25, 4.0);
        let step = self.decode_step(n_seqs, kv_tokens);
        // attribute the error to the binding resource
        if step.compute_s >= step.memory_s {
            self.flops_factor = 0.9 * self.flops_factor + 0.1 * self.flops_factor * ratio;
        } else {
            self.mem_factor = 0.9 * self.mem_factor + 0.1 * self.mem_factor * ratio;
        }
    }

    /// The EP imbalance multiplier the static feature flags already
    /// bake into [`Self::decode_step`] (1.0 for dense models).  The
    /// dynamic EPLB executor policy divides its *achieved* imbalance
    /// by this assumption so the two mechanisms compose instead of
    /// double-counting.
    pub fn moe_imbalance_assumed(&self) -> f64 {
        if !self.model.is_moe {
            return 1.0;
        }
        if self.features.eplb {
            EP_IMBALANCE_EPLB
        } else {
            EP_IMBALANCE_STATIC
        }
    }

    /// Launch-time reduction a warm cached graph gives one step over
    /// the configured launch path (the §4.2 adaptive-graph executor
    /// policy credits this on bucket cache hits; Full graph mode
    /// already pays only the single launch, so the gain is zero).
    pub fn graph_warm_gain_s(&self) -> f64 {
        let n_ops = OPS_PER_LAYER * self.model.n_layers as f64;
        let eager = EAGER_EXPOSED_FRACTION * n_ops * self.hw.kernel_launch_s;
        match self.features.graph_mode {
            GraphMode::Eager => (eager - GRAPH_LAUNCH_S).max(0.0),
            GraphMode::Full => 0.0,
            GraphMode::Adaptive => ADAPTIVE_EAGER_FRACTION * eager,
        }
    }

    /// Which resource binds a decode step (for co-location batch mixing).
    pub fn decode_bound(&self, n_seqs: u64, kv_tokens: u64) -> Bound {
        let s = self.decode_step(n_seqs, kv_tokens);
        if s.compute_s >= s.memory_s {
            Bound::Compute
        } else {
            Bound::Memory
        }
    }
}

/// Binding resource of a step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};

    fn cm(features: EngineFeatures) -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), features)
    }

    #[test]
    fn decode_is_memory_bound_at_small_batch() {
        let m = cm(EngineFeatures::xllm(1));
        assert_eq!(m.decode_bound(1, 2048), Bound::Memory);
    }

    #[test]
    fn prefill_scales_superlinearly_with_tokens() {
        let m = cm(EngineFeatures::xllm(1));
        let t1 = m.prefill_s(512, 0);
        let t2 = m.prefill_s(2048, 0);
        // 4x tokens => ~4x compute, but constant launch overhead amortizes
        assert!(t2 > 2.5 * t1, "t1={t1} t2={t2}");
        assert!(t2 < 6.0 * t1, "t1={t1} t2={t2}");
    }

    #[test]
    fn graph_mode_beats_eager_and_gap_shrinks_with_model_size() {
        let small = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-1.7B").unwrap(),
            EngineFeatures::xllm(1),
        );
        let mut small_eager = small.clone();
        small_eager.features.graph_mode = GraphMode::Eager;
        let g = small.decode_step_s(32, 32 * 2048);
        let e = small_eager.decode_step_s(32, 32 * 2048);
        assert!(e > g, "eager {e} should be slower than graph {g}");
        let gain_small = e / g;

        let big = cm(EngineFeatures::xllm(1));
        let mut big_eager = big.clone();
        big_eager.features.graph_mode = GraphMode::Eager;
        let gain_big =
            big_eager.decode_step_s(32, 32 * 2048) / big.decode_step_s(32, 32 * 2048);
        assert!(
            gain_small > gain_big,
            "small-model gain {gain_small} should exceed big-model gain {gain_big}"
        );
    }

    #[test]
    fn async_sched_hides_cpu_time() {
        let sync = cm(EngineFeatures::mindie(1));
        let mut asyn = sync.clone();
        asyn.features.async_sched = true;
        let s = sync.decode_step_s(16, 16 * 1024);
        let a = asyn.decode_step_s(16, 16 * 1024);
        assert!(a < s, "async {a} !< sync {s}");
    }

    #[test]
    fn dual_stream_reduces_exposed_comm_for_moe() {
        let moe = catalog("DeepSeek-R1").unwrap();
        let mut base = CostModel::new(ascend_910b(), moe, EngineFeatures::xllm(16));
        base.features.dual_stream = false;
        let single = base.decode_step(128, 128 * 2048);
        let mut dual = base.clone();
        dual.features.dual_stream = true;
        let ds = dual.decode_step(128, 128 * 2048);
        assert!(ds.comm_exposed_s < single.comm_exposed_s * 0.3);
    }

    #[test]
    fn eplb_speeds_up_moe_decode() {
        let moe = catalog("DeepSeek-R1").unwrap();
        let with = CostModel::new(ascend_910b(), moe.clone(), EngineFeatures::xllm(16));
        let mut without = with.clone();
        without.features.eplb = false;
        assert!(
            without.decode_step_s(64, 64 * 2048) > with.decode_step_s(64, 64 * 2048)
        );
    }

    #[test]
    fn paged_attention_slower_than_xtensor() {
        let x = cm(EngineFeatures::xllm(1));
        let mut paged = x.clone();
        paged.features.paged_attention = true;
        assert!(paged.decode_step_s(32, 32 * 4096) > x.decode_step_s(32, 32 * 4096));
    }

    #[test]
    fn factor_learning_moves_toward_observation() {
        let mut m = cm(EngineFeatures::xllm(1));
        let before = m.decode_step_s(8, 8 * 1024);
        for _ in 0..50 {
            m.learn_decode(8, 8 * 1024, before * 2.0);
        }
        let after = m.decode_step_s(8, 8 * 1024);
        assert!(after > before * 1.2, "learning should raise prediction: {before} -> {after}");
    }

    #[test]
    fn kv_transfer_linear_in_tokens() {
        let m = cm(EngineFeatures::xllm(1));
        let t1 = m.kv_transfer_s(1000);
        let t2 = m.kv_transfer_s(2000);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tp_comm_is_exactly_zero_at_tp_one() {
        let m = cm(EngineFeatures::xllm(1));
        assert_eq!(m.tp_comm_s(4096.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(m.tp_comm_s(0.0).to_bits(), 0.0f64.to_bits());
    }

    #[test]
    fn tp_comm_ring_term_is_monotone_in_tp() {
        // ring factor 2(tp-1)/tp strictly increases in tp, so at fixed
        // token count the exposed comm must too
        let mut prev = cm(EngineFeatures::xllm(1)).tp_comm_s(2048.0);
        assert_eq!(prev, 0.0);
        for tp in 2..=16 {
            let cur = cm(EngineFeatures::xllm(tp)).tp_comm_s(2048.0);
            assert!(cur > prev, "tp={tp}: {cur} !> {prev}");
            prev = cur;
        }
    }

    #[test]
    fn pp_activation_transfer_is_exactly_zero_at_pp_one() {
        let m = cm(EngineFeatures::xllm(4)); // tp alone must not wake the pp term
        assert_eq!(m.features.shard.pp, 1);
        assert_eq!(m.pp_comm_s(4096.0).to_bits(), 0.0f64.to_bits());
        let sharded =
            cm(EngineFeatures::xllm(1).with_shard(ShardSpec::new(1, 2, 4)));
        assert!(sharded.pp_comm_s(4096.0) > 0.0);
        // linear in crossed boundaries: pp=3 crosses twice as many as pp=2
        let pp3 = cm(EngineFeatures::xllm(1).with_shard(ShardSpec::new(1, 3, 4)));
        assert!((pp3.pp_comm_s(4096.0) / sharded.pp_comm_s(4096.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pipeline_bubble_models_micro_batch_fill() {
        // pp=1: exactly 1.0 regardless of micro_batches
        let m1 = cm(EngineFeatures::xllm(1).with_shard(ShardSpec::new(1, 1, 8)));
        assert_eq!(m1.pipeline_bubble().to_bits(), 1.0f64.to_bits());
        assert_eq!(m1.pp_ramp_fraction().to_bits(), 0.0f64.to_bits());
        // pp=2, m=4: (2+4-1)/(2*4) = 5/8; drain tail (2-1)/(2+4-1) = 1/5
        let m2 = cm(EngineFeatures::xllm(1).with_shard(ShardSpec::new(1, 2, 4)));
        assert!((m2.pipeline_bubble() - 0.625).abs() < 1e-12);
        assert!((m2.pp_ramp_fraction() - 0.2).abs() < 1e-12);
        // more micro-batches shrink the bubble toward the ideal 1/pp
        let m8 = cm(EngineFeatures::xllm(1).with_shard(ShardSpec::new(1, 2, 16)));
        assert!(m8.pipeline_bubble() < m2.pipeline_bubble());
        assert!(m8.pipeline_bubble() > 0.5);
    }

    #[test]
    fn pp_with_micro_batching_speeds_up_long_prefill() {
        // a pp=2/m=4 device group finishes a long prompt faster than one
        // stage, even after paying the inter-stage activation transfers
        let flat = cm(EngineFeatures::xllm(1));
        let piped = cm(EngineFeatures::xllm(1).with_shard(ShardSpec::new(1, 2, 4)));
        let t_flat = flat.prefill_s(8192, 0);
        let t_piped = piped.prefill_s(8192, 0);
        assert!(t_piped < t_flat, "pp=2/m=4 {t_piped} !< pp=1 {t_flat}");
    }

    #[test]
    fn presets_route_parallelism_through_shard_spec() {
        // exactly one source of truth: the preset tp scalar lands in
        // `shard` and the deprecated view reads back from it
        for f in [EngineFeatures::xllm(4), EngineFeatures::vllm(4), EngineFeatures::mindie(4)] {
            assert_eq!(f.shard, ShardSpec::tp(4));
            assert_eq!(f.tp(), 4);
            assert_eq!(f.shard.devices(), 4);
        }
        let wide = EngineFeatures::xllm(2).with_shard(ShardSpec::new(2, 2, 4));
        assert_eq!(wide.tp(), 2);
        assert_eq!(wide.shard.devices(), 4);
    }
}
