//! Model and hardware catalogs.
//!
//! [`ModelSpec`] carries the analytic dimensions the roofline cost model
//! (sim::roofline) needs to predict prefill/decode step costs for the
//! paper's evaluation models (Qwen2/3-series, DeepSeek-R1/V3, the
//! DS-Distill-Qwen sizes) — these are the *simulated* models of the
//! figure/table benches.  The `tiny` spec mirrors the real AOT-compiled
//! model in `artifacts/` and is what the runtime actually executes.
//!
//! [`HardwareSpec`] is the Ascend-910B/910C-shaped accelerator abstraction:
//! peak matrix FLOPs, vector FLOPs, HBM bandwidth, kernel launch overhead,
//! and the Cube/Vector unit counts used by the operator-overlap optimizer
//! (paper Eq. (1)).

/// Analytic description of a served model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Total parameter count.
    pub params: f64,
    /// Activated parameters per token (== `params` for dense models).
    pub active_params: f64,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    /// KV heads (GQA); bytes/token scale with this.
    pub n_kv_heads: u32,
    pub head_dim: u32,
    /// Mixture-of-experts?
    pub is_moe: bool,
    /// Routed experts per layer (MoE only).
    pub n_experts: u32,
    /// Experts activated per token (MoE only).
    pub experts_per_tok: u32,
}

impl ModelSpec {
    /// KV cache bytes per token (fp16 K+V across all layers).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * 2.0 * self.n_layers as f64 * self.n_kv_heads as f64 * self.head_dim as f64
    }

    /// Weight bytes (fp16).
    pub fn weight_bytes(&self) -> f64 {
        2.0 * self.params
    }

    /// Activated weight bytes per token (fp16) — what decode streams.
    pub fn active_weight_bytes(&self) -> f64 {
        2.0 * self.active_params
    }

    /// FLOPs to process one token (forward): ~2 * active params, plus the
    /// attention term 2 * ctx * d_model * 2 per layer handled by the cost
    /// model (context-dependent).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.active_params
    }

    fn dense(
        name: &'static str,
        params_b: f64,
        n_layers: u32,
        d_model: u32,
        n_heads: u32,
        n_kv_heads: u32,
    ) -> ModelSpec {
        ModelSpec {
            name,
            params: params_b * 1e9,
            active_params: params_b * 1e9,
            n_layers,
            d_model,
            n_heads,
            n_kv_heads,
            head_dim: d_model / n_heads,
            is_moe: false,
            n_experts: 0,
            experts_per_tok: 0,
        }
    }
}

/// The real AOT-compiled model (must match python/compile/model.py TINY).
pub fn tiny() -> ModelSpec {
    ModelSpec {
        name: "tiny",
        params: 130_000.0,
        active_params: 130_000.0,
        n_layers: 2,
        d_model: 64,
        n_heads: 4,
        n_kv_heads: 4,
        head_dim: 16,
        is_moe: false,
        n_experts: 0,
        experts_per_tok: 0,
    }
}

/// Paper evaluation models (public configs; head counts per release docs).
pub fn catalog(name: &str) -> Option<ModelSpec> {
    Some(match name {
        "tiny" => tiny(),
        "Qwen3-0.6B" => ModelSpec::dense("Qwen3-0.6B", 0.6, 28, 1024, 16, 8),
        "Qwen3-1.7B" => ModelSpec::dense("Qwen3-1.7B", 1.7, 28, 2048, 16, 8),
        "Qwen3-4B" => ModelSpec::dense("Qwen3-4B", 4.0, 36, 2560, 32, 8),
        "Qwen3-8B" => ModelSpec::dense("Qwen3-8B", 8.0, 36, 4096, 32, 8),
        "Qwen3-14B" => ModelSpec::dense("Qwen3-14B", 14.0, 40, 5120, 40, 8),
        "Qwen3-32B" => ModelSpec::dense("Qwen3-32B", 32.0, 64, 5120, 64, 8),
        "Qwen2-7B" => ModelSpec::dense("Qwen2-7B", 7.0, 28, 3584, 28, 4),
        "Qwen2-72B" => ModelSpec::dense("Qwen2-72B", 72.0, 80, 8192, 64, 8),
        "DS-Distill-Qwen-1.5B" => ModelSpec::dense("DS-Distill-Qwen-1.5B", 1.5, 28, 1536, 12, 2),
        "DS-Distill-Qwen-7B" => ModelSpec::dense("DS-Distill-Qwen-7B", 7.0, 28, 3584, 28, 4),
        "DS-Distill-Qwen-14B" => ModelSpec::dense("DS-Distill-Qwen-14B", 14.0, 48, 5120, 40, 8),
        "DS-Distill-Qwen-32B" => ModelSpec::dense("DS-Distill-Qwen-32B", 32.0, 64, 5120, 40, 8),
        "DeepSeek-R1" | "DeepSeek-V3" => ModelSpec {
            name: if name == "DeepSeek-R1" { "DeepSeek-R1" } else { "DeepSeek-V3" },
            params: 671e9,
            active_params: 37e9,
            n_layers: 61,
            d_model: 7168,
            n_heads: 128,
            // MLA compressed KV: model as few effective KV heads
            n_kv_heads: 1,
            head_dim: 576,
            is_moe: true,
            n_experts: 256,
            experts_per_tok: 8,
        },
        _ => return None,
    })
}

/// All catalog names (for CLI listing).
pub const CATALOG_NAMES: &[&str] = &[
    "tiny",
    "Qwen3-0.6B",
    "Qwen3-1.7B",
    "Qwen3-4B",
    "Qwen3-8B",
    "Qwen3-14B",
    "Qwen3-32B",
    "Qwen2-7B",
    "Qwen2-72B",
    "DS-Distill-Qwen-1.5B",
    "DS-Distill-Qwen-7B",
    "DS-Distill-Qwen-14B",
    "DS-Distill-Qwen-32B",
    "DeepSeek-R1",
    "DeepSeek-V3",
];

/// How a replica shards its model across a device group (DESIGN.md
/// §Sharding): `tp`-way tensor parallelism within each pipeline stage,
/// `pp` pipeline stages over the layer stack, and `micro_batches`
/// micro-batches filling the pipeline per iteration.  A replica
/// occupies `tp * pp` devices.  The default `{1, 1, 1}` is the
/// single-device replica and must be cost-neutral everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Tensor-parallel degree within each pipeline stage.
    pub tp: u32,
    /// Pipeline-parallel stage count over the layer stack.
    pub pp: u32,
    /// Micro-batches per iteration filling the pp pipeline (ignored
    /// when `pp == 1`).
    pub micro_batches: u32,
}

impl Default for ShardSpec {
    fn default() -> ShardSpec {
        ShardSpec { tp: 1, pp: 1, micro_batches: 1 }
    }
}

impl ShardSpec {
    /// Tensor-parallel-only shard (the pre-ShardSpec `tp` scalar).
    pub fn tp(tp: u32) -> ShardSpec {
        ShardSpec { tp: tp.max(1), ..ShardSpec::default() }
    }

    pub fn new(tp: u32, pp: u32, micro_batches: u32) -> ShardSpec {
        ShardSpec { tp: tp.max(1), pp: pp.max(1), micro_batches: micro_batches.max(1) }
    }

    /// Devices one replica occupies (`tp * pp`).
    pub fn devices(&self) -> u32 {
        self.tp.saturating_mul(self.pp)
    }

    /// Parse the CLI form `tp=4,pp=2,mb=8` (any subset of keys, any
    /// order; `micro_batches=` accepted as an alias for `mb=`).
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let mut spec = ShardSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("bad shard component {part:?} (want key=value)"))?;
            let n: u32 = val
                .trim()
                .parse()
                .map_err(|_| format!("bad shard value {val:?} in {part:?}"))?;
            if n == 0 {
                return Err(format!("shard degree must be >= 1 in {part:?}"));
            }
            match key.trim() {
                "tp" => spec.tp = n,
                "pp" => spec.pp = n,
                "mb" | "micro_batches" => spec.micro_batches = n,
                other => return Err(format!("unknown shard key {other:?} (tp/pp/mb)")),
            }
        }
        Ok(spec)
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tp={},pp={},mb={}", self.tp, self.pp, self.micro_batches)
    }
}

/// Accelerator abstraction (Ascend-shaped; see DESIGN.md §Hardware-Adaptation).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareSpec {
    pub name: &'static str,
    /// Peak dense matrix FLOPs/s (fp16).
    pub matrix_flops: f64,
    /// Peak vector FLOPs/s.
    pub vector_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_bytes: f64,
    /// Per-kernel launch overhead, seconds (paper §4.2: 5–50 µs).
    pub kernel_launch_s: f64,
    /// Interconnect (All-to-All / KV transfer) bandwidth, bytes/s.
    pub net_bw: f64,
    /// Matrix (Cube) unit count — operator-overlap optimizer.
    pub n_cube: u32,
    /// Vector unit count.
    pub n_vector: u32,
}

/// Ascend 910B-like device.
pub fn ascend_910b() -> HardwareSpec {
    HardwareSpec {
        name: "910B",
        matrix_flops: 376e12,
        vector_flops: 94e12 / 16.0,
        hbm_bw: 1.6e12,
        hbm_bytes: 64e9,
        kernel_launch_s: 20e-6,
        net_bw: 56e9,
        n_cube: 24,
        n_vector: 48,
    }
}

/// Ascend 910C-like device (next generation: ~2x compute, ~2x bandwidth).
pub fn ascend_910c() -> HardwareSpec {
    HardwareSpec {
        name: "910C",
        matrix_flops: 752e12,
        vector_flops: 2.0 * 94e12 / 16.0,
        hbm_bw: 3.2e12,
        hbm_bytes: 128e9,
        kernel_launch_s: 18e-6,
        net_bw: 112e9,
        n_cube: 48,
        n_vector: 96,
    }
}

/// The CPU host running the real PJRT path (calibrated by `bench calibrate`).
pub fn cpu_host() -> HardwareSpec {
    HardwareSpec {
        name: "cpu",
        matrix_flops: 5e10,
        vector_flops: 2e10,
        hbm_bw: 2e10,
        hbm_bytes: 8e9,
        kernel_launch_s: 10e-6,
        net_bw: 1e10,
        n_cube: 4,
        n_vector: 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_all_names() {
        for name in CATALOG_NAMES {
            let spec = catalog(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(spec.params > 0.0);
            assert!(spec.active_params <= spec.params);
            assert!(spec.n_layers > 0);
        }
        assert!(catalog("nope").is_none());
    }

    #[test]
    fn moe_models_have_fewer_active_params() {
        let r1 = catalog("DeepSeek-R1").unwrap();
        assert!(r1.is_moe);
        assert!(r1.active_params < r1.params / 10.0);
        assert_eq!(r1.n_layers, 61); // paper table 7 uses 61 layers
    }

    #[test]
    fn kv_bytes_scale_with_kv_heads() {
        let a = catalog("Qwen3-8B").unwrap();
        let b = catalog("Qwen3-32B").unwrap();
        assert!(a.kv_bytes_per_token() > 0.0);
        assert!(b.kv_bytes_per_token() > a.kv_bytes_per_token() * 0.9);
    }

    #[test]
    fn hw_910c_is_faster_than_910b() {
        let b = ascend_910b();
        let c = ascend_910c();
        assert!(c.matrix_flops > b.matrix_flops);
        assert!(c.hbm_bw > b.hbm_bw);
    }

    #[test]
    fn shard_spec_parses_and_counts_devices() {
        assert_eq!(ShardSpec::default(), ShardSpec { tp: 1, pp: 1, micro_batches: 1 });
        assert_eq!(ShardSpec::default().devices(), 1);
        assert_eq!(ShardSpec::tp(4), ShardSpec { tp: 4, pp: 1, micro_batches: 1 });
        let s = ShardSpec::parse("tp=4,pp=2,mb=8").unwrap();
        assert_eq!(s, ShardSpec { tp: 4, pp: 2, micro_batches: 8 });
        assert_eq!(s.devices(), 8);
        // subsets, aliases, whitespace
        assert_eq!(ShardSpec::parse("pp=2").unwrap(), ShardSpec::new(1, 2, 1));
        assert_eq!(
            ShardSpec::parse(" tp=2 , micro_batches=4 ").unwrap(),
            ShardSpec::new(2, 1, 4)
        );
        assert_eq!(ShardSpec::parse("").unwrap(), ShardSpec::default());
        // rejects malformed input
        assert!(ShardSpec::parse("tp").is_err());
        assert!(ShardSpec::parse("tp=zero").is_err());
        assert!(ShardSpec::parse("tp=0").is_err());
        assert!(ShardSpec::parse("ep=2").is_err());
        assert_eq!(ShardSpec::new(4, 2, 8).to_string(), "tp=4,pp=2,mb=8");
    }

    #[test]
    fn tiny_matches_python_config() {
        let t = tiny();
        assert_eq!(t.n_layers, 2);
        assert_eq!(t.d_model, 64);
        assert_eq!(t.n_heads, 4);
        assert_eq!(t.head_dim, 16);
    }
}
