//! Mini property-testing harness + shared test fixtures.
//!
//! The offline crate set has no `proptest`, so this module provides the
//! same methodology in ~100 lines: run a property over many seeded random
//! cases and report the first failing seed (re-runnable deterministically).
//! Used by the coordinator/engine invariant tests (routing, batching,
//! paging, beam search).  [`FixedCostExecutor`] is the shared trivial
//! [`Executor`] backing the orchestrator/control-plane unit tests.

use crate::coordinator::orchestrator::{
    Executor, IterationOutcome, IterationTicket, IterationWork,
};
use crate::coordinator::pools::InstanceId;
use crate::coordinator::request::RequestId;
use crate::model::{ascend_910b, catalog};
use crate::sim::roofline::{CostModel, EngineFeatures};
use crate::util::Rng;

/// Number of cases per property (kept modest; each case is cheap).
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` seeded RNGs; panic with the failing seed.
///
/// `prop` returns `Err(reason)` (or panics) to signal a violation.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(reason) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {reason}");
        }
    }
}

/// Shorthand: `check` with [`DEFAULT_CASES`].
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, DEFAULT_CASES, prop);
}

/// A trivial fixed-cost [`Executor`]: every planned iteration takes
/// `step_s` device time (plus an optional `host_s` host share) and each
/// decode emits one token.  Proves the lifecycle runs with no roofline
/// model and no PJRT runtime behind it; the public counters let tests
/// assert the orchestrator↔executor two-phase contract (including how
/// many tickets were ever outstanding at once).
pub struct FixedCostExecutor {
    pub cost: CostModel,
    pub step_s: f64,
    /// Host share reported per iteration ([`IterationOutcome::host_s`]).
    pub host_s: f64,
    /// Pipeline-parallel drain tail per iteration
    /// ([`IterationOutcome::ramp_s`]; 0.0 = unsharded).
    pub ramp_s: f64,
    pub iterations: u64,
    pub finished: u64,
    /// Tickets submitted but not yet completed, and its high-water mark
    /// (the pipeline tests pin the in-flight bound with these).
    pub outstanding: u64,
    pub max_outstanding: u64,
    seq: u64,
}

impl FixedCostExecutor {
    pub fn new(step_s: f64) -> FixedCostExecutor {
        FixedCostExecutor {
            cost: CostModel::new(
                ascend_910b(),
                catalog("Qwen3-8B").unwrap(),
                EngineFeatures::xllm(1),
            ),
            step_s,
            host_s: 0.0,
            ramp_s: 0.0,
            iterations: 0,
            finished: 0,
            outstanding: 0,
            max_outstanding: 0,
            seq: 0,
        }
    }

    /// [`Self::new`] with a nonzero host share per iteration.
    pub fn with_host(step_s: f64, host_s: f64) -> FixedCostExecutor {
        let mut e = FixedCostExecutor::new(step_s);
        e.host_s = host_s;
        e
    }

    /// [`Self::new`] with a nonzero pp drain tail per iteration (a
    /// sharded device group whose first stage frees up `ramp_s` early).
    pub fn with_ramp(step_s: f64, ramp_s: f64) -> FixedCostExecutor {
        let mut e = FixedCostExecutor::new(step_s);
        e.ramp_s = ramp_s;
        e
    }
}

impl Executor for FixedCostExecutor {
    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn submit_iteration(
        &mut self,
        instance: InstanceId,
        _now_s: f64,
        _work: &IterationWork,
    ) -> IterationTicket {
        self.iterations += 1;
        self.seq += 1;
        self.outstanding += 1;
        self.max_outstanding = self.max_outstanding.max(self.outstanding);
        IterationTicket {
            instance,
            seq: self.seq,
            est: IterationOutcome {
                host_s: self.host_s,
                device_s: self.step_s,
                ramp_s: self.ramp_s,
            },
        }
    }

    fn poll_complete(&mut self, ticket: IterationTicket) -> IterationOutcome {
        self.outstanding = self.outstanding.saturating_sub(1);
        ticket.est
    }

    fn finished(&mut self, _req: RequestId, _now_s: f64) {
        self.finished += 1;
    }
}

/// Assert helper producing `Result` instead of panicking, so properties can
/// bubble a readable message with the failing seed attached.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 64, |rng| {
            n += 1;
            let x = rng.range(0, 100);
            prop_assert!(x <= 100, "x={x}");
            Ok(())
        });
        assert_eq!(n, 64);
    }

    #[test]
    #[should_panic(expected = "property 'bad'")]
    fn failing_property_reports_seed() {
        check("bad", 64, |rng| {
            let x = rng.range(0, 100);
            prop_assert!(x < 50, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn determinism_same_seed_same_values() {
        let mut first: Vec<u64> = Vec::new();
        check("collect", 8, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("collect2", 8, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
