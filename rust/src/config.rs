//! Serving configuration and a tiny CLI argument parser.
//!
//! No `clap` in the offline crate set; `Args` implements the small subset
//! needed by the launcher and benches: `--key value`, `--key=value`, and
//! bare subcommands.

use std::collections::HashMap;

use crate::engine::policies::EnginePolicies;
use crate::metrics::Slo;
use crate::model::ShardSpec;

/// Parsed command-line arguments: one subcommand + `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.flags.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Positional arguments after the subcommand.
    pub fn positional(&self) -> &[String] {
        &self.flags
    }
}

/// Top-level serving configuration for the real (PJRT) server.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Directory holding the AOT artifacts (HLO text + weights + manifest).
    pub artifacts_dir: String,
    /// Max sequences per decode batch (must match an AOT decode bucket).
    pub max_batch: usize,
    /// Token budget per iteration for chunked prefill.
    pub prefill_chunk_tokens: usize,
    /// Max output tokens per request.
    pub max_output_tokens: usize,
    /// SLO attached to online requests.
    pub slo: Slo,
    /// Enable speculative decoding with the draft model.
    pub speculative: bool,
    /// Iterations kept in flight (§4.2 async scheduling): 1 = blocking
    /// engine on the orchestrator thread; ≥ 2 moves the engine onto a
    /// worker thread so host scheduling overlaps device execution.
    pub pipeline_depth: usize,
    /// Prefix-chain block granularity in tokens (§3.4) — must match the
    /// fleet control plane's global-index granularity when this engine
    /// serves as a fleet replica (`xllm fleet --backend pjrt`).
    pub prefix_block_tokens: u64,
    /// Executor-level engine policies (§4).  On the real engine path
    /// only `graph_mode` changes behavior today (per-batch graph/eager
    /// selection against the AOT buckets, counted in `ServerStats`);
    /// the rest are accepted for CLI symmetry with `simulate`.
    pub policies: EnginePolicies,
    /// Device-group shape behind this replica (`--shard tp=..,pp=..`).
    /// The real engine runs single-device today; the shard still flows
    /// into the stand-in cost model and the control plane's load
    /// reports, so fleet-level device accounting sees the true width.
    pub shard: ShardSpec,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: "artifacts".to_string(),
            max_batch: 8,
            prefill_chunk_tokens: 128,
            max_output_tokens: 32,
            slo: Slo::interactive(2.0, 0.5),
            speculative: false,
            pipeline_depth: 1,
            prefix_block_tokens: crate::coordinator::orchestrator::DEFAULT_PREFIX_BLOCK_TOKENS,
            policies: EnginePolicies::default(),
            shard: ShardSpec::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --model tiny --rate 2.5 --max-batch=8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
        assert_eq!(a.get_u64("max-batch", 0), 8);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_f64("rate", 1.5), 1.5);
    }

    #[test]
    fn empty_args() {
        let a = parse("");
        assert!(a.subcommand.is_none());
    }
}
