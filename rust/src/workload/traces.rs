//! Arrival processes and request specifications.

use crate::util::Rng;

/// Online (latency-sensitive, SLO-bound) vs offline (best-effort) class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequestClass {
    Online,
    Offline,
}

/// A request to be served: arrival time + token lengths (+ multimodality).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpec {
    pub arrival_s: f64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub class: RequestClass,
    /// Number of image patches to encode (0 = text-only).
    pub image_patches: u64,
    /// Prefix-cache group: requests sharing a group share a prompt prefix
    /// of `shared_prefix` tokens (system prompts etc.).
    pub prefix_group: u64,
    pub shared_prefix: u64,
    /// Multi-tenant service tier (0 = premium interactive, 1 = standard,
    /// 2 = relaxed / best-effort).  Per-tier TTFT/TPOT targets live in
    /// [`crate::metrics::tier_slo`]; the tier changes *reporting*
    /// (per-tier goodput) and the SLO-aware scaler, never scheduling.
    pub tier: u8,
}

impl RequestSpec {
    pub fn text(arrival_s: f64, input_tokens: u64, output_tokens: u64) -> Self {
        RequestSpec {
            arrival_s,
            input_tokens,
            output_tokens,
            class: RequestClass::Online,
            image_patches: 0,
            prefix_group: 0,
            shared_prefix: 0,
            tier: 0,
        }
    }

    pub fn offline(mut self) -> Self {
        self.class = RequestClass::Offline;
        self.tier = 2;
        self
    }

    pub fn with_tier(mut self, tier: u8) -> Self {
        self.tier = tier;
        self
    }

    pub fn is_multimodal(&self) -> bool {
        self.image_patches > 0
    }
}

/// Arrival process shapes seen in the paper's workloads.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// Poisson arrivals at a constant rate (req/s).
    Poisson { rate: f64 },
    /// Deterministic fixed interval.
    Uniform { rate: f64 },
    /// Poisson baseline plus minute-scale bursts: with probability
    /// `burst_prob` per second, the rate multiplies by `burst_factor` for
    /// `burst_len_s` (the Azure *Code* trace shape — "significant bursty
    /// traffic", §5.2).
    Bursty { rate: f64, burst_factor: f64, burst_prob: f64, burst_len_s: f64 },
    /// Sinusoidal "tidal" day/night pattern compressed to `period_s`
    /// (§3.1: hourly/daily tidal variation of online traffic).
    Tidal { mean_rate: f64, amplitude: f64, period_s: f64 },
}

impl ArrivalProcess {
    /// Generate arrival times covering `[0, horizon_s)`.
    ///
    /// Thin collect-adapter over [`ArrivalIter`]: the lazy iterator is
    /// the single source of truth for the draw sequence, so collecting
    /// it is bit-identical to the historical eager loop (the caller's
    /// RNG is left at the post-generation state either way).
    pub fn arrivals(&self, horizon_s: f64, rng: &mut Rng) -> Vec<f64> {
        let mut it = self.iter(horizon_s, rng.clone());
        let out: Vec<f64> = (&mut it).collect();
        *rng = it.into_rng();
        out
    }

    /// Lazy O(1)-state arrival iterator over `[0, horizon_s)`, owning
    /// its RNG lane.  `horizon_s = f64::INFINITY` yields an unbounded
    /// open-loop process (cap with `Iterator::take`).
    pub fn iter(&self, horizon_s: f64, rng: Rng) -> ArrivalIter {
        ArrivalIter { proc: *self, horizon_s, rng, t: 0.0, burst_until: -1.0, done: false }
    }

    /// Advance `rng` through every draw [`Self::arrivals`] would make
    /// over a *finite* horizon, without materializing the arrivals;
    /// returns how many there were.  This is the O(1)-memory replay
    /// pass that lets a stream split one seed RNG into an arrival lane
    /// and a field lane (see `workload::stream`).
    pub fn advance(&self, horizon_s: f64, rng: &mut Rng) -> usize {
        debug_assert!(horizon_s.is_finite(), "advance() requires a finite horizon");
        let mut it = self.iter(horizon_s, rng.clone());
        let n = (&mut it).count();
        *rng = it.into_rng();
        n
    }

    /// Instantaneous expected rate at time `t` (for monitoring tests).
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } | ArrivalProcess::Uniform { rate } => rate,
            ArrivalProcess::Bursty { rate, .. } => rate,
            ArrivalProcess::Tidal { mean_rate, amplitude, period_s } => {
                let phase = 2.0 * std::f64::consts::PI * t / period_s;
                mean_rate * (1.0 + amplitude * phase.sin())
            }
        }
    }
}

/// Pull-based arrival generator: one `(t, burst_until)` cursor plus an
/// owned RNG lane, so a million-request open-loop workload costs the
/// same memory as a ten-request one.  The draw order per emitted (or,
/// for the thinned tidal process, rejected) arrival is exactly the
/// historical eager loop's — [`ArrivalProcess::arrivals`] is now a
/// collect of this iterator, which pins the equivalence structurally.
#[derive(Debug, Clone)]
pub struct ArrivalIter {
    proc: ArrivalProcess,
    horizon_s: f64,
    rng: Rng,
    t: f64,
    burst_until: f64,
    done: bool,
}

impl ArrivalIter {
    /// The RNG lane at its current position (post-generation state once
    /// the iterator is drained; used to hand the lane back to a caller).
    pub fn into_rng(self) -> Rng {
        self.rng
    }
}

impl Iterator for ArrivalIter {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        match self.proc {
            ArrivalProcess::Poisson { rate } => {
                self.t += self.rng.exp(1.0 / rate.max(1e-9));
                if self.t >= self.horizon_s {
                    self.done = true;
                    return None;
                }
                Some(self.t)
            }
            ArrivalProcess::Uniform { rate } => {
                let dt = 1.0 / rate.max(1e-9);
                self.t += dt;
                if self.t >= self.horizon_s {
                    self.done = true;
                    return None;
                }
                Some(self.t)
            }
            ArrivalProcess::Bursty { rate, burst_factor, burst_prob, burst_len_s } => {
                let in_burst = self.t < self.burst_until;
                let r = if in_burst { rate * burst_factor } else { rate };
                self.t += self.rng.exp(1.0 / r.max(1e-9));
                if self.t >= self.horizon_s {
                    self.done = true;
                    return None;
                }
                if !in_burst && self.rng.chance(burst_prob * (1.0 / r).min(1.0)) {
                    self.burst_until = self.t + burst_len_s;
                }
                Some(self.t)
            }
            ArrivalProcess::Tidal { mean_rate, amplitude, period_s } => {
                // thinning over the sinusoidal intensity: rejected
                // candidates consume draws but emit nothing, so loop
                // until an accept (or the horizon)
                let peak = mean_rate * (1.0 + amplitude);
                loop {
                    self.t += self.rng.exp(1.0 / peak.max(1e-9));
                    if self.t >= self.horizon_s {
                        self.done = true;
                        return None;
                    }
                    let phase = 2.0 * std::f64::consts::PI * self.t / period_s;
                    let intensity = mean_rate * (1.0 + amplitude * phase.sin());
                    if self.rng.chance((intensity / peak).clamp(0.0, 1.0)) {
                        return Some(self.t);
                    }
                }
            }
        }
    }
}

/// Length distribution helpers used by the scenario generators.
#[derive(Debug, Clone, Copy)]
pub enum LengthDist {
    Fixed(u64),
    /// Log-normal with given median and sigma, clamped to [lo, hi].
    LogNormal { median: f64, sigma: f64, lo: u64, hi: u64 },
    Uniform { lo: u64, hi: u64 },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match *self {
            LengthDist::Fixed(n) => n,
            LengthDist::LogNormal { median, sigma, lo, hi } => {
                let x = rng.lognormal(median.ln(), sigma);
                (x.round() as u64).clamp(lo, hi)
            }
            LengthDist::Uniform { lo, hi } => rng.range(lo, hi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut rng = Rng::new(1);
        let arr = ArrivalProcess::Poisson { rate: 10.0 }.arrivals(1000.0, &mut rng);
        let rate = arr.len() as f64 / 1000.0;
        assert!((rate - 10.0).abs() < 0.5, "rate={rate}");
    }

    #[test]
    fn arrivals_sorted_and_in_horizon() {
        crate::testutil::quickcheck("arrivals-sorted", |rng| {
            let procs = [
                ArrivalProcess::Poisson { rate: 5.0 },
                ArrivalProcess::Bursty {
                    rate: 3.0,
                    burst_factor: 8.0,
                    burst_prob: 0.05,
                    burst_len_s: 5.0,
                },
                ArrivalProcess::Tidal { mean_rate: 4.0, amplitude: 0.8, period_s: 60.0 },
            ];
            for p in procs {
                let arr = p.arrivals(100.0, rng);
                for w in arr.windows(2) {
                    crate::prop_assert!(w[0] <= w[1], "unsorted arrivals");
                }
                for &t in &arr {
                    crate::prop_assert!((0.0..100.0).contains(&t), "t={t} out of horizon");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn bursty_has_heavier_peaks_than_poisson() {
        let mut rng = Rng::new(2);
        let bursty = ArrivalProcess::Bursty {
            rate: 5.0,
            burst_factor: 10.0,
            burst_prob: 0.02,
            burst_len_s: 10.0,
        }
        .arrivals(2000.0, &mut rng);
        let mut rng2 = Rng::new(2);
        let poisson = ArrivalProcess::Poisson { rate: 5.0 }.arrivals(2000.0, &mut rng2);

        let peak = |arr: &[f64]| {
            let mut max_in_window = 0usize;
            let mut lo = 0;
            for hi in 0..arr.len() {
                while arr[hi] - arr[lo] > 5.0 {
                    lo += 1;
                }
                max_in_window = max_in_window.max(hi - lo + 1);
            }
            max_in_window
        };
        assert!(
            peak(&bursty) as f64 > peak(&poisson) as f64 * 1.5,
            "bursty peak {} vs poisson peak {}",
            peak(&bursty),
            peak(&poisson)
        );
    }

    #[test]
    fn tidal_rate_oscillates() {
        let p = ArrivalProcess::Tidal { mean_rate: 10.0, amplitude: 0.9, period_s: 100.0 };
        assert!(p.rate_at(25.0) > 18.0); // peak
        assert!(p.rate_at(75.0) < 2.0); // trough
    }

    #[test]
    fn advance_replays_the_exact_draw_count_and_rng_state() {
        let procs = [
            ArrivalProcess::Poisson { rate: 6.0 },
            ArrivalProcess::Uniform { rate: 4.0 },
            ArrivalProcess::Bursty {
                rate: 3.0,
                burst_factor: 8.0,
                burst_prob: 0.05,
                burst_len_s: 5.0,
            },
            ArrivalProcess::Tidal { mean_rate: 4.0, amplitude: 0.9, period_s: 40.0 },
        ];
        for p in procs {
            let mut eager = Rng::new(77);
            let v = p.arrivals(50.0, &mut eager);
            let mut advanced = Rng::new(77);
            let n = p.advance(50.0, &mut advanced);
            assert_eq!(n, v.len(), "{p:?}");
            // both RNGs must sit at the same post-generation state
            assert_eq!(eager.next_u64(), advanced.next_u64(), "{p:?}");
        }
    }

    #[test]
    fn unbounded_iter_streams_past_any_horizon() {
        let tidal = ArrivalProcess::Tidal { mean_rate: 5.0, amplitude: 0.8, period_s: 30.0 };
        let v: Vec<f64> = tidal.iter(f64::INFINITY, Rng::new(13)).take(5000).collect();
        assert_eq!(v.len(), 5000, "the open-loop iterator never runs dry");
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
        assert!(v[4999] > 900.0, "5000 arrivals at ~5/s must span far past a finite horizon");
    }

    #[test]
    fn length_dists_in_bounds() {
        crate::testutil::quickcheck("length-bounds", |rng| {
            let d = LengthDist::LogNormal { median: 500.0, sigma: 0.8, lo: 16, hi: 4096 };
            let x = d.sample(rng);
            crate::prop_assert!((16..=4096).contains(&x), "x={x}");
            let u = LengthDist::Uniform { lo: 5, hi: 10 }.sample(rng);
            crate::prop_assert!((5..=10).contains(&u));
            Ok(())
        });
    }
}
