//! Pull-based workload streaming: `ArrivalStream` yields `RequestSpec`s
//! one at a time in O(1) memory, replacing the pre-materialized
//! `Vec<RequestSpec>` for million-request open-loop runs.
//!
//! # Determinism contract (two-lane RNG replay)
//!
//! The historical `Scenario::generate` consumes the caller's RNG in two
//! phases: every arrival-time draw first, then per-request field draws
//! (prefix-share chance, input sample, output sample, group pick) in
//! request order.  A naive lazy generator would interleave the two and
//! produce a *different* request sequence from the same seed.
//!
//! [`ArrivalStream`] reproduces the legacy order exactly with two RNG
//! lanes split from one seed state:
//!
//! 1. clone the caller's RNG as the **arrival lane** (pre-arrival state);
//! 2. advance the caller's RNG through the whole arrival pass once
//!    without storing anything ([`ArrivalProcess::advance`], O(1)
//!    memory), leaving it at the post-arrival state — the **field
//!    lane**;
//! 3. lazily replay arrivals from the arrival lane while drawing each
//!    request's fields from the field lane in legacy per-request order.
//!
//! Draining the stream therefore yields bit-identical specs in the same
//! order as `generate()`, and `generate()` itself is now a collect of
//! this stream that syncs the final field-lane state back into the
//! caller's RNG — so every existing scenario, golden fixture, and seed
//! keeps its exact behavior.  The arrival pass runs twice (once to
//! advance, once to replay); that trade buys O(1) memory at unchanged
//! output.
//!
//! For *unbounded* runs (`--requests N` at fleet scope) the advance
//! pass cannot terminate, so [`Scenario::stream_unbounded`] forks two
//! independent lanes instead — deterministic per seed, but its draw
//! order is its own (documented, not bit-comparable to `generate()`,
//! which cannot express an infinite horizon anyway).

use crate::util::Rng;
use crate::workload::scenarios::Scenario;
use crate::workload::traces::{ArrivalIter, ArrivalProcess, RequestSpec};

/// Lazy request generator: O(1) state (one arrival cursor + two RNG
/// lanes + a counter), no matter how many requests it emits.
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    scenario: Scenario,
    arrivals: ArrivalIter,
    fields: Rng,
    emitted: usize,
    limit: Option<usize>,
}

impl ArrivalStream {
    /// Finite-horizon stream that is bit-identical to the legacy
    /// `generate()` (see the module docs for the two-lane replay).
    /// `rng` is left at the post-arrival (field-lane) state; callers
    /// that need the legacy post-generation state take it back via
    /// [`Self::into_field_rng`] after draining.
    pub(crate) fn replaying(
        scenario: Scenario,
        proc: ArrivalProcess,
        horizon_s: f64,
        rng: &mut Rng,
    ) -> ArrivalStream {
        let arrival_rng = rng.clone();
        proc.advance(horizon_s, rng);
        ArrivalStream {
            scenario,
            arrivals: proc.iter(horizon_s, arrival_rng),
            fields: rng.clone(),
            emitted: 0,
            limit: None,
        }
    }

    /// Unbounded open-loop stream (horizon = ∞) over two forked lanes;
    /// cap with [`Self::with_limit`] or `Iterator::take`.
    pub(crate) fn open_loop(
        scenario: Scenario,
        proc: ArrivalProcess,
        rng: &mut Rng,
    ) -> ArrivalStream {
        let arrival_rng = rng.fork();
        let fields = rng.fork();
        ArrivalStream {
            scenario,
            arrivals: proc.iter(f64::INFINITY, arrival_rng),
            fields,
            emitted: 0,
            limit: None,
        }
    }

    /// Stop after `n` requests (the `--requests N` cap).
    pub fn with_limit(mut self, n: usize) -> ArrivalStream {
        self.limit = Some(n);
        self
    }

    /// Requests emitted so far.
    pub fn emitted(&self) -> usize {
        self.emitted
    }

    /// The field-lane RNG — after draining a replaying stream this is
    /// exactly the state the legacy eager `generate()` left its caller
    /// with.
    pub fn into_field_rng(self) -> Rng {
        self.fields
    }
}

impl Iterator for ArrivalStream {
    type Item = RequestSpec;

    fn next(&mut self) -> Option<RequestSpec> {
        if let Some(cap) = self.limit {
            if self.emitted >= cap {
                return None;
            }
        }
        let t = self.arrivals.next()?;
        let sc = &self.scenario;
        let rng = &mut self.fields;
        // legacy per-request draw order: share chance, input sample,
        // output sample, then the group pick iff shared
        let shared = rng.chance(sc.prefix_share);
        let spec = RequestSpec {
            arrival_s: t,
            input_tokens: sc.input_len.sample(rng).max(1),
            output_tokens: sc.output_len.sample(rng).max(1),
            class: sc.class,
            image_patches: sc.image_patches,
            prefix_group: if shared { 1 + rng.range(0, sc.prefix_groups.max(1) - 1) } else { 0 },
            shared_prefix: if shared { sc.prefix_len } else { 0 },
            // tier assignment consumes NO randomness (deterministic
            // cycle over the scenario's tenant mix) so adding tiers
            // cannot perturb any legacy draw sequence
            tier: sc.tier_for(self.emitted),
        };
        self.emitted += 1;
        Some(spec)
    }
}

#[cfg(test)]
mod tests {
    use crate::util::Rng;
    use crate::workload::scenarios::{scenario, SCENARIO_NAMES};

    /// Satellite pin: for every named scenario, draining the stream
    /// with the seed RNG yields the exact request sequence (and final
    /// RNG state) the eager generate() produces.
    #[test]
    fn stream_is_bit_identical_to_generate_for_every_scenario() {
        for name in SCENARIO_NAMES {
            let sc = scenario(name).unwrap();
            let mut eager_rng = Rng::new(0xA11CE);
            let eager = sc.generate(45.0, 3.0, &mut eager_rng);

            let mut stream_rng = Rng::new(0xA11CE);
            let mut stream = sc.stream(45.0, 3.0, &mut stream_rng);
            let mut lazy = Vec::new();
            for spec in &mut stream {
                lazy.push(spec);
            }
            assert_eq!(eager, lazy, "{name}: stream and generate disagree");
            let mut final_rng = stream.into_field_rng();
            assert_eq!(
                eager_rng.next_u64(),
                final_rng.next_u64(),
                "{name}: post-generation RNG states diverged"
            );
        }
    }

    #[test]
    fn stream_is_lazy_and_resumable_mid_drain() {
        let sc = scenario("tide").unwrap();
        let mut rng = Rng::new(7);
        let all = sc.generate(40.0, 4.0, &mut rng);
        let mut rng = Rng::new(7);
        let mut stream = sc.stream(40.0, 4.0, &mut rng);
        let head: Vec<_> = stream.by_ref().take(5).collect();
        let tail: Vec<_> = stream.collect();
        assert_eq!(&all[..5], head.as_slice());
        assert_eq!(&all[5..], tail.as_slice());
    }

    #[test]
    fn unbounded_stream_caps_at_the_request_limit() {
        let sc = scenario("tide").unwrap();
        let mut rng = Rng::new(99);
        let specs: Vec<_> = sc.stream_unbounded(5.0, &mut rng).with_limit(10_000).collect();
        assert_eq!(specs.len(), 10_000);
        assert!(specs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(
            specs.last().unwrap().arrival_s > 1000.0,
            "10k requests at ~5/s must stream far past any one-shot horizon"
        );
        // deterministic per seed
        let mut rng2 = Rng::new(99);
        let again: Vec<_> = sc.stream_unbounded(5.0, &mut rng2).with_limit(10_000).collect();
        assert_eq!(specs, again);
    }

    #[test]
    fn tiers_cycle_deterministically_and_offline_is_relaxed() {
        let sc = scenario("tide").unwrap();
        let mut rng = Rng::new(3);
        let specs = sc.generate(40.0, 4.0, &mut rng);
        let tiers: std::collections::HashSet<u8> = specs.iter().map(|s| s.tier).collect();
        assert!(tiers.len() >= 2, "tenant mix must span tiers, got {tiers:?}");
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.tier, sc.tier_for(i));
        }
        let mut rng = Rng::new(3);
        let offline = scenario("offline-docs").unwrap().generate(30.0, 2.0, &mut rng);
        assert!(offline.iter().all(|s| s.tier == 2), "offline class is best-effort tier");
    }
}
