//! Paper evaluation scenarios as synthetic workload generators.
//!
//! Each scenario fixes (a) the arrival process and (b) the input/output
//! length distributions to match what the paper reports for that dataset
//! (fixed lengths for the ShareGPT main results; published Azure trace
//! statistics; the prompt/output lengths in Tables 4–5; conversational
//! shapes for JingYan / customer service).

use crate::util::Rng;
use crate::workload::stream::ArrivalStream;
use crate::workload::traces::{ArrivalProcess, LengthDist, RequestClass, RequestSpec};

/// The default multi-tenant mix: a quarter premium interactive, half
/// standard, a quarter relaxed (see [`crate::metrics::tier_slo`]).
pub const DEFAULT_TIER_MIX: [u8; 4] = [0, 1, 1, 2];

/// A named, reproducible workload.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub arrivals: ArrivalProcess,
    pub input_len: LengthDist,
    pub output_len: LengthDist,
    pub class: RequestClass,
    /// Patches per image for multimodal scenarios (0 = text-only).
    pub image_patches: u64,
    /// Fraction of requests sharing a system-prompt prefix, and its length.
    pub prefix_share: f64,
    pub prefix_len: u64,
    /// Number of distinct shared prefixes.
    pub prefix_groups: u64,
    /// Repeating tenant-tier assignment (request `i` gets
    /// `tier_mix[i % 4]`; offline scenarios are all best-effort).
    /// Deterministic by index — consumes no randomness — so tiers ride
    /// along without perturbing any seeded draw sequence.
    pub tier_mix: [u8; 4],
}

impl Scenario {
    /// Generate the request list over `[0, horizon_s)` at `rate` req/s
    /// (overrides the scenario's nominal rate, keeping its *shape*).
    ///
    /// Thin collect-adapter over [`Self::stream`]: the pull-based
    /// stream is the single source of truth for the draw sequence, and
    /// syncing its field lane back into `rng` preserves the historical
    /// post-generation RNG state bit for bit.
    pub fn generate(&self, horizon_s: f64, rate: f64, rng: &mut Rng) -> Vec<RequestSpec> {
        let mut stream = self.stream(horizon_s, rate, rng);
        let out: Vec<RequestSpec> = (&mut stream).collect();
        *rng = stream.into_field_rng();
        out
    }

    /// Pull-based request stream over `[0, horizon_s)` at `rate` req/s:
    /// O(1) memory, bit-identical specs/order to [`Self::generate`]
    /// (see `workload::stream` for the two-lane determinism story).
    pub fn stream(&self, horizon_s: f64, rate: f64, rng: &mut Rng) -> ArrivalStream {
        ArrivalStream::replaying(self.clone(), self.scaled_arrivals(rate), horizon_s, rng)
    }

    /// Unbounded open-loop stream at `rate` req/s (horizon = ∞) for
    /// request-count-driven runs (`xllm fleet --requests N`); cap with
    /// [`ArrivalStream::with_limit`].  Deterministic per seed, but its
    /// lane split differs from `generate()` (which cannot express an
    /// infinite horizon).
    pub fn stream_unbounded(&self, rate: f64, rng: &mut Rng) -> ArrivalStream {
        ArrivalStream::open_loop(self.clone(), self.scaled_arrivals(rate), rng)
    }

    /// Tenant tier for request index `i` (deterministic, RNG-free).
    pub fn tier_for(&self, i: usize) -> u8 {
        if self.class == RequestClass::Offline {
            2
        } else {
            self.tier_mix[i % self.tier_mix.len()]
        }
    }

    fn scaled_arrivals(&self, rate: f64) -> ArrivalProcess {
        match self.arrivals {
            ArrivalProcess::Poisson { .. } => ArrivalProcess::Poisson { rate },
            ArrivalProcess::Uniform { .. } => ArrivalProcess::Uniform { rate },
            ArrivalProcess::Bursty { burst_factor, burst_prob, burst_len_s, .. } => {
                ArrivalProcess::Bursty { rate, burst_factor, burst_prob, burst_len_s }
            }
            ArrivalProcess::Tidal { amplitude, period_s, .. } => {
                ArrivalProcess::Tidal { mean_rate: rate, amplitude, period_s }
            }
        }
    }

    /// Mean total tokens per request (for capacity planning in benches).
    pub fn mean_tokens(&self, rng: &mut Rng) -> (f64, f64) {
        let n = 2000;
        let mut i = 0.0;
        let mut o = 0.0;
        for _ in 0..n {
            i += self.input_len.sample(rng) as f64;
            o += self.output_len.sample(rng) as f64;
        }
        (i / n as f64, o / n as f64)
    }
}

/// Look up a scenario by name.
pub fn scenario(name: &str) -> Option<Scenario> {
    Some(match name {
        // §5.1.1 main results: fixed input/output lengths of 2048.
        "sharegpt-2048" => Scenario {
            name: "sharegpt-2048",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::Fixed(2048),
            output_len: LengthDist::Fixed(2048),
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.0,
            prefix_len: 0,
            prefix_groups: 0,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Fig 15 variants: [2500,1500] and [1500,2500]
        "sharegpt-2500-1500" => Scenario {
            name: "sharegpt-2500-1500",
            input_len: LengthDist::Fixed(2500),
            output_len: LengthDist::Fixed(1500),
            ..scenario("sharegpt-2048").unwrap()
        },
        "sharegpt-1500-2500" => Scenario {
            name: "sharegpt-1500-2500",
            input_len: LengthDist::Fixed(1500),
            output_len: LengthDist::Fixed(2500),
            ..scenario("sharegpt-2048").unwrap()
        },
        // ShareGPT with its natural length spread (for scheduler tests).
        "sharegpt" => Scenario {
            name: "sharegpt",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::LogNormal { median: 220.0, sigma: 1.1, lo: 8, hi: 8192 },
            output_len: LengthDist::LogNormal { median: 180.0, sigma: 1.0, lo: 4, hi: 4096 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.0,
            prefix_len: 0,
            prefix_groups: 0,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Azure Code: bursty arrivals, long prompts, short outputs (§5.2).
        "azure-code" => Scenario {
            name: "azure-code",
            arrivals: ArrivalProcess::Bursty {
                rate: 1.0,
                burst_factor: 8.0,
                burst_prob: 0.03,
                burst_len_s: 8.0,
            },
            input_len: LengthDist::LogNormal { median: 2000.0, sigma: 0.9, lo: 64, hi: 8192 },
            output_len: LengthDist::LogNormal { median: 40.0, sigma: 0.8, lo: 4, hi: 512 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.3,
            prefix_len: 256,
            prefix_groups: 8,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Azure Conversation: stable arrivals, conversational lengths.
        "azure-conv" => Scenario {
            name: "azure-conv",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::LogNormal { median: 800.0, sigma: 0.6, lo: 32, hi: 4096 },
            output_len: LengthDist::LogNormal { median: 220.0, sigma: 0.5, lo: 8, hi: 1024 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.5,
            prefix_len: 512,
            prefix_groups: 4,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // JingYan AI shopping assistant: conversational logs (§5.1.2).
        "jingyan" => Scenario {
            name: "jingyan",
            arrivals: ArrivalProcess::Tidal { mean_rate: 1.0, amplitude: 0.6, period_s: 600.0 },
            input_len: LengthDist::LogNormal { median: 900.0, sigma: 0.8, lo: 32, hi: 6800 },
            output_len: LengthDist::LogNormal { median: 300.0, sigma: 0.6, lo: 16, hi: 1024 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.7,
            prefix_len: 384,
            prefix_groups: 6,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // JingYan DeepSeek-V3 setting (Table 4): 6800 in / 400 out.
        "jingyan-6800-400" => Scenario {
            name: "jingyan-6800-400",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::Fixed(6800),
            output_len: LengthDist::Fixed(400),
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.0,
            prefix_len: 0,
            prefix_groups: 0,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Customer service dialogues (Fig 17; E2E = 10 s).
        "customer-service" => Scenario {
            name: "customer-service",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::LogNormal { median: 1200.0, sigma: 0.7, lo: 64, hi: 6000 },
            output_len: LengthDist::LogNormal { median: 150.0, sigma: 0.5, lo: 8, hi: 600 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.8,
            prefix_len: 512,
            prefix_groups: 3,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Merchant assistant (Fig 18; E2E = 1 s): three short tasks.
        "merchant-search-terms" => Scenario {
            name: "merchant-search-terms",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::Uniform { lo: 100, hi: 400 },
            output_len: LengthDist::Uniform { lo: 8, hi: 48 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.9,
            prefix_len: 128,
            prefix_groups: 1,
            tier_mix: DEFAULT_TIER_MIX,
        },
        "merchant-arrangement" => Scenario {
            name: "merchant-arrangement",
            input_len: LengthDist::Uniform { lo: 300, hi: 900 },
            output_len: LengthDist::Uniform { lo: 32, hi: 128 },
            ..scenario("merchant-search-terms").unwrap()
        },
        "merchant-intent" => Scenario {
            name: "merchant-intent",
            input_len: LengthDist::Uniform { lo: 60, hi: 240 },
            output_len: LengthDist::Uniform { lo: 2, hi: 16 },
            ..scenario("merchant-search-terms").unwrap()
        },
        // Product understanding (Table 5): 1200 in / 40 out, batchy.
        "product-understanding" => Scenario {
            name: "product-understanding",
            arrivals: ArrivalProcess::Uniform { rate: 1.0 },
            input_len: LengthDist::Fixed(1200),
            output_len: LengthDist::Fixed(40),
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.6,
            prefix_len: 200,
            prefix_groups: 2,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // TextCaps-like multimodal captioning (Fig 22).
        "textcaps" => Scenario {
            name: "textcaps",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::Uniform { lo: 16, hi: 64 },
            output_len: LengthDist::Uniform { lo: 24, hi: 96 },
            class: RequestClass::Online,
            image_patches: 576, // ViT-L/14 @ 336px-like patch count
            prefix_share: 0.0,
            prefix_len: 0,
            prefix_groups: 0,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Skewed shared-prefix traffic (control-plane experiments,
        // §3.4): many distinct system prompts, nearly every request
        // reusing one — the workload where cache-aware routing beats
        // load-only routing, and the fixture for replica-failure runs.
        "skewed-prefix" => Scenario {
            name: "skewed-prefix",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::LogNormal { median: 900.0, sigma: 0.5, lo: 600, hi: 4096 },
            output_len: LengthDist::LogNormal { median: 120.0, sigma: 0.5, lo: 16, hi: 512 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.9,
            prefix_len: 512,
            prefix_groups: 12,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Bursty tidal traffic (elastic-scaling experiments, §3.1): one
        // compressed day/night swing with a strong amplitude, so a fixed
        // fleet sized for the trough drowns at the peak and one sized
        // for the peak idles at the trough — the workload where replica
        // autoscaling (scale up into the flood, decommission on the
        // ebb) beats any fixed size.  Moderate prefix sharing keeps the
        // cache-aware router and the global index exercised.
        "tide" => Scenario {
            name: "tide",
            arrivals: ArrivalProcess::Tidal { mean_rate: 1.0, amplitude: 0.9, period_s: 40.0 },
            input_len: LengthDist::LogNormal { median: 800.0, sigma: 0.6, lo: 64, hi: 4096 },
            output_len: LengthDist::LogNormal { median: 150.0, sigma: 0.5, lo: 16, hi: 512 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.5,
            prefix_len: 256,
            prefix_groups: 4,
            tier_mix: DEFAULT_TIER_MIX,
        },
        // Open-loop diurnal traffic (§3.1 "hourly/daily tidal
        // variation"): the tide shape stretched to a long day/night
        // period for streaming million-request runs — the swing is slow
        // enough that the SLO-aware scaler sees sustained load trends
        // rather than per-heartbeat noise.  Premium-heavy tenant mix.
        "diurnal" => Scenario {
            name: "diurnal",
            arrivals: ArrivalProcess::Tidal { mean_rate: 1.0, amplitude: 0.7, period_s: 240.0 },
            input_len: LengthDist::LogNormal { median: 700.0, sigma: 0.6, lo: 64, hi: 4096 },
            output_len: LengthDist::LogNormal { median: 180.0, sigma: 0.5, lo: 16, hi: 512 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.5,
            prefix_len: 256,
            prefix_groups: 4,
            tier_mix: [0, 0, 1, 2],
        },
        // Flash-crowd traffic (the Azure-Code burst shape pushed to a
        // viral spike): rare but violent rate multiplications that a
        // backlog-target scaler chases too late — the stress workload
        // for predicted-SLO scaling.  Standard-heavy tenant mix.
        "flash-crowd" => Scenario {
            name: "flash-crowd",
            arrivals: ArrivalProcess::Bursty {
                rate: 1.0,
                burst_factor: 12.0,
                burst_prob: 0.02,
                burst_len_s: 6.0,
            },
            input_len: LengthDist::LogNormal { median: 600.0, sigma: 0.7, lo: 32, hi: 4096 },
            output_len: LengthDist::LogNormal { median: 120.0, sigma: 0.5, lo: 8, hi: 512 },
            class: RequestClass::Online,
            image_patches: 0,
            prefix_share: 0.6,
            prefix_len: 256,
            prefix_groups: 6,
            tier_mix: [1, 0, 1, 2],
        },
        // Offline batch analytics (co-location experiments, §3.1/Fig 23).
        "offline-docs" => Scenario {
            name: "offline-docs",
            arrivals: ArrivalProcess::Poisson { rate: 1.0 },
            input_len: LengthDist::LogNormal { median: 3000.0, sigma: 0.5, lo: 256, hi: 8192 },
            output_len: LengthDist::LogNormal { median: 400.0, sigma: 0.4, lo: 64, hi: 1024 },
            class: RequestClass::Offline,
            image_patches: 0,
            prefix_share: 0.0,
            prefix_len: 0,
            prefix_groups: 0,
            tier_mix: DEFAULT_TIER_MIX,
        },
        _ => return None,
    })
}

/// All scenario names (CLI listing + exhaustive tests).
pub const SCENARIO_NAMES: &[&str] = &[
    "sharegpt-2048",
    "sharegpt-2500-1500",
    "sharegpt-1500-2500",
    "sharegpt",
    "azure-code",
    "azure-conv",
    "jingyan",
    "jingyan-6800-400",
    "customer-service",
    "merchant-search-terms",
    "merchant-arrangement",
    "merchant-intent",
    "product-understanding",
    "textcaps",
    "skewed-prefix",
    "tide",
    "diurnal",
    "flash-crowd",
    "offline-docs",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_scenarios_generate() {
        let mut rng = Rng::new(3);
        for name in SCENARIO_NAMES {
            let sc = scenario(name).unwrap_or_else(|| panic!("missing {name}"));
            let reqs = sc.generate(60.0, 2.0, &mut rng);
            assert!(!reqs.is_empty(), "{name} generated nothing");
            for r in &reqs {
                assert!(r.input_tokens >= 1);
                assert!(r.output_tokens >= 1);
                assert!(r.arrival_s >= 0.0 && r.arrival_s < 60.0);
            }
        }
        assert!(scenario("bogus").is_none());
    }

    #[test]
    fn fixed_scenarios_have_exact_lengths() {
        let mut rng = Rng::new(4);
        let reqs = scenario("sharegpt-2048").unwrap().generate(30.0, 2.0, &mut rng);
        for r in reqs {
            assert_eq!(r.input_tokens, 2048);
            assert_eq!(r.output_tokens, 2048);
        }
    }

    #[test]
    fn textcaps_is_multimodal() {
        let mut rng = Rng::new(5);
        let reqs = scenario("textcaps").unwrap().generate(30.0, 2.0, &mut rng);
        assert!(reqs.iter().all(|r| r.is_multimodal()));
    }

    #[test]
    fn offline_class_propagates() {
        let mut rng = Rng::new(6);
        let reqs = scenario("offline-docs").unwrap().generate(30.0, 2.0, &mut rng);
        assert!(reqs.iter().all(|r| r.class == RequestClass::Offline));
    }

    #[test]
    fn prefix_sharing_appears() {
        let mut rng = Rng::new(7);
        let reqs = scenario("customer-service").unwrap().generate(120.0, 4.0, &mut rng);
        let shared = reqs.iter().filter(|r| r.shared_prefix > 0).count();
        assert!(shared as f64 > 0.6 * reqs.len() as f64, "shared={shared}/{}", reqs.len());
    }

    #[test]
    fn skewed_prefix_is_mostly_shared_across_many_groups() {
        let mut rng = Rng::new(9);
        let reqs = scenario("skewed-prefix").unwrap().generate(120.0, 4.0, &mut rng);
        let shared = reqs.iter().filter(|r| r.shared_prefix > 0).count();
        assert!(shared as f64 > 0.8 * reqs.len() as f64, "shared={shared}/{}", reqs.len());
        let groups: std::collections::HashSet<u64> =
            reqs.iter().filter(|r| r.prefix_group > 0).map(|r| r.prefix_group).collect();
        assert!(groups.len() >= 8, "expected many distinct groups, got {}", groups.len());
        // inputs always exceed the shared prefix, so a hit never covers
        // the whole prompt
        assert!(reqs.iter().all(|r| r.input_tokens > r.shared_prefix));
    }

    #[test]
    fn tide_swings_between_flood_and_ebb() {
        let sc = scenario("tide").unwrap();
        // one full period: peak near t=10, trough near t=30
        let peak = sc.arrivals.rate_at(10.0);
        let trough = sc.arrivals.rate_at(30.0);
        assert!(peak > 5.0 * trough.max(1e-9), "peak {peak} vs trough {trough}");
        // arrivals concentrate in the flood half of the period
        let mut rng = Rng::new(11);
        let reqs = sc.generate(40.0, 4.0, &mut rng);
        assert!(reqs.len() > 40, "got {}", reqs.len());
        let flood = reqs.iter().filter(|r| r.arrival_s < 20.0).count();
        assert!(
            flood as f64 > 0.65 * reqs.len() as f64,
            "flood half holds {flood}/{}",
            reqs.len()
        );
        let shared = reqs.iter().filter(|r| r.shared_prefix > 0).count();
        assert!(shared > 0, "tide must exercise the prefix cache");
    }

    #[test]
    fn rate_override_scales_volume() {
        let mut rng = Rng::new(8);
        let lo = scenario("sharegpt").unwrap().generate(200.0, 1.0, &mut rng).len();
        let mut rng = Rng::new(8);
        let hi = scenario("sharegpt").unwrap().generate(200.0, 4.0, &mut rng).len();
        assert!(hi as f64 > 3.0 * lo as f64, "lo={lo} hi={hi}");
    }
}
