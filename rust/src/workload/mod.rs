//! Workload generation: request specs, arrival processes, paper scenarios.
//!
//! The paper evaluates on ShareGPT, Azure Code / Conversation traces, and
//! five JD.com business scenarios (JingYan, customer service, merchant
//! assistant, product understanding, generative recommendation) plus a
//! TextCaps-like multimodal set.  None of the proprietary traces are
//! public, so [`scenarios`] provides statistically matched synthetic
//! generators (length distributions + arrival burstiness) — see DESIGN.md
//! §Substitutions.

pub mod scenarios;
pub mod stream;
pub mod traces;

pub use scenarios::{scenario, Scenario};
pub use stream::ArrivalStream;
pub use traces::{ArrivalIter, ArrivalProcess, RequestClass, RequestSpec};
