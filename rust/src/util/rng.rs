//! Deterministic xorshift* PRNG.
//!
//! The offline crate set has no `rand`; everything stochastic in the
//! simulator, the workload generators, and the property tests derives from
//! this seeded generator so that benches and tests are reproducible
//! bit-for-bit.

/// A 64-bit xorshift* generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed (any value; 0 is remapped).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform usize in [0, n) — panics if n == 0.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::index on empty range");
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample parameterized by the *underlying* normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-like heavy-tailed integer in [1, n] with exponent `alpha`
    /// (inverse-CDF over precomputable harmonic mass would be exact; this
    /// rejection-free approximation is adequate for workload skew).
    pub fn zipf(&mut self, n: u64, alpha: f64) -> u64 {
        let u = self.f64();
        let x = ((n as f64).powf(1.0 - alpha) * u + (1.0 - u)).powf(1.0 / (1.0 - alpha));
        x.floor().clamp(1.0, n as f64) as u64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent child generator.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.range(3, 9);
            assert!((3..=9).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_bounds_and_skew() {
        let mut r = Rng::new(11);
        let mut count1 = 0;
        for _ in 0..10_000 {
            let x = r.zipf(100, 1.1);
            assert!((1..=100).contains(&x));
            if x == 1 {
                count1 += 1;
            }
        }
        assert!(count1 > 1000, "zipf should be head-heavy, got {count1}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
