//! Small shared utilities: deterministic RNG, tiny JSON writer, stats.

pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
