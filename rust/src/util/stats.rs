//! Streaming summary statistics and percentile estimation.

/// Order-preserving sample collector with summary statistics.
///
/// Stores all samples (workloads here are <1e7 samples); percentiles are
/// exact over the collected set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new(), sorted: true }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Exact percentile (nearest-rank). `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = ((p / 100.0) * (self.samples.len() as f64 - 1.0)).floor() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Fraction of samples <= threshold (e.g. SLO attainment).
    pub fn fraction_within(&self, threshold: f64) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        self.samples.iter().filter(|&&x| x <= threshold).count() as f64
            / self.samples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.p99(), 99.0);
    }

    #[test]
    fn fraction_within() {
        let mut s = Summary::new();
        for i in 1..=10 {
            s.add(i as f64);
        }
        assert!((s.fraction_within(5.0) - 0.5).abs() < 1e-9);
        assert_eq!(s.fraction_within(100.0), 1.0);
        assert_eq!(s.fraction_within(0.0), 0.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.fraction_within(1.0), 1.0);
    }

    #[test]
    fn stddev_basic() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.stddev() - 2.138).abs() < 0.01);
    }
}
