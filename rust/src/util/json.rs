//! Minimal JSON writer (no serde in the offline crate set).
//!
//! Only what the metric reporters and bench harness need: objects, arrays,
//! strings, numbers, bools.  Output is deterministic (insertion order).

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a field (object only; panics otherwise — builder misuse).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push an element (array only).
    pub fn push(mut self, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Arr(items) => items.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_object() {
        let j = Json::obj()
            .set("name", "xllm")
            .set("tput", 123.5)
            .set("n", 42u64)
            .set("ok", true);
        assert_eq!(
            j.to_string(),
            r#"{"name":"xllm","tput":123.5,"n":42,"ok":true}"#
        );
    }

    #[test]
    fn nested_and_escaped() {
        let j = Json::obj()
            .set("rows", Json::arr().push(1u64).push(2u64))
            .set("msg", "a\"b\nc");
        assert_eq!(j.to_string(), r#"{"rows":[1,2],"msg":"a\"b\nc"}"#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
