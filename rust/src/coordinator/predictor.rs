//! TTFT predictor (paper §2.1 / §3.2).
//!
//! "A TTFT prediction model built for text requests. It evaluates SLO
//! fulfillment by analyzing queueing delays from each prefill instance
//! queue and request input lengths."  TTFT is predictable because prefill
//! compute is ~quadratic in input length (§3.2); TPOT is *not* reliably
//! predictable, which is why the runtime monitor (instance.rs) exists.
//!
//! Model: `ttft = queue_delay + scale * (a2·L² + a1·L + a0)` where the
//! polynomial comes from the roofline cost model and `scale` is learned
//! online from (predicted, observed) pairs — the paper's "online factor
//! learning" applied at the service layer.

use crate::sim::CostModel;

/// Online-calibrated TTFT predictor.
#[derive(Debug, Clone)]
pub struct TtftPredictor {
    /// Multiplicative correction learned from observations.
    scale: f64,
    /// EMA smoothing for the correction.
    alpha: f64,
    pub observations: u64,
}

impl Default for TtftPredictor {
    fn default() -> Self {
        Self::new()
    }
}

impl TtftPredictor {
    pub fn new() -> TtftPredictor {
        TtftPredictor { scale: 1.0, alpha: 0.1, observations: 0 }
    }

    /// Raw prefill-time estimate for `input_tokens` on `cost`'s instance.
    pub fn prefill_estimate(&self, cost: &CostModel, input_tokens: u64) -> f64 {
        self.scale * cost.prefill_s(input_tokens, 0)
    }

    /// Predict TTFT = queueing delay + own prefill time.
    ///
    /// `queued_tokens` — prompt tokens already waiting in the instance's
    /// prefill queue (each must run before this request).
    pub fn predict(&self, cost: &CostModel, queued_tokens: u64, input_tokens: u64) -> f64 {
        let queue_delay = if queued_tokens > 0 {
            self.scale * cost.prefill_s(queued_tokens, 0)
        } else {
            0.0
        };
        queue_delay + self.prefill_estimate(cost, input_tokens)
    }

    /// Feed back an observed TTFT for calibration.
    pub fn observe(&mut self, cost: &CostModel, queued_tokens: u64, input_tokens: u64, observed_s: f64) {
        let predicted = self.predict(cost, queued_tokens, input_tokens);
        if predicted <= 1e-9 || observed_s <= 0.0 {
            return;
        }
        let ratio = (observed_s / predicted).clamp(0.2, 5.0);
        self.scale = (1.0 - self.alpha) * self.scale + self.alpha * self.scale * ratio;
        self.scale = self.scale.clamp(0.05, 20.0);
        self.observations += 1;
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn cost() -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1))
    }

    #[test]
    fn prediction_grows_superlinearly_in_length() {
        let p = TtftPredictor::new();
        let c = cost();
        let t1 = p.predict(&c, 0, 512);
        let t4 = p.predict(&c, 0, 2048);
        assert!(t4 > 2.5 * t1);
    }

    #[test]
    fn queueing_delay_adds() {
        let p = TtftPredictor::new();
        let c = cost();
        let no_queue = p.predict(&c, 0, 1024);
        let queued = p.predict(&c, 4096, 1024);
        assert!(queued > no_queue * 1.5);
    }

    #[test]
    fn calibration_converges_to_observed_ratio() {
        let mut p = TtftPredictor::new();
        let c = cost();
        let truth_factor = 1.8;
        for _ in 0..200 {
            // ground truth: real prefill takes base * truth_factor
            let base = c.prefill_s(1024, 0);
            p.observe(&c, 0, 1024, base * truth_factor);
        }
        let calibrated = p.predict(&c, 0, 1024) / c.prefill_s(1024, 0);
        assert!(
            (calibrated - truth_factor).abs() < 0.3,
            "scale {calibrated} should approach {truth_factor}"
        );
    }

    #[test]
    fn scale_stays_bounded() {
        let mut p = TtftPredictor::new();
        let c = cost();
        for _ in 0..1000 {
            p.observe(&c, 0, 512, 1e6); // absurd observations
        }
        assert!(p.scale() <= 20.0);
        for _ in 0..1000 {
            p.observe(&c, 0, 512, 1e-9);
        }
        assert!(p.scale() >= 0.05);
    }
}
