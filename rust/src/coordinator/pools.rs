//! Elastic instance pools (paper §3.2).
//!
//! Four pools — P, D, P→D, D→P — of *stateless* instances.  Flipping a
//! role only moves the instance id between pools ("zero-wait-time instance
//! scheduling": no restart, no model reload).  Transitional pools hold
//! instances that have been retargeted but still drain work of their old
//! role; the scheduler prefers them when flipping back (§3.2: prioritize
//! the lightest-load instance from the P→D pool when converting to
//! prefill, and vice versa).

pub type InstanceId = usize;

/// Pool membership of an instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Prefill,
    Decode,
    /// Converting Prefill -> Decode (still draining prefill work).
    PrefillToDecode,
    /// Converting Decode -> Prefill (still draining decode work).
    DecodeToPrefill,
    /// Multimodal encode pool (§3.3 EPD).
    Encode,
}

impl PoolKind {
    /// Which phases this pool currently serves (transitional pools serve
    /// both their old and new roles while draining).
    pub fn serves_prefill(&self) -> bool {
        matches!(self, PoolKind::Prefill | PoolKind::PrefillToDecode | PoolKind::DecodeToPrefill)
    }

    pub fn serves_decode(&self) -> bool {
        matches!(self, PoolKind::Decode | PoolKind::PrefillToDecode | PoolKind::DecodeToPrefill)
    }

    pub fn serves_encode(&self) -> bool {
        matches!(self, PoolKind::Encode)
    }

    /// Target role the pool is headed to.
    pub fn target_is_decode(&self) -> bool {
        matches!(self, PoolKind::Decode | PoolKind::PrefillToDecode)
    }
}

/// The four (plus encode) elastic pools.
#[derive(Debug, Clone, Default)]
pub struct ElasticPools {
    membership: Vec<PoolKind>, // indexed by InstanceId
    pub flips: u64,
}

impl ElasticPools {
    /// Create with `n_prefill` P instances, `n_decode` D instances and
    /// `n_encode` E instances (ids assigned in that order).
    pub fn new(n_prefill: usize, n_decode: usize, n_encode: usize) -> ElasticPools {
        let mut membership = Vec::new();
        membership.extend(std::iter::repeat(PoolKind::Prefill).take(n_prefill));
        membership.extend(std::iter::repeat(PoolKind::Decode).take(n_decode));
        membership.extend(std::iter::repeat(PoolKind::Encode).take(n_encode));
        ElasticPools { membership, flips: 0 }
    }

    pub fn len(&self) -> usize {
        self.membership.len()
    }

    pub fn is_empty(&self) -> bool {
        self.membership.is_empty()
    }

    pub fn kind(&self, id: InstanceId) -> PoolKind {
        self.membership[id]
    }

    pub fn of_kind(&self, kind: PoolKind) -> Vec<InstanceId> {
        self.membership
            .iter()
            .enumerate()
            .filter(|(_, k)| **k == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Instances that can take new prefill work right now.
    pub fn prefill_capable(&self) -> Vec<InstanceId> {
        self.membership
            .iter()
            .enumerate()
            .filter(|(_, k)| k.serves_prefill() && !k.target_is_decode())
            .map(|(i, _)| i)
            .collect()
    }

    /// Instances that can take new decode work right now.
    pub fn decode_capable(&self) -> Vec<InstanceId> {
        self.membership
            .iter()
            .enumerate()
            .filter(|(_, k)| k.serves_decode() && k.target_is_decode())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn encode_capable(&self) -> Vec<InstanceId> {
        self.of_kind(PoolKind::Encode)
    }

    /// Count of instances whose *target* role is decode.
    pub fn decode_target_count(&self) -> usize {
        self.membership.iter().filter(|k| k.target_is_decode()).count()
    }

    pub fn prefill_target_count(&self) -> usize {
        self.membership
            .iter()
            .filter(|k| matches!(k, PoolKind::Prefill | PoolKind::DecodeToPrefill))
            .count()
    }

    /// Retarget an instance toward decode (P -> P→D).  Returns false if it
    /// already targets decode or is an encode instance.
    pub fn flip_to_decode(&mut self, id: InstanceId) -> bool {
        match self.membership[id] {
            PoolKind::Prefill | PoolKind::DecodeToPrefill => {
                self.membership[id] = PoolKind::PrefillToDecode;
                self.flips += 1;
                true
            }
            _ => false,
        }
    }

    /// Retarget an instance toward prefill (D -> D→P), keeping at least
    /// `min_decode` instances targeting decode (§3.2: "always ensures that
    /// at least two decode instances are available").
    pub fn flip_to_prefill(&mut self, id: InstanceId, min_decode: usize) -> bool {
        if !self.membership[id].target_is_decode() {
            return false;
        }
        if self.decode_target_count() <= min_decode {
            return false;
        }
        self.membership[id] = PoolKind::DecodeToPrefill;
        self.flips += 1;
        true
    }

    /// Finalize a transitional instance that has drained its old work.
    pub fn settle(&mut self, id: InstanceId) {
        self.membership[id] = match self.membership[id] {
            PoolKind::PrefillToDecode => PoolKind::Decode,
            PoolKind::DecodeToPrefill => PoolKind::Prefill,
            k => k,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_partition() {
        let p = ElasticPools::new(2, 3, 1);
        assert_eq!(p.of_kind(PoolKind::Prefill), vec![0, 1]);
        assert_eq!(p.of_kind(PoolKind::Decode), vec![2, 3, 4]);
        assert_eq!(p.of_kind(PoolKind::Encode), vec![5]);
        assert_eq!(p.decode_target_count(), 3);
    }

    #[test]
    fn flip_cycle_with_settle() {
        let mut p = ElasticPools::new(2, 2, 0);
        assert!(p.flip_to_decode(0));
        assert_eq!(p.kind(0), PoolKind::PrefillToDecode);
        assert_eq!(p.decode_target_count(), 3);
        // transitional instance still serves prefill while draining
        assert!(p.kind(0).serves_prefill());
        assert!(p.kind(0).serves_decode());
        p.settle(0);
        assert_eq!(p.kind(0), PoolKind::Decode);
        assert!(!p.kind(0).serves_prefill());
    }

    #[test]
    fn min_decode_floor_enforced() {
        let mut p = ElasticPools::new(1, 2, 0);
        assert!(!p.flip_to_prefill(1, 2), "would drop below 2 decode targets");
        assert!(p.flip_to_decode(0));
        assert!(p.flip_to_prefill(1, 2), "now 3 targets, can spare one");
        assert_eq!(p.decode_target_count(), 2);
    }

    #[test]
    fn encode_instances_never_flip() {
        let mut p = ElasticPools::new(1, 1, 1);
        assert!(!p.flip_to_decode(2));
        assert!(!p.flip_to_prefill(2, 0));
        assert_eq!(p.kind(2), PoolKind::Encode);
    }

    #[test]
    fn capable_sets_respect_transitions() {
        let mut p = ElasticPools::new(2, 2, 0);
        p.flip_to_decode(0); // 0: P->D — no NEW prefill work
        assert_eq!(p.prefill_capable(), vec![1]);
        let dec = p.decode_capable();
        assert!(dec.contains(&0) && dec.contains(&2) && dec.contains(&3));
    }

    #[test]
    fn property_flip_count_and_membership_conservation() {
        crate::testutil::quickcheck("pools-conserve", |rng| {
            let n = rng.range(3, 10) as usize;
            let mut p = ElasticPools::new(n / 2, n - n / 2, 0);
            for _ in 0..50 {
                let id = rng.index(n);
                if rng.chance(0.5) {
                    p.flip_to_decode(id);
                } else {
                    p.flip_to_prefill(id, 1);
                }
                if rng.chance(0.3) {
                    p.settle(rng.index(n));
                }
                crate::prop_assert!(p.decode_target_count() >= 1, "decode floor violated");
                crate::prop_assert!(p.len() == n, "membership size changed");
            }
            Ok(())
        });
    }
}
