//! Global request scheduling + SLO-aware instance role switching (§3.2).
//!
//! Three dispatch policies (the Fig 21 ablation):
//! * `RoundRobin`   — vLLM/SGLang-style static assignment.
//! * `MinimalLoad`  — greedy least-load.
//! * `SloAware`     — xLLM: greedy least-load *verified by the TTFT
//!   predictor*; falls through P pool -> D→P pool -> instance flip.
//!
//! Role switching (`plan_role_switches`) implements §3.2: convert decode
//! instances to prefill when predicted TTFT violates the SLO, convert
//! prefill instances to decode when the observed token-generation interval
//! exceeds the TPOT threshold or prefill instances sit idle, always
//! keeping >= 2 decode-target instances, and preferring the
//! lightest-loaded instance in the transitional pool.

use crate::coordinator::instance::InstanceView;
use crate::coordinator::pools::{ElasticPools, InstanceId, PoolKind};
use crate::coordinator::predictor::TtftPredictor;
use crate::metrics::Slo;
use crate::sim::CostModel;

/// Request dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    RoundRobin,
    MinimalLoad,
    SloAware,
}

/// Outcome of a prefill placement attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Dispatch to this instance.
    Instance(InstanceId),
    /// No instance satisfies the SLO: the caller should flip a decode
    /// instance to prefill and then dispatch to it.
    NeedFlip,
}

/// Global scheduler state.
#[derive(Debug, Clone)]
pub struct GlobalScheduler {
    pub policy: DispatchPolicy,
    pub predictor: TtftPredictor,
    rr_next: usize,
}

impl GlobalScheduler {
    pub fn new(policy: DispatchPolicy) -> GlobalScheduler {
        GlobalScheduler { policy, predictor: TtftPredictor::new(), rr_next: 0 }
    }

    /// Choose a prefill instance for a request of `input_tokens`.
    ///
    /// `primary` — instances in the Prefill pool; `fallback` — instances in
    /// the D→P pool (already converting).  Views must be alive (not failed).
    pub fn place_prefill(
        &mut self,
        primary: &[InstanceView],
        fallback: &[InstanceView],
        cost: &CostModel,
        input_tokens: u64,
        slo: &Slo,
    ) -> Placement {
        let alive =
            |vs: &[InstanceView]| -> Vec<InstanceView> { vs.iter().copied().filter(|v| !v.failed).collect() };
        let primary = alive(primary);
        let fallback = alive(fallback);
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let all: Vec<&InstanceView> = primary.iter().chain(fallback.iter()).collect();
                if all.is_empty() {
                    return Placement::NeedFlip;
                }
                let pick = all[self.rr_next % all.len()].id;
                self.rr_next += 1;
                Placement::Instance(pick)
            }
            DispatchPolicy::MinimalLoad => {
                let best = primary
                    .iter()
                    .chain(fallback.iter())
                    .min_by_key(|v| v.queued_prefill_tokens + v.running_tokens);
                match best {
                    Some(v) => Placement::Instance(v.id),
                    None => Placement::NeedFlip,
                }
            }
            DispatchPolicy::SloAware => {
                // 1) least estimated queueing delay in the P pool, verified
                //    by the TTFT predictor against the SLO; ties broken by
                //    total load so colocated instances spread decode work
                let mut candidates: Vec<&InstanceView> = primary.iter().collect();
                candidates.sort_by_key(|v| (v.queued_prefill_tokens, v.running_tokens, v.n_running));
                for v in &candidates {
                    let ttft =
                        self.predictor.predict(cost, v.queued_prefill_tokens, input_tokens);
                    if ttft <= slo.ttft_s {
                        return Placement::Instance(v.id);
                    }
                }
                // 2) D→P pool
                let mut fb: Vec<&InstanceView> = fallback.iter().collect();
                fb.sort_by_key(|v| v.queued_prefill_tokens);
                for v in &fb {
                    let ttft =
                        self.predictor.predict(cost, v.queued_prefill_tokens, input_tokens);
                    if ttft <= slo.ttft_s {
                        return Placement::Instance(v.id);
                    }
                }
                // 3) nothing satisfies the SLO: ask for a flip, or if the
                //    SLO is unconstrained just take the least-loaded
                if slo.ttft_s.is_infinite() {
                    return candidates
                        .first()
                        .or(fb.first())
                        .map(|v| Placement::Instance(v.id))
                        .unwrap_or(Placement::NeedFlip);
                }
                Placement::NeedFlip
            }
        }
    }

    /// Choose a decode instance.  Prefers `prefer` (the instance that ran
    /// prefill — avoids KV transfer, §3.2) when it has capacity; otherwise
    /// the fewest running tokens whose admission keeps the batch under its
    /// memory/throughput limits.
    pub fn place_decode(
        &mut self,
        views: &[InstanceView],
        prefer: Option<InstanceId>,
        context_tokens: u64,
        max_decode_seqs: usize,
    ) -> Option<InstanceId> {
        let ok = |v: &InstanceView| {
            !v.failed && v.n_running < max_decode_seqs && v.kv_free() >= context_tokens
        };
        if self.policy == DispatchPolicy::SloAware {
            if let Some(p) = prefer {
                if let Some(v) = views.iter().find(|v| v.id == p) {
                    if ok(v) {
                        return Some(p);
                    }
                }
            }
        }
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let alive: Vec<&InstanceView> = views.iter().filter(|v| ok(v)).collect();
                if alive.is_empty() {
                    return None;
                }
                let pick = alive[self.rr_next % alive.len()].id;
                self.rr_next += 1;
                Some(pick)
            }
            _ => views
                .iter()
                .filter(|v| ok(v))
                .min_by_key(|v| v.running_tokens)
                .map(|v| v.id),
        }
    }
}

/// A role-flip decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoleFlip {
    ToPrefill(InstanceId),
    ToDecode(InstanceId),
}

/// SLO-aware instance role switching (§3.2).
///
/// Inputs are the current views (indexed by instance id), the pools, the
/// predictor, a representative cost model, the SLO, and the prompt-token
/// backlog that has not been dispatched yet.
pub fn plan_role_switches(
    views: &[InstanceView],
    pools: &ElasticPools,
    predictor: &TtftPredictor,
    cost: &CostModel,
    slo: &Slo,
    undispatched_prefill_tokens: u64,
    min_decode: usize,
) -> Vec<RoleFlip> {
    let mut flips = Vec::new();

    // --- prefill side: predicted TTFT violation => pull a decode instance
    let prefill_ids = pools.prefill_capable();
    if !prefill_ids.is_empty() || undispatched_prefill_tokens > 0 {
        let backlog: u64 = prefill_ids
            .iter()
            .map(|&i| views[i].queued_prefill_tokens)
            .sum::<u64>()
            + undispatched_prefill_tokens;
        let per_instance = backlog / (prefill_ids.len().max(1) as u64);
        let est = predictor.predict(cost, per_instance, 0);
        if est > slo.ttft_s && slo.ttft_s.is_finite() {
            // convert the lightest decode instance, preferring P→D pool
            // (§3.2: "prioritizes selecting the instance with the lightest
            // load from the P→D pool")
            let candidates: Vec<InstanceId> = {
                let p2d = pools.of_kind(PoolKind::PrefillToDecode);
                if p2d.is_empty() {
                    pools.of_kind(PoolKind::Decode)
                } else {
                    p2d
                }
            };
            if pools.decode_target_count() > min_decode {
                if let Some(&lightest) = candidates
                    .iter()
                    .filter(|&&i| !views[i].failed)
                    .min_by_key(|&&i| views[i].running_tokens)
                {
                    flips.push(RoleFlip::ToPrefill(lightest));
                }
            }
        }
    }

    // --- decode side: TPOT at risk or idle prefill => add decode capacity
    let decode_ids = pools.decode_capable();
    let tpot_risk = decode_ids.iter().any(|&i| {
        let v = &views[i];
        v.ema_token_interval > slo.tpot_s && v.n_running > 0
    });
    let kv_pressure = decode_ids
        .iter()
        .any(|&i| views[i].kv_used as f64 > 0.9 * views[i].kv_capacity as f64);
    let idle_prefill: Vec<InstanceId> = pools
        .prefill_capable()
        .into_iter()
        .filter(|&i| !views[i].failed && views[i].n_queued == 0 && views[i].queued_prefill_tokens == 0)
        .collect();
    if (tpot_risk || kv_pressure) && !idle_prefill.is_empty() {
        // prefer D→P pool members back to decode (§3.2)
        let d2p = pools.of_kind(PoolKind::DecodeToPrefill);
        let pick = d2p
            .iter()
            .copied()
            .filter(|&i| idle_prefill.contains(&i))
            .min_by_key(|&i| views[i].running_tokens)
            .or_else(|| idle_prefill.iter().copied().min_by_key(|&i| views[i].running_tokens));
        if let Some(i) = pick {
            flips.push(RoleFlip::ToDecode(i));
        }
    }

    flips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn cost() -> CostModel {
        CostModel::new(ascend_910b(), catalog("Qwen3-8B").unwrap(), EngineFeatures::xllm(1))
    }

    fn view(id: usize, queued: u64, running: u64) -> InstanceView {
        InstanceView {
            id,
            queued_prefill_tokens: queued,
            running_tokens: running,
            n_running: (running / 1024) as usize,
            n_queued: (queued / 1024) as usize,
            kv_used: running,
            kv_capacity: 1_000_000,
            failed: false,
            ema_token_interval: 0.03,
            ema_ttft: 0.5,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut s = GlobalScheduler::new(DispatchPolicy::RoundRobin);
        let views = [view(0, 0, 0), view(1, 0, 0), view(2, 0, 0)];
        let slo = Slo::UNCONSTRAINED;
        let picks: Vec<_> = (0..6)
            .map(|_| match s.place_prefill(&views, &[], &cost(), 512, &slo) {
                Placement::Instance(i) => i,
                _ => panic!(),
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn minimal_load_picks_least() {
        let mut s = GlobalScheduler::new(DispatchPolicy::MinimalLoad);
        let views = [view(0, 5000, 0), view(1, 100, 0), view(2, 9000, 0)];
        match s.place_prefill(&views, &[], &cost(), 512, &Slo::UNCONSTRAINED) {
            Placement::Instance(i) => assert_eq!(i, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn slo_aware_requests_flip_when_all_overloaded() {
        let mut s = GlobalScheduler::new(DispatchPolicy::SloAware);
        // enormous queues: predictor will say TTFT blown
        let views = [view(0, 2_000_000, 0), view(1, 3_000_000, 0)];
        let slo = Slo::interactive(0.5, 0.05);
        assert_eq!(s.place_prefill(&views, &[], &cost(), 2048, &slo), Placement::NeedFlip);
    }

    #[test]
    fn slo_aware_uses_fallback_pool() {
        let mut s = GlobalScheduler::new(DispatchPolicy::SloAware);
        let primary = [view(0, 5_000_000, 0)];
        let fallback = [view(7, 0, 0)];
        let slo = Slo::interactive(2.0, 0.05);
        assert_eq!(
            s.place_prefill(&primary, &fallback, &cost(), 512, &slo),
            Placement::Instance(7)
        );
    }

    #[test]
    fn decode_prefers_prefill_origin() {
        let mut s = GlobalScheduler::new(DispatchPolicy::SloAware);
        let mut origin = view(0, 0, 900_000);
        origin.n_running = 10; // has slots free
        let views = [origin, view(1, 0, 100)];
        // prefer=0 has capacity (kv_free = 100k >= 2048)
        assert_eq!(s.place_decode(&views, Some(0), 2048, 64), Some(0));
        // without preference, least running tokens wins
        assert_eq!(s.place_decode(&views, None, 2048, 64), Some(1));
    }

    #[test]
    fn decode_respects_kv_and_seq_limits() {
        let mut s = GlobalScheduler::new(DispatchPolicy::SloAware);
        let mut full = view(0, 0, 999_000);
        full.n_running = 64;
        let views = [full, view(1, 0, 500)];
        assert_eq!(s.place_decode(&views, Some(0), 2048, 64), Some(1));
        // nothing fits
        let mut v1 = view(1, 0, 999_999);
        v1.kv_used = 999_999;
        let views2 = [full, v1];
        assert_eq!(s.place_decode(&views2, None, 2048, 64), None);
    }

    #[test]
    fn role_switch_pulls_decode_when_ttft_blown() {
        let views = vec![view(0, 4_000_000, 0), view(1, 0, 1000), view(2, 0, 500)];
        let pools = ElasticPools::new(1, 2, 0); // 0=P, 1/2=D
        let flips = plan_role_switches(
            &views,
            &pools,
            &TtftPredictor::new(),
            &cost(),
            &Slo::interactive(0.5, 0.05),
            0,
            1,
        );
        assert!(flips.contains(&RoleFlip::ToPrefill(2)), "lightest decode flips: {flips:?}");
    }

    #[test]
    fn role_switch_adds_decode_on_tpot_risk() {
        let mut v1 = view(1, 0, 5000);
        v1.ema_token_interval = 0.2; // way above slo
        let views = vec![view(0, 0, 0), v1, view(2, 0, 100)];
        let pools = ElasticPools::new(1, 2, 0);
        let flips = plan_role_switches(
            &views,
            &pools,
            &TtftPredictor::new(),
            &cost(),
            &Slo::interactive(10.0, 0.05),
            0,
            1,
        );
        assert!(flips.contains(&RoleFlip::ToDecode(0)), "idle prefill flips: {flips:?}");
    }

    #[test]
    fn role_switch_empty_pools_is_noop() {
        let pools = ElasticPools::new(0, 0, 0);
        let flips = plan_role_switches(
            &[],
            &pools,
            &TtftPredictor::new(),
            &cost(),
            &Slo::interactive(0.5, 0.05),
            10_000,
            1,
        );
        assert!(flips.is_empty(), "no instances, nothing to flip: {flips:?}");
    }

    #[test]
    fn role_switch_never_flips_last_decode_instance() {
        // one decode instance, massive prefill pressure: the decode floor
        // must hold (flipping the last decode instance would deadlock
        // every request finishing prefill)
        let views = vec![view(0, 5_000_000, 0), view(1, 0, 2000)];
        let pools = ElasticPools::new(1, 1, 0); // 0=P, 1=D
        let flips = plan_role_switches(
            &views,
            &pools,
            &TtftPredictor::new(),
            &cost(),
            &Slo::interactive(0.1, 0.05),
            1_000_000,
            1,
        );
        assert!(
            !flips.iter().any(|f| matches!(f, RoleFlip::ToPrefill(_))),
            "must not flip the only decode instance: {flips:?}"
        );
    }

    #[test]
    fn role_switch_never_strands_last_busy_prefill_instance() {
        // The sole prefill instance has queued prompts while decode TPOT
        // is blown: converting it would strand the queued prefill work,
        // and the planner only converts *idle* prefill instances — so no
        // flip.  (An idle last prefill instance MAY convert: prefill
        // capacity is recoverable on demand through the NeedFlip path in
        // dispatch, whereas the decode floor below is a hard invariant.)
        let mut d = view(1, 0, 5000);
        d.ema_token_interval = 0.5; // far above TPOT SLO
        let views = vec![view(0, 2000, 0), d];
        let pools = ElasticPools::new(1, 1, 0);
        let flips = plan_role_switches(
            &views,
            &pools,
            &TtftPredictor::new(),
            &cost(),
            &Slo::interactive(60.0, 0.05),
            0,
            1,
        );
        assert!(
            !flips.iter().any(|f| matches!(f, RoleFlip::ToDecode(_))),
            "busy prefill instance must keep its role: {flips:?}"
        );
    }

    #[test]
    fn role_switch_single_instance_cluster_never_flips() {
        // A 1-instance cluster must keep its role under any load, in
        // either starting configuration: a lone decode instance is
        // protected by the decode floor, a lone prefill instance has no
        // decode peer whose pressure could pull it over.
        let slos = [(0.1, 0.01), (60.0, 10.0)];
        for (ttft, tpot) in slos {
            let views = vec![view(0, 4_000_000, 4_000_000)];
            let decode_only = ElasticPools::new(0, 1, 0);
            let flips = plan_role_switches(
                &views,
                &decode_only,
                &TtftPredictor::new(),
                &cost(),
                &Slo::interactive(ttft, tpot),
                1_000_000,
                1,
            );
            assert!(flips.is_empty(), "lone decode instance flipped: {flips:?}");

            let mut idle = view(0, 0, 0);
            idle.ema_token_interval = 0.5;
            let prefill_only = ElasticPools::new(1, 0, 0);
            let flips = plan_role_switches(
                &[idle],
                &prefill_only,
                &TtftPredictor::new(),
                &cost(),
                &Slo::interactive(ttft, tpot),
                1_000_000,
                1,
            );
            assert!(flips.is_empty(), "lone prefill instance flipped: {flips:?}");
        }
    }

    #[test]
    fn role_switch_hysteresis_under_oscillating_load() {
        // Alternate prefill-heavy and decode-heavy snapshots.  The
        // transitional-pool preference (§3.2) localizes the churn: one
        // elastic instance ping-pongs through P→D/D→P while the rest of
        // the fleet keeps its role, the decode floor holds throughout,
        // and flips stay bounded by one per load swing (no cascade).
        let mut pools = ElasticPools::new(2, 2, 0); // 0,1=P  2,3=D
        let predictor = TtftPredictor::new();
        let c = cost();
        let slo = Slo::interactive(0.5, 0.05);
        let rounds = 40u64;
        for round in 0..rounds {
            let views: Vec<InstanceView> = (0..4)
                .map(|i| {
                    if round % 2 == 0 {
                        // prefill burst: huge queues, decode healthy
                        let mut v = view(i, 3_000_000, 0);
                        v.ema_token_interval = 0.01;
                        v
                    } else {
                        // decode burst: TPOT blown, prefill idle
                        let mut v = view(i, 0, 500_000);
                        v.ema_token_interval = 0.5;
                        v
                    }
                })
                .collect();
            let flips = plan_role_switches(&views, &pools, &predictor, &c, &slo, 0, 1);
            assert!(flips.len() <= 1, "round {round}: cascade of flips {flips:?}");
            for f in flips {
                match f {
                    RoleFlip::ToPrefill(i) => {
                        pools.flip_to_prefill(i, 1);
                    }
                    RoleFlip::ToDecode(i) => {
                        pools.flip_to_decode(i);
                    }
                }
            }
            assert!(pools.decode_target_count() >= 1, "decode floor violated mid-oscillation");
        }
        // churn is absorbed by a single elastic instance; the rest of the
        // fleet never changes role
        assert_eq!(pools.kind(0), PoolKind::Prefill, "stable prefill instance flipped");
        assert_eq!(pools.kind(1), PoolKind::Prefill, "stable prefill instance flipped");
        assert!(pools.kind(3).target_is_decode(), "stable decode instance flipped");
        assert!(pools.flips <= rounds, "{} flips in {rounds} rounds", pools.flips);
    }

    #[test]
    fn no_flip_when_slo_met() {
        let views = vec![view(0, 100, 0), view(1, 0, 100), view(2, 0, 100)];
        let pools = ElasticPools::new(1, 2, 0);
        let flips = plan_role_switches(
            &views,
            &pools,
            &TtftPredictor::new(),
            &cost(),
            &Slo::interactive(60.0, 10.0),
            0,
            1,
        );
        assert!(flips.is_empty(), "{flips:?}");
    }
}
