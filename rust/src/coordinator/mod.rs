//! Coordinator: the shared request/batch/instance machinery under both
//! xLLM-Service policies (service/) and the engine optimizations (engine/).
//!
//! * [`request`]      — request lifecycle (Encode/Prefill/Decode phases).
//! * [`batcher`]      — continuous batching + chunked prefill planning.
//! * [`instance`]     — stateless instance state + runtime monitor.
//! * [`pools`]        — the four elastic pools (P, D, P→D, D→P) + Encode.
//! * [`predictor`]    — online-calibrated TTFT predictor.
//! * [`scheduler`]    — global dispatch policies + SLO-aware role switching.
//! * [`orchestrator`] — the shared request-lifecycle state machine driving
//!   all of the above over a pluggable [`orchestrator::Executor`] backend
//!   (roofline simulation or real PJRT execution).

pub mod batcher;
pub mod instance;
pub mod orchestrator;
pub mod pools;
pub mod predictor;
pub mod request;
pub mod scheduler;

pub use batcher::{plan_iteration, BatchConfig, IterationPlan};
pub use instance::{InstanceState, InstanceView, Monitor};
pub use orchestrator::{
    ColocationMode, Executor, IterationWork, Orchestrator, OrchestratorConfig, RunResult,
    ServingMode,
};
pub use pools::{ElasticPools, InstanceId, PoolKind};
pub use predictor::TtftPredictor;
pub use request::{Phase, Request, RequestId};
pub use scheduler::{plan_role_switches, DispatchPolicy, GlobalScheduler, Placement, RoleFlip};
